"""Algorithm 1 efficiency: the epsilon-norm root Lambda(x, alpha, R).

Paper claim: the sorted prefix-sum algorithm is O(d log d) worst case
(Prop. 9) versus O(d^2) for the naive scan.  We benchmark three
implementations, vectorised over a batch of groups:

  * ``lam``        — exact sorted prefix-sum (Algorithm 1, vectorised)
  * ``lam_bisect`` — fixed-iteration bisection (TPU-friendly variant)
  * ``naive``      — O(d^2) candidate scan (the baseline Alg. 1 replaces)

All three must agree to ~1e-10; timings demonstrate the asymptotics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lam, lam_bisect

from .common import emit, timeit


def _naive_lam(x, alpha, R):
    """O(d^2): test every candidate interval directly."""
    ax = jnp.sort(jnp.abs(x))[::-1]
    d = ax.shape[0]

    def solve_for_j0(j0):
        # assume exactly the top-j0 entries survive the threshold
        s = jnp.where(jnp.arange(d) < j0, ax, 0.0)
        S = jnp.sum(s)
        S2 = jnp.sum(s * s)
        a = alpha * alpha * j0 - R * R
        disc = jnp.maximum(alpha * alpha * S * S - S2 * a, 0.0)
        nu_quad = (alpha * S - jnp.sqrt(disc)) / jnp.where(a == 0, 1.0, a)
        nu_lin = S2 / (2.0 * alpha * S)
        nu = jnp.where(a == 0, nu_lin, nu_quad)
        # valid iff nu*alpha separates entry j0-1 from entry j0
        hi = ax[j0 - 1]
        lo = jnp.where(j0 < d, ax[jnp.minimum(j0, d - 1)], 0.0)
        ok = (nu * alpha <= hi) & (nu * alpha > lo) & (nu > 0)
        return jnp.where(ok, nu, jnp.inf)

    cands = jax.vmap(solve_for_j0)(jnp.arange(1, d + 1))
    return jnp.min(cands)


def main(sizes=(64, 256, 1024, 4096), batch: int = 64) -> None:
    key = jax.random.PRNGKey(0)
    for d in sizes:
        key, k = jax.random.split(key)
        x = jax.random.normal(k, (batch, d), dtype=jnp.float64)
        alpha = jnp.full((batch,), 0.6, jnp.float64)
        R = jnp.full((batch,), 0.8, jnp.float64)

        sorted_fn = jax.jit(jax.vmap(lam))
        bisect_fn = jax.jit(jax.vmap(lambda a, b, c: lam_bisect(a, b, c)))
        naive_fn = jax.jit(jax.vmap(_naive_lam))

        v_sorted = sorted_fn(x, alpha, R)
        v_bisect = bisect_fn(x, alpha, R)
        v_naive = naive_fn(x, alpha, R)
        err_b = float(jnp.max(jnp.abs(v_sorted - v_bisect)))
        err_n = float(jnp.max(jnp.abs(v_sorted - v_naive)))
        assert err_b < 1e-8, f"bisect disagrees: {err_b}"
        assert err_n < 1e-8, f"naive disagrees: {err_n}"

        case = f"d{d}_b{batch}"
        emit("dual_norm", case, "us_sorted",
             1e6 * timeit(sorted_fn, x, alpha, R) / batch)
        emit("dual_norm", case, "us_bisect",
             1e6 * timeit(bisect_fn, x, alpha, R) / batch)
        emit("dual_norm", case, "us_naive",
             1e6 * timeit(naive_fn, x, alpha, R) / batch)
        emit("dual_norm", case, "max_err_bisect", err_b)
        emit("dual_norm", case, "max_err_naive", err_n)


if __name__ == "__main__":
    from .common import header

    header()
    main()
