"""Typed metrics registry: declared names, help text, scoped snapshots.

Every metric name is **declared** once, process-wide, with a kind and a
non-empty help string (:func:`declare`); instantiation without a matching
declaration is an error.  This is what makes ``python -m repro.obs --check``
possible: OB001 audits the declaration table, not whatever strings happen
to be flying around at runtime.

Naming convention (enforced): lowercase dotted ``layer.noun`` with an
optional ``_<unit>`` suffix — ``kernels.transpose_traces``,
``serve.queue_wait_s``, ``ckpt.quarantined``.  At least one dot, so every
metric carries its owning layer.

Kinds
-----
``Counter``
    Monotonic count with ``inc(n)``.  ``_set`` exists only for the
    back-compat shims (``SGLServer.counters`` dict writes, scope
    save/restore) and is deliberately underscored.
``Gauge``
    Last-write-wins level, ``set(v)``.
``Histogram``
    ``observe(v)`` keeps exact ``count``/``total``/``vmin``/``vmax`` plus a
    bounded sample reservoir (newest ``maxlen`` samples) for percentile
    aggregation via :func:`repro.obs.export.percentile`.

Scoping
-------
:meth:`MetricsRegistry.scope` subsumes the old ``kernels.ops.audit_scope()``
idiom: on entry the named metrics are zeroed, inside the block the
:class:`ScopeView` reads live in-scope deltas, and on exit the outer values
are restored (in-scope deltas are *not* propagated out) and the view is
frozen.  ``snapshot()`` / ``diff()`` / ``reset()`` are the non-context
building blocks.

All mutation is thread-safe: one lock per metric, one registry lock for
creation and snapshot/restore.  Reads of plain numbers are lock-free.
"""
from __future__ import annotations

import contextlib
import re
import threading
from collections import deque
from collections.abc import MutableMapping
from typing import Dict, Iterable, NamedTuple, Optional, Tuple, Union

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z][a-z0-9_]*)+$")

_KINDS = ("counter", "gauge", "histogram")


class MetricSpec(NamedTuple):
    kind: str
    help: str


#: Process-global declaration table, audited by ``repro.obs --check``.
SCHEMA: Dict[str, MetricSpec] = {}
_SCHEMA_LOCK = threading.Lock()


def declare(name: str, kind: str, help: str) -> str:
    """Declare a metric name once, process-wide.  Idempotent if the kind
    matches; a kind conflict is a programming error and raises."""
    if kind not in _KINDS:
        raise ValueError(f"unknown metric kind {kind!r} (want one of {_KINDS})")
    if not _NAME_RE.match(name):
        raise ValueError(
            f"metric name {name!r} violates the naming convention "
            "(lowercase dotted 'layer.noun', e.g. 'serve.requests')")
    with _SCHEMA_LOCK:
        prev = SCHEMA.get(name)
        if prev is not None and prev.kind != kind:
            raise ValueError(
                f"metric {name!r} already declared as {prev.kind}, not {kind}")
        if prev is None or (not prev.help and help):
            SCHEMA[name] = MetricSpec(kind, help)
    return name


class Counter:
    """Monotonic counter.  ``inc`` is the public mutator."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def _set(self, v: int) -> None:
        """Shim/scoping escape hatch — not part of the public surface."""
        with self._lock:
            self._value = int(v)


class Gauge:
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self) -> float:
        return self._value

    _set = set


class Histogram:
    """Exact count/total/min/max plus a bounded sample reservoir."""

    __slots__ = ("name", "count", "total", "vmin", "vmax", "_samples", "_lock")

    def __init__(self, name: str, maxlen: int = 4096):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self._samples: deque = deque(maxlen=maxlen)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = v if self.vmin is None else min(self.vmin, v)
            self.vmax = v if self.vmax is None else max(self.vmax, v)
            self._samples.append(v)

    def samples(self) -> Tuple[float, ...]:
        with self._lock:
            return tuple(self._samples)

    def percentile(self, q: float) -> Optional[float]:
        from .export import percentile
        return percentile(self.samples(), q)

    def summary(self) -> dict:
        with self._lock:
            snap = tuple(self._samples)
            out = {"count": self.count, "total": self.total,
                   "min": self.vmin, "max": self.vmax,
                   "mean": (self.total / self.count) if self.count else None}
        from .export import percentile
        out["p50"] = percentile(snap, 50.0)
        out["p99"] = percentile(snap, 99.0)
        return out

    # scoping support
    def _state(self):
        with self._lock:
            return (self.count, self.total, self.vmin, self.vmax,
                    tuple(self._samples))

    def _restore(self, state) -> None:
        count, total, vmin, vmax, samples = state
        with self._lock:
            self.count, self.total = count, total
            self.vmin, self.vmax = vmin, vmax
            self._samples.clear()
            self._samples.extend(samples)

    def _set(self, _v=0) -> None:  # zero, for reset()/scope()
        self._restore((0, 0.0, None, None, ()))


Metric = Union[Counter, Gauge, Histogram]
_CLASSES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class ScopeView:
    """Live window onto a set of metrics while a :meth:`MetricsRegistry.scope`
    block is open; frozen to the final in-scope values on exit (the
    ``AuditCounters`` freeze-on-exit contract)."""

    def __init__(self, registry: "MetricsRegistry", names: Tuple[str, ...]):
        self._registry = registry
        self._names = names
        self._frozen: Optional[Dict[str, Union[int, float]]] = None

    def value(self, name: str) -> Union[int, float]:
        if name not in self._names:
            raise KeyError(name)
        if self._frozen is not None:
            return self._frozen[name]
        m = self._registry.get(name)
        return m.count if isinstance(m, Histogram) else m.value

    __getitem__ = value

    def as_dict(self) -> Dict[str, Union[int, float]]:
        return {n: self.value(n) for n in self._names}

    def _freeze(self) -> None:
        self._frozen = self.as_dict()

    @property
    def frozen(self) -> bool:
        return self._frozen is not None


class MetricsRegistry:
    """A named collection of metric instances sharing the global SCHEMA.

    The process has one default :data:`REGISTRY`; owners that need
    per-instance numbers under the same declared names (e.g. each
    ``SGLServer``) create their own registry.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, kind: str,
                       help: Optional[str], **kw) -> Metric:
        if help is not None:
            declare(name, kind, help)
        spec = SCHEMA.get(name)
        if spec is None:
            raise KeyError(f"metric {name!r} is not declared; pass help= or "
                           "call obs.metrics.declare() first")
        if spec.kind != kind:
            raise TypeError(f"metric {name!r} is declared as {spec.kind}, "
                            f"requested as {kind}")
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = _CLASSES[kind](name, **kw)
                self._metrics[name] = m
            return m

    def counter(self, name: str, help: Optional[str] = None) -> Counter:
        return self._get_or_create(name, "counter", help)  # type: ignore

    def gauge(self, name: str, help: Optional[str] = None) -> Gauge:
        return self._get_or_create(name, "gauge", help)  # type: ignore

    def histogram(self, name: str, help: Optional[str] = None,
                  maxlen: int = 4096) -> Histogram:
        return self._get_or_create(name, "histogram", help,  # type: ignore
                                   maxlen=maxlen)

    def get(self, name: str) -> Metric:
        with self._lock:
            return self._metrics[name]

    def names(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._metrics))

    # -- snapshot / diff / reset / scope ---------------------------------
    def snapshot(self, names: Optional[Iterable[str]] = None) -> dict:
        """Point-in-time state of the selected metrics (all by default)."""
        sel = tuple(names) if names is not None else self.names()
        out = {}
        for n in sel:
            m = self.get(n)
            out[n] = m._state() if isinstance(m, Histogram) else m.value
        return out

    def diff(self, snap: dict) -> Dict[str, Union[int, float]]:
        """Numeric delta since ``snap`` (histograms diff on count)."""
        out: Dict[str, Union[int, float]] = {}
        for n, old in snap.items():
            m = self.get(n)
            if isinstance(m, Histogram):
                out[n] = m.count - old[0]
            else:
                out[n] = m.value - old
        return out

    def reset(self, names: Optional[Iterable[str]] = None) -> None:
        sel = tuple(names) if names is not None else self.names()
        for n in sel:
            self.get(n)._set(0)

    @contextlib.contextmanager
    def scope(self, names: Optional[Iterable[str]] = None):
        """Zero the selected metrics on entry, restore the outer values on
        exit; in-scope deltas are visible through the yielded
        :class:`ScopeView` and are NOT propagated out — exactly the
        ``kernels.ops.audit_scope()`` contract, generalized."""
        sel = tuple(names) if names is not None else self.names()
        saved = self.snapshot(sel)
        self.reset(sel)
        view = ScopeView(self, sel)
        try:
            yield view
        finally:
            view._freeze()
            for n, state in saved.items():
                m = self.get(n)
                if isinstance(m, Histogram):
                    m._restore(state)
                else:
                    m._set(state)

    def as_dict(self) -> dict:
        """Flat export: numbers for counters/gauges, summaries for
        histograms (the shape the BENCH exporter embeds)."""
        out = {}
        for n in self.names():
            m = self.get(n)
            out[n] = m.summary() if isinstance(m, Histogram) else m.value
        return out


class CounterMap(MutableMapping):
    """dict-shaped back-compat shim over registry counters.

    ``CounterMap(reg, "serve.", {"requests": ...})`` maps the legacy key
    ``"requests"`` onto the declared counter ``serve.requests`` in ``reg``.
    Reads return plain ints, ``m[k] += 1`` and ``m[k] = v`` work, and
    ``dict(m)`` / ``{**m}`` behave like the plain dict it replaces (the
    ``SGLServer.counters`` surface).  The key set is fixed at construction
    — these shims cover *declared* metrics, not an open dict.
    """

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 keys: Iterable[str]):
        self._keys = tuple(keys)
        self._counters = {k: registry.counter(prefix + k)
                          for k in self._keys}

    def __getitem__(self, k: str) -> int:
        return self._counters[k].value

    def __setitem__(self, k: str, v: int) -> None:
        self._counters[k]._set(int(v))

    def __delitem__(self, k: str) -> None:
        raise TypeError("CounterMap keys are fixed declared metrics")

    def __iter__(self):
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def counter(self, k: str) -> Counter:
        """The underlying typed Counter (for atomic ``inc`` callers)."""
        return self._counters[k]


#: Default process-global registry (kernels.ops counters, ckpt quarantine,
#: faults fire tally, solver gathers all live here).
REGISTRY = MetricsRegistry()
