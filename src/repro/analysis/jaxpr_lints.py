"""Jaxpr lints: dtype demotion, transpose materialisation, retrace hazards.

Every registered entry point (see :mod:`repro.analysis.entrypoints`) is
traced into a jaxpr on a small shape/dtype template and every nested eqn
is walked (``pjit``/``scan``/``while``/``cond`` bodies included):

* **JX001** ``convert_element_type`` narrowing a float below the spec's
  ``min_float_bits`` (default 64) — a certificate value silently leaving
  f64.  The gap/radius/Theorem-1 quantities are *outputs* of these
  programs, so any in-program float narrowing sits on a certificate-
  producing path.
* **JX002** a ``transpose`` on an operand at least as large as the design
  matrix — a (p, n) copy materialised outside the audited
  ``kernels.ops.transposed_design`` (the runtime counter, promoted to a
  static guarantee: the einsum paths lower to ``dot_general`` with no
  transpose, and the Pallas paths consume the persistent pre-transposed
  design).
* **JX003** the same for a design-sized ``gather`` (a full copy smuggled
  through fancy indexing).
* **JX004** jit-cache growth when the entry point is called twice with
  dtype-identical, freshly-built inputs (weak-type literal splits and
  friends).  Observed retraces also bump
  :func:`repro.kernels.ops.note_retrace`, so ``audit_scope`` sees them.
* **JX005** a ``TypeError`` mentioning hashability while dispatching —
  an unhashable value reached ``static_argnums``.
"""
from __future__ import annotations

from typing import Iterator, List

import jax
import numpy as np

from ..kernels import ops as kops
from .findings import Finding

__all__ = ["iter_eqns", "lint_entry_point", "retrace_harness", "run"]


def _as_jaxpr(v):
    if hasattr(v, "eqns"):
        return v
    inner = getattr(v, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return None


def iter_eqns(jaxpr) -> Iterator:
    """All eqns of ``jaxpr`` and every nested sub-jaxpr (pjit bodies, scan/
    while/cond branches, custom-call closures), depth-first."""
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            for val in eqn.params.values():
                vals = val if isinstance(val, (list, tuple)) else (val,)
                for v in vals:
                    sub = _as_jaxpr(v)
                    if sub is not None:
                        stack.append(sub)


def _aval_elems(var) -> int:
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return int(np.prod(shape)) if len(shape) else 1


def _is_float(dt) -> bool:
    return np.issubdtype(np.dtype(dt), np.floating)


def lint_jaxpr(jaxpr, spec) -> List[Finding]:
    """Walk one traced entry point for dtype/transpose findings."""
    findings: List[Finding] = []
    for eqn in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim == "convert_element_type":
            new = np.dtype(eqn.params.get("new_dtype"))
            old_aval = getattr(eqn.invars[0], "aval", None)
            old = np.dtype(getattr(old_aval, "dtype", new))
            if (_is_float(new) and _is_float(old)
                    and new.itemsize < old.itemsize
                    and new.itemsize * 8 < spec.min_float_bits):
                findings.append(Finding(
                    pass_name="jaxpr", code="JX001",
                    message=(f"float demoted {old.name} -> {new.name} on a "
                             f"certificate-producing path"),
                    location=spec.name,
                    details={"primitive": prim, "from": old.name,
                             "to": new.name,
                             "min_float_bits": spec.min_float_bits},
                ))
        elif prim == "transpose":
            elems = _aval_elems(eqn.invars[0])
            if (spec.design_elements
                    and elems >= spec.design_elements
                    and not spec.allow_design_transpose):
                findings.append(Finding(
                    pass_name="jaxpr", code="JX002",
                    message=(f"design-sized transpose materialised in the "
                             f"traced program ({elems} elements); (p, n) "
                             f"copies must go through the audited "
                             f"kernels.ops.transposed_design"),
                    location=spec.name,
                    details={"elements": elems,
                             "design_elements": spec.design_elements},
                ))
        elif prim == "gather":
            in_elems = _aval_elems(eqn.invars[0])
            out_elems = _aval_elems(eqn.outvars[0])
            if (spec.design_elements
                    and min(in_elems, out_elems) >= spec.design_elements
                    and not spec.allow_design_transpose):
                findings.append(Finding(
                    pass_name="jaxpr", code="JX003",
                    message=(f"design-sized gather copy in the traced "
                             f"program ({out_elems} elements out)"),
                    location=spec.name,
                    details={"in_elements": in_elems,
                             "out_elements": out_elems,
                             "design_elements": spec.design_elements},
                ))
    return findings


def lint_entry_point(spec) -> List[Finding]:
    """Trace ``spec`` on its template and run the jaxpr walks."""
    try:
        fn, args, kwargs = spec.build()
        closed = jax.make_jaxpr(lambda: fn(*args, **kwargs))()
    except Exception as e:  # a broken template IS a gate failure
        return [Finding(
            pass_name="jaxpr", code="JX000",
            message=f"entry point failed to trace: {type(e).__name__}: {e}",
            location=spec.name,
        )]
    return lint_jaxpr(closed.jaxpr, spec)


def retrace_harness(spec) -> List[Finding]:
    """Compile ``spec`` twice with dtype-identical fresh inputs; any jit
    cache growth between the calls is a retrace hazard."""
    findings: List[Finding] = []
    try:
        fn, args, kwargs = spec.build()
        jax.block_until_ready(fn(*args, **kwargs))
        size1 = fn._cache_size() if hasattr(fn, "_cache_size") else None
        fn2, args, kwargs = spec.build()
        jax.block_until_ready(fn2(*args, **kwargs))
        size2 = fn2._cache_size() if hasattr(fn2, "_cache_size") else None
    except (TypeError, ValueError) as e:
        # jax raises TypeError or a ValueError wrapping one, both
        # mentioning hashability, when an unhashable value reaches a
        # static argument
        if "hash" in str(e).lower():
            return [Finding(
                pass_name="jaxpr", code="JX005",
                message=f"unhashable value reached a static argument: {e}",
                location=spec.name,
            )]
        return [Finding(
            pass_name="jaxpr", code="JX000",
            message=(f"entry point failed to execute its template: "
                     f"{type(e).__name__}: {e}"),
            location=spec.name,
        )]
    except Exception as e:
        return [Finding(
            pass_name="jaxpr", code="JX000",
            message=(f"entry point failed to execute its template: "
                     f"{type(e).__name__}: {e}"),
            location=spec.name,
        )]
    if size1 is None or size2 is None:
        findings.append(Finding(
            pass_name="jaxpr", code="JX006", severity="info",
            message="entry point exposes no jit cache; retrace check "
                    "skipped",
            location=spec.name,
        ))
    elif size2 > size1:
        kops.note_retrace(size2 - size1)
        findings.append(Finding(
            pass_name="jaxpr", code="JX004",
            message=(f"retraced on dtype-identical inputs (jit cache grew "
                     f"{size1} -> {size2}); look for weak-type literals or "
                     f"unstable static arguments"),
            location=spec.name,
            details={"cache_before": size1, "cache_after": size2},
        ))
    return findings


def run(specs) -> List[Finding]:
    findings: List[Finding] = []
    for spec in specs:
        findings.extend(lint_entry_point(spec))
        if spec.check_retrace:
            findings.extend(retrace_harness(spec))
    return findings
