"""Typed failure taxonomy for the fault-tolerance layer.

Every failure a solve or a served request can end in has a type here, so
callers branch on ``isinstance`` instead of parsing messages — and a
future is *always* resolved with one of these (or a result), never left
forever-pending.  The taxonomy mirrors the safety contract of GAP
screening: a degraded answer still carries an honest full-problem gap
(any feasible dual point yields a safe sphere), and anything that cannot
make that promise surfaces as a typed error instead of a silently wrong
result.

* :class:`Degraded` — the solve hit its deadline / epoch budget; carries
  the truncated :class:`~repro.core.session.PathResult` and the honest
  full-problem gap of the last certified round.
* :class:`ServeError` — terminal serve-side failure (retries exhausted,
  or the per-problem circuit breaker is open).
* :class:`WorkerCrash` — the serve worker's solve loop died mid-request
  (the supervisor restarts it; injected by the chaos harness).
* :class:`NumericsError` — repeated non-finite certified rounds: the
  rewind guard could not recover a finite trajectory.
* :class:`KernelLaunchError` — a Pallas kernel launch failed and no
  reference-path fallback was possible (or the injected failure hit the
  XLA path itself).
* :class:`CheckpointCorrupt` — an explicitly requested checkpoint failed
  its payload-digest verification (``latest()`` quarantines and falls
  back instead of raising).

``Preempted`` (server drain/SIGTERM) predates this module and lives in
:mod:`repro.serve.server`; together they form the documented error
taxonomy (README "Fault tolerance & degradation").
"""
from __future__ import annotations

from typing import Any, Optional

__all__ = [
    "Degraded",
    "ServeError",
    "WorkerCrash",
    "NumericsError",
    "KernelLaunchError",
    "CheckpointCorrupt",
]


class Degraded(RuntimeError):
    """A budgeted solve returned early with an honest partial result.

    ``result`` is the truncated path (every solved lambda carries its
    certified full-problem gap); ``reason`` is ``"deadline"`` or
    ``"epoch_budget"``; ``gap`` is the full-problem duality gap at the
    last lambda actually solved — honest, never extrapolated.
    """

    def __init__(self, result: Any, reason: str, gap: float):
        super().__init__(
            f"solve degraded ({reason}); honest gap at truncation: {gap:.3e}"
        )
        self.result = result
        self.reason = reason
        self.gap = gap


class ServeError(RuntimeError):
    """Terminal serve-side failure: retries exhausted or breaker open."""

    def __init__(self, message: str, request_digest: str = "",
                 cause: Optional[BaseException] = None):
        super().__init__(message)
        self.request_digest = request_digest
        self.cause = cause


class WorkerCrash(RuntimeError):
    """The serve worker's solve loop died mid-request."""


class NumericsError(RuntimeError):
    """Consecutive non-finite certified rounds; rewind could not recover."""


class KernelLaunchError(RuntimeError):
    """A kernel launch failed with no reference path left to fall back to."""


class CheckpointCorrupt(RuntimeError):
    """An explicitly requested checkpoint failed digest verification."""

    def __init__(self, path: str, detail: str = ""):
        super().__init__(
            f"checkpoint {path} failed payload verification"
            + (f": {detail}" if detail else "")
        )
        self.path = path
