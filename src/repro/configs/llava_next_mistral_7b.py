"""llava-next-mistral-7b — mistral-7b backbone; anyres vision frontend is a
STUB (precomputed patch embeddings). [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv=8,
    d_ff=14_336,
    vocab=32_000,
    frontend_tokens=2_880,   # anyres tiling: up to 5 tiles x 576 patches
    subquadratic=False,
    notes="mistral-7b backbone; patch embeddings precomputed (anyres stub)",
)
