"""Async request queue with compatibility-keyed coalescing.

Tenants submit :class:`repro.serve.types.PathRequest` objects and get a
``concurrent.futures.Future`` back immediately; a single worker drains
the queue in small time windows and groups what it drained:

* requests whose **full digests** match (same problem values, grid, and
  config statics) collapse into one solve — one future fan-out per
  member, betas bit-identical to a solo run because exactly one solve
  runs;
* requests with the same **problem digest** but different grids can
  optionally merge into one union-grid solve (``merge_grids``) — each
  member's response slices its own grid points out of the union path.
  Off by default: the union grid changes the warm-start trajectory, so
  merged betas agree with solo runs only to solver tolerance, not bit-
  exactly (documented trade-off; the tests pin both behaviours).

The compatibility *signature* (same (n, p, group layout, tau, dtype) +
config statics, :func:`repro.serve.types.compat_signature`) is what makes
a group eligible for the batched-lambda machinery downstream: every
member of a group drives one jit-warm session, so the fused
lambda-batched kernels amortise one X read across every tenant in the
group.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, List, NamedTuple, Optional

import numpy as np

from ..core.session import SolverConfig
from .types import PathRequest, compat_signature, problem_digest

__all__ = ["RequestQueue", "CoalescedGroup", "coalesce"]


class Pending(NamedTuple):
    """A submitted request awaiting service."""

    request: PathRequest
    future: Future
    digest: str
    t_submit: float


class CoalescedGroup(NamedTuple):
    """One solve serving one or more pending requests.

    ``lambdas`` is the grid actually solved; ``member_index[i]`` maps
    member ``i``'s requested grid points into it (identity slices unless
    ``merged`` — identical-digest members share the whole grid).
    """

    members: List[Pending]
    lambdas: np.ndarray
    member_index: List[np.ndarray]
    merged: bool


class RequestQueue:
    """Thread-safe submit side of the server.

    Event-driven: one :class:`threading.Condition` over a deque — submit
    and close notify, :meth:`drain` waits on the condition, so there is
    no polling sleep anywhere (a submit landing mid-window wakes the
    drainer immediately, and the coalescing window closes exactly when
    its deadline passes, not at the next poll tick).

    ``clock`` / ``wait`` are injectable for deterministic tests: ``wait``
    replaces the condition-timeout primitive (called with the remaining
    window while holding the queue lock), letting a fake clock drive the
    window logic without real sleeping.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 wait: Optional[Callable[[float], bool]] = None) -> None:
        self._items: "deque[Pending]" = deque()
        self._cond = threading.Condition()
        self._is_closed = False
        self._clock = clock
        self._wait = wait if wait is not None \
            else (lambda timeout: self._cond.wait(timeout))
        self.submitted = 0

    def submit(self, request: PathRequest,
               default_config: SolverConfig) -> Future:
        fut: Future = Future()
        pending = Pending(request, fut, request.digest(default_config),
                          self._clock())
        with self._cond:
            if self._is_closed:
                raise RuntimeError("queue is closed")
            self._items.append(pending)
            self.submitted += 1
            self._cond.notify_all()
        return fut

    def close(self) -> None:
        with self._cond:
            self._is_closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        return self._is_closed

    def pending(self) -> int:
        return len(self._items)

    def drain(self, max_batch: int = 32,
              window_s: float = 0.02) -> Optional[List[Pending]]:
        """Block for the next request, then keep collecting for at most
        ``window_s`` (the coalescing window) or until ``max_batch``.

        Returns ``None`` when the queue is closed and empty (worker
        shutdown signal).
        """
        out: List[Pending] = []
        with self._cond:
            while not self._items:
                if self._is_closed:
                    return None
                self._cond.wait()
            out.append(self._items.popleft())
            deadline = self._clock() + window_s
            while len(out) < max_batch:
                if self._items:
                    out.append(self._items.popleft())
                    continue
                remaining = deadline - self._clock()
                if remaining <= 0 or self._is_closed:
                    break
                self._wait(remaining)
                if not self._items and self._clock() >= deadline:
                    break
        return out


def coalesce(pending: List[Pending], default_config: SolverConfig,
             merge_grids: bool = False) -> List[CoalescedGroup]:
    """Group drained requests into solves (arrival order preserved).

    Identical digests always collapse.  With ``merge_grids``, groups that
    share a problem digest (and therefore a compat signature) but differ
    in grid merge into one descending union grid; every member's points
    are located in the union by exact float match, so responses carry
    precisely the lambdas their tenants asked for.
    """
    by_digest: "dict[str, List[Pending]]" = {}
    order: List[str] = []
    for p in pending:
        if p.digest not in by_digest:
            by_digest[p.digest] = []
            order.append(p.digest)
        by_digest[p.digest].append(p)

    groups: List[CoalescedGroup] = []
    if not merge_grids:
        for dig in order:
            members = by_digest[dig]
            grid = members[0].request.grid()
            idx = np.arange(len(grid))
            groups.append(CoalescedGroup(
                members=members, lambdas=grid,
                member_index=[idx] * len(members), merged=False,
            ))
        return groups

    # merge_grids: bucket the digest-groups by problem identity (compat
    # signature is implied by equal problem digest + config token, but the
    # signature check keeps the invariant explicit and cheap).
    by_problem: "dict[tuple, List[str]]" = {}
    porder: List[tuple] = []
    for dig in order:
        req = by_digest[dig][0].request
        cfg = req.resolved_config(default_config)
        # Problem-level key: requests merge only when the problem values
        # AND the compile-relevant config agree (the request digest is
        # grid-inclusive, so it cannot serve as the merge key).
        key = (compat_signature(req.problem, cfg),
               problem_digest(req.problem, cfg))
        if key not in by_problem:
            by_problem[key] = []
            porder.append(key)
        by_problem[key].append(dig)

    for key in porder:
        digs = by_problem[key]
        members = [p for d in digs for p in by_digest[d]]
        grids = [by_digest[d][0].request.grid() for d in digs]
        if len(digs) == 1:
            grid = grids[0]
            idx = np.arange(len(grid))
            groups.append(CoalescedGroup(
                members=members, lambdas=grid,
                member_index=[idx] * len(members), merged=False,
            ))
            continue
        union = np.unique(np.concatenate(grids))[::-1]   # descending
        member_index = []
        for d in digs:
            g = by_digest[d][0].request.grid()
            idx = np.searchsorted(-union, -g)            # union is desc
            for m in by_digest[d]:
                member_index.append(idx)
        groups.append(CoalescedGroup(
            members=members, lambdas=union,
            member_index=member_index, merged=True,
        ))
    return groups
