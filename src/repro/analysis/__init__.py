"""Static analysis gate: jaxpr lints, Pallas launch auditor, certificate
dataflow lints.

    PYTHONPATH=src python -m repro.analysis --check [--report out.json]

GAP safe screening lives or dies on invariants the type system cannot see:
certificates must be computed in f64 on the full problem, the hot path must
never silently materialise a (p, n) transpose or retrace, Pallas tiles must
fit VMEM and cover their outputs exactly once, and an unsafe rule's
discards must never flow into a ``safe=True`` result.  This package checks
all of that *before anything runs*, as a tier-1 test module
(``tests/test_analysis.py``) and a CI step.

What each pass guarantees
-------------------------
``jaxpr`` (:mod:`.jaxpr_lints`)
    Traces every registered entry point (the solver's jitted rounds and
    epoch drivers) into a jaxpr on small shape templates derived from
    ``configs/sgl_paper.py`` and walks every nested eqn:

    * **JX001 dtype demotion** — no ``convert_element_type`` from a f64
      float to a sub-64-bit float anywhere in a certificate-producing
      program.  The one sanctioned sub-f64 path is the mesh strategy's f32
      solves, whose certificate adoption is already runtime-guarded (low-
      precision rounds are not adopted, see ``session.py``); such specs
      declare ``min_float_bits=32`` and the exemption is visible in the
      report.
    * **JX002/JX003 transpose materialisation** — no ``transpose`` (or
      design-sized ``gather``) on an operand as large as the design
      matrix: every (p, n) copy must come from the audited
      ``kernels.ops.transposed_design`` / ``prepare_transposed``.  This
      promotes the runtime ``transpose_trace_count`` audit to a static
      guarantee.
    * **JX004/JX005 retrace hazards** — each entry point is compiled twice
      with dtype-identical, freshly-built inputs; any jit-cache growth
      (weak-type literal splits, unhashable static arguments) is an error
      and bumps ``kernels.ops.retrace_count()``.

``pallas`` (:mod:`.pallas_audit`)
    Evaluates every registered kernel's ``BlockSpec`` index maps over the
    full grid (the same :class:`repro.kernels._util.LaunchSpec` objects
    the ``pallas_call`` wrappers execute from): no out-of-bounds block
    reads (PL001), every output block written exactly once over the
    non-carried grid axes (PL002 gaps / PL003 overlaps), declared carried
    axes actually invariant (PL005), and the per-grid-step VMEM footprint
    within budget — 16 MiB by default (PL004).

``cert`` (:mod:`.cert_lint`)
    AST pass over ``src/repro``: every ``RoundResult``/``PathResult``
    construction threads ``safe=``/``certificates_safe=`` from rule
    metadata — never a bare ``True`` literal, never the field default
    (CS001); no module under ``core/``/``kernels/`` imports the unsafe
    ``StrongSequentialRule`` (CS002); every rule registered with
    ``is_safe=True`` appears in the safety-matrix tests (CS003).

Registering new code
--------------------
* **New jitted entry point**: ``register_traceable(name, fn)`` at the
  bottom of its module (:mod:`repro.analysis.registry` is a leaf import),
  then add a same-named template builder in
  :mod:`repro.analysis.entrypoints`.  A traceable without a template — or
  a template without a traceable — is itself a finding (RG001), so the
  gate forces the pairing.
* **New Pallas kernel**: build its launch from a ``*_launch_spec()``
  function (see any module in ``kernels/``) and
  ``register_kernel_audit(name, builder)`` in ``kernels/ops.py`` with a
  representative config.
* **New screening rule**: register it as usual; if ``is_safe=True`` the
  cert pass requires the safety-matrix tests in ``tests/test_rules.py``
  to exercise it by name — add it to their parametrize lists.  Results it
  produces must thread ``safe=rule.is_safe``; a bare ``True`` anywhere in
  ``src/repro`` outside ``rules/library.py`` fails the gate.

Keeping the gate green is cheap by construction: the lints read the same
objects the runtime executes (registered jits, executed LaunchSpecs), so
an honest change only ever needs a registration, never a parallel spec.
"""
from __future__ import annotations

__all__ = [
    "Finding",
    "kernel_audits",
    "register_kernel_audit",
    "register_traceable",
    "run_checks",
    "traceables",
]

from .findings import Finding
from .registry import (
    kernel_audits,
    register_kernel_audit,
    register_traceable,
    traceables,
)


def __getattr__(name):
    # Lazy: .main pulls in jax + the whole solver; the registry/findings
    # leaves above must stay importable from core/kernels hook sites
    # without completing that cycle.
    if name == "run_checks":
        from .main import run_checks

        return run_checks
    raise AttributeError(name)
