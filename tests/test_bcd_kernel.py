"""Fused BCD-epoch mega-kernel: interpret-mode bit-parity vs the lax.scan
reference, batched-lambda grid semantics, and the session-level pin that
``solver_backend="pallas"`` reproduces the XLA path exactly."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import sgl
from repro.core.session import SGLSession, SolverConfig
from repro.core.solver import bcd_epochs, resolve_solver_backend
from repro.data.synthetic import make_synthetic
from repro.kernels import ops, ref


def _gathered_like(rng, Gb, n, ng, B=1, dead_frac=0.3, dup_alias=True):
    """Random compacted-buffer state with masked/padded groups.

    ``dead`` groups model screened + bucket-padded slots: Lg = 0, zero
    feature mask, zero coefficients — and (dup_alias) the last dead slot
    carries a COPY of group 0's design, mimicking _gather_static's padded
    ``take`` slots that alias group 0.
    """
    Xt = rng.standard_normal((Gb, n, ng))
    Lg = rng.uniform(0.5, 3.0, Gb)
    dead = rng.random(Gb) < dead_frac
    dead[0] = False                      # keep the aliased group live
    if dup_alias and dead.any():
        Xt[np.nonzero(dead)[0][-1]] = Xt[0]
    Lg[dead] = 0.0
    fm = (rng.random((B, Gb, ng)) < 0.85).astype(float)
    fm[:, dead] = 0.0
    w = np.sqrt(ng) * np.ones(Gb)
    beta = rng.standard_normal((B, Gb, ng)) * fm
    resid = rng.standard_normal((B, n))
    return (jnp.asarray(Xt), jnp.asarray(Lg), jnp.asarray(w),
            jnp.asarray(fm), jnp.asarray(beta), jnp.asarray(resid))


@pytest.mark.parametrize("Gb,n,ng,n_epochs", [
    (8, 17, 5, 1),      # minimum bucket
    (16, 40, 10, 3),    # multi-epoch block
    (32, 100, 7, 5),    # paper-config-like odd ng
    (10, 25, 4, 2),     # Gb not a block_g multiple (wrapper pads)
    (64, 30, 3, 1),     # multi-tile group stream
])
def test_fused_epochs_bit_identical_to_scan(Gb, n, ng, n_epochs, rng):
    """f64 interpret-mode fused kernel == lax.scan reference, bit for bit,
    across bucket sizes, masked/padded (duplicate-alias) groups, and
    multi-epoch blocks."""
    Xt, Lg, w, fm, beta, resid = _gathered_like(rng, Gb, n, ng)
    tau, lam = jnp.asarray(0.3), jnp.asarray(0.45)
    want_b, want_r = bcd_epochs(Xt, Lg, w, fm[0], beta[0], resid[0],
                                tau, lam, n_epochs)
    got_b, got_r = ops.bcd_epochs_fused(Xt, Lg, w, fm, beta, resid, tau,
                                        jnp.reshape(lam, (1,)), n_epochs)
    np.testing.assert_array_equal(np.asarray(got_b[0]), np.asarray(want_b))
    np.testing.assert_array_equal(np.asarray(got_r[0]), np.asarray(want_r))


def test_fused_epochs_matches_ref_oracle(rng):
    """kernels.ref.bcd_epochs_ref is the same reference (bench parity)."""
    Xt, Lg, w, fm, beta, resid = _gathered_like(rng, 16, 20, 6, B=2)
    tau = jnp.asarray(0.4)
    lam_b = jnp.asarray([0.3, 0.9])
    want = ref.bcd_epochs_ref(Xt, Lg, w, fm, beta, resid, tau, lam_b, 3)
    got = ops.bcd_epochs_fused(Xt, Lg, w, fm, beta, resid, tau, lam_b, 3)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_epochs_batched_grid_equals_per_lambda(rng):
    """The lambda-batch grid axis: B lambdas in one launch, each carrying
    its own beta/resid/mask/threshold, bit-identical to B separate
    single-lambda launches (and hence to B scan references)."""
    B = 4
    Xt, Lg, w, fm, beta, resid = _gathered_like(rng, 16, 30, 6, B=B)
    tau = jnp.asarray(0.35)
    lam_b = jnp.asarray([0.2, 0.5, 0.9, 1.7])
    got_b, got_r = ops.bcd_epochs_fused(Xt, Lg, w, fm, beta, resid, tau,
                                        lam_b, 4)
    for b in range(B):
        want_b, want_r = bcd_epochs(Xt, Lg, w, fm[b], beta[b], resid[b],
                                    tau, lam_b[b], 4)
        np.testing.assert_array_equal(np.asarray(got_b[b]),
                                      np.asarray(want_b))
        np.testing.assert_array_equal(np.asarray(got_r[b]),
                                      np.asarray(want_r))


def test_fused_epochs_zero_epochs_is_identity(rng):
    Xt, Lg, w, fm, beta, resid = _gathered_like(rng, 8, 10, 4)
    out_b, out_r = ops.bcd_epochs_fused(Xt, Lg, w, fm, beta, resid,
                                        jnp.asarray(0.3),
                                        jnp.asarray([0.5]), 0)
    np.testing.assert_array_equal(np.asarray(out_b), np.asarray(beta))
    np.testing.assert_array_equal(np.asarray(out_r), np.asarray(resid))


def test_resolve_solver_backend_validates():
    assert resolve_solver_backend("xla") == "xla"
    assert resolve_solver_backend("pallas") == "pallas"
    assert resolve_solver_backend("auto") in ("xla", "pallas")
    with pytest.raises(ValueError, match="solver backend"):
        resolve_solver_backend("cuda")
    with pytest.raises(ValueError, match="solver backend"):
        SGLSession(
            sgl.make_problem(np.eye(4), np.ones(4), [2, 2], tau=0.5),
            SolverConfig(solver_backend="cuda"),
        )


@pytest.fixture(scope="module")
def prob():
    X, y, _, sizes = make_synthetic(n=48, p=256, n_groups=32, gamma1=3,
                                    gamma2=3, seed=5)
    return sgl.make_problem(X, y, sizes, tau=0.3)


@pytest.fixture(scope="module")
def xla_path(prob):
    session = SGLSession(prob, SolverConfig(tol=1e-7, max_epochs=20_000,
                                            solver_backend="xla"))
    return session.solve_path(T=8, delta=2.0)


def test_session_pallas_solver_reproduces_xla_path(prob, xla_path):
    """Session pin: solver_backend="pallas" (interpret) reproduces the full
    path of "xla" — betas BIT-identical, epoch counts and seq/dyn screen
    counters equal, round audits equal — while actually dispatching fused
    launches."""
    session = SGLSession(prob, SolverConfig(tol=1e-7, max_epochs=20_000,
                                            solver_backend="pallas"))
    res = session.solve_path(T=8, delta=2.0, batch_lambdas=1)
    ref_res = xla_path
    np.testing.assert_array_equal(res.betas, ref_res.betas)
    np.testing.assert_array_equal(res.epochs, ref_res.epochs)
    np.testing.assert_array_equal(res.seq_screened, ref_res.seq_screened)
    np.testing.assert_array_equal(res.dyn_screened, ref_res.dyn_screened)
    assert res.n_rounds == ref_res.n_rounds
    assert res.n_compact_rounds == ref_res.n_compact_rounds
    assert res.n_full_rounds == ref_res.n_full_rounds
    assert ref_res.n_fused_epoch_launches == 0
    assert res.n_fused_epoch_launches > 0
    assert res.batched_lambdas == 0          # batch_lambdas=1: no batching


def test_session_pallas_single_solve_bit_parity(prob):
    """Single-lambda solves agree bit-for-bit too (incl. the non-compact
    branch, which dispatches the fused kernel on the full buffer)."""
    lam = float(sgl.lambda_max(prob)) / 15.0
    for compact in (True, False):
        r_x = SGLSession(prob, SolverConfig(
            tol=1e-7, compact=compact, solver_backend="xla")).solve(lam)
        s_p = SGLSession(prob, SolverConfig(
            tol=1e-7, compact=compact, solver_backend="pallas"))
        r_p = s_p.solve(lam)
        np.testing.assert_array_equal(np.asarray(r_p.beta),
                                      np.asarray(r_x.beta))
        assert r_p.n_epochs == r_x.n_epochs
        assert s_p.fused_epoch_launches > 0


def test_batched_lambda_path_single_device(prob):
    """Coinciding-active-set WARM path points (dense grid — batching is
    gated to warm stretches) solve through the kernel's lambda-batch axis:
    audit counters move, every lambda still meets tol, and the path stays
    within solver tolerance of the per-lambda XLA reference (trajectories
    differ — all batched lambdas warm-start from the same beta — so parity
    is tol-level, not bit-level)."""
    xla_dense = SGLSession(prob, SolverConfig(
        tol=1e-7, max_epochs=20_000, solver_backend="xla",
    )).solve_path(T=8, delta=0.5)
    session = SGLSession(prob, SolverConfig(tol=1e-7, max_epochs=20_000,
                                            solver_backend="pallas"))
    res = session.solve_path(T=8, delta=0.5, batch_lambdas=4)
    assert res.batched_lambdas > 0
    assert session.batched_lambdas == res.batched_lambdas
    assert res.n_fused_epoch_launches > 0
    assert (res.gaps <= 1e-7).all()
    np.testing.assert_allclose(res.betas, xla_dense.betas, atol=1e-7)
    # Batched-lambda runs must preserve path SAFETY: certified masks can
    # never kill a coefficient that is nonzero at the optimum.
    nz = np.abs(xla_dense.betas) > 1e-9
    assert not (nz & ~res.feat_active).any()


def test_batched_path_respects_screen_counters(prob):
    """seq/dyn counters stay consistent under batching: dyn_screened is
    non-negative and seq_screened counts the adopted certificates."""
    session = SGLSession(prob, SolverConfig(tol=1e-7, max_epochs=20_000,
                                            solver_backend="pallas"))
    res = session.solve_path(T=8, delta=0.5, batch_lambdas=3)
    assert res.batched_lambdas > 0
    assert (res.dyn_screened >= 0).all()
    assert (res.seq_screened >= 0).all()
    n_groups = res.group_active.shape[1]
    assert (res.seq_screened <= n_groups).all()
