"""Distributed SGL solver: FISTA + GAP safe screening under shard_map.

The paper's BCD is inherently sequential over groups; the parallel-safe
variant is proximal gradient (ISTA/FISTA) with the *global* Lipschitz
constant L = ||X||_2^2, which updates every group simultaneously — each
model-shard owns a slice of the groups, each data-shard a slice of the rows.

Communication pattern per FISTA step (see DESIGN.md §5):
    grad   = X^T resid          local matmul + psum over "data"
    prox   = two-level ST       local (Pallas kernel on TPU)
    resid  = y - X beta         local matmul + psum over "model"
Screening round (every f_ce steps):
    dual norm Omega^D           local eps-norms + pmax over "model"
    gap / primal / dual         scalar psums
    masks (Thm 1)               local per group shard

Screened groups stay in place but are masked (zero columns contribute
nothing); a host-side *rebalance* (launch/train.py --elastic) periodically
compacts surviving groups across shards — safe because certificates are
permanent.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import inspect

try:                                     # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                      # jax 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    # Older spelling of the replication check is check_rep, regardless of
    # where the function is exported from.
    def shard_map(f, *, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        return _shard_map(f, **kwargs)

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.sgl import epsilons, group_weight_total, soft_threshold
from repro.core.epsilon_norm import lam as lam_exact


class DistKernels(NamedTuple):
    fista: object          # one FISTA step, single lambda
    screen: object         # certified GAP screen round (Thm 1-2)
    norms: object          # column/group norms of X (compute once)
    fista_batch: object    # batched-lambda FISTA (path points in parallel)


class DistSGLState(NamedTuple):
    beta: jax.Array       # (G, ng) sharded P("model", None)
    z: jax.Array          # FISTA momentum iterate
    t: jax.Array          # FISTA momentum scalar
    feat_mask: jax.Array  # (G, ng) float — 0 for screened/padded
    group_mask: jax.Array # (G,) float
    gap: jax.Array
    step: jax.Array


def _dp_axes(multi_pod):
    return ("pod", "data") if multi_pod else ("data",)


def make_dist_step(mesh: Mesh, *, tau: float, multi_pod: bool = False,
                   f32=jnp.float32):
    """Builds (init_fn, fista_step, screen_step) shard_mapped on ``mesh``.

    Arrays: X (n, G, ng), y (n,), w (G,), Lg global Lipschitz scalar.
    """
    dp = _dp_axes(multi_pod)
    xspec = P(dp, "model", None)
    yspec = P(dp)
    gspec = P("model", None)
    sspec = P("model")
    bspec_g = P(None, "model", None)   # (B, G_l, ng) batched-lambda state

    def local_corr(X, v):
        # X (n_l, G_l, ng) v (n_l,) -> psum over data
        # f32 accumulation so a bf16 X (mixed-precision FISTA) keeps
        # full-precision partial sums
        c = jnp.einsum("ngk,n->gk", X, v.astype(X.dtype),
                       preferred_element_type=jnp.promote_types(
                           X.dtype, jnp.float32))
        return jax.lax.psum(c, dp)

    def local_matvec(X, b):
        r = jnp.einsum("ngk,gk->n", X, b.astype(X.dtype),
                       preferred_element_type=jnp.promote_types(
                           X.dtype, jnp.float32))
        return jax.lax.psum(r, "model")

    # --- FISTA step (jit over shard_map) ---
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(xspec, yspec, gspec, gspec, gspec, sspec, P(), P(), P()),
        out_specs=(gspec, gspec, P()),
        check_vma=False,
    )
    def fista_kernel(X, y, beta, z, feat_mask, w, t, lam_, L):
        resid = y - local_matvec(X, z)
        grad = -local_corr(X, resid)                    # (G_l, ng)
        u = (z - grad / L) * feat_mask
        # two-level prox at step 1/L
        a = soft_threshold(u, tau * lam_ / L)
        thr = ((1.0 - tau) * lam_ * w / L)[:, None]
        nrm = jnp.linalg.norm(a, axis=-1, keepdims=True)
        scale = jnp.maximum(1.0 - thr / jnp.maximum(nrm, 1e-30), 0.0)
        beta_new = scale * a * feat_mask
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = beta_new + ((t - 1.0) / t_new) * (beta_new - beta)
        return beta_new, z_new, t_new

    # --- batched-lambda FISTA: solve B path points simultaneously.
    # The matvec becomes a matmul with B columns — arithmetic intensity
    # scales by B, the lever that moves this memory-bound workload toward
    # the compute roofline (§Perf iteration 3 on the sgl-paper cell). ---
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(xspec, yspec, bspec_g, bspec_g, bspec_g, sspec,
                  P(), P(), P()),
        out_specs=(bspec_g, bspec_g, P()),
        check_vma=False,
    )
    def fista_batch_kernel(X, y, beta, z, feat_mask, w, t, lam_, L):
        """beta/z/feat_mask: (B, G_l, ng); lam_/t: (B,)."""
        # resid (B, n_l): one X read serves all B lambdas
        acc = jnp.promote_types(X.dtype, jnp.float32)
        r = jnp.einsum("ngk,bgk->bn", X, z.astype(X.dtype),
                       preferred_element_type=acc)
        resid = y[None, :] - jax.lax.psum(r, "model")
        g = jnp.einsum("ngk,bn->bgk", X, resid.astype(X.dtype),
                       preferred_element_type=acc)
        grad = -jax.lax.psum(g, dp)
        u = (z - grad / L) * feat_mask
        step = (lam_ / L)[:, None, None]
        a = soft_threshold(u, tau * step)
        thr = (1.0 - tau) * step * w[None, :, None]
        nrm = jnp.linalg.norm(a, axis=-1, keepdims=True)
        scale = jnp.maximum(1.0 - thr / jnp.maximum(nrm, 1e-30), 0.0)
        beta_new = scale * a * feat_mask
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = beta_new + ((t - 1.0) / t_new)[:, None, None] * (
            beta_new - beta)
        return beta_new, z_new, t_new

    # --- design-matrix norms (constants of the problem; computed ONCE at
    # setup — hoisting these two full passes over X out of every screening
    # round was §Perf iteration 1 on the sgl-paper cell) ---
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(xspec,),
        out_specs=(gspec, sspec),
        check_vma=False,
    )
    def norms_kernel(X):
        accn = jnp.promote_types(X.dtype, jnp.float32)
        colnorm = jax.lax.psum(
            jnp.einsum("ngk,ngk->gk", X, X,
                       preferred_element_type=accn), dp) ** 0.5
        # ||X_g||_2 <= ||X_g||_F: Frobenius is a safe (over-)estimate, so
        # the screening ball bound (Thm 1) stays valid without a
        # distributed power iteration
        gfro = jnp.sqrt(jax.lax.psum(
            jnp.sum((X * X).astype(accn), axis=(0, 2)), dp))
        return colnorm, gfro

    # --- screening round ---
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(xspec, yspec, gspec, gspec, sspec, gspec, sspec,
                  P(), P()),
        out_specs=(gspec, sspec, P(), P()),
        check_vma=False,
    )
    def screen_kernel(X, y, beta, feat_mask, w, colnorm, gfro, lam_, ynorm2):
        """GAP sphere + Theorem-1 tests, fully sharded.

        Returns (feat_mask, group_mask, gap, theta_scale).
        """
        resid = y - local_matvec(X, beta)
        corr = local_corr(X, resid)                     # (G_l, ng), full rows

        eps = epsilons(tau, w)
        scale_g = group_weight_total(tau, w)
        per_group = lam_exact(corr, 1.0 - eps, eps) / scale_g
        dual_norm = jax.lax.pmax(jnp.max(per_group), "model")
        sc = jnp.maximum(lam_, dual_norm)

        # primal / dual / gap (resid is replicated across model shards;
        # beta terms psum over model)
        fit = 0.5 * jnp.sum(resid * resid)
        l1 = jax.lax.psum(jnp.sum(jnp.abs(beta)), "model")
        l2 = jax.lax.psum(jnp.sum(w * jnp.linalg.norm(beta, axis=-1)),
                          "model")
        # row shards: fit must also psum over data
        fit = jax.lax.psum(fit, dp)
        primal = fit + lam_ * (tau * l1 + (1.0 - tau) * l2)
        ydist = jax.lax.psum(
            jnp.sum((resid / sc - y / lam_) ** 2), dp
        )
        dual_val = 0.5 * ynorm2 - 0.5 * lam_ * lam_ * ydist
        gap = jnp.maximum(primal - dual_val, 0.0)
        r = jnp.sqrt(2.0 * gap) / lam_

        # Theorem 1 tests on theta = resid / sc
        corr_t = corr / sc
        st = soft_threshold(corr_t, tau)
        st_norm = jnp.linalg.norm(st, axis=-1)
        inf_norm = jnp.max(jnp.abs(corr_t), axis=-1)
        Tg = jnp.where(
            inf_norm > tau,
            st_norm + r * gfro,
            jnp.maximum(inf_norm + r * gfro - tau, 0.0),
        )
        gmask = (Tg >= (1.0 - tau) * w).astype(X.dtype)
        fmask = (
            (jnp.abs(corr_t) + r * colnorm >= tau).astype(X.dtype)
            * gmask[:, None]
            * feat_mask
        )
        return fmask, gmask, gap, sc

    return DistKernels(fista=fista_kernel, screen=screen_kernel,
                       norms=norms_kernel, fista_batch=fista_batch_kernel)


def solve_distributed(
    mesh: Mesh,
    X, y, w,
    *,
    tau: float,
    lam_: float,
    L: float,
    multi_pod: bool = False,
    tol: float = 1e-6,
    max_steps: int = 2000,
    f_ce: int = 10,
):
    """Host driver: FISTA with screening every f_ce steps on a live mesh.

    .. deprecated::
        Thin wrapper over the session API — the raw-array signature became
        ``SGLSession(problem_from_grouped(X, y, tau, w), mesh=mesh)``::

            from repro.core import SGLSession, SolverConfig, problem_from_grouped
            session = SGLSession(problem_from_grouped(X, y, tau=tau, w=w),
                                 SolverConfig(tol=tol, max_epochs=max_steps,
                                              f_ce=f_ce),
                                 mesh=mesh, L=L)
            res = session.solve(lam_)

        The session form additionally exposes ``solve_path`` (sequential
        certificates + batched-lambda FISTA on the mesh) and ``screen``.

    Returns the legacy tuple ``(beta, gap, gaps, feat_mask)``.
    """
    import warnings

    from repro.core.session import SGLSession, SolverConfig
    from repro.core.sgl import problem_from_grouped

    warnings.warn(
        "solve_distributed() is deprecated; use "
        "SGLSession(problem_from_grouped(...), mesh=mesh).solve(lam_)",
        DeprecationWarning, stacklevel=2,
    )
    problem = problem_from_grouped(X, y, tau=tau, w=w)
    cfg = SolverConfig(tol=tol, max_epochs=max_steps, f_ce=f_ce)
    session = SGLSession(problem, cfg, mesh=mesh, multi_pod=multi_pod, L=L)
    res = session.solve(lam_)
    feat_mask = jnp.asarray(res.feat_active, problem.X.dtype)
    return res.beta, float(res.gap), res.gap_history, feat_mask


# ----------------------------------------------------------------------------
# Static-analysis hook: the mesh kernels are built per-mesh, so the factory
# itself is registered; the analysis template instantiates it on the (1, 1)
# test mesh (repro.analysis.entrypoints, dist_fista/* specs).
# ----------------------------------------------------------------------------

from ..analysis.registry import register_traceable  # noqa: E402

register_traceable("dist_step_factory", make_dist_step,
                   module=__name__, kind="factory")
