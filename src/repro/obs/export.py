"""The one percentile implementation and the unified BENCH JSON schema.

Before this module, percentile math lived in three places
(``bench_serve``'s hand-rolled ``np.percentile`` calls, ad-hoc stats in
tests) and every benchmark re-built its own env-metadata dict.  Now:

* :func:`percentile` — single linear-interpolation implementation
  (``numpy.percentile`` default method, pure python so the obs leaf stays
  import-cheap).  ``Histogram.summary``, ``Tracer.percentiles`` and the
  benchmarks all route through it.
* :func:`env_meta` — the one place that records jax version / backend /
  platform / x64 (``benchmarks/common.write_json`` delegates here).
* :func:`merge_bench` — the unified BENCH schema ``repro.obs.bench/v1``:
  ``{"schema", "meta", "sections": {name: payload}}``, merged
  order-independently so ``bench_path --obs-json`` and ``bench_serve
  --obs-json`` can both land in one ``BENCH_pr10.json``.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, Optional

BENCH_SCHEMA = "repro.obs.bench/v1"


def percentile(values: Iterable[float], q: float) -> Optional[float]:
    """Linear-interpolated percentile (numpy's default method).

    Returns ``None`` on an empty input rather than raising — stage
    summaries routinely aggregate span sites that never fired.
    """
    xs = sorted(float(v) for v in values)
    if not xs:
        return None
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} out of [0, 100]")
    if len(xs) == 1:
        return xs[0]
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def env_meta(extra: Optional[dict] = None) -> dict:
    """Environment metadata stamped into every BENCH payload."""
    import jax  # local: keep repro.obs importable without touching jax

    meta = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": jax.devices()[0].platform,
        "device_count": jax.device_count(),
        "x64": bool(jax.config.read("jax_enable_x64")),
    }
    if extra:
        meta.update(extra)
    return meta


def merge_bench(path: str, section: str, payload: dict,
                meta_extra: Optional[dict] = None) -> dict:
    """Merge one section into a ``repro.obs.bench/v1`` file on disk.

    Sections are independent (kernel timings, path smoke, serve load…);
    merging keyed by name makes the final artifact order-independent, the
    same property ``bench_serve``'s old ``_merge_json`` had.
    """
    doc: dict = {"schema": BENCH_SCHEMA, "meta": {}, "sections": {}}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                prev = json.load(fh)
            if isinstance(prev, dict) and prev.get("schema") == BENCH_SCHEMA:
                doc = prev
                doc.setdefault("meta", {})
                doc.setdefault("sections", {})
        except (json.JSONDecodeError, OSError):
            pass  # start the file over rather than fail the bench
    doc["meta"].update(env_meta(meta_extra))
    doc["sections"][section] = payload
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return doc
