"""Pallas kernel parity + dispatch-path timing.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock numbers measure the jnp fallback / dispatch overhead only; the
correctness deltas against ``ref.py`` are the meaningful output (the TPU
timing story lives in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, timeit


def main(G=512, ng=16, n=256, tau=0.3) -> None:
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    beta = jax.random.normal(k1, (G, ng), jnp.float32)
    step = jnp.abs(jax.random.normal(k2, (G,), jnp.float32)) + 0.1
    w = jnp.sqrt(jnp.full((G,), float(ng), jnp.float32))
    Xt = jax.random.normal(k3, (G * ng, n), jnp.float32)  # (p, n) layout
    theta = jax.random.normal(k4, (n,), jnp.float32)
    lam = 0.7

    # fused two-level prox
    out = ops.sgl_prox(beta, step, w, tau=tau, lam=lam)
    want = ref.sgl_prox_ref(beta, step, w, tau, lam)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("kernels", f"sgl_prox_G{G}", "max_err", err)
    emit("kernels", f"sgl_prox_G{G}", "us_per_call",
         1e6 * timeit(lambda: ops.sgl_prox(beta, step, w, tau=tau, lam=lam)))

    # fused screening scores
    sc = ops.screening_scores(Xt, theta, tau=tau)
    sc_ref = ref.screening_scores_ref(Xt, theta, tau)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(sc, sc_ref))
    emit("kernels", f"screening_G{G}", "max_err", err)
    emit("kernels", f"screening_G{G}", "us_per_call",
         1e6 * timeit(lambda: ops.screening_scores(Xt, theta, tau=tau)))

    # grouped dual-norm bisection
    x = jax.random.normal(k1, (G, ng), jnp.float32)
    alpha = jnp.full((G,), 0.6, jnp.float32)
    R = jnp.full((G,), 0.8, jnp.float32)
    nu = ops.dual_norm_groups(x, alpha, R)
    nu_ref = jax.vmap(ref.dual_norm_ref)(x, alpha, R)
    err = float(jnp.max(jnp.abs(nu - nu_ref)))
    emit("kernels", f"dual_norm_G{G}", "max_err", err)
    emit("kernels", f"dual_norm_G{G}", "us_per_call",
         1e6 * timeit(lambda: ops.dual_norm_groups(x, alpha, R)))


def bcd_epoch_case(Gb=32, n=128, ng=8, n_epochs=10, B=4) -> None:
    """Fused BCD-epoch mega-kernel vs the lax.scan reference.

    Correctness: f64 bit-parity (max_err must read exactly 0.0 — the
    kernel's contract, not an allclose).  Timing compares one fused launch
    per epoch block against the per-group scan dispatch; on this CPU
    container the kernel runs interpreted, so treat the wall-clock as a
    dispatch-overhead floor, not a TPU number.  ``launches_per_block``
    records the dispatch-count story: 1 fused launch vs Gb scan steps.
    """
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    dt = jnp.float64
    Xt = jax.random.normal(ks[0], (Gb, n, ng), dt) / jnp.sqrt(n * 1.0)
    Lg = jnp.abs(jax.random.normal(ks[1], (Gb,), dt)) + 0.5
    w = jnp.sqrt(jnp.full((Gb,), float(ng), dt))
    fm = (jax.random.uniform(ks[2], (B, Gb, ng)) < 0.9).astype(dt)
    beta = jax.random.normal(ks[3], (B, Gb, ng), dt) * fm
    resid = jax.random.normal(ks[4], (B, n), dt)
    tau = jnp.asarray(0.3, dt)
    lam_b = jnp.linspace(0.2, 0.8, B, dtype=dt)

    got = ops.bcd_epochs_fused(Xt, Lg, w, fm, beta, resid, tau, lam_b,
                               n_epochs)
    want = ref.bcd_epochs_ref(Xt, Lg, w, fm, beta, resid, tau, lam_b,
                              n_epochs)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(got, want))
    assert err == 0.0, f"fused BCD kernel lost f64 bit-parity: {err}"
    case = f"bcd_epoch_G{Gb}_B{B}"
    emit("kernels", case, "max_err", err)
    emit("kernels", case, "launches_per_block_fused", 1)
    emit("kernels", case, "launches_per_block_scan", Gb)
    emit("kernels", case, "us_per_call_fused",
         1e6 * timeit(lambda: ops.bcd_epochs_fused(
             Xt, Lg, w, fm, beta, resid, tau, lam_b, n_epochs)))

    scan_ref = jax.jit(
        lambda b, r: ref.bcd_epochs_ref(Xt, Lg, w, fm, b, r, tau, lam_b,
                                        n_epochs))
    emit("kernels", case, "us_per_call_scan",
         1e6 * timeit(lambda: scan_ref(beta, resid)))


if __name__ == "__main__":
    import argparse

    from .common import header, write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump emitted rows as machine-readable JSON")
    args = ap.parse_args()
    header()
    main()
    bcd_epoch_case()
    if args.json:
        write_json(args.json)
