"""Safety and correctness tests for the screening rules (paper Thm 1/2, App C)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    dst3_sphere,
    dual_scale,
    duality_gap,
    dynamic_sphere,
    gap_sphere,
    lambda_max,
    make_problem,
    screen,
    sgl_dual_norm,
    solve,
    static_sphere,
)
from repro.core.sgl import primal, dual
from repro.data import make_synthetic


@pytest.fixture(scope="module")
def small_problem():
    X, y, _, sizes = make_synthetic(
        n=40, p=200, n_groups=25, gamma1=3, gamma2=3, seed=7
    )
    return make_problem(X, y, sizes, tau=0.3)


@pytest.fixture(scope="module")
def exact_solutions(small_problem):
    lmax = float(lambda_max(small_problem))
    sols = {}
    for frac in (0.7, 0.3, 0.08):
        res = solve(small_problem, lmax * frac, tol=1e-11, rule="none",
                    max_epochs=30_000)
        sols[frac] = res
    return lmax, sols


def test_dual_scale_is_feasible(small_problem, rng):
    """Eq. 15 always produces a dual-feasible point."""
    lmax = float(lambda_max(small_problem))
    for _ in range(5):
        beta = jnp.asarray(
            rng.standard_normal((small_problem.G, small_problem.ng))
        ) * jnp.asarray(small_problem.feat_mask)
        resid = small_problem.y - jnp.einsum("ngk,gk->n", small_problem.X, beta)
        theta = dual_scale(small_problem, resid, 0.4 * lmax)
        corr = jnp.einsum("ngk,n->gk", small_problem.X, theta)
        dn = float(sgl_dual_norm(corr, small_problem.tau, small_problem.w))
        assert dn <= 1.0 + 1e-9


def test_gap_sphere_contains_dual_optimum(small_problem, exact_solutions):
    """Thm 2: theta_hat in B(theta, sqrt(2 gap)/lam) for any feasible theta."""
    lmax, sols = exact_solutions
    for frac, res in sols.items():
        lam_ = lmax * frac
        theta_hat = res.theta  # converged to gap <= 1e-11
        # A crude primal iterate far from optimum:
        beta_crude = res.beta * 0.5
        resid = small_problem.y - jnp.einsum(
            "ngk,gk->n", small_problem.X, beta_crude
        )
        theta_c = dual_scale(small_problem, resid, lam_)
        sph = gap_sphere(small_problem, beta_crude, theta_c, lam_)
        dist = float(jnp.linalg.norm(theta_hat - sph.center))
        assert dist <= float(sph.radius) + 1e-7


@pytest.mark.parametrize("rule", ["gap", "static", "dynamic", "dst3"])
def test_rules_are_safe(small_problem, exact_solutions, rule):
    """No variable that is nonzero at the optimum may be screened out."""
    lmax, sols = exact_solutions
    for frac, ref in sols.items():
        lam_ = lmax * frac
        res = solve(small_problem, lam_, tol=1e-9, rule=rule, lam_max=lmax,
                    max_epochs=30_000)
        beta_ref = np.asarray(ref.beta)
        screened = ~np.asarray(res.feat_active) & np.asarray(
            small_problem.feat_mask
        )
        assert np.all(np.abs(beta_ref[screened]) < 1e-7), (
            rule, frac, np.abs(beta_ref[screened]).max()
        )
        # and the solutions agree
        np.testing.assert_allclose(
            np.asarray(res.beta), beta_ref, atol=2e-4
        )


def test_gap_screens_more_than_static_dynamic(small_problem, exact_solutions):
    """GAP spheres shrink with convergence; baselines don't. At convergence the
    GAP active set must be no larger than static/dynamic ones."""
    lmax, _ = exact_solutions
    lam_ = 0.3 * lmax
    n_active = {}
    for rule in ("gap", "static", "dynamic"):
        res = solve(small_problem, lam_, tol=1e-9, rule=rule, lam_max=lmax,
                    max_epochs=30_000)
        n_active[rule] = int(res.feat_active.sum())
    assert n_active["gap"] <= n_active["static"]
    assert n_active["gap"] <= n_active["dynamic"]


def test_screen_monotone_in_radius(small_problem):
    """A bigger safe ball can only keep more variables."""
    lmax = float(lambda_max(small_problem))
    theta = small_problem.y / lmax
    from repro.core import Sphere
    prev_groups, prev_feats = -1, -1
    for r in (0.5, 0.2, 0.05, 0.0):
        res = screen(small_problem, Sphere(theta, jnp.asarray(r)))
        g, f = int(res.group_active.sum()), int(res.feat_active.sum())
        if prev_groups >= 0:
            assert g <= prev_groups
            assert f <= prev_feats
        prev_groups, prev_feats = g, f


def test_pallas_screen_fallback_transpose_is_audited(small_problem):
    """Regression (session-wiring audit): screen(backend='pallas') without a
    persistent transposed design materialises a (p, n) transpose on the fly
    — that copy must move kernels.ops.transpose_trace_count(), and the
    xt_pre-fed call must not, or a broken xt_pre wiring on this path would
    be invisible to the audit the tests/benchmarks watch."""
    from repro.kernels import ops as kops

    lmax = float(lambda_max(small_problem))
    theta = small_problem.y / lmax
    from repro.core import Sphere
    sphere = Sphere(theta, jnp.asarray(0.1))

    with kops.audit_scope() as audit:
        res_nopre = screen(small_problem, sphere, backend="pallas")
        assert audit.transpose_traces == 1

        xt = kops.prepare_transposed(small_problem.X)  # persistent: uncounted
        assert audit.transpose_traces == 1
        res_pre = screen(small_problem, sphere, backend="pallas", xt_pre=xt)
    assert audit.transpose_traces == 1
    # same screens either way
    assert np.array_equal(np.asarray(res_nopre.group_active),
                          np.asarray(res_pre.group_active))
    assert np.array_equal(np.asarray(res_nopre.feat_active),
                          np.asarray(res_pre.feat_active))


def test_lambda_max_is_critical(small_problem):
    """Remark 2: beta = 0 optimal iff lam >= lambda_max."""
    lmax = float(lambda_max(small_problem))
    res_above = solve(small_problem, lmax * 1.001, tol=1e-10, rule="gap")
    assert float(jnp.abs(res_above.beta).max()) == 0.0
    res_below = solve(small_problem, lmax * 0.95, tol=1e-10, rule="gap",
                      max_epochs=30_000)
    assert float(jnp.abs(res_below.beta).max()) > 0.0


def test_weak_duality(small_problem, rng):
    lmax = float(lambda_max(small_problem))
    lam_ = 0.4 * lmax
    for _ in range(5):
        beta = jnp.asarray(
            rng.standard_normal((small_problem.G, small_problem.ng))
        ) * jnp.asarray(small_problem.feat_mask)
        resid = small_problem.y - jnp.einsum("ngk,gk->n", small_problem.X, beta)
        theta = dual_scale(small_problem, resid, lam_)
        assert float(duality_gap(small_problem, beta, theta, lam_)) >= -1e-9


def test_tau_limits_lasso_and_group_lasso():
    """Remark 3: tau=1 is the Lasso, tau=0 the Group-Lasso."""
    X, y, _, sizes = make_synthetic(n=30, p=80, n_groups=10, gamma1=2,
                                    gamma2=2, seed=3)
    prob_lasso = make_problem(X, y, sizes, tau=1.0)
    lmax = float(lambda_max(prob_lasso))
    # For tau=1: lambda_max = ||X^T y||_inf
    np.testing.assert_allclose(lmax, np.abs(X.T @ y).max(), rtol=1e-10)

    prob_gl = make_problem(X, y, sizes, tau=0.0)
    lmax_gl = float(lambda_max(prob_gl))
    # For tau=0: lambda_max = max_g ||X_g^T y|| / w_g
    corr = X.T @ y
    ng = sizes[0]
    per_group = np.linalg.norm(corr.reshape(-1, ng), axis=1) / np.sqrt(ng)
    np.testing.assert_allclose(lmax_gl, per_group.max(), rtol=1e-10)
