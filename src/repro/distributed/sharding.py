"""Sharding layout for the distributed SGL solver.

The design matrix X (n, G, ng) shards rows over "data" (and "pod") and
feature groups over "model":

    X     : P(dp, "model", None)
    y     : P(dp)                  (row shard)
    beta  : P("model", None)       (group shard, replicated over data)
    resid : P(dp)

Per FISTA step each device holds an (n_loc, G_loc, ng) block; the gradient
X^T resid needs only a psum over the data axis; the dual-norm max is a
collective max of one scalar per model shard; the residual update psums the
partial products over the model axis.  Screening is local per group shard.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def sgl_specs(multi_pod: bool = False):
    dp = ("pod", "data") if multi_pod else "data"
    return {
        "X": P(dp, "model", None),
        "y": P(dp),
        "beta": P("model", None),
        "w": P("model"),
        "Lg": P("model"),
        "feat_mask": P("model", None),
        "resid": P(dp),
    }
