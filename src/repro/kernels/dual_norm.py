"""Per-group epsilon-norm Lambda(x, alpha, R) Pallas kernel (bisection form).

The paper's Algorithm 1 is an early-exit sort — optimal on CPU, hostile to a
systolic/vector machine.  Lambda is the unique positive root of the monotone
function  g(nu) = sum_i S_{nu alpha}(x_i)^2 - (nu R)^2, bracketed by
[||x||_inf/(alpha+R), ||x||_inf/alpha]  (paper App., proof of Prop. 9), so a
fixed-count bisection is exact to machine precision in <= 64 iterations and
is pure element-wise VPU work with zero data-dependent control flow.

Each grid step owns a (block_g, ng) tile of group rows; lo/hi/alpha/R are
(block_g, 1) columns.  Outputs Lambda per group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import ArraySpec, LaunchSpec, block_specs, default_interpret, out_shapes


def dual_norm_launch_spec(G: int, ng: int, *, block_g: int = 256,
                          dtype="float64") -> LaunchSpec:
    """Auditable launch geometry of :func:`dual_norm_pallas`: 1-D grid over
    group tiles, every operand tiled the same way, no carried state."""
    col = ArraySpec((G, 1), (block_g, 1), lambda i: (i, 0), dtype)
    return LaunchSpec(
        name="dual_norm",
        grid=(G // block_g,),
        inputs=(
            ArraySpec((G, ng), (block_g, ng), lambda i: (i, 0), dtype),
            col,   # alpha
            col,   # R
        ),
        outputs=(col,),
        carried=((),),
        note="per-group epsilon-norm bisection",
    )


def _dual_norm_kernel(x_ref, alpha_ref, R_ref, out_ref, *, n_iter: int):
    ax = jnp.abs(x_ref[...])              # (bg, ng)
    alpha = alpha_ref[...]                # (bg, 1)
    R = R_ref[...]

    linf = jnp.max(ax, axis=1, keepdims=True)
    safe_a = jnp.where(alpha > 0, alpha, 1.0)
    safe_R = jnp.where(R > 0, R, 1.0)
    lo = linf / (safe_a + safe_R)
    hi = linf / safe_a

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        st = jnp.maximum(ax - mid * safe_a, 0.0)
        g = jnp.sum(st * st, axis=1, keepdims=True) - (mid * safe_R) ** 2
        lo = jnp.where(g > 0, mid, lo)
        hi = jnp.where(g > 0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    nu = 0.5 * (lo + hi)

    l2 = jnp.sqrt(jnp.sum(ax * ax, axis=1, keepdims=True))
    nu = jnp.where(R == 0, linf / safe_a, nu)
    nu = jnp.where(alpha == 0, l2 / safe_R, nu)
    nu = jnp.where(linf == 0, 0.0, nu)
    out_ref[...] = nu


def dual_norm_pallas(
    x: jax.Array,        # (G, ng) grouped correlations
    alpha: jax.Array,    # (G,)
    R: jax.Array,        # (G,)
    *,
    n_iter: int = 64,
    block_g: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    G, ng = x.shape
    assert G % block_g == 0, (G, block_g)
    spec = dual_norm_launch_spec(G, ng, block_g=block_g, dtype=x.dtype)
    out = pl.pallas_call(
        functools.partial(_dual_norm_kernel, n_iter=n_iter),
        grid=spec.grid,
        in_specs=block_specs(spec.inputs),
        out_specs=block_specs(spec.outputs)[0],
        out_shape=out_shapes(spec.outputs)[0],
        interpret=interpret,
    )(x, alpha[:, None], R[:, None])
    return out[:, 0]
