"""The paper's own workload: Sparse-Group Lasso at production scale.

Used by the SGL distributed dry-run (`launch/dryrun.py --arch sgl-paper`):
the distributed FISTA + GAP-screening step lowered on the production mesh,
with the climate problem scaled up (rows = samples over `data`, feature
groups over `model`).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SGLConfig:
    name: str = "sgl-paper"
    n_samples: int = 262_144         # rows (sharded over data axis)
    n_groups: int = 262_144          # feature groups (sharded over model axis)
    group_size: int = 8              # padded group size (paper: 7-10)
    tau: float = 0.4                 # paper's cross-validated tau*
    dtype: str = "float32"


CONFIG = SGLConfig()
