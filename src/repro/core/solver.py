"""ISTA-BC (block coordinate descent) with dynamic safe screening — Algorithm 2.

Faithful reproduction of the paper's solver:

* cyclic block coordinate descent over *active* groups, block Lipschitz
  steps  L_g = ||X_g||_2^2, two-level prox (soft-threshold then group
  soft-threshold),
* duality gap computed every ``f_ce`` passes (paper: f_ce = 10), giving the
  dual feasible point via residual rescaling (Eq. 15) and the GAP safe
  sphere (Thm 2), from which groups/features are screened (Thm 1),
* alternative spheres (static / dynamic / DST3 / none) for the paper's
  comparison experiments (Fig. 2c).

TPU/XLA adaptation (see DESIGN.md §3): screened variables are removed by
**gathering the surviving groups into a dense buffer padded to power-of-two
buckets**, so the inner jitted BCD epochs only touch active data; XLA
recompiles at most log2(G) times and the compile cache is shared across the
lambda path.  Screening certificates are permanent (safe), so active sets
shrink monotonically.  The full-matrix correlation X^T theta needed for the
gap/screening round is kept on the *full* problem, exactly as in the paper
(that cost is amortised by f_ce).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import screening as scr
from . import sgl
from .sgl import SGLProblem

__all__ = ["SolveResult", "solve", "bcd_epochs"]


class SolveResult(NamedTuple):
    beta: jax.Array            # (G, ng) grouped coefficients
    theta: jax.Array           # (n,) dual feasible point
    gap: jax.Array             # final duality gap
    n_epochs: int              # BCD passes performed
    group_active: np.ndarray   # (G,) final active mask
    feat_active: np.ndarray    # (G, ng) final active mask
    gap_history: list
    active_history: list       # [(epoch, n_groups_active, n_feats_active)]


# ----------------------------------------------------------------------------
# Inner jitted BCD epochs over a compacted active buffer
# ----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_epochs",), donate_argnums=(4, 5))
def bcd_epochs(
    Xt: jax.Array,         # (Gb, n, ng) compacted design (group-major)
    Lg: jax.Array,         # (Gb,)
    w: jax.Array,          # (Gb,)
    feat_mask: jax.Array,  # (Gb, ng) float mask (0 also encodes screened feats)
    beta: jax.Array,       # (Gb, ng)
    resid: jax.Array,      # (n,)
    tau: jax.Array,
    lam_: jax.Array,
    n_epochs: int,
):
    """Run ``n_epochs`` cyclic BCD passes, carrying the residual.

    Update for group g (paper Section 6):
        z      = beta_g + X_g^T resid / L_g            (gradient step)
        z      = S_{tau lam / L_g}(z)                  (feature prox)
        beta_g = S^gp_{(1-tau) w_g lam / L_g}(z)       (group prox)
        resid += X_g (beta_g_old - beta_g_new)
    Inactive (padded / screened) groups have feat_mask == 0 and Lg <= 0 and
    are skipped via masking.
    """
    live = (Lg > 0).astype(beta.dtype)                # (Gb,)
    safe_L = jnp.where(Lg > 0, Lg, 1.0)
    step = lam_ / safe_L                              # alpha_g = lam / L_g
    thr1 = tau * step                                 # (Gb,)
    thr2 = (1.0 - tau) * w * step                     # (Gb,)

    def group_update(resid, inputs):
        Xg, bg, L, t1, t2, m, lv = inputs
        grad_step = (Xg.T @ resid) / L                # (ng,)
        z = (bg + grad_step) * m
        z = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t1, 0.0)
        nrm = jnp.linalg.norm(z)
        z = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0) * z
        new_bg = jnp.where(lv > 0, z, bg)
        resid = resid + Xg @ (bg - new_bg)
        return resid, new_bg

    def epoch(carry, _):
        beta, resid = carry
        resid, beta = jax.lax.scan(
            group_update, resid, (Xt, beta, safe_L, thr1, thr2, feat_mask, live)
        )
        return (beta, resid), None

    (beta, resid), _ = jax.lax.scan(epoch, (beta, resid), None, length=n_epochs)
    return beta, resid


@functools.partial(jax.jit, static_argnames=())
def _full_corr(X: jax.Array, v: jax.Array) -> jax.Array:
    return jnp.einsum("ngk,n->gk", X, v)


@functools.partial(jax.jit, static_argnames=("rule",))
def _screen_round(problem: SGLProblem, beta: jax.Array, lam_: jax.Array,
                  lam_max: jax.Array, rule: str):
    """One fused gap + screening round (single XLA program).

    The eager version of this round cost ~50 small dispatches; fusing it is
    what makes screening overhead negligible per round (see EXPERIMENTS.md
    §Perf, solver iteration 1).  Returns (gap, theta, group_act, feat_act);
    for rules that do not screen dynamically the masks are all-true.
    """
    resid = problem.y - jnp.einsum("ngk,gk->n", problem.X, beta)
    corr = jnp.einsum("ngk,n->gk", problem.X, resid)
    dual_norm = sgl.sgl_dual_norm(corr, problem.tau, problem.w)
    scale = jnp.maximum(lam_, dual_norm)
    theta = resid / scale
    gap = sgl.duality_gap(problem, beta, theta, lam_)

    if rule == "gap":
        sphere = scr.Sphere(
            theta, jnp.sqrt(2.0 * jnp.maximum(gap, 0.0)) / lam_
        )
        res = scr.screen_with_corr(problem, sphere, corr / scale)
    elif rule == "dynamic":
        res = scr.screen(problem, scr.dynamic_sphere(problem, theta, lam_))
    elif rule == "dst3":
        res = scr.screen(
            problem, scr.dst3_sphere(problem, theta, lam_, lam_max)
        )
    else:  # "none" / "static" — no dynamic screening
        res = scr.ScreenResult(
            jnp.ones((problem.G,), bool),
            jnp.asarray(problem.feat_mask),
            scr.Sphere(theta, jnp.inf),
        )
    return gap, theta, res.group_active, res.feat_active


def _bucket(n: int, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("f_ce", "k_rounds"))
def _inner_rounds(Xt, Lg, w, y, beta, feat_active, take, gmask, tau, lam_,
                  tol, f_ce, k_rounds):
    """Up to ``k_rounds`` blocks of ``f_ce`` BCD epochs in ONE jitted call.

    Between blocks the *reduced-problem* duality gap (dual norm over the
    compacted buffer only) is checked for early exit.  This gap is exact
    for the reduced problem but may under-estimate the full certified gap,
    so it is used ONLY as a work heuristic — the caller always recomputes
    the full-problem gap (paper Eq. 15/Thm 2) before stopping or screening.
    Amortises the full X^T rho correlation and the host sync over
    ~k_rounds x f_ce epochs instead of f_ce (see EXPERIMENTS.md §Perf).

    ``take`` may contain padded slots aliasing group 0; the scatter uses a
    masked *delta* with .add so duplicate indices contribute zero and the
    real group-0 row is preserved.
    """
    dtype = beta.dtype
    fmask = (jnp.take(feat_active, take, axis=0).astype(dtype)
             * gmask[:, None])
    bsub0 = jnp.take(beta, take, axis=0) * fmask
    resid0 = y - jnp.einsum("gnk,gk->n", Xt, bsub0)
    y2half = 0.5 * jnp.sum(y * y)

    def reduced_gap(bsub, resid):
        corr = jnp.einsum("gnk,n->gk", Xt, resid) * fmask
        dn = sgl.sgl_dual_norm(corr, tau, w)
        theta = resid / jnp.maximum(lam_, dn)
        primal = (0.5 * jnp.sum(resid * resid)
                  + lam_ * sgl.sgl_norm(bsub, tau, w))
        diff = theta - y / lam_
        dual = y2half - 0.5 * lam_ * lam_ * jnp.sum(diff * diff)
        return primal - dual

    def cond(c):
        bsub, resid, k, gap = c
        return (k < k_rounds) & (gap > tol)

    def body(c):
        bsub, resid, k, gap = c
        bsub, resid = bcd_epochs(
            Xt, Lg * gmask, w, fmask, bsub, resid, tau, lam_, f_ce
        )
        return bsub, resid, k + 1, reduced_gap(bsub, resid)

    bsub, resid, k, gap = jax.lax.while_loop(
        cond, body, (bsub0, resid0, jnp.zeros((), jnp.int32),
                     jnp.asarray(jnp.inf, dtype))
    )
    delta = (bsub - bsub0) * fmask
    return beta.at[take].add(delta), k, gap


def _gather_static(problem: SGLProblem, group_active):
    """Gather the active groups' design slices into a power-of-two padded
    buffer.  Depends only on the active-group set, so ``solve`` caches the
    result between rounds (the (n x p_active) copy of X is the expensive
    part); per-round masks are applied by the caller.

    Masked/padded groups are *not* zeroed in Xt: ``bcd_epochs`` masks their
    updates (feat_mask, live) so their columns never contribute.
    """
    idx = np.nonzero(np.asarray(group_active))[0]
    Gb = _bucket(max(len(idx), 1))
    pad = Gb - len(idx)
    take = np.concatenate([idx, np.zeros(pad, np.int64)])
    gmask = np.concatenate([np.ones(len(idx)), np.zeros(pad)])

    take_j = jnp.asarray(take)
    Xt = jnp.transpose(jnp.take(problem.X, take_j, axis=1), (1, 0, 2))
    Lg = jnp.take(problem.Lg, take_j)
    w = jnp.take(problem.w, take_j)
    gmask_j = jnp.asarray(gmask, problem.X.dtype)
    return idx, take_j, Xt, Lg, w, gmask_j


# ----------------------------------------------------------------------------
# Outer driver
# ----------------------------------------------------------------------------

def solve(
    problem: SGLProblem,
    lam_: float,
    beta0: Optional[jax.Array] = None,
    tol: float = 1e-8,
    max_epochs: int = 10_000,
    f_ce: int = 10,
    rule: str = "gap",
    lam_max: Optional[float] = None,
    compact: bool = True,
    inner_rounds: int = 5,
) -> SolveResult:
    """Solve one SGL instance at regularisation ``lam_``.

    rule in {"gap", "static", "dynamic", "dst3", "none"}.
    ``tol`` is the duality-gap stopping threshold (paper uses 1e-8).
    ``inner_rounds``: how many f_ce-epoch blocks run inside one jitted
    call between certified (full-problem) gap/screening rounds; the inner
    early-exit uses the reduced-problem gap, so safety is unaffected.
    """
    G, ng = problem.G, problem.ng
    dtype = problem.X.dtype
    beta = jnp.zeros((G, ng), dtype) if beta0 is None else jnp.asarray(beta0, dtype)
    lam_j = jnp.asarray(lam_, dtype)

    if lam_max is None and rule in ("static", "dst3"):
        lam_max = float(sgl.lambda_max(problem))

    group_active = np.array(jnp.any(problem.feat_mask, axis=-1))
    feat_active = np.array(problem.feat_mask)

    # Static rule screens once, up front.
    if rule == "static":
        sphere = scr.static_sphere(problem, lam_j, jnp.asarray(lam_max, dtype))
        res = scr.screen(problem, sphere)
        group_active &= np.asarray(res.group_active)
        feat_active &= np.asarray(res.feat_active)
        beta = beta * jnp.asarray(feat_active, dtype)

    gap_history: list = []
    active_history: list = []
    epochs_done = 0
    theta = problem.y / jnp.maximum(lam_j, sgl.lambda_max(problem))
    gap = jnp.inf

    # Gather cache: the (n x p_active) copy of X is only re-made when the
    # active-group set actually changes (it shrinks monotonically, so this
    # amortises to a handful of gathers per lambda).
    gather_key = None
    gather_val = None

    while epochs_done < max_epochs:
        # ---- fused gap + screening round (one XLA program; paper does this
        # every f_ce passes on the full problem) ----
        lam_max_j = jnp.asarray(lam_max if lam_max is not None else 0.0, dtype)
        gap, theta, g_act, f_act = _screen_round(
            problem, beta, lam_j, lam_max_j, rule
        )
        gap_history.append((epochs_done, float(gap)))

        if float(gap) <= tol:
            break

        if rule in ("gap", "dynamic", "dst3"):
            group_active &= np.asarray(g_act)
            feat_active &= np.asarray(f_act)
            feat_active &= group_active[:, None]
            beta = beta * jnp.asarray(feat_active, dtype)

        active_history.append(
            (epochs_done, int(group_active.sum()), int(feat_active.sum()))
        )

        # ---- up to inner_rounds x f_ce BCD epochs in one jitted call ----
        if compact:
            key = group_active.tobytes()
            if key != gather_key:
                gather_val = _gather_static(problem, group_active)
                gather_key = key
            idx, take, Xt, Lg, w, gmask = gather_val
            beta, k_done, _ = _inner_rounds(
                Xt, Lg, w, problem.y, beta, jnp.asarray(feat_active),
                take, gmask, problem.tau, lam_j, jnp.asarray(tol, dtype),
                f_ce, inner_rounds
            )
            epochs_done += f_ce * (int(k_done) - 1)  # +f_ce added below
        else:
            Xt = jnp.transpose(problem.X, (1, 0, 2))
            fmask = jnp.asarray(feat_active, dtype)
            Lg = problem.Lg * jnp.asarray(group_active, dtype)
            resid = problem.y - jnp.einsum("gnk,gk->n", Xt, beta)
            beta, resid = bcd_epochs(
                Xt, Lg, problem.w, fmask, beta, resid, problem.tau, lam_j, f_ce
            )
        epochs_done += f_ce

    return SolveResult(
        beta=beta,
        theta=theta,
        gap=gap,
        n_epochs=epochs_done,
        group_active=group_active,
        feat_active=feat_active,
        gap_history=gap_history,
        active_history=active_history,
    )
