"""Loss registry: name -> singleton, mirroring :mod:`repro.rules.registry`.

``resolve_loss`` keeps string configs (``SolverConfig(loss="logistic")``)
working and fails fast on unknown names with the registered list — the
same contract the rule registry gives ``SolverConfig.rule``.
"""
from __future__ import annotations

from typing import Dict, List, Union

from .base import Loss

__all__ = ["register_loss", "available_losses", "get_loss", "resolve_loss"]

_REGISTRY: Dict[str, Loss] = {}


def register_loss(loss: Loss, *, overwrite: bool = False) -> Loss:
    """Register a loss singleton under its ``name``."""
    if not isinstance(loss, Loss):
        raise TypeError(
            f"register_loss expects a Loss instance, got {type(loss)!r}"
        )
    if loss.name in _REGISTRY and not overwrite:
        raise ValueError(
            f"loss {loss.name!r} is already registered "
            f"(pass overwrite=True to replace)"
        )
    _REGISTRY[loss.name] = loss
    return loss


def available_losses() -> List[str]:
    """Registered loss names, sorted."""
    return sorted(_REGISTRY)


def get_loss(name: str) -> Loss:
    """The registered singleton for ``name`` (ValueError on unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown loss {name!r}; registered losses: {available_losses()}"
        ) from None


def resolve_loss(loss: Union[str, Loss]) -> Loss:
    """Accept a loss object or a legacy string name."""
    if isinstance(loss, Loss):
        return loss
    if isinstance(loss, str):
        return get_loss(loss)
    raise TypeError(
        f"loss must be a Loss instance or a registered name, "
        f"got {type(loss)!r}"
    )
