"""Training step: next-token cross entropy + AdamW (+ optional SGL
structured-sparsity regularisation with safe screening — the paper's
technique as a training feature, see train/sgl_regularizer.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import optimizer as opt
from . import sgl_regularizer as sglreg


def softmax_xent(logits, labels, ignore_below: int = 0):
    """logits (B, S, V); labels (B, S) int32 (< ignore_below => masked)."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    ll = jnp.take_along_axis(
        logits32, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    mask = (labels >= ignore_below).astype(jnp.float32)
    loss = jnp.sum((logz - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss


def loss_fn(api, params, batch, moe_aux_weight: float = 0.01,
            q_chunk: int = 512):
    """batch: {"tokens": (B,S) int32, optional "embeds": (B,F,D)}.

    Next-token loss over token positions only (frontend embeddings, if any,
    occupy the first F positions of the sequence and carry no labels).
    """
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    logits, aux = api.forward(params, tokens, embeds, q_chunk=q_chunk)
    # Decoder-prepended frontends (vlm/audio decoder-only) shift the logit
    # positions; enc-dec feeds embeds to the encoder, so no offset there.
    F = 0
    if embeds is not None and api.cfg.family != "encdec":
        F = embeds.shape[1]
    token_logits = logits[:, F:, :]
    loss = softmax_xent(token_logits[:, :-1], tokens[:, 1:])
    return loss + moe_aux_weight * aux, (loss, aux)


def make_train_step(
    api,
    lr: float = 3e-4,
    weight_decay: float = 0.1,
    moment_dtype=jnp.float32,
    sgl_cfg: Optional[sglreg.SGLRegConfig] = None,
    q_chunk: int = 512,
):
    """Returns (init_state, train_step).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    If ``sgl_cfg`` is given, the SGL two-level prox runs after the AdamW
    update on the FFN neuron groups (training-time structured sparsity with
    the paper's machinery).
    """

    def init_state(params):
        return opt.init(params, moment_dtype)

    def train_step(params, opt_state, batch):
        (total, (loss, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(api, p, batch, q_chunk=q_chunk), has_aux=True
        )(params)
        params, opt_state = opt.update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay
        )
        if sgl_cfg is not None:
            params = sglreg.apply_prox(params, sgl_cfg, lr)
        gnorm = jnp.sqrt(
            sum(jnp.vdot(g.astype(jnp.float32), g.astype(jnp.float32))
                for g in jax.tree.leaves(grads))
        )
        metrics = {"loss": loss, "moe_aux": aux, "grad_norm": gnorm,
                   "total": total}
        return params, opt_state, metrics

    return init_state, train_step
