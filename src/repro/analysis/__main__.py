"""CLI for the static-analysis gate.

    PYTHONPATH=src python -m repro.analysis --check
    PYTHONPATH=src python -m repro.analysis --check \
        --report artifacts/analysis.json --md artifacts/analysis.md

Exit code 1 iff any *error*-severity finding was emitted (warnings and
info findings report but do not fail the gate).  ``--report`` writes the
``repro.analysis/v1`` JSON payload; ``--md`` the markdown rendering
(also re-renderable later from the JSON via
:func:`repro.launch.report.render_analysis_markdown`).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr lints, Pallas launch auditor, certificate "
                    "dataflow lints",
    )
    ap.add_argument("--check", action="store_true",
                    help="run the gate (the default action; the flag "
                         "exists so CI invocations read as intent)")
    ap.add_argument("--passes", nargs="+", default=None,
                    choices=("cert", "pallas", "jaxpr"),
                    help="subset of passes to run (default: all)")
    ap.add_argument("--no-retrace", action="store_true",
                    help="skip the execute-twice retrace harness (fast "
                         "mode; the CI gate runs it)")
    ap.add_argument("--report", metavar="OUT.json", default=None,
                    help="write the findings payload as JSON")
    ap.add_argument("--md", metavar="OUT.md", default=None,
                    help="write the markdown rendering")
    args = ap.parse_args(argv)

    from .main import run_checks

    payload = run_checks(args.passes, check_retrace=not args.no_retrace)

    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.md:
        from repro.launch.report import render_analysis_markdown

        os.makedirs(os.path.dirname(args.md) or ".", exist_ok=True)
        with open(args.md, "w") as f:
            f.write(render_analysis_markdown(payload))

    s = payload["summary"]
    print(f"repro.analysis: {s['errors']} errors, {s['warnings']} "
          f"warnings, {s['infos']} info "
          f"({', '.join(payload['passes']) or 'no passes'})")
    for f in payload["findings"]:
        if f["severity"] != "info":
            loc = f" [{f['location']}]" if f["location"] else ""
            print(f"  {f['code']} ({f['severity']}){loc}: {f['message']}")
    return 0 if payload["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
