"""Sparse-Group Lasso + Elastic Net (paper Appendix D).

    min_beta 1/2 ||y - X beta||^2 + lam1 * Omega_{tau,w}(beta)
             + lam2/2 ||beta||^2

is exactly the plain SGL problem on the augmented design

    X~ = [X; sqrt(lam2) I_p],  y~ = [y; 0],

so the whole GAP-safe machinery (screening, epsilon-norm dual evaluation,
ISTA-BC) applies unchanged — including the safety certificates, which now
hold for the elastic-net objective.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .sgl import SGLProblem, make_problem

__all__ = ["make_elastic_problem", "elastic_objective"]


def make_elastic_problem(
    X_flat,
    y,
    group_sizes,
    tau: float,
    lam2: float,
    w=None,
) -> SGLProblem:
    """SGL+ridge as an augmented plain-SGL problem (Appendix D, Eq. 38)."""
    X_flat = np.asarray(X_flat)
    y = np.asarray(y)
    n, p = X_flat.shape
    X_aug = np.concatenate(
        [X_flat, np.sqrt(lam2) * np.eye(p, dtype=X_flat.dtype)], axis=0
    )
    y_aug = np.concatenate([y, np.zeros(p, y.dtype)])
    return make_problem(X_aug, y_aug, group_sizes, tau=tau, w=w)


def elastic_objective(X_flat, y, beta_flat, tau, w, lam1, lam2, group_sizes):
    """Direct evaluation of the Appendix-D objective (for tests)."""
    X_flat = jnp.asarray(X_flat)
    beta_flat = jnp.asarray(beta_flat)
    resid = jnp.asarray(y) - X_flat @ beta_flat
    fit = 0.5 * jnp.sum(resid * resid)
    l1 = jnp.sum(jnp.abs(beta_flat))
    l2g = 0.0
    off = 0
    for g, s in enumerate(group_sizes):
        l2g = l2g + w[g] * jnp.linalg.norm(beta_flat[off:off + s])
        off += s
    ridge = 0.5 * lam2 * jnp.sum(beta_flat * beta_flat)
    return fit + lam1 * (tau * l1 + (1.0 - tau) * l2g) + ridge
