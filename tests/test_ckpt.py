"""Checkpoint/restore: atomicity, keep-k GC, elastic restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ck


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.standard_normal(8), jnp.float32),
                   "step": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ck.save(str(tmp_path), 10, tree)
    got = ck.restore(str(tmp_path), tree, 10)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_gc(tmp_path):
    tree = _tree()
    for s in (1, 5, 3, 9):
        ck.save(str(tmp_path), s, tree)
    assert ck.latest_step(str(tmp_path)) == 9
    ck.gc_keep_k(str(tmp_path), keep=2)
    steps = sorted(
        int(d.split("_")[-1]) for d in os.listdir(tmp_path)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    assert steps == [5, 9]


def test_restore_latest_none_when_empty(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=1)
    step, tree = mgr.restore_latest(_tree())
    assert step is None and tree is None


def test_manager_maybe_save_every(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=3, keep=10)
    tree = _tree()
    saved = [s for s in range(1, 10) if mgr.maybe_save(s, tree)]
    assert saved == [3, 6, 9]


def test_elastic_restore_is_device_layout_independent(tmp_path):
    """Restore must not depend on the device mesh the save ran on: values
    are read back into whatever sharding the new run requests."""
    tree = _tree()
    ck.save(str(tmp_path), 1, tree)
    # restore into a differently-replicated target (single device here, but
    # the API path is the same the multi-pod restart takes)
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    got = ck.restore(str(tmp_path), target, 1)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_partial_write_is_not_visible(tmp_path):
    """A crashed (torn) checkpoint directory must be ignored."""
    tree = _tree()
    ck.save(str(tmp_path), 2, tree)
    os.makedirs(tmp_path / "step_5.tmp")  # simulated torn write
    assert ck.latest_step(str(tmp_path)) == 2


def test_latest_returns_step_and_manifest_with_extra(tmp_path):
    ck.save(str(tmp_path), 3, _tree(),
            extra_manifest={"cursor": 3, "request": "abc"})
    ck.save(str(tmp_path), 7, _tree(),
            extra_manifest={"cursor": 7, "request": "abc"})
    step, manifest = ck.latest(str(tmp_path))
    assert step == 7
    assert manifest["extra"] == {"cursor": 7, "request": "abc"}
    assert "w" in manifest["leaves"]


def test_latest_none_when_empty(tmp_path):
    assert ck.latest(str(tmp_path)) is None
    assert ck.latest(str(tmp_path / "missing")) is None


def test_latest_falls_back_without_pointer(tmp_path):
    """Deleting latest.json (or a stale pointer after GC) must not break
    resume: latest() falls back to scanning the step directories."""
    ck.save(str(tmp_path), 4, _tree(), extra_manifest={"cursor": 4})
    os.remove(tmp_path / "latest.json")
    step, manifest = ck.latest(str(tmp_path))
    assert step == 4 and manifest["extra"]["cursor"] == 4
    # stale pointer: points at a GC'd step dir -> fall back to the scan
    ck.save(str(tmp_path), 9, _tree(), extra_manifest={"cursor": 9})
    import shutil
    shutil.rmtree(tmp_path / "step_000000000009")
    step, manifest = ck.latest(str(tmp_path))
    assert step == 4 and manifest["extra"]["cursor"] == 4
