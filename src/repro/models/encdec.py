"""Encoder-decoder backbone (seamless-m4t style).

Encoder: bidirectional self-attention over *precomputed frame embeddings*
(the audio frontend is a stub per the assignment).  Decoder: causal
self-attention + cross-attention to encoder outputs.  Both stacks are
scan-stacked.

Serving: ``prefill`` runs the encoder + target prompt, building (a) the
decoder self-attention KV cache and (b) the per-layer cross-attention K/V
(computed once from encoder output); ``decode_step`` is one target token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from .transformer import _stack_spec


def _init_enc_layer(cfg, key, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "attn": L.init_attn(ka, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(km, cfg, dtype),
    }


def _init_dec_layer(cfg, key, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "attn": L.init_attn(ka, cfg, dtype),
        "ln_x": L.init_norm(cfg, dtype),
        "xattn": L.init_attn(kc, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(km, cfg, dtype),
    }


def init_params(cfg, key, dtype=jnp.bfloat16):
    ke, k1, k2, ko = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.n_enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype)
        * cfg.d_model ** -0.5,
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, k, dtype))(enc_keys),
        "enc_ln_f": L.init_norm(cfg, dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, k, dtype))(dec_keys),
        "ln_f": L.init_norm(cfg, dtype),
        "unembed": jax.random.normal(ko, (cfg.d_model, cfg.vocab), dtype)
        * cfg.d_model ** -0.5,
    }


def param_specs(cfg, model_axis: int = 16):
    enc = {"ln1": P(None), "attn": L.specs_attn(cfg), "ln2": P(None),
           "mlp": L.specs_mlp(cfg)}
    dec = {"ln1": P(None), "attn": L.specs_attn(cfg), "ln_x": P(None),
           "xattn": L.specs_attn(cfg), "ln2": P(None), "mlp": L.specs_mlp(cfg)}
    return {
        "embed": P("model", "data"),
        "enc_layers": _stack_spec(enc),
        "enc_ln_f": P(None),
        "dec_layers": _stack_spec(dec),
        "ln_f": P(None),
        "unembed": P("data", "model"),
    }


def encode(cfg, params, frames, *, q_chunk=512, remat=True):
    """frames: (B, F, D) stub frontend embeddings."""
    B, F, D = frames.shape
    h = frames
    positions = jnp.broadcast_to(jnp.arange(F)[None, :], (B, F))
    qc = min(q_chunk, F)

    def body(h, lp):
        a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
        o = L.full_attention(q, k, v, q_chunk=qc)
        h = h + o.reshape(B, F, -1) @ lp["attn"]["wo"]
        b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + L.mlp(lp["mlp"], b), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return L.rms_norm(h, params["enc_ln_f"], cfg.norm_eps)


def _cross_attend(cfg, lp, h, enc_kv, positions_q):
    """Cross attention; enc_kv = (k, v) each (B, F, K, hd)."""
    B, S, D = h.shape
    a = L.rms_norm(h, lp["ln_x"], cfg.norm_eps)
    q = (a @ lp["xattn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k, v = enc_kv
    o = L.full_attention(q, k, v, q_chunk=min(512, S))
    return h + o.reshape(B, S, -1) @ lp["xattn"]["wo"]


def _enc_kv(cfg, lp, enc_out):
    B, F, D = enc_out.shape
    k = (enc_out @ lp["xattn"]["wk"]).reshape(B, F, cfg.n_kv, cfg.hd)
    v = (enc_out @ lp["xattn"]["wv"]).reshape(B, F, cfg.n_kv, cfg.hd)
    return k, v


def forward(cfg, params, tokens, embeds=None, *, q_chunk=512, remat=True, **_):
    """Training: frames (embeds) -> encoder; tokens -> decoder; returns logits."""
    assert embeds is not None, "enc-dec needs frontend embeddings"
    enc_out = encode(cfg, params, embeds, q_chunk=q_chunk, remat=remat)
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    qc = min(q_chunk, S)

    def body(h, lp):
        a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
        o = L.causal_attention(q, k, v, q_chunk=qc)
        h = h + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        h = _cross_attend(cfg, lp, h, _enc_kv(cfg, lp, enc_out), positions)
        b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + L.mlp(lp["mlp"], b), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    return h @ params["unembed"], jnp.zeros((), jnp.float32)


class EncDecCache(NamedTuple):
    k: jax.Array        # (L, B, S_max, K, hd) decoder self-attn
    v: jax.Array
    xk: jax.Array       # (L, B, F, K, hd) cross K/V (static after prefill)
    xv: jax.Array
    pos: jax.Array


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    Ld = cfg.n_layers
    return EncDecCache(
        k=jnp.zeros((Ld, batch, max_seq, cfg.n_kv, cfg.hd), dtype),
        v=jnp.zeros((Ld, batch, max_seq, cfg.n_kv, cfg.hd), dtype),
        xk=jnp.zeros((Ld, batch, cfg.frontend_tokens, cfg.n_kv, cfg.hd), dtype),
        xv=jnp.zeros((Ld, batch, cfg.frontend_tokens, cfg.n_kv, cfg.hd), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def cache_specs(cfg, model_axis: int = 16):
    s = P(None, "data", None, "model", None) if cfg.n_kv % model_axis == 0 \
        else P(None, "data", None, None, None)
    return EncDecCache(k=s, v=s, xk=s, xv=s, pos=P())


def prefill(cfg, params, tokens, embeds=None, *, q_chunk=512,
            cache_len=None, dtype=jnp.bfloat16, **_):
    assert embeds is not None
    enc_out = encode(cfg, params, embeds, q_chunk=q_chunk, remat=False)
    B, S = tokens.shape
    C = cache_len or S
    h = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    qc = min(q_chunk, S)

    def body(h, lp):
        a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
        o = L.causal_attention(q, k, v, q_chunk=qc)
        h = h + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        xk, xv = _enc_kv(cfg, lp, enc_out)
        h = _cross_attend(cfg, lp, h, (xk, xv), positions)
        b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        kc = jnp.zeros((B, C, cfg.n_kv, cfg.hd), dtype).at[:, :S].set(
            k.astype(dtype))
        vc = jnp.zeros((B, C, cfg.n_kv, cfg.hd), dtype).at[:, :S].set(
            v.astype(dtype))
        return h + L.mlp(lp["mlp"], b), (kc, vc, xk.astype(dtype),
                                         xv.astype(dtype))

    h, (kcs, vcs, xks, xvs) = jax.lax.scan(body, h, params["dec_layers"])
    h = L.rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = (h @ params["unembed"])[:, 0]
    return logits, EncDecCache(k=kcs, v=vcs, xk=xks, xv=xvs,
                               pos=jnp.asarray(S, jnp.int32))


def decode_step(cfg, params, cache: EncDecCache, token, pos):
    B = token.shape[0]
    h = jnp.take(params["embed"], token[:, None], axis=0)
    positions = jnp.broadcast_to(pos, (B, 1))
    S_cache = cache.k.shape[2]
    scale = 1.0 / float(cfg.hd) ** 0.5

    def body(h, lp_and_cache):
        lp, kc, vc, xk, xv = lp_and_cache
        a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        valid = jnp.arange(S_cache)[None, :] <= pos
        qg = L._split_gqa(q, cfg.n_kv)
        o = L._attend_block(qg, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2),
                            valid[None, None, None], scale)
        h = h + L._merge_gqa(o).reshape(B, 1, -1) @ lp["attn"]["wo"]
        # cross attention against the static encoder K/V
        ax = L.rms_norm(h, lp["ln_x"], cfg.norm_eps)
        qx = (ax @ lp["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
        qxg = L._split_gqa(qx, cfg.n_kv)
        ox = L._attend_block(qxg, jnp.swapaxes(xk, 1, 2),
                             jnp.swapaxes(xv, 1, 2),
                             jnp.ones((1, xk.shape[1]), bool), scale)
        h = h + L._merge_gqa(ox).reshape(B, 1, -1) @ lp["xattn"]["wo"]
        b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + L.mlp(lp["mlp"], b), (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(
        body, h, (params["dec_layers"], cache.k, cache.v, cache.xk, cache.xv)
    )
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = (h @ params["unembed"])[:, 0]
    return logits, EncDecCache(k=kcs, v=vcs, xk=cache.xk, xv=cache.xv,
                               pos=pos + 1)
