"""Structured tracing: nested spans, ring buffer, JSONL, percentiles.

Span taxonomy (declared in :data:`SPAN_SITES`, audited by OB002)::

    serve.request            one coalesced group through _serve_group
      serve.coalesce         queue drain + value-digest grouping window
      serve.store            certificate-store lookup / publish
      serve.cache            session/compile cache lookup
      serve.warm_eval        measured warm-hint admission
      path                   one SGLSession.solve_path
        lambda               one path point
          round              one certified GAP round (full or compact)
          epoch_block        one BCD epoch-block dispatch
            kernel_launch    one fused Pallas launch (host-side dispatch)

Contract
--------
* **Off by default, zero-overhead when off.**  ``span(name)`` with tracing
  disabled is one module-global read returning the preallocated
  :data:`NOOP` singleton — no ``Span`` allocation, no lock.  The hot solver
  loops rely on this; ``tests/test_obs.py`` asserts the allocation count
  stays flat across a full solve.
* **Counters exact, recording sampled.**  While enabled, every ``span()``
  call bumps the per-site fire counter exactly; only every
  ``sample_every``-th *root* span (and its whole subtree) is recorded into
  the bounded ring buffer.  Percentiles therefore come from a sample;
  counts never do.
* **Injectable clock.**  ``configure(clock=...)`` takes any monotonic
  ``() -> float``; tests drive a fake clock to get deterministic
  histograms.
* Span timings taken around jitted calls measure the *host-side dispatch
  window* (JAX is asynchronous); measured kernel wall-clock truth comes
  from :mod:`repro.obs.timing`'s ``block_until_ready`` harness.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

#: Declared span sites: name -> where it fires.  ``repro.obs --check``
#: (OB002) runs a smoke path and fails if any of these never fired.
SPAN_SITES: Dict[str, str] = {
    "serve.request": "serve/server.py:_serve_group — one coalesced group",
    "serve.coalesce": "serve/server.py:_worker_loop — drain+group window",
    "serve.store": "serve/server.py — certificate store lookup/publish",
    "serve.cache": "serve/server.py — session/compile cache lookup",
    "serve.warm_eval": "serve/server.py — measured warm-hint admission",
    "path": "core/session.py:solve_path — one lambda path",
    "lambda": "core/session.py:solve_path — one path point",
    "round": "core/session.py — one certified GAP round (full or compact)",
    "epoch_block": "core/session.py:solve — one BCD epoch-block dispatch",
    "kernel_launch": "core/session.py — fused Pallas launch dispatch",
}


class Span:
    """A recorded span.  Only ever allocated while tracing is enabled."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t_start",
                 "t_end", "attrs", "sampled", "_tracer")

    _allocated = 0  # class-level tally; GIL-atomic += is fine for the assert

    def __init__(self, tracer: "Tracer", name: str):
        Span._allocated += 1
        self._tracer = tracer
        self.name = name
        self.trace_id = -1
        self.span_id = -1
        self.parent_id: Optional[int] = None
        self.t_start = 0.0
        self.t_end = 0.0
        self.attrs: Optional[dict] = None
        self.sampled = False

    @classmethod
    def allocated(cls) -> int:
        return cls._allocated

    def set(self, key: str, value) -> "Span":
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = value
        return self

    def __enter__(self) -> "Span":
        self._tracer._enter(self)
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._exit(self)
        return False

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start


class _NoopSpan:
    """Preallocated do-nothing span returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, key: str, value) -> "_NoopSpan":
        return self


NOOP = _NoopSpan()


class Tracer:
    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 buffer: int = 4096, sample_every: int = 1):
        self._clock = clock
        self._buffer: deque = deque(maxlen=buffer)
        self._sample_every = max(1, int(sample_every))
        self._enabled = False
        self._lock = threading.Lock()
        self._local = threading.local()
        self._counts: Dict[str, int] = {}
        self._root_seq = 0
        self._span_seq = 0
        self._open = 0

    # -- lifecycle -------------------------------------------------------
    def configure(self, enabled: Optional[bool] = None,
                  sample_every: Optional[int] = None,
                  buffer: Optional[int] = None,
                  clock: Optional[Callable[[], float]] = None) -> None:
        with self._lock:
            if enabled is not None:
                self._enabled = bool(enabled)
            if sample_every is not None:
                self._sample_every = max(1, int(sample_every))
            if buffer is not None:
                self._buffer = deque(self._buffer, maxlen=buffer)
            if clock is not None:
                self._clock = clock

    @property
    def enabled(self) -> bool:
        return self._enabled

    def reset(self) -> None:
        with self._lock:
            self._buffer.clear()
            self._counts = {}
            self._root_seq = 0
            self._span_seq = 0

    # -- span machinery --------------------------------------------------
    def span(self, name: str):
        """The one hot-path entry point.  Disabled → NOOP singleton."""
        if not self._enabled:
            return NOOP
        return Span(self, name)

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _enter(self, sp: Span) -> None:
        st = self._stack()
        with self._lock:
            self._counts[sp.name] = self._counts.get(sp.name, 0) + 1
            self._span_seq += 1
            sp.span_id = self._span_seq
            self._open += 1
            if st:
                parent = st[-1]
                sp.parent_id = parent.span_id
                sp.trace_id = parent.trace_id
                sp.sampled = parent.sampled
            else:
                self._root_seq += 1
                sp.trace_id = self._root_seq
                sp.sampled = (self._root_seq - 1) % self._sample_every == 0
        st.append(sp)
        sp.t_start = self._clock()

    def _exit(self, sp: Span) -> None:
        sp.t_end = self._clock()
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        elif sp in st:  # mismatched exit order — recover rather than leak
            st.remove(sp)
        with self._lock:
            self._open -= 1
            if sp.sampled:
                self._buffer.append({
                    "name": sp.name, "trace": sp.trace_id,
                    "span": sp.span_id, "parent": sp.parent_id,
                    "t_start": sp.t_start, "t_end": sp.t_end,
                    "dur_s": sp.t_end - sp.t_start,
                    "attrs": sp.attrs,
                })

    # -- introspection / export ------------------------------------------
    def counts(self) -> Dict[str, int]:
        """Exact per-site fire counts since the last reset()."""
        with self._lock:
            return dict(self._counts)

    def open_spans(self) -> int:
        return self._open

    def records(self, name: Optional[str] = None) -> List[dict]:
        with self._lock:
            recs = list(self._buffer)
        if name is not None:
            recs = [r for r in recs if r["name"] == name]
        return recs

    def durations(self, name: Optional[str] = None) -> List[float]:
        return [r["dur_s"] for r in self.records(name)]

    def aggregate(self) -> Dict[str, List[float]]:
        out: Dict[str, List[float]] = {}
        for r in self.records():
            out.setdefault(r["name"], []).append(r["dur_s"])
        return out

    def percentiles(self, name: str,
                    qs: Tuple[float, ...] = (50.0, 99.0)) -> dict:
        """Sampled-duration percentiles for one span site (seconds),
        via the single shared percentile implementation."""
        from .export import percentile
        durs = self.durations(name)
        out = {f"p{int(q) if float(q).is_integer() else q}":
               percentile(durs, q) for q in qs}
        out["n"] = len(durs)
        out["mean"] = (sum(durs) / len(durs)) if durs else None
        return out

    def stage_summary(self) -> Dict[str, dict]:
        """Percentile summary for every span site seen in the buffer —
        the per-stage latency breakdown bench_serve embeds in BENCH."""
        return {name: self.percentiles(name)
                for name in sorted(self.aggregate())}

    def export_jsonl(self, path: str) -> int:
        recs = self.records()
        with open(path, "w") as fh:
            for r in recs:
                fh.write(json.dumps(r) + "\n")
        return len(recs)


#: Process-global tracer; module-level :func:`span` is the fast path.
TRACER = Tracer()


def span(name: str):
    """Open a span on the global tracer.  With tracing disabled this is a
    single global read returning the :data:`NOOP` singleton — no
    allocation, no lock."""
    t = TRACER
    if not t._enabled:
        return NOOP
    return Span(t, name)


def configure(enabled: Optional[bool] = None,
              sample_every: Optional[int] = None,
              buffer: Optional[int] = None,
              clock: Optional[Callable[[], float]] = None) -> None:
    TRACER.configure(enabled=enabled, sample_every=sample_every,
                     buffer=buffer, clock=clock)


def enabled() -> bool:
    return TRACER._enabled
