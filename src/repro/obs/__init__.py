"""repro.obs — unified observability for the SGL reproduction.

Third leg of the tooling triad next to :mod:`repro.analysis` (static
correctness) and :mod:`repro.faults` (robustness): *measurement*.

Pieces
------
:mod:`repro.obs.metrics`
    Typed metrics registry — ``Counter`` / ``Gauge`` / ``Histogram`` with
    fixed declared names and help text, thread-safe, plus snapshot / diff /
    reset scoping that subsumes the old ``kernels.ops.audit_scope()`` idiom.
    The scattered ad-hoc counters (kernels.ops transpose/retrace/demotion
    globals, ``SGLServer.counters``, ``SessionCache`` hit/miss counts, the
    ckpt quarantine tally) are all backed by it; the legacy surfaces remain
    as back-compat shims.

:mod:`repro.obs.trace`
    Structured tracing: nested spans ``serve.request → serve.coalesce →
    path → lambda → round → epoch_block → kernel_launch`` with an
    injectable monotonic clock, a bounded ring buffer, JSONL export and
    percentile aggregation.  Span *recording* is sampled; per-site fire
    counters are always exact.  The whole layer is OFF by default, and the
    disabled path allocates no span objects and takes no lock — hot solver
    loops see a single module-global read returning a no-op singleton.

:mod:`repro.obs.timing`
    Measured kernel timing: a jit-warm + ``block_until_ready`` harness
    around every registered ``LaunchSpec`` kernel, feeding
    :func:`repro.launch.roofline.achieved_vs_peak`.

:mod:`repro.obs.export`
    The one percentile implementation and the unified BENCH JSON schema
    (``repro.obs.bench/v1``) shared by ``benchmarks/``.

:mod:`repro.obs.check`
    ``python -m repro.obs --check`` self-audit gate: every declared metric
    documented (OB001), every declared span site fires on a smoke path
    (OB002); analysis-style findings, re-renderable via
    ``reanalyze --obs``.

Enabling
--------
Tracing is opt-in per process::

    from repro.obs import trace
    trace.configure(enabled=True)        # or REPRO_OBS=1 in the env
    ... run ...
    trace.TRACER.export_jsonl("spans.jsonl")

Metrics counters are always live (they are just locked ints — the
pre-obs code paths already paid for plain module globals / dict writes).
"""
from __future__ import annotations

import os

from . import metrics, trace  # noqa: F401  (stdlib-only leaf modules)

if os.environ.get("REPRO_OBS", "") not in ("", "0"):  # pragma: no cover
    trace.configure(enabled=True)
