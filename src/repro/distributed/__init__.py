from . import compression, sharding, solver_dist

__all__ = ["compression", "sharding", "solver_dist"]
