"""Seeded CS003 violation: a safety matrix that forgot a safe rule.

Fixture for tests/test_analysis.py — parsed, never imported or collected
(the analysis_fixtures directory is excluded from pytest discovery).
"""


def test_safety_matrix_incomplete():
    for rule in ["gap", "static"]:   # "dynamic" missing on purpose
        assert rule
