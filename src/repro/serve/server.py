"""The serve loop: queue -> coalesce -> cached session -> warm solve.

:class:`SGLServer` owns one worker thread and four pieces of state — a
:class:`repro.serve.queue.RequestQueue`, a
:class:`repro.serve.cache.SessionCache`, a
:class:`repro.serve.store.CertificateStore`, and (optionally) a
checkpoint directory — and turns tenant :class:`PathRequest`\\ s into
:class:`PathResponse`\\ s:

1. drained requests coalesce by value (identical requests collapse into
   one solve; ``merge_grids`` additionally unions same-problem grids);
2. the session cache supplies a jit-warm :class:`SGLSession` (per-request
   solver caches are reset, so a cached session's trajectory is
   bit-identical to a fresh one — the coalescing parity guarantee);
3. the certificate store short-circuits exact repeats and offers primal
   warm-start hints for perturbed-``y`` / refined-grid re-solves —
   admitted only when :func:`repro.serve.store.warm_eval` measures the
   hint's gap beating the cold start's, and NEVER as certificates (every
   reported discard comes from a fresh GAP round inside the solve);
   merged-grid slices seed warm-start records only, never the
   exact-repeat map, whose contract is the solo solve's output verbatim;
4. with checkpointing enabled, paths run in ``ckpt_every``-lambda
   segments through the atomic :mod:`repro.ckpt` writer; a drain (or
   SIGTERM via :meth:`install_sigterm_hook`) checkpoints at the next
   segment boundary and fails in-flight futures with :class:`Preempted`,
   and a re-submitted request on a restarted server resumes from the
   stored cursor — bit-identical to an uninterrupted run with the same
   segmenting (`solve_path`'s ``beta0``/``prev_epochs`` threading).
   Resume is guarded by the manifest's request digest, solver-cache
   digest, AND a digest of the grid actually solved, so a union-grid
   checkpoint left by a merged group is never adopted by a solo
   re-submission of its lead request.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import signal
import threading
import time
from typing import Callable, List, Optional

import numpy as np
import jax.numpy as jnp

from .. import ckpt
from ..core.session import PathResult, SGLSession, SolverConfig
from ..core.solver import SolveCaches
from .cache import SessionCache
from .queue import CoalescedGroup, Pending, RequestQueue, coalesce
from .store import CertificateStore, warm_eval
from .types import PathRequest, PathResponse, array_digest

__all__ = ["ServeConfig", "SGLServer", "Preempted"]


class Preempted(RuntimeError):
    """The server drained (shutdown/SIGTERM) before this request finished.

    ``cursor`` is the lambda index the path had reached (checkpointed
    when the server runs with a ckpt dir); resubmitting the identical
    request to a restarted server resumes there.
    """

    def __init__(self, request_digest: str, cursor: int):
        super().__init__(
            f"request {request_digest} preempted at lambda index {cursor}"
        )
        self.request_digest = request_digest
        self.cursor = cursor


@dataclasses.dataclass
class ServeConfig:
    """Serving knobs (solver knobs live in ``default_solver``)."""

    default_solver: SolverConfig = dataclasses.field(
        default_factory=SolverConfig)
    coalesce: bool = True            # False: every request solves alone
    merge_grids: bool = False        # union-grid merging (tol-level parity)
    coalesce_window_s: float = 0.02  # drain window after the first request
    max_batch: int = 32              # requests per drain
    warm_start: bool = True          # certificate-store primal hints
    serve_from_store: bool = True    # exact-repeat short-circuit
    session_capacity: int = 8        # LRU sessions (0 disables caching)
    store_capacity: int = 32         # LRU stored paths (0 disables)
    batch_lambdas: int = 4           # forwarded to solve_path
    ckpt_dir: Optional[str] = None   # enables resumable paths
    ckpt_every: int = 0              # lambdas per segment (0: no chunking)
    ckpt_keep: int = 3               # keep-k GC per request dir
    on_segment: Optional[Callable[[str, int, int], None]] = None
                                     # (digest, cursor, T) after each
                                     # segment — observability/test hook


class SGLServer:
    """Multi-tenant path-solve server over one worker thread."""

    def __init__(self, config: Optional[ServeConfig] = None):
        self.config = config if config is not None else ServeConfig()
        self.queue = RequestQueue()
        self.cache = SessionCache(capacity=self.config.session_capacity)
        self.store = CertificateStore(capacity=self.config.store_capacity)
        self._drain = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._served: set = set()      # digests completed at least once
        self._lock = threading.Lock()
        self.counters = {
            "requests": 0,
            "responses": 0,
            "path_solves": 0,
            "coalesced_requests": 0,
            "store_served": 0,
            "warm_started": 0,
            "resumed": 0,
            "preempted": 0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "SGLServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(target=self._worker,
                                        name="sgl-serve", daemon=True)
        self._thread.start()
        return self

    def submit(self, request: PathRequest):
        """Enqueue one tenant request; returns a Future[PathResponse]."""
        fut = self.queue.submit(request, self.config.default_solver)
        with self._lock:     # tenants submit from arbitrary threads
            self.counters["requests"] += 1
        return fut

    def stop(self, timeout: Optional[float] = None) -> None:
        """Finish everything queued, then stop the worker."""
        self.queue.close()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def drain(self) -> None:
        """Preemption path: stop accepting work, checkpoint in-flight
        paths at the next segment boundary, fail their futures with
        :class:`Preempted`.  Safe to call from a signal handler."""
        self._drain.set()
        self.queue.close()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def install_sigterm_hook(self):
        """Route SIGTERM (pod preemption) to :meth:`drain`; returns the
        previous handler so callers/tests can restore it."""
        prev = signal.getsignal(signal.SIGTERM)

        def handler(signum, frame):
            self.drain()

        signal.signal(signal.SIGTERM, handler)
        return prev

    @property
    def draining(self) -> bool:
        return self._drain.is_set()

    def stats(self) -> dict:
        return {
            **self.counters,
            "cache": self.cache.stats(),
            "store": self.store.stats(),
            "queue_submitted": self.queue.submitted,
        }

    # -- worker ------------------------------------------------------------

    def _worker(self) -> None:
        cfg = self.config
        while True:
            pending = self.queue.drain(max_batch=cfg.max_batch,
                                       window_s=cfg.coalesce_window_s)
            if pending is None:
                return
            if self._drain.is_set():
                self._fail(pending, cursor=0)
                continue
            if cfg.coalesce:
                groups = coalesce(pending, cfg.default_solver,
                                  merge_grids=cfg.merge_grids)
            else:
                groups = [
                    CoalescedGroup(
                        members=[p], lambdas=p.request.grid(),
                        member_index=[np.arange(len(p.request.grid()))],
                        merged=False,
                    )
                    for p in pending
                ]
            for group in groups:
                if self._drain.is_set():
                    self._fail(group.members, cursor=0)
                    continue
                try:
                    self._serve_group(group)
                except Preempted as e:
                    self.counters["preempted"] += len(group.members)
                    for p in group.members:
                        p.future.set_exception(
                            Preempted(p.digest, e.cursor))
                except Exception as e:  # pragma: no cover - defensive
                    for p in group.members:
                        if not p.future.done():
                            p.future.set_exception(e)

    def _fail(self, members: List[Pending], cursor: int) -> None:
        self.counters["preempted"] += len(members)
        for p in members:
            p.future.set_exception(Preempted(p.digest, cursor))

    # -- serving one coalesced group ----------------------------------------

    def _serve_group(self, group: CoalescedGroup) -> None:
        cfg = self.config
        t_start = time.perf_counter()
        lead = group.members[0]
        req = lead.request
        scfg = req.resolved_config(cfg.default_solver)
        digest = lead.digest

        # Exact-repeat short-circuit: the stored result of an identical
        # request (problem + grid + config values) is the solve's output
        # verbatim — served from memory, zero solver work.
        if cfg.serve_from_store and not group.merged:
            stored = self.store.exact(digest)
            if stored is not None:
                self.counters["store_served"] += len(group.members)
                self._respond(group, stored, served_from="store",
                              store_hit=True, t_start=t_start)
                return

        session, hit = self.cache.get(req.problem, scfg)
        # Per-request solver caches: a cached session must produce the
        # exact trajectory a fresh one would (coalesced-vs-solo parity),
        # so cross-request gather/reference state never leaks in.
        session.caches = SolveCaches()

        beta0 = None
        warm_started = False
        warm_lam = None
        if cfg.warm_start and req.warm_start and self.store.capacity > 0:
            hint = self.store.warm_hint(req.problem, scfg, group.lambdas)
            if hint is not None:
                dtype = req.problem.X.dtype
                lam0 = jnp.asarray(float(group.lambdas[0]), dtype)
                beta_h = jnp.asarray(hint.beta, dtype)
                # The admission gap is evaluated under the REQUEST's loss
                # (loss=None is the squared loss, sharing the historical
                # jit program): a hint must beat the cold start on the
                # data fidelity actually being solved.
                wloss = (None if session.loss.name == "lsq"
                         else session.loss)
                gap_h = float(warm_eval(req.problem, beta_h, lam0,
                                        loss=wloss))
                gap_c = float(warm_eval(
                    req.problem, jnp.zeros_like(beta_h), lam0, loss=wloss))
                # Admission is measured: adopt the hint only when its gap
                # on the NEW problem beats the cold start's.  The hint is
                # a primal point only — solve_path re-screens it with a
                # fresh GAP round before any epoch, so stored certificates
                # are never reused (see repro.serve.store).
                if np.isfinite(gap_h) and gap_h < gap_c:
                    beta0 = beta_h
                    warm_started = True
                    warm_lam = hint.lam_src
                    self.counters["warm_started"] += len(group.members)

        # Retrace watch (cache correctness): an exact repeat of a request
        # this server already solved, served from a session-cache hit,
        # must not grow any jit cache — measured, and fed to the
        # kernels.ops audit so tests can assert it via audit_scope().
        watch = (self.cache.watch_retraces()
                 if hit and digest in self._served
                 else contextlib.nullcontext())
        with watch:
            result, resumed_from = self._run_path(
                session, scfg, group.lambdas, beta0, digest
            )
        self.counters["path_solves"] += 1
        if len(group.members) > 1:
            self.counters["coalesced_requests"] += len(group.members)
        if resumed_from:
            self.counters["resumed"] += 1
        with self._lock:
            self._served.add(digest)

        self._respond(
            group, result,
            served_from="coalesced" if len(group.members) > 1 else "solve",
            session_cache_hit=hit, warm_started=warm_started,
            warm_source_lam=warm_lam, resumed_from=resumed_from,
            t_start=t_start, solve_s=time.perf_counter() - t_start,
        )

    def _respond(self, group: CoalescedGroup, result: PathResult, *,
                 served_from: str, t_start: float,
                 session_cache_hit: bool = False, store_hit: bool = False,
                 warm_started: bool = False,
                 warm_source_lam: Optional[float] = None,
                 resumed_from: Optional[int] = None,
                 solve_s: float = 0.0) -> None:
        cfg = self.config
        for p, idx in zip(group.members, group.member_index):
            member_res = (result if not group.merged
                          else _slice_result(result, idx))
            if served_from != "store" and cfg.serve_from_store:
                scfg = p.request.resolved_config(cfg.default_solver)
                # A merged-grid slice agrees with the request's solo run
                # only to solver tolerance, so it may seed warm-start
                # records but never the exact-repeat map — a later
                # identical solo request must get the verbatim guarantee
                # the store promises, not a tolerance-level stand-in.
                self.store.put(p.digest, p.request.problem, scfg,
                               member_res, exact=not group.merged)
            self.counters["responses"] += 1
            p.future.set_result(PathResponse(
                tenant=p.request.tenant,
                request_digest=p.digest,
                result=member_res,
                served_from=served_from,
                coalesced_n=len(group.members),
                session_cache_hit=session_cache_hit,
                store_hit=store_hit,
                warm_started=warm_started,
                warm_source_lam=warm_source_lam,
                resumed_from=resumed_from,
                merged_grid=group.merged,
                queue_s=t_start - p.t_submit,
                solve_s=solve_s,
            ))

    # -- the (optionally resumable) path runner ------------------------------

    def _run_path(self, session: SGLSession, scfg: SolverConfig,
                  lambdas: np.ndarray, beta0, digest: str):
        """Run one path, in ``ckpt_every``-lambda segments when
        checkpointing is on; returns ``(PathResult, resumed_from)``."""
        cfg = self.config
        T_ = len(lambdas)
        chunked = cfg.ckpt_dir is not None and cfg.ckpt_every > 0
        if not chunked:
            if self.draining:
                raise Preempted(digest, 0)
            res = session.solve_path(
                lambdas, beta0=beta0, batch_lambdas=cfg.batch_lambdas,
            )
            return res, None

        rdir = os.path.join(cfg.ckpt_dir, digest)
        caches_dig = hashlib.blake2b(
            repr(self.cache.key(session.problem, scfg)).encode(),
            digest_size=8,
        ).hexdigest()
        # Identity of the grid actually being solved.  The request digest
        # alone is not enough: a merged group checkpoints under the lead
        # member's digest but solves the UNION grid, so a later solo
        # re-submission of the lead request (same digest, different grid)
        # must not adopt that checkpoint — its prefix arrays belong to
        # union lambda points.  Verified on resume below.
        grid_dig = array_digest(lambdas)
        cursor = 0
        prev_epochs = 0
        beta_carry = beta0
        segments: List[PathResult] = []
        acc = None              # restored pre-preemption state, if any
        resumed_from = None
        rule_restored = None    # rule_name when resuming a complete path

        found = ckpt.latest(rdir)
        if found is not None:
            step, manifest = found
            extra = manifest.get("extra", {})
            if (extra.get("request") == digest
                    and extra.get("grid") == grid_dig
                    and extra.get("caches") == caches_dig
                    and 0 < int(extra.get("cursor", 0)) <= T_):
                tree_like = {
                    k: np.zeros(spec["shape"], np.dtype(spec["dtype"]))
                    for k, spec in manifest["leaves"].items()
                }
                acc = ckpt.restore(rdir, tree_like, step=step)
                cursor = int(extra["cursor"])
                prev_epochs = int(extra.get("prev_epochs", 0))
                beta_carry = jnp.asarray(acc["beta_carry"],
                                         session.problem.X.dtype)
                resumed_from = cursor
                rule_restored = extra.get("rule_name")

        while cursor < T_:
            if self.draining:
                raise Preempted(digest, cursor)
            # Fresh per-segment solver caches: a resumed run starts its
            # segment with empty caches, so the continuous run must too —
            # that is what makes interrupted+resumed bit-identical to
            # uninterrupted (with the same segmenting).
            session.caches = SolveCaches()
            sub = lambdas[cursor:cursor + cfg.ckpt_every]
            pr = session.solve_path(
                sub, beta0=beta_carry,
                prev_epochs=prev_epochs or None,
                batch_lambdas=cfg.batch_lambdas,
            )
            segments.append(pr)
            cursor += len(sub)
            prev_epochs = int(pr.epochs[-1])
            beta_carry = jnp.asarray(pr.betas[-1],
                                     session.problem.X.dtype)
            state = _pack_state(acc, segments, beta_carry)
            ckpt.save(rdir, cursor, state, extra_manifest={
                "request": digest,
                "grid": grid_dig,
                "cursor": cursor,
                "prev_epochs": prev_epochs,
                "caches": caches_dig,
                "rule_name": pr.rule_name,
                "T": T_,
            })
            ckpt.gc_keep_k(rdir, cfg.ckpt_keep)
            if cfg.on_segment is not None:
                cfg.on_segment(digest, cursor, T_)

        return _assemble(lambdas, acc, segments, rule_restored), resumed_from


# ----------------------------------------------------------------------------
# Segment bookkeeping: pack/accumulate/stitch PathResult state
# ----------------------------------------------------------------------------

_ARRAY_FIELDS = ("betas", "gaps", "epochs", "group_active_frac",
                 "feat_active_frac", "group_active", "feat_active",
                 "seq_screened", "dyn_screened")
_SUM_FIELDS = ("n_rounds", "n_transpose_copies", "n_compact_rounds",
               "n_full_rounds", "round_flops", "n_fused_epoch_launches",
               "batched_lambdas", "n_gathers")


def _pack_state(acc, segments: List[PathResult], beta_carry) -> dict:
    """Flat checkpoint tree: solved-prefix arrays + counters + carry."""
    state: dict = {}
    for f in _ARRAY_FIELDS:
        parts = ([acc[f]] if acc is not None else []) \
            + [np.asarray(getattr(s, f)) for s in segments]
        state[f] = np.concatenate(parts, axis=0)
    for f in _SUM_FIELDS:
        prior = float(acc[f]) if acc is not None else 0.0
        state[f] = np.asarray(
            prior + sum(float(getattr(s, f)) for s in segments))
    safe_prior = bool(acc["certificates_safe"]) if acc is not None else True
    state["certificates_safe"] = np.asarray(
        safe_prior and all(bool(s.certificates_safe) for s in segments))
    state["beta_carry"] = np.asarray(beta_carry)
    return state


def _assemble(lambdas: np.ndarray, acc,
              segments: List[PathResult],
              rule_restored: Optional[str] = None) -> PathResult:
    """Stitch restored state + fresh segments into one PathResult.

    ``rule_restored`` is the rule_name persisted in the checkpoint
    manifest — the only rule source when resume finds a fully-complete
    checkpoint (no fresh segments ran)."""
    state = _pack_state(acc, segments, np.zeros(0))
    counters = {f: (float(state[f]) if f == "round_flops"
                    else int(state[f])) for f in _SUM_FIELDS}
    rule_name = (segments[-1].rule_name if segments
                 else rule_restored if rule_restored is not None
                 else "gap")
    return PathResult(
        lambdas=np.asarray(lambdas, float),
        betas=state["betas"],
        gaps=state["gaps"],
        epochs=state["epochs"],
        group_active_frac=state["group_active_frac"],
        feat_active_frac=state["feat_active_frac"],
        group_active=state["group_active"],
        feat_active=state["feat_active"],
        seq_screened=state["seq_screened"],
        dyn_screened=state["dyn_screened"],
        n_gathers=counters["n_gathers"],
        results=[],
        n_rounds=counters["n_rounds"],
        n_transpose_copies=counters["n_transpose_copies"],
        n_compact_rounds=counters["n_compact_rounds"],
        n_full_rounds=counters["n_full_rounds"],
        round_flops=counters["round_flops"],
        n_fused_epoch_launches=counters["n_fused_epoch_launches"],
        batched_lambdas=counters["batched_lambdas"],
        rule_name=rule_name,
        certificates_safe=bool(state["certificates_safe"]),
    )


def _slice_result(result: PathResult, idx: np.ndarray) -> PathResult:
    """A member's view of a merged-grid solve: its own grid points sliced
    out of the union path.  Solve counters are those of the shared union
    run (one solve served several tenants — per-member attribution would
    be fiction)."""
    return PathResult(
        lambdas=np.asarray(result.lambdas)[idx],
        betas=np.asarray(result.betas)[idx],
        gaps=np.asarray(result.gaps)[idx],
        epochs=np.asarray(result.epochs)[idx],
        group_active_frac=np.asarray(result.group_active_frac)[idx],
        feat_active_frac=np.asarray(result.feat_active_frac)[idx],
        group_active=np.asarray(result.group_active)[idx],
        feat_active=np.asarray(result.feat_active)[idx],
        seq_screened=np.asarray(result.seq_screened)[idx],
        dyn_screened=np.asarray(result.dyn_screened)[idx],
        n_gathers=result.n_gathers,
        results=[],
        n_rounds=result.n_rounds,
        n_transpose_copies=result.n_transpose_copies,
        n_compact_rounds=result.n_compact_rounds,
        n_full_rounds=result.n_full_rounds,
        round_flops=result.round_flops,
        n_fused_epoch_launches=result.n_fused_epoch_launches,
        batched_lambdas=result.batched_lambdas,
        rule_name=result.rule_name,
        certificates_safe=result.certificates_safe,
    )
