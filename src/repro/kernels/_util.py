"""Shared backend predicates for the Pallas kernels.

Leaf module (imports nothing from this package) so both the kernel entry
points and their dispatch wrappers in ops.py — and the solver — can use one
spelling of the "are we on TPU" test.  When Pallas gains another compiled
backend, this is the only place to update.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Pallas interpret-mode default: compile on TPU, interpret elsewhere."""
    return not on_tpu()
