"""Production training / solving driver.

Two modes, mirroring the two workloads in this framework:

  LM training (the assigned-architecture zoo, with the paper's SGL
  regularizer as an optional first-class feature)::

    PYTHONPATH=src python -m repro.launch.train \
        --arch demo --reduced --steps 200 --batch 8 --seq 128 \
        --sgl-lam 3e-4 --ckpt-dir /tmp/ckpt

  Distributed SGL solve (the paper's own problem on a mesh)::

    PYTHONPATH=src python -m repro.launch.train --solver --tol 1e-6

Fault tolerance (designed for 1000+ nodes, exercised here on CPU):
  * atomic checkpoints every --ckpt-every steps, keep-k GC, and a SIGTERM
    preemption hook that snapshots before the scheduler kills the job;
  * restart = re-invoke the same command: the driver restores the latest
    checkpoint (device-count independent, so elastic rescale = restart on
    a different mesh);
  * a straggler watchdog: per-step wall time is tracked against a rolling
    median; steps slower than --straggler-factor x median are counted and
    reported (on a real pod this signal feeds the scheduler's hot-swap).
"""
from __future__ import annotations

import argparse
import signal
import time

import numpy as np


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--sgl-lam", type=float, default=0.0,
                    help="enable SGL structured sparsity when > 0")
    ap.add_argument("--sgl-tau", type=float, default=0.3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (needs 256 devices)")
    # solver mode
    ap.add_argument("--solver", action="store_true",
                    help="run the distributed SGL solver instead of LM train")
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--tau", type=float, default=0.2)
    ap.add_argument("--n", type=int, default=100)
    ap.add_argument("--p", type=int, default=1000)
    ap.add_argument("--groups", type=int, default=100)
    ap.add_argument("--path-T", type=int, default=1,
                    help="also run a T-point lambda path on the mesh "
                         "(sequential certificates + batched FISTA)")
    return ap.parse_args()


def run_solver(args):
    import jax
    import jax.numpy as jnp

    from repro.core import SGLSession, SolverConfig, make_problem
    from repro.data.synthetic import make_synthetic
    from repro.launch import mesh as meshlib

    mesh = (meshlib.make_production_mesh() if args.production_mesh
            else meshlib.make_test_mesh())
    X, y, _, sizes = make_synthetic(n=args.n, p=args.p,
                                    n_groups=args.groups, dtype=np.float32)
    G = args.groups
    L = float(jnp.linalg.norm(X, 2) ** 2)

    # One session = problem + mesh strategy + solver config; the same
    # front-end the single-device examples use.
    problem = make_problem(X, y, sizes, tau=args.tau)
    session = SGLSession(
        problem, SolverConfig(tol=args.tol, max_epochs=5000),
        mesh=mesh, L=L,
    )
    lam = session.lam_max / 20.0
    print(f"distributed FISTA+GAP on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"lam = lam_max/20 = {lam:.4f}")
    t0 = time.perf_counter()
    res = session.solve(lam)
    dt = time.perf_counter() - t0
    active = int(jnp.sum(jnp.any(jnp.abs(res.beta) > 0, axis=1)))
    print(f"gap {float(res.gap):.3e} in {dt:.1f}s ({res.n_epochs} FISTA "
          f"steps, {session.rounds} screen rounds); "
          f"active groups {active}/{G}; "
          f"screened {G - int(res.group_active.sum())}")

    if args.path_T > 1:
        # Lambda path on the mesh: sequential certificates + batched-lambda
        # FISTA for consecutive points with coinciding certified sets.
        t0 = time.perf_counter()
        path = session.solve_path(T=args.path_T, delta=2.0)
        dt = time.perf_counter() - t0
        print(f"path T={args.path_T}: {dt:.1f}s, "
              f"epochs {path.epochs.tolist()}, "
              f"seq screened {int(path.seq_screened.sum())} certificates, "
              f"{session.batched_lambdas} lambdas batched")


def run_train(args):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.ckpt.checkpoint import CheckpointManager
    from repro.configs import get
    from repro.launch import mesh as meshlib
    from repro.models import build
    from repro.train.sgl_regularizer import SGLRegConfig, group_sparsity
    from repro.train.train_step import make_train_step

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    mesh = (meshlib.make_production_mesh() if args.production_mesh
            else meshlib.make_test_mesh())
    model_axis = meshlib.model_size(mesh)
    if model_axis > 1:
        from repro.models import layers as L
        L.set_activation_mesh(
            {"data": meshlib.dp_size(mesh), "model": model_axis})

    params = api.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    p_specs = api.param_specs(model_axis)
    params = jax.device_put(params, meshlib.shardings_for(
        mesh, p_specs, multi_pod=False))
    n_params = sum(x.size for x in jax.tree.leaves(params))

    sgl_cfg = (SGLRegConfig(lam=args.sgl_lam, tau=args.sgl_tau)
               if args.sgl_lam > 0 else None)
    init_state, train_step = make_train_step(
        api, lr=args.lr, sgl_cfg=sgl_cfg, q_chunk=min(512, args.seq))
    opt_state = init_state(params)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    print(f"arch={args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{n_params / 1e6:.2f}M params on mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"SGL={'on' if sgl_cfg else 'off'}")

    mgr = None
    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every, keep=3)
        got, restored = mgr.restore_latest((params, opt_state))
        if restored is not None:
            params, opt_state = restored
            start = got
            print(f"resumed from step {start} (elastic: restore is "
                  f"device-count independent)")
        # preemption hook: snapshot on SIGTERM before the scheduler kills us
        state_ref = {"step": start, "tree": (params, opt_state)}
        mgr.install_sigterm_hook(
            lambda: (state_ref["step"], state_ref["tree"]))

    rng = np.random.default_rng(start)
    step_times: list = []
    stragglers = 0
    with mesh:
        for step in range(start, args.steps):
            half = args.seq // 2
            first = rng.integers(2, cfg.vocab, size=(args.batch, half))
            toks = np.concatenate([first, first], axis=1)
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}

            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            # straggler watchdog (rolling-median deadline)
            if len(step_times) >= 5:
                med = float(np.median(step_times[-50:]))
                if dt > args.straggler_factor * med:
                    stragglers += 1
                    print(f"  [straggler] step {step}: {dt * 1e3:.0f}ms "
                          f"vs median {med * 1e3:.0f}ms")
            step_times.append(dt)

            if mgr:
                state_ref["step"] = step + 1
                state_ref["tree"] = (params, opt_state)
                mgr.maybe_save(step + 1, (params, opt_state))

            if step % 20 == 0 or step == args.steps - 1:
                msg = (f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                       f"{dt * 1e3:6.1f} ms/step")
                if sgl_cfg:
                    sp = group_sparsity(params)
                    if sp:
                        msg += f"  ffn_zero {float(np.mean(list(sp.values()))):.1%}"
                print(msg)

    med = float(np.median(step_times)) if step_times else float("nan")
    print(f"\ndone: median {med * 1e3:.1f} ms/step, "
          f"{stragglers} straggler step(s) flagged")


def main():
    args = parse_args()
    if args.solver:
        run_solver(args)
    else:
        run_train(args)


if __name__ == "__main__":
    main()
