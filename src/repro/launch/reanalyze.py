"""Recompute derived artifacts from saved raw ones — no recompute needed.

Two modes, same pattern (raw data is saved next to the derived report, so
analyzer/renderer improvements re-apply for free):

* dry-run roofline (default): re-analyze each cell's saved HLO

      PYTHONPATH=src python -m repro.launch.reanalyze artifacts/dryrun2

* screening-rule sweep: re-render the Fig. 2/3 markdown report from a
  saved ``benchmarks/sweep_rules.py`` JSON payload (``BENCH_pr5.json``)
  without re-running a single solver epoch

      PYTHONPATH=src python -m repro.launch.reanalyze --sweep BENCH_pr5.json
      PYTHONPATH=src python -m repro.launch.reanalyze --sweep BENCH_pr5.json --md BENCH_pr5.md
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from . import roofline as rl


def reanalyze_cell(json_path: str) -> bool:
    hlo_path = json_path + ".hlo.gz"
    if not os.path.exists(hlo_path):
        return False
    with open(json_path) as f:
        d = json.load(f)
    if d.get("status") != "ok" or "roofline" not in d:
        return False
    with gzip.open(hlo_path, "rt") as f:
        hlo = f.read()
    a = rl.analyze_hlo(hlo)
    chips = d["chips"]
    roof = rl.Roofline(
        flops=a["flops"] * chips,
        bytes_accessed=a["bytes_accessed"] * chips,
        collective_bytes=a["collective_bytes"] * chips,
        chips=chips,
        model_flops=d["roofline"]["model_flops"],
    )
    d["roofline"] = roof.as_dict()
    d["collectives"] = {k[len("coll_"):]: v for k, v in a.items()
                        if k.startswith("coll_")}
    with open(json_path, "w") as f:
        json.dump(d, f, indent=2)
    return True


def reanalyze_sweep(json_path: str, md_path: str | None = None) -> str:
    """Re-render the Fig. 2/3 sweep markdown from a saved sweep JSON.

    Writes next to the JSON (``.json`` -> ``.md``) unless ``md_path`` is
    given; returns the output path.  The renderer lives in
    :func:`repro.launch.report.render_sweep_markdown`, shared with the
    sweep harness itself, so both always agree on the layout.
    """
    from .report import render_sweep_markdown

    with open(json_path) as f:
        payload = json.load(f)
    if "curves" not in payload:
        raise SystemExit(
            f"{json_path} has no 'curves' section - not a sweep_rules "
            "payload (see benchmarks/sweep_rules.py)"
        )
    if md_path is None:
        base, _ = os.path.splitext(json_path)
        md_path = base + ".md"
    with open(md_path, "w") as f:
        f.write(render_sweep_markdown(payload))
        f.write("\n")
    print(f"re-rendered {json_path} -> {md_path}")
    return md_path


def reanalyze_obs(json_path: str, md_path: str | None = None) -> str:
    """Re-render the observability bench markdown from a saved
    ``repro.obs.bench/v1`` JSON (``BENCH_pr10.json``) — kernel timings,
    path overhead contract, serve per-stage breakdown — without re-running
    a single measurement.  Renderer:
    :func:`repro.launch.report.render_obs_markdown`."""
    from ..obs.export import BENCH_SCHEMA
    from .report import render_obs_markdown

    with open(json_path) as f:
        payload = json.load(f)
    if payload.get("schema") != BENCH_SCHEMA:
        raise SystemExit(
            f"{json_path} is not a {BENCH_SCHEMA} payload (schema: "
            f"{payload.get('schema')!r}) - see repro.obs.export"
        )
    if md_path is None:
        base, _ = os.path.splitext(json_path)
        md_path = base + ".md"
    with open(md_path, "w") as f:
        f.write(render_obs_markdown(payload))
        f.write("\n")
    print(f"re-rendered {json_path} -> {md_path}")
    return md_path


def main():
    usage = ("usage: reanalyze [--sweep|--obs] <bench.json> "
             "[--md <out.md>]")
    args = sys.argv[1:]
    if args and args[0] in ("--sweep", "--obs"):
        mode = args[0]
        md = None
        rest = args[1:]
        if "--md" in rest:
            i = rest.index("--md")
            if i + 1 >= len(rest):
                raise SystemExit(usage)
            md = rest[i + 1]
            rest = rest[:i] + rest[i + 2:]
        if len(rest) != 1 or rest[0].startswith("--"):
            raise SystemExit(usage)
        if mode == "--sweep":
            reanalyze_sweep(rest[0], md)
        else:
            reanalyze_obs(rest[0], md)
        return
    out_dir = args[0] if args else "artifacts/dryrun2"
    n = 0
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if reanalyze_cell(p):
            n += 1
            print(f"reanalyzed {os.path.basename(p)}")
    print(f"{n} cells reanalyzed")


if __name__ == "__main__":
    main()
