"""The obs self-audit behind ``python -m repro.obs --check``.

Two passes, reported as :class:`repro.analysis.findings.Finding` objects
(the same result type — and the same JSON/markdown rendering — as the
static-analysis gate, so CI treats both gates identically):

* **OB001 — metric schema audit.**  Every metric declared in
  :data:`repro.obs.metrics.SCHEMA` must be documented: non-empty help
  text, a known kind, and a name matching the dotted lowercase
  convention.  ``declare()`` enforces name/kind at declaration time, so
  in a healthy process OB001 mostly guards the help-text contract; the
  pass re-checks everything so a doctored or hand-merged schema (or a
  future relaxation of ``declare``) still fails loudly.

* **OB002 — span coverage.**  Every span site declared in
  :data:`repro.obs.trace.SPAN_SITES` must actually fire on a smoke
  path: a tiny two-request serve sequence (pallas backends, warm-start
  second request) that traverses request → coalesce → store → cache →
  warm_eval → path → lambda → round → epoch_block → kernel_launch.  A
  site that never fires means its instrumentation was dropped in a
  refactor — exactly the regression this gate exists to catch.  The
  tracer's *exact* per-site counters are used (sampling only thins the
  recorded span buffer, never the counts).

Both passes accept injected inputs (``schema=``, ``counts=``) so tests
can prove each finding fires on a seeded fixture without monkey-patching
globals or running the smoke solve.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Mapping, Optional

from ..analysis.findings import Finding, summarize, to_payload
from . import metrics, trace

__all__ = ["check_schema", "check_span_coverage", "run_smoke",
           "run_check", "main"]

_VALID_KINDS = ("counter", "gauge", "histogram")


# ---------------------------------------------------------------------------
# OB001: every declared metric is documented
# ---------------------------------------------------------------------------

def check_schema(
        schema: Optional[Mapping[str, metrics.MetricSpec]] = None,
) -> List[Finding]:
    """OB001 findings for ``schema`` (default: the live global SCHEMA)."""
    if schema is None:
        schema = dict(metrics.SCHEMA)
    out: List[Finding] = []
    for name in sorted(schema):
        spec = schema[name]
        if not metrics._NAME_RE.match(name):
            out.append(Finding(
                "obs", "OB001",
                f"metric name {name!r} violates the dotted lowercase "
                f"naming convention ({metrics._NAME_RE.pattern})",
                location=name,
            ))
        if spec.kind not in _VALID_KINDS:
            out.append(Finding(
                "obs", "OB001",
                f"metric {name!r} declares unknown kind {spec.kind!r} "
                f"(expected one of {', '.join(_VALID_KINDS)})",
                location=name,
            ))
        if not str(spec.help or "").strip():
            out.append(Finding(
                "obs", "OB001",
                f"metric {name!r} is undocumented: declared without help "
                "text (every metric must say what it counts)",
                location=name,
            ))
    return out


# ---------------------------------------------------------------------------
# OB002: every declared span site fires on the smoke path
# ---------------------------------------------------------------------------

def run_smoke() -> Dict[str, int]:
    """Exercise every declared span site; return exact per-site counts.

    Runs a tiny two-request serve sequence against a private server with
    pallas screen/solver backends (interpret mode off-TPU): the first
    request exercises the full solve pipeline, the second — same problem,
    a tail grid — takes the certificate-store warm-start admission path.
    Tracer state (enabled flag, buffers) is saved and restored, so this
    is safe to call from a process that is itself tracing.
    """
    from ..core import sgl
    from ..core.session import SolverConfig, lambda_grid
    from ..data.synthetic import make_synthetic
    from ..serve import PathRequest, ServeConfig, SGLServer

    was_enabled = trace.TRACER.enabled
    trace.configure(enabled=True, sample_every=1)
    trace.TRACER.reset()
    try:
        X, y, _, sizes = make_synthetic(n=24, p=64, n_groups=8,
                                        gamma1=3, gamma2=2, seed=0)
        prob = sgl.make_problem(X, y, sizes, tau=0.3)
        scfg = SolverConfig(tol=1e-6, max_epochs=500,
                            screen_backend="pallas",
                            solver_backend="pallas")
        grid = lambda_grid(float(sgl.lambda_max(prob)), T=4, delta=1.5)
        server = SGLServer(ServeConfig(default_solver=scfg,
                                       coalesce_window_s=0.05)).start()
        try:
            server.submit(PathRequest("obs-smoke-a", prob, grid)).result(600)
            server.submit(
                PathRequest("obs-smoke-b", prob, grid[1:])
            ).result(600)
        finally:
            server.stop()
        return dict(trace.TRACER.counts())
    finally:
        trace.TRACER.reset()
        trace.configure(enabled=was_enabled)


def check_span_coverage(
        counts: Optional[Mapping[str, int]] = None) -> List[Finding]:
    """OB002 findings: declared span sites missing from ``counts``
    (default: the counts measured by :func:`run_smoke`)."""
    if counts is None:
        counts = run_smoke()
    out: List[Finding] = []
    for site in sorted(trace.SPAN_SITES):
        if int(counts.get(site, 0)) <= 0:
            out.append(Finding(
                "obs", "OB002",
                f"span site {site!r} never fired on the smoke path — "
                "its instrumentation was dropped or gated off "
                f"(declared for {trace.SPAN_SITES[site]})",
                location=site,
            ))
    for site in sorted(counts):
        if site not in trace.SPAN_SITES:
            out.append(Finding(
                "obs", "OB002",
                f"span name {site!r} fired but is not declared in "
                "SPAN_SITES — declare it (with its location) or fix the "
                "call site's name",
                severity="warning",
                location=site,
            ))
    return out


# ---------------------------------------------------------------------------
# the gate
# ---------------------------------------------------------------------------

def run_check(smoke: bool = True) -> dict:
    """Run both passes; return the ``repro.analysis/v1`` payload."""
    findings = check_schema()
    counts: Dict[str, int] = {}
    if smoke:
        counts = run_smoke()
        findings += check_span_coverage(counts)
    passes = {
        "obs": {
            "findings": len(findings),
            "metrics_declared": len(metrics.SCHEMA),
            "span_sites": sorted(trace.SPAN_SITES),
            "smoke_span_counts": {k: int(v)
                                  for k, v in sorted(counts.items())},
        },
    }
    return to_payload(findings, passes=passes)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="repro.obs self-audit (OB001 schema, OB002 spans)",
    )
    ap.add_argument("--check", action="store_true",
                    help="run the self-audit (the only mode; required "
                         "so the invocation reads as a gate)")
    ap.add_argument("--no-smoke", action="store_true",
                    help="schema audit only — skip the OB002 smoke solve")
    ap.add_argument("--report", metavar="OUT.json", default=None,
                    help="write the findings payload as JSON")
    ap.add_argument("--md", metavar="OUT.md", default=None,
                    help="render the findings payload as markdown")
    ns = ap.parse_args(argv)
    if not ns.check:
        ap.error("nothing to do: pass --check")

    payload = run_check(smoke=not ns.no_smoke)
    if ns.report:
        with open(ns.report, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {ns.report}")
    if ns.md:
        from ..launch.report import render_analysis_markdown
        with open(ns.md, "w") as f:
            f.write(render_analysis_markdown(payload))
            f.write("\n")
        print(f"wrote {ns.md}")

    summary = summarize([Finding(**f) for f in payload["findings"]])
    for f in payload["findings"]:
        loc = f" [{f['location']}]" if f["location"] else ""
        print(f"{f['code']} ({f['severity']}){loc}: {f['message']}",
              file=sys.stderr)
    n_sites = len(trace.SPAN_SITES)
    print(f"obs --check: {len(metrics.SCHEMA)} metrics, {n_sites} span "
          f"sites — {summary['errors']} errors, "
          f"{summary['warnings']} warnings")
    return 0 if payload["ok"] else 1
