from . import optimizer, sgl_regularizer
from .train_step import make_train_step, loss_fn

__all__ = ["optimizer", "sgl_regularizer", "make_train_step", "loss_fn"]
