"""The data-fidelity ``Loss`` strategy protocol.

GAP safe screening (paper Thm 1/2, Eq. 15) never needed least squares —
it needs a smooth data-fidelity term ``F(z) = sum_i f_i(z_i)`` with a
computable Fenchel conjugate.  The primal is ``P(beta) = F(X beta) +
lam * Omega(beta)``; the generalized dual point is built from the
negative loss gradient ``rho = -grad F(X beta)`` through the same Eq. 15
scaling ``theta = rho / max(lam, Omega^D(X^T rho))``; the GAP sphere
radius generalizes to ``r = sqrt(2 * nu * gap) / lam`` where ``nu`` is
the per-sample smoothness constant of ``f_i`` (``nu = 1`` for squared
loss, ``nu = 1/4`` for logistic) — see the journal follow-ups arXiv
1611.05780 (smooth losses) and arXiv 1506.03736 (multi-task).

A :class:`Loss` is a **frozen, hashable value object**, exactly like
:class:`repro.rules.ScreeningRule`: instances ride into jitted functions
as static arguments, so two equal losses must hash equal and carry no
arrays.  Everything a loss defines is a *proof obligation*:

``value(y, z)``
    ``F(z)`` — the full data-fidelity term at linear predictor
    ``z = X beta_flat`` (summed over samples).
``neg_grad(y, z)``
    ``rho = -grad_z F(z)`` — the generalized residual.  For squared loss
    this is literally ``y - z``; every layer that used to write
    ``resid`` now means this.
``conjugate(y, u)``
    ``F*(u) = sum_i f_i*(u_i)`` — must satisfy Fenchel–Young so that
    ``D(theta) = -F*(-lam * theta)`` is a true dual lower bound and
    ``gap = P(beta) - D(theta) >= 0`` at every feasible ``theta``.
``dual_obj(y, theta, lam_)``
    ``-F*(-lam * theta)``.  The default derives it from ``conjugate``;
    a loss may override with algebraically equal but numerically
    preferred arithmetic (lsq does, to stay bit-identical to the
    historical quadratic form).
``nu``
    Sample-wise smoothness: ``f_i`` must be ``1/nu``-strongly-smooth,
    i.e. ``f_i*`` is ``nu``-strongly convex, so the GAP radius
    ``sqrt(2 nu gap) / lam`` is safe (Thm 2 generalization).  Also the
    majorization constant: ``(1/nu) * ||X_g||^2`` upper-bounds the block
    Hessian, which is what the BCD update divides by.

The Eq. 15 scaling keeps feasibility for free: ``Omega^D(X^T theta) <=
1`` by construction, and for losses whose conjugate has a bounded domain
(logistic: ``-lam theta_i`` must lie in ``(y_i - 1, y_i)``) the scaling
``max(lam, Omega^D(X^T rho)) >= lam`` keeps ``lam * theta = lam * rho /
scale`` inside the domain whenever ``rho`` itself is (logistic:
``rho_i = y_i - sigmoid(z_i)`` is strictly inside).

``multi_output`` losses (multi-task, arXiv 1506.03736) grow a task axis
on ``y`` and on beta; they are currently supported at the
:mod:`repro.core.sgl` math level (norms, primal/dual/gap, safe sphere
test) and rejected by :class:`repro.core.session.SGLSession` with a
clear error — the solver threading is future work.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["Loss"]


@dataclasses.dataclass(frozen=True)
class Loss:
    """Base class for data-fidelity strategies (see module docstring).

    Subclasses override the class attributes and the four math methods.
    Instances are jit static arguments — keep them frozen/hashable.
    """

    # -- metadata (plain class attributes, NOT dataclass fields, so
    # frozen subclasses just shadow them — same pattern as ScreeningRule)
    name = "abstract"
    #: per-sample smoothness constant: GAP radius = sqrt(2*nu*gap)/lam,
    #: block majorization bound = nu*Lg.  Python float on purpose — it
    #: constant-folds at trace time (nu=1.0 leaves the lsq radius graph
    #: bit-identical to the pre-loss code).
    nu = 1.0
    #: True when y/beta carry a task axis (matrix-valued coefficients).
    multi_output = False

    # -- the strategy surface ---------------------------------------------

    def value(self, y, z):
        """``F(z)``: data-fidelity at linear predictor ``z`` (scalar)."""
        raise NotImplementedError

    def neg_grad(self, y, z):
        """``rho = -grad_z F(z)``: the generalized residual (shape of y)."""
        raise NotImplementedError

    def conjugate(self, y, u):
        """``F*(u)`` (scalar); +inf outside the conjugate's domain."""
        raise NotImplementedError

    def dual_obj(self, y, theta, lam_):
        """``D(theta) = -F*(-lam * theta)`` — override only to swap in
        algebraically equal, numerically preferred arithmetic."""
        return -self.conjugate(y, -lam_ * theta)

    def lam_max_rho(self, y):
        """``rho`` at ``beta = 0`` (drives ``lam_max = Omega^D(X^T rho0)``)."""
        return self.neg_grad(y, jnp.zeros_like(y))

    def __repr__(self) -> str:  # stable cache-token identity, like rules
        return f"{type(self).__name__}(name={self.name!r})"
