"""Seeded CS004 violations: results/masks forged on exception paths.

FIXTURE for tests/test_analysis.py — parsed, never imported.  Each
handler below must be flagged by
repro.analysis.cert_lint.lint_exception_paths; the clean ones must not.
The safety keywords are threaded from names (``safe=ok``) on purpose so
this file adds nothing to the CS001 counts asserted elsewhere.
"""


def swallow_into_round(gap, theta, g, f, ok):
    try:
        risky()                                              # noqa: F821
    except Exception:
        # CS004: a result synthesised where the dataflow just broke
        return RoundResult(gap, theta, g, f, safe=ok)        # noqa: F821


def swallow_into_path(lambdas, betas, ok):
    try:
        risky()                                              # noqa: F821
    except ValueError:
        # CS004: same forgery, path-level
        return PathResult(lambdas=lambdas, betas=betas,
                          certificates_safe=ok)              # noqa: F821


def narrow_mask_on_error(group_active, mask):
    try:
        risky()                                              # noqa: F821
    except Exception:
        # CS004: uncertified discard adopted on the exception path
        group_active &= mask
    return group_active


def narrow_attr_mask_on_error(state, mask):
    try:
        risky()                                              # noqa: F821
    except Exception:
        # CS004: attribute-form mask adoption
        state.feat_active &= mask
    return state


def clean_rewind(gap, theta, g, f, ok, best):
    # fine: handler rewinds to known-good state, result built OUTSIDE
    try:
        gap, theta = risky()                                 # noqa: F821
    except Exception:
        gap, theta = best
    return RoundResult(gap, theta, g, f, safe=ok)            # noqa: F821


def clean_rewrap(r):
    try:
        return risky()                                       # noqa: F821
    except Exception:
        # fine: the bit travels through the star (existing result)
        return RoundResult(*r)                               # noqa: F821
