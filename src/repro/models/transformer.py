"""Decoder-only transformer LM covering the dense / moe / vlm families.

Pure functions over dict pytrees.  Layers are scan-stacked (one compiled
layer body regardless of depth — essential for the 126-layer 405B dry-run).

Public surface (used by launch/ and tests):
    init_params(cfg, key, dtype)        -> params pytree
    param_specs(cfg, model_axis)        -> same-structure PartitionSpec tree
    forward(cfg, params, tokens, embeds=None)       -> logits (train path)
    prefill(cfg, params, tokens, embeds=None)       -> (last_logits, cache)
    init_cache(cfg, batch, max_seq, dtype)          -> cache pytree
    cache_specs(cfg, model_axis)                    -> spec tree for cache
    decode_step(cfg, params, cache, token, pos)     -> (logits, cache)

VLM / audio variants feed precomputed frontend embeddings via ``embeds``
(B, F, D), prepended to the token embeddings (the modality frontend itself is
stubbed per the assignment).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L


# ----------------------------------------------------------------------------
# Params
# ----------------------------------------------------------------------------

def _init_layer(cfg, key, dtype):
    ka, km = jax.random.split(key)
    p = {
        "ln1": L.init_norm(cfg, dtype),
        "attn": L.init_attn(ka, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = L.init_moe(km, cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(km, cfg, dtype)
    return p


def init_params(cfg, key, dtype=jnp.bfloat16):
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys)
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype)
        * cfg.d_model ** -0.5,
        "layers": stacked,
        "ln_f": L.init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(ko, (cfg.d_model, cfg.vocab), dtype)
            * cfg.d_model ** -0.5
        )
    return params


def _layer_specs(cfg, model_axis):
    sp = {
        "ln1": P(None),
        "attn": L.specs_attn(cfg),
        "ln2": P(None),
    }
    if cfg.moe is not None:
        sp["moe"] = L.specs_moe(cfg, model_axis)
    else:
        sp["mlp"] = L.specs_mlp(cfg)
    return sp


def _stack_spec(spec_tree):
    """Prepend the scan (layer) axis (unsharded) to every leaf spec."""
    return jax.tree.map(
        lambda s: P(None, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_specs(cfg, model_axis: int = 16):
    sp = {
        "embed": P("model", "data"),
        "layers": _stack_spec(_layer_specs(cfg, model_axis)),
        "ln_f": P(None),
    }
    if not cfg.tie_embeddings:
        sp["unembed"] = P("data", "model")
    return sp


# ----------------------------------------------------------------------------
# Forward (train / prefill)
# ----------------------------------------------------------------------------

def _embed_inputs(cfg, params, tokens, embeds):
    h = jnp.take(params["embed"], tokens, axis=0)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    return h


def _layer_fwd(cfg, lp, h, positions, q_chunk):
    a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
    q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
    o = L.causal_attention(q, k, v, window=cfg.window, q_chunk=q_chunk)
    B, S, H, hd = o.shape
    h = h + o.reshape(B, S, H * hd) @ lp["attn"]["wo"]
    b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = L.moe_ffn(lp["moe"], b, cfg)
    else:
        f, aux = L.mlp(lp["mlp"], b), jnp.zeros((), jnp.float32)
    return h + f, aux


def forward(cfg, params, tokens, embeds=None, *, q_chunk: int = 512,
            remat: bool = True, remat_policy: str = "full"):
    """Training forward. Returns (logits, moe_aux).

    remat_policy: "full" (recompute everything in the backward) or
    "dots" (jax.checkpoint_policies.checkpoint_dots — matmul outputs are
    saved, elementwise recomputed; trades HBM residency for ~1/3 less
    recompute, see EXPERIMENTS.md §Perf llama3-405b iteration).
    """
    h = _embed_inputs(cfg, params, tokens, embeds)
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    qc = min(q_chunk, S)

    def body(h, lp):
        out, aux = _layer_fwd(cfg, lp, h, positions, qc)
        return out, aux

    if remat:
        policy = (jax.checkpoint_policies.checkpoint_dots
                  if remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)
    h, auxs = jax.lax.scan(body, h, params["layers"])
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    )
    logits = h @ unembed
    return logits, jnp.sum(auxs)


# ----------------------------------------------------------------------------
# KV cache serving path
# ----------------------------------------------------------------------------

class Cache(NamedTuple):
    k: jax.Array  # (n_layers, B, S_max, K, hd)
    v: jax.Array
    pos: jax.Array  # scalar int32 — tokens already in cache


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    eff_seq = max_seq if cfg.window is None else min(max_seq, cfg.window)
    shape = (cfg.n_layers, batch, eff_seq, cfg.n_kv, cfg.hd)
    return Cache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def kv_spec(cfg, model_axis: int = 16):
    """Shard kv heads over model if divisible, else shard head_dim."""
    K, hd = cfg.n_kv, cfg.hd
    if K % model_axis == 0:
        return P(None, "data", None, "model", None)
    if hd % model_axis == 0:
        return P(None, "data", None, None, "model")
    return P(None, "data", None, None, None)


def cache_specs(cfg, model_axis: int = 16):
    s = kv_spec(cfg, model_axis)
    return Cache(k=s, v=s, pos=P())


def prefill(cfg, params, tokens, embeds=None, *, q_chunk: int = 512,
            cache_len: Optional[int] = None, dtype=jnp.bfloat16):
    """Run the prompt through the model, materialising the KV cache."""
    h = _embed_inputs(cfg, params, tokens, embeds)
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    qc = min(q_chunk, S)
    C = cache_len or S
    # without a window the cache must hold the whole history (S includes any
    # prepended frontend embeddings)
    eff_C = max(C, S) if cfg.window is None else min(C, cfg.window)

    def body(h, lp):
        a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
        o = L.causal_attention(q, k, v, window=cfg.window, q_chunk=qc)
        hh = h + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        b = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = L.moe_ffn(lp["moe"], b, cfg)
        else:
            f = L.mlp(lp["mlp"], b)
        # rolling-layout cache fill (slot == abs_pos %% buffer length)
        kc = L.fill_rolling_cache(k, eff_C, dtype)
        vc = L.fill_rolling_cache(v, eff_C, dtype)
        return hh + f, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(body, h, params["layers"])
    h = L.rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (h @ unembed)[:, 0]
    cache = Cache(k=kcs, v=vcs, pos=jnp.asarray(S, jnp.int32))
    return logits, cache


def decode_step(cfg, params, cache: Cache, token, pos):
    """One-token decode against the KV cache.

    token: (B,) int32; pos: scalar int32 absolute position.
    For windowed attention the cache is a rolling buffer of size window.
    """
    B = token.shape[0]
    h = jnp.take(params["embed"], token[:, None], axis=0)  # (B, 1, D)
    positions = jnp.broadcast_to(pos, (B, 1))
    S_cache = cache.k.shape[2]
    slot = pos % S_cache if cfg.window is not None else pos

    def body(h, lp_and_cache):
        lp, kc, vc = lp_and_cache
        a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
        # valid-length mask: positions beyond `pos` (or outside the window)
        # are masked via key positions
        kpos = jnp.arange(S_cache)[None, :]
        if cfg.window is not None:
            # rolling buffer: entry i holds absolute position
            # pos - ((slot - i) mod S_cache)
            age = (slot - kpos) % S_cache
            abs_pos = pos - age
            valid = (abs_pos >= 0) & (abs_pos > pos - cfg.window)
        else:
            valid = kpos <= pos
        qg = L._split_gqa(q, cfg.n_kv)
        o = L._attend_block(
            qg, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2),
            valid[None, None, None], 1.0 / float(cfg.hd) ** 0.5,
        )
        o = L._merge_gqa(o)
        hh = h + o.reshape(B, 1, -1) @ lp["attn"]["wo"]
        b = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = L.moe_ffn(lp["moe"], b, cfg)
        else:
            f = L.mlp(lp["mlp"], b)
        return hh + f, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(body, h, (params["layers"], cache.k, cache.v))
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (h @ unembed)[:, 0]
    return logits, Cache(k=kcs, v=vcs, pos=pos + 1)
