"""Figure 3b: whole-path computation time on the climate-like dataset as a
function of the prescribed duality-gap accuracy, GAP rule vs no screening.

Paper: NCEP/NCAR Reanalysis 1, n=814, p=73577 (groups of 7 variables per
grid point), delta=2.5, tau*=0.4.  The offline generator reproduces the
group structure and preprocessing; the default grid is reduced so the
harness completes in CPU-minutes (``--full`` restores 144x73).
"""
from __future__ import annotations

import time

from repro.core import sgl
from repro.core.path import lambda_grid, solve_path
from repro.data.climate import make_climate_like

from .common import emit


def main(n=256, n_lon=16, n_lat=8, T=20, delta=2.5, tau=0.4,
         tols=(1e-4, 1e-6, 1e-8), max_epochs=3000) -> None:
    X, y, _, sizes = make_climate_like(n=n, n_lon=n_lon, n_lat=n_lat)
    problem = sgl.make_problem(X, y, sizes, tau=tau)
    lam_max = float(sgl.lambda_max(problem))
    lambdas = lambda_grid(lam_max, T=T, delta=delta)

    for rule in ("gap", "none"):
        for tol in tols:
            t0 = time.perf_counter()
            res = solve_path(problem, lambdas=lambdas, tol=tol,
                             max_epochs=max_epochs, rule=rule)
            dt = time.perf_counter() - t0
            case = f"{rule}_tol{tol:g}"
            emit("path_fig3b", case, "path_seconds", dt)
            emit("path_fig3b", case, "total_epochs", int(res.epochs.sum()))


if __name__ == "__main__":
    import argparse

    from .common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    header()
    if args.full:
        main(n=814, n_lon=144, n_lat=73, T=100)
    else:
        main()
