"""Session-API tests: legacy-wrapper parity (solve / solve_path /
solve_distributed), persistent-transposed-design accounting, unflatten,
st2-consuming screen, and the distributed path with sequential certificates.
"""
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    RoundResult,
    SGLSession,
    SolverConfig,
    flatten,
    lambda_max,
    make_problem,
    problem_from_grouped,
    solve,
    solve_path,
    unflatten,
)
from repro.core.screening import gap_sphere, screen
from repro.data.synthetic import make_synthetic
from repro.launch import mesh as meshlib


@pytest.fixture(scope="module")
def prob():
    # Reduced synthetic paper config (AR(1) design, equal groups, tau=0.2).
    X, y, _, sizes = make_synthetic(n=40, p=200, n_groups=20, gamma1=4,
                                    gamma2=3, seed=7)
    return make_problem(X, y, sizes, tau=0.2)


@pytest.fixture(scope="module")
def session_path(prob):
    session = SGLSession(prob, SolverConfig(tol=1e-8))
    res = session.solve_path(T=8, delta=2.0)
    return session, res


def test_session_path_matches_legacy_path(prob, session_path):
    """PathResult parity on the synthetic config: betas / gaps / epochs /
    screen counters (acceptance criterion: epochs within +-1 per lambda,
    identical seq/dyn counters)."""
    _, res = session_path
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = solve_path(prob, T=8, delta=2.0, tol=1e-8)
    np.testing.assert_allclose(res.betas, legacy.betas, atol=1e-10)
    np.testing.assert_allclose(res.gaps, legacy.gaps, rtol=1e-8, atol=1e-14)
    assert (res.gaps <= 1e-8).all()
    assert np.abs(res.epochs - legacy.epochs).max() <= 1
    assert np.array_equal(res.seq_screened, legacy.seq_screened)
    assert np.array_equal(res.dyn_screened, legacy.dyn_screened)
    assert np.array_equal(res.group_active, legacy.group_active)


def test_legacy_solve_delegates_to_session(prob):
    lam = 0.25 * float(lambda_max(prob))
    session = SGLSession(prob, SolverConfig(tol=1e-9))
    r_new = session.solve(lam)
    with pytest.deprecated_call():
        r_old = solve(prob, lam, tol=1e-9)
    np.testing.assert_allclose(np.asarray(r_new.beta),
                               np.asarray(r_old.beta), atol=1e-12)
    assert r_new.n_epochs == r_old.n_epochs
    assert np.array_equal(r_new.group_active, r_old.group_active)


def test_screen_round_is_roundresult(prob, session_path):
    session, res = session_path
    cert = session.screen(0.2 * session.lam_max, res.betas[-1])
    assert isinstance(cert, RoundResult)
    gap, theta, g_act, f_act = cert[:4]      # legacy positional quartet
    assert not bool(cert.compact)            # screen() is always a full round
    assert g_act.shape == (prob.G,)
    assert f_act.shape == (prob.G, prob.ng)
    assert float(gap) >= 0 or np.isfinite(float(gap))


def test_pallas_session_zero_transpose_copies(prob):
    """Acceptance criterion: Pallas-backed certified rounds perform zero
    per-call transposed copies — ONE persistent transposed design serves
    the whole path (built once, reused across solve_path calls)."""
    from repro.kernels import ops as kops

    s_pal = SGLSession(prob, SolverConfig(tol=1e-7,
                                          screen_backend="pallas"))
    s_xla = SGLSession(prob, SolverConfig(tol=1e-7, screen_backend="xla"))
    with kops.audit_scope() as audit:
        p_pal = s_pal.solve_path(T=5, delta=1.5)
    # The real audit: no jitted round traced an on-the-fly transpose — the
    # persistent design reached the kernel (a broken xt_pre wiring would
    # build a transposing trace on the first round and trip this).
    assert audit.transpose_traces == 0
    p_xla = s_xla.solve_path(T=5, delta=1.5)
    np.testing.assert_allclose(p_pal.betas, p_xla.betas, atol=1e-10)
    assert np.array_equal(p_pal.epochs, p_xla.epochs)
    assert p_pal.n_rounds > 0
    assert p_pal.n_transpose_copies == 0
    xt = s_pal.xt_pre
    assert xt is not None and xt.shape[0] >= prob.G * prob.ng
    s_pal.solve_path(T=3, delta=1.0)
    assert s_pal.xt_pre is xt                 # still the same buffer
    # XLA backend needs no transposed design at all.
    assert s_xla.xt_pre is None


def test_unflatten_inverts_flatten():
    rng = np.random.default_rng(3)
    n, sizes = 20, [3, 7, 5, 2]
    X = rng.standard_normal((n, sum(sizes)))
    y = rng.standard_normal(n)
    prob = make_problem(X, y, sizes, tau=0.3)
    beta = jnp.asarray(rng.standard_normal((prob.G, prob.ng))) * prob.feat_mask
    flat = flatten(prob, beta)
    assert flat.shape == (sum(sizes),)
    np.testing.assert_allclose(np.asarray(unflatten(prob, flat)),
                               np.asarray(beta))
    # flatten(unflatten(x)) is the identity on flat vectors too
    np.testing.assert_allclose(
        np.asarray(flatten(prob, unflatten(prob, flat))), np.asarray(flat)
    )


def test_screen_consumes_fused_st2(prob, session_path):
    """screen(backend='pallas') feeds the fused kernel's S_tau(corr)^2 to
    screen_with_corr instead of re-thresholding — masks must be identical
    to the einsum path."""
    session, res = session_path
    lam = 0.2 * session.lam_max
    cert = session.screen(lam, res.betas[-1])
    sphere = gap_sphere(prob, jnp.asarray(res.betas[-1]), cert.theta,
                        jnp.asarray(lam))
    r_x = screen(prob, sphere)
    r_p = screen(prob, sphere, backend="pallas")
    assert np.array_equal(np.asarray(r_x.group_active),
                          np.asarray(r_p.group_active))
    assert np.array_equal(np.asarray(r_x.feat_active),
                          np.asarray(r_p.feat_active))


# ---------------------------------------------------------------------------
# Distributed strategy
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dist_prob():
    X, y, _, sizes = make_synthetic(n=40, p=160, n_groups=16, gamma1=3,
                                    gamma2=3, seed=3, dtype=np.float64)
    return X, y, sizes


def test_dist_session_matches_legacy_wrapper(dist_prob):
    X, y, sizes = dist_prob
    n, p = X.shape
    G, ng = len(sizes), p // len(sizes)
    tau = 0.3
    problem = make_problem(X, y, sizes, tau=tau)
    lam = float(lambda_max(problem)) / 10.0
    L = float(np.linalg.norm(X, 2) ** 2)
    mesh = meshlib.make_test_mesh()

    session = SGLSession(problem, SolverConfig(tol=1e-7, max_epochs=20_000),
                         mesh=mesh, L=L)
    res = session.solve(lam)

    from repro.distributed.solver_dist import solve_distributed
    Xg = jnp.asarray(X.reshape(n, G, ng))
    w = jnp.sqrt(jnp.full((G,), float(ng), jnp.float64))
    with pytest.deprecated_call():
        beta, gap, gaps, mask = solve_distributed(
            mesh, Xg, jnp.asarray(y), w, tau=tau, lam_=lam, L=L,
            tol=1e-7, max_steps=20_000,
        )
    assert float(res.gap) <= 1e-7 and gap <= 1e-7
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(beta),
                               atol=1e-9)
    assert res.n_epochs == gaps[-1][0]


def test_dist_path_sequential_certificates_are_safe(dist_prob):
    """Distributed path safety: nothing sequentially (or dynamically)
    screened under the mesh may be nonzero in a single-device tight-tol
    reference solution."""
    X, y, sizes = dist_prob
    tau = 0.3
    problem = make_problem(X, y, sizes, tau=tau)
    mesh = meshlib.make_test_mesh()
    session = SGLSession(problem, SolverConfig(tol=1e-6, max_epochs=20_000),
                         mesh=mesh)
    path = session.solve_path(T=5, delta=1.5)
    assert (path.gaps <= 1e-6).all()
    # Sequential certificates were actually exercised on the mesh, and the
    # coinciding-certificate runs went through the batched-lambda kernel.
    assert path.seq_screened.sum() > 0
    assert session.batched_lambdas > 0

    feat_mask = np.asarray(problem.feat_mask)
    ref_session = SGLSession(problem, SolverConfig(tol=1e-10, rule="none",
                                                   max_epochs=60_000))
    beta_ref = jnp.zeros((problem.G, problem.ng), problem.X.dtype)
    for t, lam_ in enumerate(path.lambdas):
        ref = ref_session.solve(float(lam_), beta0=beta_ref)
        beta_ref = ref.beta
        screened = ~path.feat_active[t] & feat_mask
        leaked = np.abs(np.asarray(ref.beta))[screened]
        assert leaked.size == 0 or leaked.max() < 1e-7, (t, leaked.max())


def test_dist_f32_converged_certificate_not_reported(dist_prob):
    """Sub-f64 mesh runs must not adopt/report the masks of a round the
    solve converged on (cancellation error can mis-certify borderline
    groups) — mirrors the single-device path reporter guard."""
    X, y, sizes = dist_prob
    problem = make_problem(X.astype(np.float32), y.astype(np.float32),
                           sizes, tau=0.3)
    mesh = meshlib.make_test_mesh()
    session = SGLSession(problem, SolverConfig(tol=1e-3, max_epochs=2000),
                         mesh=mesh)
    path = session.solve_path(T=3, delta=1.0)
    # lambda_max converges on its sequential certificate with zero steps;
    # in f32 the certificate is neither applied nor reported.
    assert path.epochs[0] == 0
    assert path.seq_screened[0] == 0
    assert path.group_active[0].all()
    assert float(np.abs(path.betas[0]).max()) == 0.0


def test_dist_lipschitz_safeguard_recovers_from_bad_L(dist_prob):
    """An under-estimated global Lipschitz constant makes FISTA diverge;
    the safeguard must raise L at runtime and still reach tolerance."""
    X, y, sizes = dist_prob
    problem = make_problem(X, y, sizes, tau=0.3)
    lam = float(lambda_max(problem)) / 10.0
    L_exact = float(np.linalg.norm(X, 2) ** 2)
    mesh = meshlib.make_test_mesh()
    session = SGLSession(problem, SolverConfig(tol=1e-6, max_epochs=40_000),
                         mesh=mesh, L=L_exact / 16.0)
    res = session.solve(lam)
    assert float(res.gap) <= 1e-6
    assert session._dist.L >= L_exact * 0.9     # safeguard raised it
    ref = SGLSession(problem, SolverConfig(tol=1e-8)).solve(lam)
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(ref.beta),
                               atol=5e-3)


def test_dist_nan_round_does_not_adopt_masks(dist_prob):
    """A FISTA blow-up makes the screen round's comparisons all read False;
    adopting those masks would permanently zero beta and report false
    zero-certificates.  The driver must skip non-finite rounds' masks,
    rewind, and still converge to the right solution."""
    X, y, sizes = dist_prob
    problem = make_problem(X, y, sizes, tau=0.3)
    lam = float(lambda_max(problem)) / 10.0
    L_exact = float(np.linalg.norm(X, 2) ** 2)
    mesh = meshlib.make_test_mesh()
    session = SGLSession(problem, SolverConfig(tol=1e-6, max_epochs=40_000),
                         mesh=mesh, L=L_exact / 2 ** 40)
    res = session.solve(lam)
    assert float(res.gap) <= 1e-6
    assert res.group_active.any()               # not the all-False wipe-out
    ref = SGLSession(problem, SolverConfig(tol=1e-8)).solve(lam)
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(ref.beta),
                               atol=5e-3)
    support = np.abs(np.asarray(ref.beta)) > 1e-7
    assert not np.any(support & ~res.feat_active)


def test_dist_session_rejects_non_gap_rules(dist_prob):
    """The sharded screen kernel only produces GAP certificates; a mesh
    session must refuse other rules instead of silently relabeling."""
    X, y, sizes = dist_prob
    problem = make_problem(X, y, sizes, tau=0.3)
    mesh = meshlib.make_test_mesh()
    with pytest.raises(ValueError, match="rule='gap' only"):
        SGLSession(problem, SolverConfig(rule="dynamic"), mesh=mesh)
    session = SGLSession(problem, SolverConfig(tol=1e-6), mesh=mesh)
    with pytest.raises(ValueError, match="rule='gap' only"):
        session.screen(1.0, rule="dst3")


def test_problem_from_grouped_safe_bounds(dist_prob):
    """The cheap grouped constructor must over-estimate (never under-) the
    spectral norms, keeping Theorem-1 tests safe."""
    X, y, sizes = dist_prob
    n, p = X.shape
    G, ng = len(sizes), p // len(sizes)
    exact = make_problem(X, y, sizes, tau=0.3)
    cheap = problem_from_grouped(X.reshape(n, G, ng), y, tau=0.3)
    assert np.all(np.asarray(cheap.Xnorm_grp) >=
                  np.asarray(exact.Xnorm_grp) - 1e-8)
    np.testing.assert_allclose(np.asarray(cheap.Xnorm_col),
                               np.asarray(exact.Xnorm_col), rtol=1e-10)
    assert np.array_equal(np.asarray(cheap.feat_mask),
                          np.asarray(exact.feat_mask))


def test_unknown_backend_raises_at_config_construction():
    """Backend typos fail at SolverConfig() with the valid choices — not
    as a jit-time error deep inside the first certified round."""
    with pytest.raises(ValueError, match="screen backend.*cuda"):
        SolverConfig(screen_backend="cuda")
    with pytest.raises(ValueError, match="solver backend.*gpu"):
        SolverConfig(solver_backend="gpu")
    # the valid values (and _replace) still construct fine
    cfg = SolverConfig(screen_backend="pallas", solver_backend="xla")
    assert cfg._replace(tol=1e-6).screen_backend == "pallas"
