"""Pluggable screening-rule strategies for the SGL solver family.

The paper's headline result is a *comparison* — GAP safe (sequential +
dynamic) against static safe spheres, plain dynamic safe spheres, and
unsafe sequential heuristics (Fig. 2/3) — and the journal follow-up
(Ndiaye et al. 2017) shows all of them share ONE sphere-test skeleton,
differing only in how the sphere's center and radius are built.  This
package is that observation as an API:

* :class:`ScreeningRule` (:mod:`repro.rules.base`) — the strategy
  protocol: safety/sequential/compact metadata plus
  ``center_and_radius(state) -> (center, radius, corr_at_center)``;
* the registered implementations (:mod:`repro.rules.library`):
  :class:`GapSafeRule` (``"gap"``), :class:`StaticSafeRule`
  (``"static"``), :class:`DynamicSafeRule` (``"dynamic"``),
  :class:`Dst3Rule` (``"dst3"``), :class:`NoScreening` (``"none"``), and
  the explicitly-unsafe :class:`StrongSequentialRule` (``"strong"``);
* the registry (:mod:`repro.rules.registry`) — ``resolve_rule`` keeps
  legacy string configs working and fails fast on unknown names with the
  registered list.

The shared skeleton lives in :func:`repro.core.solver._screen_round`: the
residual, the Eq. 15 dual scaling, the duality gap, the Theorem-1 tests,
the Pallas corr/dual-norm kernel routing (with the session's persistent
transposed design + transpose audit), and the compacted-round machinery
are all rule-independent — a rule only supplies its sphere and gets the
rest for free, on every strategy (single-device BCD, batched-lambda,
distributed FISTA for the rules each supports).

Adding a rule
-------------
Subclass :class:`ScreeningRule` as a frozen dataclass (instances are jit
static arguments — they must stay hashable value objects), set the
metadata honestly (``is_safe=True`` is a *proof obligation*, see the
safety contract in :mod:`repro.rules.base`), implement
``center_and_radius`` from the :class:`RuleState` the skeleton hands you,
and ``register_rule(MyRule())``.  Every front-end — ``SolverConfig(rule=
MyRule())`` or ``rule="my-name"`` — and the Fig. 2/3 sweep harness
(``benchmarks/sweep_rules.py``) pick it up immediately.  Newer rule
families (e.g. the Dual Feature Reduction rules of Feser & Evangelou
2024) slot in the same way: one sphere construction, zero solver changes.
"""
from .base import RuleState, ScreeningRule
from .library import (
    Dst3Rule,
    DynamicSafeRule,
    GapSafeRule,
    NoScreening,
    StaticSafeRule,
    StrongSequentialRule,
)
from .registry import available_rules, get_rule, register_rule, resolve_rule

__all__ = [
    "RuleState",
    "ScreeningRule",
    "GapSafeRule",
    "StaticSafeRule",
    "DynamicSafeRule",
    "Dst3Rule",
    "NoScreening",
    "StrongSequentialRule",
    "available_rules",
    "get_rule",
    "register_rule",
    "resolve_rule",
]

# Built-in registrations: the paper's Fig. 2/3 rule family.
register_rule(GapSafeRule())
register_rule(StaticSafeRule())
register_rule(DynamicSafeRule())
register_rule(Dst3Rule())
register_rule(NoScreening())
register_rule(StrongSequentialRule())
