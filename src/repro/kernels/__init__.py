"""Pallas TPU kernels for the paper's compute hot spots.

Four kernels (each `<name>.py` + dispatch in `ops.py` + oracle in `ref.py`):

* ``sgl_prox``         -- fused two-level proximal operator (soft-threshold +
                         group soft-threshold) over (G, ng) coefficient tiles.
                         Runs every solver step on the full coefficient block.
* ``dual_norm``        -- per-group epsilon-norm Lambda(x, alpha, R) by
                         fixed-iteration bisection; no sort, pure VPU work.
* ``screening_scores`` -- fused correlation matvec X^T theta with the
                         soft-thresholded square needed by the Theorem-1
                         tests, accumulated in VMEM so the correlation vector
                         never round-trips through HBM before thresholding;
                         plus a corr-only variant for the certified gap
                         round, which rescales before thresholding and fed
                         from the session's persistent transposed design
                         (``ops.prepare_transposed``) avoids the per-round
                         (p, n) transposed copy of X.
* ``bcd_epoch``        -- fused BCD *epoch* mega-kernel: whole blocks of
                         cyclic BCD passes (gradient step + two-level prox
                         + residual update per group) in ONE launch, with
                         the residual and coefficient block VMEM-resident,
                         the compacted design streamed tile-by-tile, and a
                         lambda-batch grid axis for coinciding-active-set
                         path points.  Replaces the per-group ``lax.scan``
                         dispatch on the solver's hottest loop
                         (``SolverConfig.solver_backend="pallas"``).

On CPU (this container) they execute with ``interpret=True`` and are validated
against the ``ref.py`` pure-jnp oracles; on TPU the same code lowers to Mosaic.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
