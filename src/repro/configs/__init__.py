from .registry import ARCH_IDS, get, list_archs

__all__ = ["get", "list_archs", "ARCH_IDS"]
