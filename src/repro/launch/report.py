"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables, and
render the screening-rule sweep report (paper Fig. 2/3 layout).

    PYTHONPATH=src python -m repro.launch.report artifacts/dryrun

Prints markdown: the §Dry-run status matrix and the §Roofline single-pod
table (three terms, bottleneck, useful-flops ratio) plus per-cell notes on
what would move the dominant term.

:func:`render_sweep_markdown` turns a ``benchmarks/sweep_rules.py`` JSON
payload (``BENCH_pr5.json`` schema) into the markdown report — kept here so
``repro.launch.reanalyze --sweep`` can re-render a saved sweep after
renderer improvements without re-running any solver, the same
recompute-free pattern the dry-run HLO reanalysis uses.

:func:`render_analysis_markdown` does the same for the static-analysis
gate's JSON payload (``repro.analysis/v1`` schema, see
``python -m repro.analysis --check --report``): the saved findings JSON is
the source of truth and the markdown is always re-renderable from it.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(out_dir: str):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def _fmt_t(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def _hint(cell) -> str:
    r = cell.get("roofline") or {}
    b = r.get("bottleneck")
    kind = cell.get("kind")
    if b == "memory":
        if kind == "train":
            return "less remat / fuse optimizer+cast to cut HBM traffic"
        return "KV-cache layout + quantization to cut HBM reads"
    if b == "collective":
        return "re-shard to cut all-gathers; overlap collectives with compute"
    return "already compute-bound; larger per-chip tile helps MXU util"


def dryrun_matrix(cells):
    print("\n### Dry-run status matrix (compile on 16x16=256 and "
          "2x16x16=512 meshes)\n")
    keyed = {}
    for c in cells:
        keyed[(c["arch"], c["shape"], c.get("multi_pod", False))] = c
    archs = sorted({c["arch"] for c in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "solve",
              "fista+screen"]
    shapes = [s for s in shapes
              if any(c["shape"].startswith(s.split("+")[0]) or c["shape"] == s
                     for c in cells)]
    hdr = "| arch | " + " | ".join(
        f"{s} (1pod/2pod)" for s in shapes) + " |"
    print(hdr)
    print("|" + "---|" * (len(shapes) + 1))
    for a in archs:
        row = [a]
        for s in shapes:
            marks = []
            for mp in (False, True):
                c = keyed.get((a, s, mp))
                if c is None:
                    cands = [v for (aa, ss, m), v in keyed.items()
                             if aa == a and m == mp and ss.startswith(s[:5])]
                    c = cands[0] if cands else None
                if c is None:
                    marks.append("·")
                else:
                    st = c.get("status")
                    marks.append({"ok": "✓", "skipped": "skip",
                                  "error": "✗", "timeout": "T"}.get(st, "?"))
            row.append("/".join(marks))
        print("| " + " | ".join(row) + " |")


def roofline_table(cells, multi_pod=False):
    title = "multi-pod (512 chips)" if multi_pod else "single-pod (256 chips)"
    print(f"\n### Roofline — {title}\n")
    print("| arch | shape | t_compute | t_memory | t_collective | bound |"
          " model/HLO flops | roofline frac | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("multi_pod") != multi_pod or c.get("status") != "ok":
            continue
        r = c.get("roofline")
        if not r:
            # sgl-paper cell stores one entry per kernel variant
            subs = [k for k in c
                    if isinstance(c.get(k), dict) and "roofline" in c[k]]
            for sub in subs:
                if sub in c:
                    rr = c[sub]["roofline"]
                    print(f"| {c['arch']} | {sub} | "
                          f"{_fmt_t(rr['t_compute_s'])} | "
                          f"{_fmt_t(rr['t_memory_s'])} | "
                          f"{_fmt_t(rr['t_collective_s'])} | "
                          f"{rr['bottleneck']} | "
                          f"{(rr.get('useful_flops_ratio') or 0):.3f} | "
                          f"{rr['roofline_fraction']:.4f} | "
                          f"{_hint({'roofline': rr, 'kind': 'solve'})} |")
            continue
        print(f"| {c['arch']} | {c['shape']} | "
              f"{_fmt_t(r['t_compute_s'])} | {_fmt_t(r['t_memory_s'])} | "
              f"{_fmt_t(r['t_collective_s'])} | {r['bottleneck']} | "
              f"{(r.get('useful_flops_ratio') or 0):.3f} | "
              f"{r['roofline_fraction']:.4f} | {_hint(c)} |")


def memory_table(cells):
    print("\n### Per-device memory (single-pod, from "
          "compiled.memory_analysis())\n")
    print("| arch | shape | args | temps | peak |")
    print("|---|---|---|---|---|")
    gb = 1 << 30
    for c in cells:
        if c.get("multi_pod") or c.get("status") != "ok":
            continue
        m = c.get("memory")
        if not m:
            continue
        print(f"| {c['arch']} | {c['shape']} | "
              f"{(m.get('argument_bytes') or 0) / gb:.2f} GiB | "
              f"{(m.get('temp_bytes') or 0) / gb:.2f} GiB | "
              f"{(m.get('peak_bytes') or 0) / gb:.2f} GiB |")


# ---------------------------------------------------------------------------
# Screening-rule sweep report (paper Fig. 2/3 layout)
# ---------------------------------------------------------------------------


def _fig2c_value(curve, epoch):
    """Step-function read-out of an (epoch, frac) curve at ``epoch``:
    the last applied screen at or before it (1.0 before any screen)."""
    val = 1.0
    for e, frac in curve:
        if e > epoch:
            break
        val = frac
    return val


def render_sweep_markdown(payload: dict) -> str:
    """Markdown report for a ``sweep_rules`` JSON payload.

    Layout mirrors the paper's figures: Fig. 2a/2b (active-variable
    fraction along the lambda path, one column per rule), Fig. 2c (active
    fraction as a function of epochs at a fixed lambda), Fig. 3
    (computation to tolerance per rule x tol).  Unsafe rules are starred —
    their screened sets are heuristic discards, not certificates.
    """
    meta = payload.get("meta", {})
    curves = payload.get("curves", {})
    out = ["# Screening-rule sweep — paper Fig. 2/3 layout", ""]
    out.append("Generated by `benchmarks/sweep_rules.py`; re-render with "
               "`python -m repro.launch.reanalyze --sweep <json>`.")
    out.append("")
    for k in ("config", "jax_version", "backend", "platform", "x64"):
        if k in meta:
            out.append(f"- **{k}**: {meta[k]}")
    out.append("")

    # Group curves by (config, T, tol); one figure block per group.
    groups: dict = {}
    for key, c in curves.items():
        groups.setdefault((c["config"], c["T"], c["tol"]), {})[c["rule"]] = c
    for (cfg, T, tol), by_rule in sorted(groups.items()):
        rules = sorted(by_rule, key=lambda r: (not by_rule[r]["safe"], r))
        star = {r: ("" if by_rule[r]["safe"] else "*") for r in rules}
        out.append(f"## {cfg} — T={T}, tol={tol:g}")
        out.append("")

        any_c = by_rule[rules[0]]
        lambdas = any_c["lambdas"]
        lam0 = lambdas[0]
        idxs = sorted({int(round(i)) for i in
                       [t * (T - 1) / min(9, T - 1) for t in
                        range(min(10, T))]}) if T > 1 else [0]

        out.append("### Fig. 2a/2b — active-variable fraction along the "
                   "lambda path")
        out.append("")
        out.append("Feature-level active fraction (1.0 = nothing screened); "
                   "lower is better screening.")
        out.append("")
        out.append("| t | lambda/lambda_max | "
                   + " | ".join(r + star[r] for r in rules) + " |")
        out.append("|---|---|" + "---|" * len(rules))
        for t in idxs:
            row = [str(t), f"{lambdas[t] / lam0:.3g}"]
            row += [f"{by_rule[r]['active_feat_frac'][t]:.3f}"
                    for r in rules]
            out.append("| " + " | ".join(row) + " |")
        out.append("")

        fig2c = {r: by_rule[r].get("fig2") for r in rules}
        if any(fig2c.values()):
            t_star = next(c["lambda_index"] for c in fig2c.values() if c)
            max_e = max((c["epoch_curve"][-1][0] if c and c["epoch_curve"]
                         else 0) for c in fig2c.values())
            checkpoints, e = [0], 1
            while e <= max_e:
                checkpoints.append(e)
                e *= 2
            if max_e and checkpoints[-1] != max_e:
                checkpoints.append(max_e)
            out.append(f"### Fig. 2c — active feature fraction vs epoch at "
                       f"lambda index t={t_star} "
                       f"(lambda/lambda_max={lambdas[t_star] / lam0:.3g})")
            out.append("")
            out.append("| epoch | "
                       + " | ".join(r + star[r] for r in rules) + " |")
            out.append("|---|" + "---|" * len(rules))
            for e in checkpoints:
                row = [str(e)]
                for r in rules:
                    c = fig2c[r]
                    curve = ([(pt[0], pt[2]) for pt in c["epoch_curve"]]
                             if c else [])
                    row.append(f"{_fig2c_value(curve, e):.3f}")
                out.append("| " + " | ".join(row) + " |")
            out.append("")

        out.append("### Fig. 3 — computation to tolerance")
        out.append("")
        out.append("| rule | safe | converged | total epochs | wall s | "
                   "seq discards | dyn discards | compact/full rounds | "
                   "round GFLOPs |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for r in rules:
            c = by_rule[r]
            out.append(
                f"| {r}{star[r]} | {'yes' if c['safe'] else 'NO'} | "
                f"{c['converged_lambdas']}/{T} | {sum(c['epochs'])} | "
                f"{c['wall_seconds']:.1f} | {sum(c['seq_screened'])} | "
                f"{sum(c['dyn_screened'])} | "
                f"{c['n_compact_rounds']}/{c['n_full_rounds']} | "
                f"{c['round_flops'] / 1e9:.2f} |")
        out.append("")
        if any(not by_rule[r]["safe"] for r in rules):
            out.append("\\* unsafe heuristic — screened sets are NOT "
                       "certificates (`PathResult.certificates_safe=False`);"
                       " a wrong discard shows up as a lambda that fails to "
                       "converge (the reported duality gap is always "
                       "full-problem exact).")
            out.append("")
    return "\n".join(out)


def render_analysis_markdown(payload: dict) -> str:
    """Markdown report for a ``repro.analysis/v1`` findings payload.

    One section per pass (what was checked, finding count), then a table
    of every finding sorted error-first.  The JSON is the machine artifact
    (CI uploads both); this rendering is re-runnable from the saved JSON
    without re-tracing anything.
    """
    summary = payload.get("summary", {})
    passes = payload.get("passes", {})
    findings = payload.get("findings", [])
    verdict = "PASS" if payload.get("ok") else "FAIL"
    out = [f"# Static-analysis gate — {verdict}", ""]
    out.append(f"{summary.get('errors', 0)} errors, "
               f"{summary.get('warnings', 0)} warnings, "
               f"{summary.get('infos', 0)} info findings "
               f"({len(passes)} passes).")
    out.append("")
    for name, ctx in sorted(passes.items()):
        out.append(f"## pass `{name}` — {ctx.get('findings', 0)} findings")
        out.append("")
        if "entry_points" in ctx:
            out.append(f"- traced entry points: "
                       f"{', '.join(ctx['entry_points'])}")
            out.append(f"- retrace-checked: "
                       f"{', '.join(ctx.get('retrace_checked', [])) or '—'}")
        if "kernels" in ctx:
            out.append(f"- audited kernel launches: "
                       f"{', '.join(ctx['kernels'])}")
            budget = ctx.get("vmem_budget_bytes")
            if budget:
                out.append(f"- VMEM budget: {budget / 2**20:.0f} MiB per "
                           f"grid step")
        out.append("")
    if findings:
        rank = {"error": 0, "warning": 1, "info": 2}
        out.append("## Findings")
        out.append("")
        out.append("| severity | pass | code | location | message |")
        out.append("|---|---|---|---|---|")
        for f in sorted(findings,
                        key=lambda f: (rank.get(f["severity"], 3),
                                       f["pass_name"], f["code"])):
            msg = f["message"].replace("|", "\\|").replace("\n", " ")
            out.append(f"| {f['severity']} | {f['pass_name']} | "
                       f"{f['code']} | `{f['location']}` | {msg} |")
        out.append("")
    else:
        out.append("No findings: every checked invariant holds.")
        out.append("")
    return "\n".join(out)


def _fmt_s(x) -> str:
    if x is None:
        return "—"
    x = float(x)
    if x >= 1.0:
        return f"{x:.3f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}µs"


def _stage_table(stages: dict, out: list) -> None:
    out.append("| stage | n | p50 | p99 | mean |")
    out.append("|---|---|---|---|---|")
    for name in sorted(stages):
        s = stages[name]
        out.append(f"| `{name}` | {s.get('n', 0)} | "
                   f"{_fmt_s(s.get('p50'))} | {_fmt_s(s.get('p99'))} | "
                   f"{_fmt_s(s.get('mean'))} |")
    out.append("")


def render_obs_markdown(payload: dict) -> str:
    """Markdown report for a ``repro.obs.bench/v1`` payload.

    One section per recorded bench section (kernel timings, path smoke,
    serve load), re-renderable from the saved JSON via
    ``reanalyze --obs`` — the same raw-next-to-derived pattern as the
    sweep and analysis reports.
    """
    meta = payload.get("meta", {})
    sections = payload.get("sections", {})
    out = ["# Observability bench (repro.obs.bench/v1)", ""]
    if meta:
        out.append("; ".join(f"{k}={meta[k]}" for k in sorted(meta)))
        out.append("")

    kern = sections.get("kernels")
    if kern:
        rows = kern.get("kernels", {})
        out.append(f"## Kernels — measured wall-clock "
                   f"({kern.get('scale', '?')} scale)")
        out.append("")
        out.append("| kernel | measured | min | model GFLOP | "
                   "achieved vs peak | vs model | bottleneck |")
        out.append("|---|---|---|---|---|---|---|")
        for name in sorted(rows):
            r = rows[name]
            a = r.get("achieved", {})
            interp = " (interp)" if r.get("interpret") else ""
            out.append(
                f"| `{name}`{interp} | {_fmt_s(r.get('measured_s'))} | "
                f"{_fmt_s(r.get('min_s'))} | "
                f"{r.get('model_flops', 0) / 1e9:.4f} | "
                f"{a.get('frac_peak_compute', 0):.2e} | "
                f"{a.get('achieved_vs_model', 0):.2e} | "
                f"{a.get('model_bottleneck', '—')} |")
        out.append("")
        if any(r.get("interpret") for r in rows.values()):
            out.append("Interpret-mode rows measure the Pallas emulation "
                       "on CPU — the achieved-vs-peak column is only "
                       "meaningful on a real TPU backend.")
            out.append("")

    path = sections.get("path")
    if path:
        out.append("## Path smoke — tracing overhead contract")
        out.append("")
        sh = path.get("shape", {})
        out.append(f"- shape: {sh}")
        out.append(f"- untraced: {_fmt_s(path.get('base_s'))}; "
                   f"traced: {_fmt_s(path.get('obs_s'))}; overhead "
                   f"{path.get('overhead_frac', 0):+.2%} "
                   f"(bit-identical: {path.get('bit_identical')})")
        out.append(f"- span counts: {path.get('span_counts', {})}")
        out.append("")
        if path.get("stages"):
            _stage_table(path["stages"], out)

    serve = sections.get("serve")
    if serve:
        out.append("## Serve load — end-to-end + per-stage breakdown")
        out.append("")
        wl = serve.get("workload", {})
        lat = serve.get("latency_s", {})
        base = serve.get("baseline_latency_s", {})
        out.append(f"- workload: {wl.get('tenants', '?')} tenants, "
                   f"n={wl.get('n')}, p={wl.get('p')}, "
                   f"groups={wl.get('groups')}, T={wl.get('T')}")
        out.append(f"- serve: p50 {_fmt_s(lat.get('p50'))}, "
                   f"p99 {_fmt_s(lat.get('p99'))}, "
                   f"{serve.get('requests_per_sec', 0):.2f} req/s")
        out.append(f"- baseline: p50 {_fmt_s(base.get('p50'))}, "
                   f"p99 {_fmt_s(base.get('p99'))}, "
                   f"{serve.get('baseline_requests_per_sec', 0):.2f} "
                   f"req/s (speedup {serve.get('speedup_rps', 0):.2f}x)")
        qw = serve.get("queue_wait_s", {})
        if qw:
            out.append(f"- queue wait: p50 {_fmt_s(qw.get('p50'))}, "
                       f"p99 {_fmt_s(qw.get('p99'))} over "
                       f"{qw.get('count', 0)} requests")
        out.append("")
        if serve.get("stages"):
            _stage_table(serve["stages"], out)
        if serve.get("counters"):
            nz = {k: v for k, v in sorted(serve["counters"].items()) if v}
            out.append(f"- counters (nonzero): {nz}")
            out.append("")

    for name in sorted(sections):
        if name in ("kernels", "path", "serve"):
            continue
        out.append(f"## `{name}`")
        out.append("")
        out.append("```json")
        out.append(json.dumps(sections[name], indent=2, sort_keys=True))
        out.append("```")
        out.append("")
    return "\n".join(out)


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    cells = load(out_dir)
    ok = sum(1 for c in cells if c.get("status") == "ok")
    sk = sum(1 for c in cells if c.get("status") == "skipped")
    err = len(cells) - ok - sk
    print(f"# Dry-run report: {ok} ok / {sk} skipped / {err} failed "
          f"({len(cells)} cells)")
    dryrun_matrix(cells)
    roofline_table(cells, multi_pod=False)
    roofline_table(cells, multi_pod=True)
    memory_table(cells)


if __name__ == "__main__":
    main()
