"""Request/response types and value digests for the serving layer.

Everything the serving layer keys on is a *value* digest, not an object
identity: two tenants submitting numerically identical problems (typical
in multi-tenant traffic — the same reference design shipped to every
client) must land on the same cached session, the same stored path, and
the same coalesced batch even though their arrays are distinct buffers.

Three nested identities, coarse to fine:

* **compat signature** (:func:`compat_signature`) — shape, group layout,
  dtype, tau, and the :meth:`SolverConfig.cache_token` statics.  Requests
  with equal signatures drive identical jitted programs; this is the
  coalescing *compatibility* test and the retrace boundary.
* **design digest** (:func:`design_digest`) — compat signature plus the
  bytes of X and w.  Perturbed-``y`` re-solves share it; the certificate
  store and the shared transposed-design cache key on it.
* **problem digest** (:func:`problem_digest`) — design digest plus the
  bytes of y.  Requests with equal problem digests solve the *same*
  optimisation problem; the session cache keys on it, and adding the
  lambda grid (:meth:`PathRequest.digest`) identifies a whole request.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple, Optional, Sequence

import numpy as np

from ..core.session import PathResult, SolverConfig
from ..core.sgl import SGLProblem

__all__ = [
    "array_digest",
    "compat_signature",
    "design_digest",
    "problem_digest",
    "PathRequest",
    "PathResponse",
]


def array_digest(x) -> str:
    """Stable value digest of an array: blake2b over shape + dtype +
    C-contiguous bytes (16 hex chars — collision-safe at cache scale)."""
    a = np.ascontiguousarray(np.asarray(x))
    h = hashlib.blake2b(digest_size=8)
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()


class CompatSignature(NamedTuple):
    """Coalescing-compatibility key: same (n, p, group layout, tau, dtype)
    and the same compile-relevant solver statics."""

    n: int
    G: int
    ng: int
    layout: str          # feat_mask value digest (the group layout)
    dtype: str
    tau: float
    statics: tuple       # SolverConfig.cache_token()


def compat_signature(problem: SGLProblem,
                     config: SolverConfig) -> CompatSignature:
    return CompatSignature(
        n=problem.n, G=problem.G, ng=problem.ng,
        layout=array_digest(problem.feat_mask),
        dtype=str(problem.X.dtype),
        tau=float(problem.tau),
        statics=config.cache_token(),
    )


def design_digest(problem: SGLProblem, config: SolverConfig) -> str:
    """Identity of the design side of a problem (everything but y)."""
    h = hashlib.blake2b(digest_size=8)
    h.update(repr(compat_signature(problem, config)).encode())
    h.update(array_digest(problem.X).encode())
    h.update(array_digest(problem.w).encode())
    return h.hexdigest()


def problem_digest(problem: SGLProblem, config: SolverConfig) -> str:
    h = hashlib.blake2b(digest_size=8)
    h.update(design_digest(problem, config).encode())
    h.update(array_digest(problem.y).encode())
    return h.hexdigest()


@dataclasses.dataclass
class PathRequest:
    """One tenant's lambda-path solve.

    ``lambdas`` is the explicit grid (largest first, as everywhere else);
    ``config`` defaults to the server's default config.  ``warm_start``
    opts this request out of certificate-store warm starts (the stored
    hints are safe either way — the flag exists for A/B measurement).
    """

    tenant: str
    problem: SGLProblem
    lambdas: Sequence[float]
    config: Optional[SolverConfig] = None
    warm_start: bool = True

    def resolved_config(self, default: SolverConfig) -> SolverConfig:
        return self.config if self.config is not None else default

    def grid(self) -> np.ndarray:
        return np.asarray(self.lambdas, float)

    def digest(self, default_config: SolverConfig) -> str:
        """Full request identity: problem + grid + config statics (tenant
        excluded — identical requests from different tenants coalesce)."""
        cfg = self.resolved_config(default_config)
        h = hashlib.blake2b(digest_size=8)
        h.update(problem_digest(self.problem, cfg).encode())
        h.update(array_digest(self.grid()).encode())
        return h.hexdigest()


@dataclasses.dataclass
class PathResponse:
    """A solved path plus serving metadata.

    ``result.certificates_safe`` keeps the PathResult contract end-to-end:
    it reflects the screening rule that actually ran, never a stored
    certificate (stored state warm-starts, it never certifies — see
    :mod:`repro.serve.store`).
    """

    tenant: str
    request_digest: str
    result: PathResult
    served_from: str         # "solve" | "store" | "coalesced"
    coalesced_n: int = 1     # requests served by the same path solve
    session_cache_hit: bool = False
    store_hit: bool = False
    warm_started: bool = False
    warm_source_lam: Optional[float] = None
    resumed_from: Optional[int] = None   # lambda cursor a resume started at
    merged_grid: bool = False
    queue_s: float = 0.0
    solve_s: float = 0.0
