"""Architecture config system.

Every assigned architecture is an :class:`ArchConfig` instance in its own
``configs/<id>.py`` module; ``configs.registry.get(name)`` resolves it.  The
``reduced()`` method produces the CPU-smoke-test variant (same family / same
code paths, tiny dims).  Input shapes are :class:`ShapeSpec` entries; the 4
assigned LM shapes are defined here once.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None   # sliding-window size (mixtral, local attn)
    moe: Optional[MoEConfig] = None
    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    conv_width: int = 4
    hybrid_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    # enc-dec
    n_enc_layers: int = 0
    # vlm / audio stubs
    frontend_tokens: int = 0       # patch/frame embeddings prepended
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # capability flags
    subquadratic: bool = False     # can run long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 2 if not self.hybrid_pattern else
                         len(self.hybrid_pattern)),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv=min(self.n_kv, 2) if self.n_heads else 0,
            head_dim=16 if self.n_heads else None,
            d_ff=128,
            vocab=256,
            window=min(self.window, 32) if self.window else None,
            ssm_state=16 if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_head_dim=16 if self.ssm_heads else 0,
            ssm_chunk=8,
            n_enc_layers=min(self.n_enc_layers, 2),
            frontend_tokens=8 if self.frontend_tokens else 0,
        )
        if self.moe is not None:
            changes["moe"] = MoEConfig(
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                capacity_factor=self.moe.capacity_factor,
            )
        return dataclasses.replace(self, **changes)


# Tiny dense LM used by the examples/launch demo paths and the model smoke
# tests — already reduced-sized, so ``DEMO.reduced()`` is a fixed point.
DEMO = ArchConfig(
    name="demo",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    qk_norm=True,
    subquadratic=False,
    notes="tiny dense GQA config for CPU demos and smoke tests",
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell is runnable; reason if not.

    long_500k needs sub-quadratic attention (DESIGN.md §Arch-applicability).
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "full O(L^2) attention at 524k context — skipped by design "
            "(see DESIGN.md §Arch-applicability)"
        )
    return True, ""
