"""The seeded chaos matrix — executable spec of the degradation protocol.

Every registered fault kind is driven against a small (n=24, p=64, G=8,
f64) problem and the outcome is asserted against the contract the README
states in prose:

* **bit-identical recovery** where the protocol promises it (round-local
  corruption with a healthy beta; pallas->xla kernel demotion; worker
  restart; checkpoint quarantine + resume; store-poison re-solve);
* **certified recovery** where bit-identity is impossible (beta itself
  corrupted: rewind to the best finite iterate, converge again);
* **typed, honest failure** everywhere else — ``Degraded`` carries the
  certified prefix and the true gap at truncation, ``NumericsError`` /
  ``KernelLaunchError`` / ``ServeError`` surface instead of silent wrong
  answers, and no future ever hangs.

And one global invariant swept across every scenario that yields a path:
**no unsafe certificate** — every group a faulted run reports screened is
zero in a tight-tolerance unscreened reference solve (rule="none",
tol=1e-9).  Corrupted state may cost retries, epochs, or truncation; it
must never certify.

Run as ``python -m repro.faults --check --json out.json`` (the chaos CI
job) or call :func:`run_matrix` directly.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import sgl
from ..core.session import SGLSession, SolverConfig, lambda_grid
from ..data.synthetic import make_synthetic
from ..kernels import ops as kops
from .budget import SolveBudget
from .errors import Degraded, NumericsError
from .inject import FaultLog, inject
from .plan import FaultPlan, FaultSpec

__all__ = ["run_matrix", "SCENARIOS"]

CFG = SolverConfig(tol=1e-7, max_epochs=5_000)
_REF_CFG = SolverConfig(tol=1e-9, max_epochs=50_000, rule="none")


def _problem(seed: int = 0):
    X, y, _beta, sizes = make_synthetic(
        n=24, p=64, n_groups=8, gamma1=3, gamma2=3, seed=seed)
    return sgl.make_problem(X, y, sizes, tau=0.3)


def _grid(problem, T: int = 4, delta: float = 1.5):
    return lambda_grid(float(sgl.lambda_max(problem)), T=T, delta=delta)


class _Ctx:
    """Shared fixtures: problems, fault-free baselines, tight references.

    Everything is memoised so the matrix pays each solve once; baselines
    are solved on FRESH sessions so injected runs and fault-free runs see
    identical cold caches (bit-identity is only meaningful then).
    """

    def __init__(self, seed: int):
        self.seed = seed
        self._problems: Dict[int, object] = {}
        self._base: Dict[tuple, object] = {}
        self._refs: Dict[tuple, np.ndarray] = {}

    def problem(self, seed: int = 0):
        if seed not in self._problems:
            self._problems[seed] = _problem(seed)
        return self._problems[seed]

    def baseline(self, seed: int = 0, T: int = 4, **cfg_kw):
        """Fault-free solve_path on a fresh session (memoised per config)."""
        key = (seed, T, tuple(sorted(cfg_kw.items())))
        if key not in self._base:
            prob = self.problem(seed)
            sess = SGLSession(prob, CFG._replace(**cfg_kw)
                              if cfg_kw else CFG)
            self._base[key] = sess.solve_path(_grid(prob, T=T))
        return self._base[key]

    def reference_betas(self, seed: int = 0, T: int = 4) -> np.ndarray:
        """Tight-tol unscreened reference path (the safety oracle)."""
        if (seed, T) not in self._refs:
            prob = self.problem(seed)
            ref = SGLSession(prob, _REF_CFG).solve_path(_grid(prob, T=T))
            self._refs[(seed, T)] = np.asarray(ref.betas)
        return self._refs[(seed, T)]

    def unsafe_certificates(self, result, seed: int = 0,
                            T: int = 4) -> int:
        """Screened-but-nonzero-in-reference count over a (possibly
        truncated) path result.  The one number that must be 0."""
        ref = self.reference_betas(seed, T)
        bad = 0
        for t in range(len(np.asarray(result.lambdas))):
            screened = ~np.asarray(result.group_active[t])
            nz = np.linalg.norm(ref[t], axis=-1) > 1e-8
            bad += int((screened & nz).sum())
        return bad


SCENARIOS: List[Tuple[str, Callable]] = []


def _scenario(name: str):
    def deco(fn):
        SCENARIOS.append((name, fn))
        return fn
    return deco


def _bit_identical(a, b) -> bool:
    return (np.array_equal(np.asarray(a.betas), np.asarray(b.betas))
            and np.array_equal(np.asarray(a.gaps), np.asarray(b.gaps)))


def _solve_under(plan: FaultPlan, seed: int = 0, budget=None,
                 **cfg_kw) -> Tuple[object, object, FaultLog]:
    """One injected solve_path on a fresh session; returns
    (PathResult | raised exception, session, fault log)."""
    ctx_prob = _problem(seed)
    sess = SGLSession(ctx_prob, CFG._replace(**cfg_kw) if cfg_kw else CFG)
    sess.budget = budget
    with inject(plan) as log:
        try:
            res = sess.solve_path(_grid(ctx_prob))
        except Exception as e:          # typed failures are outcomes here
            res = e
    return res, sess, log


# ---------------------------------------------------------------------------
# 1-4: round-output corruption -> refuse, re-run, bit-identical
# ---------------------------------------------------------------------------

def _round_corruption(ctx: _Ctx, spec: FaultSpec) -> dict:
    base = ctx.baseline()
    res, sess, log = _solve_under(FaultPlan((spec,), seed=ctx.seed))
    if isinstance(res, Exception):
        return {"ok": False, "detail": f"unexpected {res!r}"}
    ok = (_bit_identical(res, base)
          and log.count() >= 1
          and sess.nonfinite_rounds >= 1
          and res.certificates_safe)
    return {
        "ok": ok,
        "detail": ("bit-identical after refuse+rerun" if ok else
                   "recovered result diverged from fault-free run"),
        "unsafe": ctx.unsafe_certificates(res),
        "fired": log.count(),
        "nonfinite_rounds": sess.nonfinite_rounds,
    }


@_scenario("round_nan_theta_r1")
def _s_round_nan_theta(ctx):
    return _round_corruption(ctx, FaultSpec(
        "core.round", "nan", hits=(1,), field="theta"))


@_scenario("round_nan_resid_r0")
def _s_round_nan_resid(ctx):
    return _round_corruption(ctx, FaultSpec(
        "core.round", "nan", hits=(0,), field="resid"))


@_scenario("round_inf_corr_mid")
def _s_round_inf_corr(ctx):
    return _round_corruption(ctx, FaultSpec(
        "core.round", "inf", hits=(3,), field="corr"))


@_scenario("round_nan_final_round")
def _s_round_nan_final(ctx):
    # Hit the LAST certified round of the fault-free run — the final
    # confirmation that gates convergence of the last lambda.
    prob = ctx.problem()
    probe = SGLSession(prob, CFG)
    probe.solve_path(_grid(prob))
    # full_rounds maps 1:1 onto "core.round" injection hits (compact
    # rounds have their own site-free fast path), and the last certified
    # round is always full — convergence is re-confirmed full-problem.
    last = probe.full_rounds - 1
    return _round_corruption(ctx, FaultSpec(
        "core.round", "nan", hits=(last,), field="theta"))


# ---------------------------------------------------------------------------
# 5: beta corruption after an epoch block -> rewind, certified recovery
# ---------------------------------------------------------------------------

@_scenario("epoch_nan_beta_rewind")
def _s_epoch_nan_beta(ctx):
    base = ctx.baseline()
    res, sess, log = _solve_under(FaultPlan(
        (FaultSpec("core.epochs", "nan", hits=(1,)),), seed=ctx.seed))
    if isinstance(res, Exception):
        return {"ok": False, "detail": f"unexpected {res!r}"}
    gaps = np.asarray(res.gaps)
    ok = (log.count() >= 1
          and np.all(np.isfinite(gaps))
          and bool(np.all(gaps <= CFG.tol * (1 + 1e-12)))
          and np.allclose(np.asarray(res.betas), np.asarray(base.betas),
                          atol=1e-4)
          and res.certificates_safe)
    return {
        "ok": ok,
        "detail": ("rewound to best finite iterate, re-certified"
                   if ok else "recovery failed to re-certify"),
        "unsafe": ctx.unsafe_certificates(res),
        "nonfinite_rounds": sess.nonfinite_rounds,
    }


# ---------------------------------------------------------------------------
# 6-7: kernel launch failure -> pallas->xla demotion, bit-identical
# ---------------------------------------------------------------------------

@_scenario("screen_kernel_raise_demotes")
def _s_screen_kernel_raise(ctx):
    base = ctx.baseline(screen_backend="pallas")
    res, sess, log = _solve_under(
        FaultPlan((FaultSpec("kernels.screen", "raise", hits=(0,)),),
                  seed=ctx.seed),
        screen_backend="pallas")
    if isinstance(res, Exception):
        return {"ok": False, "detail": f"unexpected {res!r}"}
    # Betas (and masks) are bit-identical across the demotion; the
    # REPORTED gap of the demoted rounds comes from xla's reduction
    # order, so it matches pallas only to fp round-off — both are exact
    # full-problem certificates.
    ok = (np.array_equal(np.asarray(res.betas), np.asarray(base.betas))
          and np.allclose(np.asarray(res.gaps), np.asarray(base.gaps),
                          rtol=1e-6, atol=1e-12)
          and sess.kernel_demotions >= 1
          and res.certificates_safe)
    return {
        "ok": ok,
        "detail": ("demoted to xla, bit-identical (kernel parity)"
                   if ok else "demoted run diverged"),
        "unsafe": ctx.unsafe_certificates(res),
        "kernel_demotions": sess.kernel_demotions,
    }


@_scenario("epoch_kernel_raise_demotes")
def _s_epoch_kernel_raise(ctx):
    base = ctx.baseline(solver_backend="pallas")
    res, sess, log = _solve_under(
        FaultPlan((FaultSpec("kernels.epochs", "raise", hits=(0,)),),
                  seed=ctx.seed),
        solver_backend="pallas")
    if isinstance(res, Exception):
        return {"ok": False, "detail": f"unexpected {res!r}"}
    ok = (_bit_identical(res, base) and sess.kernel_demotions >= 1
          and res.certificates_safe)
    return {
        "ok": ok,
        "detail": ("fused-epoch launch demoted, bit-identical"
                   if ok else "demoted run diverged"),
        "unsafe": ctx.unsafe_certificates(res),
        "kernel_demotions": sess.kernel_demotions,
    }


# ---------------------------------------------------------------------------
# 8-9: budgets -> typed Degraded prefix with honest gaps
# ---------------------------------------------------------------------------

def _budget_trip(ctx: _Ctx, budget: SolveBudget, want: str,
                 plan: Optional[FaultPlan] = None) -> dict:
    res, sess, log = _solve_under(plan or FaultPlan((), seed=ctx.seed),
                                  budget=budget)
    if isinstance(res, Exception):
        return {"ok": False, "detail": f"unexpected {res!r}"}
    gaps = np.asarray(res.gaps)
    full_T = len(_grid(ctx.problem()))
    ok = (res.degraded == want
          and len(np.asarray(res.lambdas)) < full_T
          and len(gaps) == len(np.asarray(res.lambdas))
          and np.all(np.isfinite(gaps)))
    return {
        "ok": ok,
        "detail": (f"degraded={res.degraded!r}, certified prefix "
                   f"{len(gaps)}/{full_T} with finite honest gaps"
                   if ok else
                   f"degraded={res.degraded!r}, prefix "
                   f"{len(gaps)}/{full_T}"),
        "unsafe": ctx.unsafe_certificates(res),
    }


@_scenario("stall_deadline_degrades")
def _s_stall_deadline(ctx):
    return _budget_trip(
        ctx, SolveBudget(deadline_s=0.25), "deadline",
        plan=FaultPlan((FaultSpec("core.round", "stall",
                                  hits=tuple(range(2, 200)),
                                  stall_s=0.05),), seed=ctx.seed))


@_scenario("epoch_budget_degrades")
def _s_epoch_budget(ctx):
    return _budget_trip(ctx, SolveBudget(max_epochs=10), "epoch_budget")


# ---------------------------------------------------------------------------
# 10: unrecoverable numerics -> typed NumericsError, never a result
# ---------------------------------------------------------------------------

@_scenario("nan_storm_typed_error")
def _s_nan_storm(ctx):
    prob = ctx.problem()
    sess = SGLSession(prob, CFG)
    lam = float(_grid(prob)[1])
    plan = FaultPlan((FaultSpec("core.round", "nan", hits=(0, 1, 2),
                                field="theta"),), seed=ctx.seed)
    with inject(plan) as log:
        try:
            sess.solve(lam)
        except NumericsError as e:
            ok = "consecutive non-finite" in str(e) and log.count() == 3
            return {"ok": ok,
                    "detail": f"typed NumericsError after {log.count()} "
                              f"corrupted rounds",
                    "fired": log.count()}
        except Exception as e:
            return {"ok": False, "detail": f"wrong type {e!r}"}
    return {"ok": False, "detail": "nan storm produced a result"}


# ---------------------------------------------------------------------------
# 11-12, 15-16: serve-side faults (worker kill, segment kill + resume,
# corrupt checkpoint resume, store poison)
# ---------------------------------------------------------------------------

def _resolve(fut, timeout: float = 600.0):
    """('ok'|'error'|'hung', value) — 'hung' is the unforgivable one."""
    try:
        return "ok", fut.result(timeout)
    except Exception as e:
        return ("hung", None) if not fut.done() else ("error", e)


@_scenario("serve_worker_kill")
def _s_worker_kill(ctx):
    from ..serve import PathRequest, ServeConfig, SGLServer

    prob = ctx.problem(seed=11)
    grid = _grid(prob, T=4)
    base = ctx.baseline(seed=11)
    server = SGLServer(ServeConfig(
        default_solver=CFG, retry_backoff_s=0.0)).start()
    plan = FaultPlan((FaultSpec("serve.worker", "kill", hits=(0,)),),
                     seed=ctx.seed)
    try:
        with inject(plan):
            state, resp = _resolve(
                server.submit(PathRequest("t0", prob, grid)))
    finally:
        server.stop()
    hung = int(state == "hung")
    ok = (state == "ok"
          and server.counters["worker_restarts"] >= 1
          and server.counters["retries"] >= 1
          and np.array_equal(np.asarray(resp.result.betas),
                             np.asarray(base.betas)))
    return {
        "ok": ok, "hung": hung,
        "detail": (f"worker restarted "
                   f"x{server.counters['worker_restarts']}, future "
                   f"resolved bit-identical" if ok else
                   f"state={state}"),
        "unsafe": (ctx.unsafe_certificates(resp.result, seed=11)
                   if state == "ok" else 0),
        "worker_restarts": server.counters["worker_restarts"],
        "retries": server.counters["retries"],
    }


def _chunked_ref(ctx, prob, grid, tmp):
    """Uninterrupted chunked run (same segmenting) — the bit-identity
    reference for every resume scenario."""
    from ..serve import PathRequest, ServeConfig, SGLServer

    ref_server = SGLServer(ServeConfig(
        default_solver=CFG, ckpt_dir=tmp + "/ref", ckpt_every=2)).start()
    try:
        state, ref = _resolve(
            ref_server.submit(PathRequest("t0", prob, grid)))
        assert state == "ok"
    finally:
        ref_server.stop()
    return ref


@_scenario("serve_segment_kill_resume")
def _s_segment_kill(ctx):
    import tempfile

    from ..serve import PathRequest, ServeConfig, SGLServer

    prob = ctx.problem(seed=11)
    grid = _grid(prob, T=4)
    with tempfile.TemporaryDirectory() as tmp:
        ref = _chunked_ref(ctx, prob, grid, tmp)
        server = SGLServer(ServeConfig(
            default_solver=CFG, ckpt_dir=tmp + "/chaos",
            ckpt_every=2, retry_backoff_s=0.0)).start()
        plan = FaultPlan(
            (FaultSpec("serve.segment", "kill", hits=(1,)),),
            seed=ctx.seed)
        try:
            with inject(plan):
                state, resp = _resolve(
                    server.submit(PathRequest("t0", prob, grid)))
        finally:
            server.stop()
        hung = int(state == "hung")
        ok = (state == "ok"
              and server.counters["worker_restarts"] >= 1
              and np.array_equal(np.asarray(resp.result.betas),
                                 np.asarray(ref.result.betas)))
        return {
            "ok": ok, "hung": hung,
            "detail": ("mid-path kill resumed from checkpoint, "
                       "bit-identical to uninterrupted chunked run"
                       if ok else f"state={state}"),
            "unsafe": (ctx.unsafe_certificates(resp.result, seed=11)
                       if state == "ok" else 0),
            "worker_restarts": server.counters["worker_restarts"],
        }


@_scenario("ckpt_corrupt_resume_rewinds")
def _s_ckpt_corrupt_resume(ctx):
    import tempfile

    from .. import ckpt
    from ..serve import PathRequest, Preempted, ServeConfig, SGLServer

    prob = ctx.problem(seed=11)
    grid = _grid(prob, T=6)       # 3 segments: preempt AFTER the second
    with tempfile.TemporaryDirectory() as tmp:
        ref = _chunked_ref(ctx, prob, grid, tmp)

        # Interrupted run whose SECOND checkpoint rots on disk
        # (truncated after publish) before the server drains.
        cdir = tmp + "/chaos"
        server = SGLServer(ServeConfig(
            default_solver=CFG, ckpt_dir=cdir, ckpt_every=2))

        def bomb(digest, cursor, T):
            if cursor >= 4:
                server.drain()

        server.config.on_segment = bomb
        server.start()
        q0 = ckpt.quarantine_count()
        plan = FaultPlan(
            (FaultSpec("ckpt.payload", "truncate", hits=(1,)),),
            seed=ctx.seed)
        with inject(plan):
            fut = server.submit(PathRequest("t0", prob, grid))
            state, err = _resolve(fut)
        server.join()
        if state != "error" or not isinstance(err, Preempted):
            return {"ok": False, "hung": int(state == "hung"),
                    "detail": f"expected Preempted, got {state}"}

        # Restart on the same dir: the rotten step must be quarantined
        # and resume must rewind the cursor to the intact snapshot.
        server2 = SGLServer(ServeConfig(
            default_solver=CFG, ckpt_dir=cdir, ckpt_every=2)).start()
        try:
            state, resp = _resolve(
                server2.submit(PathRequest("t0", prob, grid)))
        finally:
            server2.stop()
        quarantined = ckpt.quarantine_count() - q0
        ok = (state == "ok"
              and quarantined >= 1
              and resp.resumed_from == 2        # rewound past cursor 4
              and np.array_equal(np.asarray(resp.result.betas),
                                 np.asarray(ref.result.betas)))
        return {
            "ok": ok, "hung": int(state == "hung"),
            "detail": (f"corrupt step quarantined (x{quarantined}), "
                       f"resume rewound to cursor 2, bit-identical"
                       if ok else
                       f"state={state}, resumed_from="
                       f"{getattr(resp, 'resumed_from', None)}"),
            "unsafe": (ctx.unsafe_certificates(resp.result, seed=11, T=6)
                       if state == "ok" else 0),
            "quarantined": quarantined,
        }


@_scenario("store_poison_drops")
def _s_store_poison(ctx):
    from ..serve import PathRequest, ServeConfig, SGLServer

    prob = ctx.problem(seed=11)
    grid = _grid(prob, T=4)
    server = SGLServer(ServeConfig(default_solver=CFG)).start()
    plan = FaultPlan((FaultSpec("store.record", "poison", hits=(0,)),),
                     seed=ctx.seed)
    try:
        with inject(plan):
            s1, r1 = _resolve(
                server.submit(PathRequest("t0", prob, grid)))
        # Outside the plan: the poisoned record sits in the store; an
        # exact repeat must detect the digest mismatch and re-solve.
        s2, r2 = _resolve(server.submit(PathRequest("t0", prob, grid)))
    finally:
        server.stop()
    hung = int(s1 == "hung") + int(s2 == "hung")
    ok = (s1 == "ok" and s2 == "ok"
          and server.store.poison_drops == 1
          and server.store.exact_hits == 0
          and np.array_equal(np.asarray(r1.result.betas),
                             np.asarray(r2.result.betas)))
    return {
        "ok": ok, "hung": hung,
        "detail": ("poisoned record dropped on digest mismatch; "
                   "repeat re-solved bit-identical" if ok else
                   f"poison_drops={server.store.poison_drops}, "
                   f"exact_hits={server.store.exact_hits}"),
        "unsafe": (ctx.unsafe_certificates(r2.result, seed=11)
                   if s2 == "ok" else 0),
        "poison_drops": server.store.poison_drops,
    }


# ---------------------------------------------------------------------------
# 13-14: checkpoint bit-rot -> quarantine + fallback to newest intact
# ---------------------------------------------------------------------------

def _ckpt_rot(ctx: _Ctx, kind: str) -> dict:
    import tempfile

    from .. import ckpt

    tree = {"beta": np.arange(12.0).reshape(3, 4), "step": np.int64(7)}
    with tempfile.TemporaryDirectory() as tmp:
        q0 = ckpt.quarantine_count()
        ckpt.save(tmp, 1, tree)
        plan = FaultPlan((FaultSpec("ckpt.payload", kind, hits=(0,)),),
                         seed=ctx.seed)
        with inject(plan) as log:
            ckpt.save(tmp, 2, tree)
        found = ckpt.latest(tmp)
        quarantined = ckpt.quarantine_count() - q0
        ok = (log.count() == 1
              and found is not None and found[0] == 1
              and quarantined == 1)
        if ok:
            restored = ckpt.restore(tmp, tree, step=1)
            ok = np.array_equal(restored["beta"], tree["beta"])
    return {
        "ok": ok,
        "detail": (f"{kind}d step 2 quarantined; latest() fell back to "
                   f"intact step 1" if ok else
                   f"latest={found}, quarantined={quarantined}"),
        "quarantined": quarantined,
    }


@_scenario("ckpt_truncate_quarantine")
def _s_ckpt_truncate(ctx):
    return _ckpt_rot(ctx, "truncate")


@_scenario("ckpt_bitflip_quarantine")
def _s_ckpt_bitflip(ctx):
    return _ckpt_rot(ctx, "bitflip")


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_matrix(seed: int = 0, verbose: bool = True,
               names: Optional[List[str]] = None) -> dict:
    """Run every scenario; returns the JSON-ready report.

    ``ok`` is True iff every scenario passed, zero unsafe certificates
    were observed, and zero futures hung.
    """
    ctx = _Ctx(seed)
    scenarios = [(n, f) for n, f in SCENARIOS
                 if names is None or n in names]
    report: dict = {"seed": seed, "scenarios": []}
    unsafe = hung = failures = 0
    t0 = time.perf_counter()
    for name, fn in scenarios:
        ts = time.perf_counter()
        try:
            out = fn(ctx)
        except Exception as e:          # a scenario crashing is a failure
            out = {"ok": False, "detail": f"scenario crashed: {e!r}"}
        out["name"] = name
        out["seconds"] = round(time.perf_counter() - ts, 3)
        unsafe += int(out.get("unsafe", 0))
        hung += int(out.get("hung", 0))
        failures += int(not out["ok"])
        report["scenarios"].append(out)
        if verbose:
            mark = "ok " if out["ok"] else "FAIL"
            print(f"  [{mark}] {name:<28s} {out['detail']}")
    report["unsafe_certificates"] = unsafe
    report["hung_futures"] = hung
    report["failures"] = failures
    report["recovery"] = {
        "kernel_demotions_total": kops.kernel_demotion_count(),
        "quarantined_total": _quarantine_total(),
    }
    report["seconds"] = round(time.perf_counter() - t0, 3)
    report["ok"] = failures == 0 and unsafe == 0 and hung == 0
    return report


def _quarantine_total() -> int:
    from .. import ckpt
    return ckpt.quarantine_count()


def _jsonable(obj):
    """numpy scalars leak into the report via np.all/np.array_equal."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.bool_, bool)):
        return bool(obj)
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


def write_report(report: dict, path: str) -> None:
    """Merge the matrix report into ``path`` under the ``"chaos"`` key.

    Merge, not clobber: ``benchmarks/bench_serve.py --faults`` records
    its availability/latency numbers into the same file under
    ``"serve_faults"`` — CI order between the two must not matter.
    """
    try:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict) or "scenarios" in data:
            data = {}
    except (FileNotFoundError, json.JSONDecodeError):
        data = {}
    data["chaos"] = _jsonable(report)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
