"""Shared backend predicates + launch-spec metadata for the Pallas kernels.

Leaf module (imports nothing from this package) so both the kernel entry
points and their dispatch wrappers in ops.py — and the solver — can use one
spelling of the "are we on TPU" test.  When Pallas gains another compiled
backend, this is the only place to update.

:class:`LaunchSpec` / :class:`ArraySpec` are the *auditable* description of
a ``pallas_call`` launch: every kernel module builds its grid and
``BlockSpec``s from a ``*_launch_spec()`` function returning one of these,
and the SAME object feeds both the actual launch (via :func:`block_specs` /
:func:`out_shapes`) and the static analyzer
(:mod:`repro.analysis.pallas_audit`), so the audited geometry can never
drift from the executed one.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import numpy as np
from jax.experimental import pallas as pl


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Pallas interpret-mode default: compile on TPU, interpret elsewhere."""
    return not on_tpu()


class ArraySpec(NamedTuple):
    """One pallas_call operand: full shape, block shape, index map, dtype.

    ``index_map`` takes the grid coordinates (python ints work — Pallas
    index maps must be pure shape arithmetic) and returns the *block*
    indices, exactly as passed to ``pl.BlockSpec``.
    """

    shape: Tuple[int, ...]
    block: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    dtype: Any = "float64"

    @property
    def block_bytes(self) -> int:
        return int(np.prod(self.block)) * np.dtype(self.dtype).itemsize

    @property
    def array_bytes(self) -> int:
        """Full (unblocked) array footprint in bytes."""
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    @property
    def nblocks(self) -> Tuple[int, ...]:
        return tuple(-(-s // b) for s, b in zip(self.shape, self.block))


class LaunchSpec(NamedTuple):
    """Auditable description of one ``pallas_call`` launch.

    ``carried``: per-output tuple of grid axes the output's index map is
    declared invariant to — the VMEM-resident accumulation/carry pattern
    (e.g. the corr tile accumulating over the K axis, the BCD state carried
    across epoch/group-tile steps).  The auditor *verifies* the invariance
    and exempts exactly these axes from the exactly-once coverage check;
    an undeclared invariant axis (or a declared one that is not invariant)
    is a finding.
    """

    name: str
    grid: Tuple[int, ...]
    inputs: Tuple[ArraySpec, ...]
    outputs: Tuple[ArraySpec, ...]
    carried: Tuple[Tuple[int, ...], ...] = ()
    note: str = ""

    @property
    def vmem_bytes(self) -> int:
        """VMEM-resident footprint of one grid step (all operand blocks)."""
        return sum(a.block_bytes for a in self.inputs + self.outputs)

    @property
    def io_bytes(self) -> int:
        """Unique-bytes HBM traffic model: every operand read or written
        once at full size.  A deliberate lower bound — carried outputs stay
        VMEM-resident and streamed inputs may be re-read per epoch axis —
        used by the obs timing harness as the ``bytes`` term of
        :func:`repro.launch.roofline.achieved_vs_peak` when a kernel has no
        hand-written traffic formula."""
        return sum(a.array_bytes for a in self.inputs + self.outputs)


def block_specs(arrays) -> list:
    """``pl.BlockSpec`` list for the launch, straight from the ArraySpecs."""
    return [pl.BlockSpec(a.block, a.index_map) for a in arrays]


def out_shapes(arrays) -> list:
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays]
