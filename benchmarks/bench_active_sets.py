"""Figures 2a/2b: proportion of active feature/group variables as a function
of lambda_t along the path (GAP safe rule).

Reports, per lambda on the grid, the fraction of groups and features still
active when the solver stops — the quantity plotted in the paper's heatmaps
(we emit the converged slice; intermediate-K slices are in the solver's
``active_history``).
"""
from __future__ import annotations

import numpy as np

from repro.core import sgl
from repro.core.path import lambda_grid, solve_path
from repro.data.synthetic import make_synthetic

from .common import emit


def main(n=100, p=2000, n_groups=200, T=20, delta=2.0, tau=0.2,
         tol=1e-6, max_epochs=3000) -> None:
    X, y, beta_true, sizes = make_synthetic(n=n, p=p, n_groups=n_groups)
    problem = sgl.make_problem(X, y, sizes, tau=tau)
    lam_max = float(sgl.lambda_max(problem))
    lambdas = lambda_grid(lam_max, T=T, delta=delta)

    res = solve_path(problem, lambdas=lambdas, tol=tol,
                     max_epochs=max_epochs, rule="gap")

    true_groups = {i for i in range(n_groups)
                   if np.any(beta_true[i * (p // n_groups):(i + 1) * (p // n_groups)])}
    for i, lam_ in enumerate(lambdas):
        case = f"lam{i:03d}"
        emit("active_sets_fig2ab", case, "lambda_over_lmax", lam_ / lam_max)
        emit("active_sets_fig2ab", case, "group_active_frac",
             res.group_active_frac[i])
        emit("active_sets_fig2ab", case, "feat_active_frac",
             res.feat_active_frac[i])
        emit("active_sets_fig2ab", case, "epochs", int(res.epochs[i]))
        emit("active_sets_fig2ab", case, "seq_screened", int(res.seq_screened[i]))
        # How much of the generative support the rule has screened away at
        # this lambda (informational: screening a generative-support group is
        # legitimate when regularization zeroes it; the actual SAFETY
        # invariant — screened => zero in an unscreened reference solve — is
        # asserted by tests/test_path.py::test_path_screening_is_safe).
        emit("active_sets_fig2ab", case, "true_support_screened",
             sum(1 for g in true_groups if not res.group_active[i, g]))


if __name__ == "__main__":
    from .common import header

    header()
    main()
