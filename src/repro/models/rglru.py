"""RecurrentGemma / Griffin-style hybrid: RG-LRU recurrent blocks + local
(sliding-window) MQA attention in a 1-attention : 2-recurrent pattern.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

The diagonal linear recurrence is evaluated with jax.lax.associative_scan
(log-depth, numerically stable) for train/prefill, and as the O(1) update for
decode.  Layers are heterogeneous (pattern), so the stack is a Python list —
fine for 26 layers.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L

_C = 8.0


def _layer_kind(cfg, i: int) -> str:
    return cfg.hybrid_pattern[i % len(cfg.hybrid_pattern)]


def _init_rec_layer(cfg, key, dtype):
    D = cfg.d_model
    dr = cfg.d_model  # lru width == d_model for recurrentgemma-2b
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "proj_x": jax.random.normal(ks[0], (D, dr), dtype) * D ** -0.5,
        "proj_gate": jax.random.normal(ks[1], (D, dr), dtype) * D ** -0.5,
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, dr), dtype) * 0.1,
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": jax.random.normal(ks[3], (dr, dr), dtype) * dr ** -0.5,
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": jax.random.normal(ks[4], (dr, dr), dtype) * dr ** -0.5,
        "b_x": jnp.zeros((dr,), jnp.float32),
        "lambda_p": jnp.full((dr,), 0.55, jnp.float32),  # a ~ U(0.9, 0.999)
        "proj_out": jax.random.normal(ks[5], (dr, D), dtype) * dr ** -0.5,
        "ln2": L.init_norm(cfg, dtype),
    }


def _finish_init_rec(cfg, key, dtype):
    p = _init_rec_layer(cfg, key, dtype)
    p["mlp"] = L.init_mlp(jax.random.fold_in(key, 7), cfg, dtype)
    return p


def _init_attn_layer(cfg, key, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "attn": L.init_attn(ka, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(km, cfg, dtype),
    }


def init_params(cfg, key, dtype=jnp.bfloat16):
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        if _layer_kind(cfg, i) == "attn":
            layers.append(_init_attn_layer(cfg, layer_keys[i], dtype))
        else:
            layers.append(_finish_init_rec(cfg, layer_keys[i], dtype))
    return {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype)
        * cfg.d_model ** -0.5,
        "layers": layers,
        "ln_f": L.init_norm(cfg, dtype),
    }
    # vocab 256k: embeddings tied (gemma convention)


def _rec_specs(cfg):
    return {
        "ln1": P(None),
        "proj_x": P("data", "model"),
        "proj_gate": P("data", "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "w_a": P("data", "model"),
        "b_a": P("model"),
        "w_x": P("data", "model"),
        "b_x": P("model"),
        "lambda_p": P("model"),
        "proj_out": P("model", "data"),
        "ln2": P(None),
        "mlp": L.specs_mlp(cfg),
    }


def _attn_specs(cfg):
    return {
        "ln1": P(None),
        "attn": L.specs_attn(cfg),
        "ln2": P(None),
        "mlp": L.specs_mlp(cfg),
    }


def param_specs(cfg, model_axis: int = 16):
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            _attn_specs(cfg) if _layer_kind(cfg, i) == "attn" else _rec_specs(cfg)
        )
    return {"embed": P("model", "data"), "layers": layers, "ln_f": P(None)}


def _rglru_scan(x_gated, log_a):
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1.

    x_gated: b_t (B,S,dr) f32;  log_a: (B,S,dr) f32 (<=0)."""
    def combine(left, right):
        la1, b1 = left
        la2, b2 = right
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, x_gated), axis=1)
    return h


def _rec_block(cfg, lp, x, state=None, single_step=False):
    """x: (B,S,D) -> (y, (conv_state, h_state))."""
    gate = jax.nn.gelu(x @ lp["proj_gate"])
    xr = x @ lp["proj_x"]

    if single_step:
        conv_state, h_prev = state
        seq = jnp.concatenate([conv_state.astype(xr.dtype), xr], axis=1)
        new_conv = seq[:, 1:]
        xc = (jnp.einsum("bwc,wc->bc", seq, lp["conv_w"]) + lp["conv_b"])[:, None]
    else:
        from .ssm import _causal_conv
        xc = _causal_conv(xr, lp["conv_w"], lp["conv_b"])
        new_conv = xr[:, -(cfg.conv_width - 1):]

    r = jax.nn.sigmoid(xc.astype(jnp.float32) @ lp["w_a"].astype(jnp.float32)
                       + lp["b_a"])
    i = jax.nn.sigmoid(xc.astype(jnp.float32) @ lp["w_x"].astype(jnp.float32)
                       + lp["b_x"])
    log_a = -_C * jax.nn.softplus(lp["lambda_p"]) * r      # (B,S,dr) f32
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xc.astype(jnp.float32)
    )

    if single_step:
        h = jnp.exp(log_a) * h_prev[:, None] + b
        new_h = h[:, 0]
    else:
        h_prev = None if state is None else state[1]
        if h_prev is not None:
            # fold carried state into the first step
            b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h_prev)
        h = _rglru_scan(b, log_a)
        new_h = h[:, -1]

    y = (h.astype(gate.dtype) * gate) @ lp["proj_out"]
    return y, (new_conv, new_h)


def _attn_block(cfg, lp, x, positions, q_chunk):
    a = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = L.attn_qkv(lp["attn"], a, cfg, positions)
    o = L.causal_attention(q, k, v, window=cfg.window, q_chunk=q_chunk)
    B, S, H, hd = o.shape
    return o.reshape(B, S, H * hd) @ lp["attn"]["wo"], (k, v)


def forward(cfg, params, tokens, embeds=None, *, q_chunk: int = 512,
            remat: bool = True, **_):
    h = jnp.take(params["embed"], tokens, axis=0)
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    qc = min(q_chunk, S)

    for i, lp in enumerate(params["layers"]):
        def block(h, lp=lp, i=i):
            if _layer_kind(cfg, i) == "attn":
                y, _ = _attn_block(cfg, lp, h, positions, qc)
                h = h + y
            else:
                a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
                y, _ = _rec_block(cfg, lp, a)
                h = h + y
            b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            return h + L.mlp(lp["mlp"], b)

        h = jax.checkpoint(block)(h) if remat else block(h)

    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = h @ params["embed"].T          # tied embeddings
    return logits, jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------------

class HybridCache(NamedTuple):
    """Per-layer state: attn layers use rolling KV, rec layers use (conv, h)."""
    kv_k: jax.Array     # (n_attn, B, window, K, hd)
    kv_v: jax.Array
    conv: jax.Array     # (n_rec, B, W-1, dr)
    h: jax.Array        # (n_rec, B, dr)
    pos: jax.Array


def _layer_counts(cfg):
    kinds = [_layer_kind(cfg, i) for i in range(cfg.n_layers)]
    return kinds, kinds.count("attn"), kinds.count("rec")


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    kinds, n_attn, n_rec = _layer_counts(cfg)
    win = min(cfg.window or max_seq, max_seq)
    dr = cfg.d_model
    return HybridCache(
        kv_k=jnp.zeros((n_attn, batch, win, cfg.n_kv, cfg.hd), dtype),
        kv_v=jnp.zeros((n_attn, batch, win, cfg.n_kv, cfg.hd), dtype),
        conv=jnp.zeros((n_rec, batch, cfg.conv_width - 1, dr), dtype),
        h=jnp.zeros((n_rec, batch, dr), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def cache_specs(cfg, model_axis: int = 16):
    return HybridCache(
        kv_k=P(None, "data", None, None, None),   # kv=1 (MQA): replicate head
        kv_v=P(None, "data", None, None, None),
        conv=P(None, "data", None, "model"),
        h=P(None, "data", "model"),
        pos=P(),
    )


def prefill(cfg, params, tokens, embeds=None, *, q_chunk: int = 512,
            cache_len=None, dtype=jnp.bfloat16, **_):
    h = jnp.take(params["embed"], tokens, axis=0)
    B, S, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    qc = min(q_chunk, S)
    C = cache_len or S
    win = min(cfg.window, C) if cfg.window else C

    kvk, kvv, convs, hs = [], [], [], []
    for i, lp in enumerate(params["layers"]):
        if _layer_kind(cfg, i) == "attn":
            y, (k, v) = _attn_block(cfg, lp, h, positions, qc)
            h = h + y
            kvk.append(L.fill_rolling_cache(k, win, dtype))
            kvv.append(L.fill_rolling_cache(v, win, dtype))
        else:
            a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            y, (conv_s, h_s) = _rec_block(cfg, lp, a)
            h = h + y
            convs.append(conv_s.astype(dtype))
            hs.append(h_s)
        b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + L.mlp(lp["mlp"], b)

    h = L.rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = (h @ params["embed"].T)[:, 0]
    cache = HybridCache(
        kv_k=jnp.stack(kvk), kv_v=jnp.stack(kvv),
        conv=jnp.stack(convs), h=jnp.stack(hs),
        pos=jnp.asarray(S, jnp.int32),
    )
    return logits, cache


def decode_step(cfg, params, cache: HybridCache, token, pos):
    B = token.shape[0]
    h = jnp.take(params["embed"], token[:, None], axis=0)
    win = cache.kv_k.shape[2]
    slot = pos % win

    kvk, kvv, convs, hs = [], [], [], []
    ia = ir = 0
    for i, lp in enumerate(params["layers"]):
        if _layer_kind(cfg, i) == "attn":
            a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(lp["attn"], a, cfg,
                                 jnp.broadcast_to(pos, (B, 1)))
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache.kv_k[ia], k.astype(cache.kv_k.dtype), slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache.kv_v[ia], v.astype(cache.kv_v.dtype), slot, axis=1)
            kpos = jnp.arange(win)[None, :]
            age = (slot - kpos) % win
            abs_pos = pos - age
            valid = (abs_pos >= 0) & (abs_pos > pos - cfg.window)
            qg = L._split_gqa(q, cfg.n_kv)
            o = L._attend_block(
                qg, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2),
                valid[None, None, None], 1.0 / float(cfg.hd) ** 0.5,
            )
            o = L._merge_gqa(o)
            h = h + o.reshape(B, 1, -1) @ lp["attn"]["wo"]
            kvk.append(kc)
            kvv.append(vc)
            ia += 1
        else:
            a = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            y, (conv_s, h_s) = _rec_block(
                cfg, lp, a,
                state=(cache.conv[ir], cache.h[ir]), single_step=True,
            )
            h = h + y
            convs.append(conv_s.astype(cache.conv.dtype))
            hs.append(h_s)
            ir += 1
        b = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + L.mlp(lp["mlp"], b)

    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = (h @ params["embed"].T)[:, 0]
    new_cache = HybridCache(
        kv_k=jnp.stack(kvk), kv_v=jnp.stack(kvv),
        conv=jnp.stack(convs), h=jnp.stack(hs), pos=pos + 1,
    )
    return logits, new_cache
