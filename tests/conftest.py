import os

# f64 for the convex-optimization core (paper tolerance 1e-8). The LM model
# smoke tests use explicit f32/bf16 dtypes and are unaffected. The dry-run
# does NOT go through this file (it is run as a script, not under pytest).
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
