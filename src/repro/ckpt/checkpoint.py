"""Fault-tolerant checkpointing (no orbax offline — built from scratch).

Guarantees:
* **atomic**: writes go to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint;
* **keep-k** garbage collection;
* **elastic restore**: arrays are stored device-agnostic (host numpy) with
  the pytree structure; restore works on ANY mesh/device count — the caller
  re-applies shardings (``jax.device_put`` with the current NamedShardings),
  which is exactly the elastic-rescale path;
* **preemption hook**: ``install_sigterm_hook`` saves on SIGTERM (the
  standard TPU-pod preemption signal) before exiting.

Format: one ``.npz`` per checkpoint with leaves keyed by their tree path +
a JSON manifest (step, leaf paths, dtypes/shapes, payload digest).

Integrity: :func:`save` records a content digest of the payload file in
the manifest; :func:`latest` and :func:`restore` verify it.  A corrupt or
truncated step is *quarantined* (renamed aside, counted) and :func:`latest`
falls back to the newest intact snapshot — so a resume after bit-rot lands
on valid state and simply rewinds the path cursor.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable, Optional

import numpy as np
import jax

from repro.faults.errors import CheckpointCorrupt
from repro.faults.inject import corrupt_file as _corrupt_file
from repro.faults.inject import fire as _fire_fault
from repro.obs import metrics as _obs_metrics

_M_QUARANTINED = _obs_metrics.REGISTRY.counter(
    "ckpt.quarantined",
    help="Corrupt checkpoint step dirs renamed aside (digest mismatch)")


def quarantine_count() -> int:
    """Checkpoints quarantined (renamed aside) this process."""
    return _M_QUARANTINED.value


def _payload_digest(path: str) -> str:
    h = hashlib.blake2b(digest_size=16)
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, step: int, tree: Any,
         extra_manifest: Optional[dict] = None) -> str:
    """Atomic checkpoint write; ``extra_manifest`` merges caller metadata
    (JSON-serialisable) into the manifest under ``"extra"`` — the serving
    layer stores its path cursor (lambda index + caches digest) there so
    resume reads one small JSON instead of re-scanning step dirs."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "payload_digest": _payload_digest(os.path.join(tmp, "arrays.npz")),
        "extra": dict(extra_manifest) if extra_manifest else {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)     # atomic publish
    # Chaos hook: bit-rot strikes AFTER publish, after the digest was
    # recorded — exactly the corruption verification must catch.
    specs = _fire_fault("ckpt.payload")
    if specs:
        _corrupt_file(os.path.join(final, "arrays.npz"), specs)
    _write_latest_pointer(directory, step, manifest)
    return final


def _write_latest_pointer(directory: str, step: int, manifest: dict) -> None:
    """Atomic ``latest.json`` next to the step dirs: the newest step and
    its full manifest, so :func:`latest` is one read, no dir scan."""
    tmp = os.path.join(directory, "latest.json.tmp")
    with open(tmp, "w") as f:
        json.dump({"step": step, "manifest": manifest}, f)
    os.replace(tmp, os.path.join(directory, "latest.json"))


def _verify_step(directory: str, step: int) -> bool:
    """True iff the step's payload matches its recorded digest.

    Manifests written before digests existed have nothing to verify and
    pass; a missing/unreadable payload or manifest fails.
    """
    path = os.path.join(directory, f"step_{step:012d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return False
    want = manifest.get("payload_digest")
    if want is None:
        return True
    try:
        return _payload_digest(os.path.join(path, "arrays.npz")) == want
    except FileNotFoundError:
        return False


def _quarantine(directory: str, step: int) -> None:
    """Rename a corrupt step dir aside so scans never see it again."""
    src = os.path.join(directory, f"step_{step:012d}")
    dst = os.path.join(directory, f"quarantined.step_{step:012d}")
    if os.path.exists(dst):
        shutil.rmtree(dst, ignore_errors=True)
    try:
        os.replace(src, dst)
    except FileNotFoundError:
        return
    _M_QUARANTINED.inc()


def latest(directory: str) -> Optional[tuple]:
    """``(step, manifest)`` of the newest *intact* checkpoint, or ``None``.

    Reads the atomic ``latest.json`` pointer written by :func:`save` —
    one small JSON instead of an O(k) step-dir scan — and falls back to
    :func:`latest_step` + the step's own ``manifest.json`` for
    directories written before the pointer existed (or whose pointer was
    deleted).  The pointed-at step dir is verified to still exist, so a
    stale pointer can never resolve to a GC'd checkpoint.

    Every candidate is digest-verified before being returned; a corrupt
    or truncated step is quarantined (renamed aside, counted in
    :func:`quarantine_count`) and the scan falls back to the next newest
    intact snapshot — resume then rewinds to the last good cursor.
    """
    pointer = os.path.join(directory, "latest.json")
    try:
        with open(pointer) as f:
            data = json.load(f)
        step = int(data["step"])
        if os.path.isdir(os.path.join(directory, f"step_{step:012d}")):
            if _verify_step(directory, step):
                return step, data["manifest"]
            _quarantine(directory, step)
    except (FileNotFoundError, KeyError, ValueError, json.JSONDecodeError):
        pass
    while True:
        step = latest_step(directory)
        if step is None:
            return None
        if not _verify_step(directory, step):
            _quarantine(directory, step)
            continue
        with open(os.path.join(directory, f"step_{step:012d}",
                               "manifest.json")) as f:
            return step, json.load(f)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(directory))
        if m
    ]
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    placed directly onto the (possibly different-size) current mesh, which is
    the elastic-rescale path.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:012d}")
    if not _verify_step(directory, step):
        raise CheckpointCorrupt(path, "payload digest mismatch")
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for (pth, like), shard in zip(flat_paths[0], shard_leaves):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pth
        )
        arr = data[key]
        if shard is not None:
            arr = jax.device_put(arr, shard)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_keep_k(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(directory))
        if m
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:012d}"),
                      ignore_errors=True)


class CheckpointManager:
    """save-every-N + keep-k + preemption hook, as used by launch/train.py."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        self._lock = threading.Lock()
        self._latest_provider: Optional[Callable[[], tuple]] = None
        self._sigterm_installed = False
        self._sigterm_prev: Any = None
        self._sigterm_once = threading.Lock()

    def maybe_save(self, step: int, tree: Any) -> Optional[str]:
        if step % self.every != 0:
            return None
        with self._lock:
            path = save(self.directory, step, tree)
            gc_keep_k(self.directory, self.keep)
            return path

    def install_sigterm_hook(self, provider: Callable[[], tuple]) -> None:
        """provider() -> (step, tree); called on SIGTERM (pod preemption).

        Idempotent: installing twice updates the provider without
        stacking handlers.  A pre-existing SIGTERM handler is chained
        (called after the save); a second SIGTERM landing while a save
        is already in progress skips the save entirely rather than
        re-entering the checkpoint write.
        """
        self._latest_provider = provider
        if self._sigterm_installed:
            return

        def handler(signum, frame):
            if self._sigterm_once.acquire(blocking=False):
                try:
                    if self._latest_provider is not None:
                        step, tree = self._latest_provider()
                        save(self.directory, step, tree)
                finally:
                    self._sigterm_once.release()
            prev = self._sigterm_prev
            if callable(prev) and prev is not handler:
                prev(signum, frame)
            raise SystemExit(143)

        self._sigterm_prev = signal.signal(signal.SIGTERM, handler)
        self._sigterm_installed = True

    def restore_latest(self, tree_like: Any, shardings: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore(self.directory, tree_like, step, shardings)
