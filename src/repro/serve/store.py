"""Warm-start + certificate store: prior paths accelerate re-solves.

The sequential-screening insight that makes serving cheap (paper §7.1,
and the warm-start-along-a-path regime of the journal follow-up,
arXiv 1611.05780): a solve of a *nearby* problem — perturbed ``y``, a
refined lambda grid — is warm almost everywhere, so starting it from a
stored path's primal points turns most tenant traffic into a handful of
epochs per lambda.

Safety contract (the part that must never soften): **stored state
warm-starts, it never certifies.**  A :class:`WarmHint` hands back only a
primal point ``beta`` (plus provenance metadata); the stored
group/feature masks and dual points ride along as diagnostics but are
never returned as active-set masks, never injected as a ``first_round``,
and never intersected into anything.  Every discard reported for the new
solve comes from a fresh GAP round evaluated on the NEW problem at the
NEW lambda — :meth:`SGLSession.solve_path` re-screens from ``beta0``
before any epoch, so the ``RoundResult.safe`` /
``PathResult.certificates_safe`` contract holds end-to-end even when the
hint came from a different ``y``.  (A GAP sphere from *any* feasible
primal/dual pair is safe — Thm 1/2 — which is exactly why warm-starting
the primal point is free while reusing masks would not be.)

Admission is measured, not assumed: :func:`warm_eval` (a registered,
gate-audited traceable) computes the duality gap of a candidate hint on
the new problem, and the server adopts the hint only when that gap beats
the cold start's — a hint from a far-away ``y`` is silently dropped.

Exact repeats short-circuit entirely: the store keeps the full
:class:`PathResult` keyed by request digest, so an identical re-request
is served from memory without touching the solver.
"""
from __future__ import annotations

import functools
import hashlib
from collections import OrderedDict
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..core import sgl
from ..core.session import PathResult, SolverConfig
from ..core.sgl import SGLProblem
from ..faults.inject import fire as _fire_fault
from ..losses import resolve_loss
from .types import array_digest, design_digest

__all__ = ["CertificateStore", "WarmHint", "warm_eval"]


def _result_digest(result: PathResult) -> str:
    """Content digest of a stored exact result's payload arrays.

    Recorded at put() time and re-checked at exact() time, so a record
    that rots in place (bit-flip, or an injected ``store.record`` poison)
    can never be served verbatim — the entry is dropped and the request
    falls through to a fresh solve.
    """
    parts = (np.asarray(result.lambdas), np.asarray(result.betas),
             np.asarray(result.gaps), np.asarray(result.epochs))
    h = hashlib.blake2b(digest_size=16)
    for a in parts:
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


@functools.partial(jax.jit, static_argnames=("loss",))
def warm_eval(problem: SGLProblem, beta, lam_, loss=None):
    """Duality gap of a warm-start candidate on the NEW problem.

    One O(n p) pass: residual at ``beta``, dual-scaled feasible point
    (Eq. 15), gap = primal - dual.  The server compares this against the
    cold start's gap to decide hint admission — the hint is adopted as a
    primal point only, so this evaluation is an economics decision, not a
    safety decision (safety comes from the fresh GAP rounds inside the
    solve).

    ``loss=None`` is the squared loss verbatim (the historical program —
    the default shares its jit cache entry with every pre-loss call
    site); a :class:`repro.losses.Loss` evaluates the same admission gap
    from the generalized residual ``rho = -grad F(X beta)`` and the
    loss's conjugate dual.
    """
    if loss is None or loss.name == "lsq":
        resid = problem.y - jnp.einsum("ngk,gk->n", problem.X, beta)
        corr = jnp.einsum("ngk,n->gk", problem.X, resid)
        scale = jnp.maximum(
            lam_, sgl.sgl_dual_norm(corr, problem.tau, problem.w)
        )
        theta = resid / scale
        pr = (0.5 * jnp.sum(resid * resid)
              + lam_ * sgl.sgl_norm(beta, problem.tau, problem.w))
        return pr - sgl.dual(problem, theta, lam_)
    z = jnp.einsum("ngk,gk->n", problem.X, beta)
    rho = loss.neg_grad(problem.y, z)
    corr = jnp.einsum("ngk,n->gk", problem.X, rho)
    scale = jnp.maximum(
        lam_, sgl.sgl_dual_norm(corr, problem.tau, problem.w)
    )
    theta = rho / scale
    pr = (loss.value(problem.y, z)
          + lam_ * sgl.sgl_norm(beta, problem.tau, problem.w))
    return pr - loss.dual_obj(problem.y, theta, lam_)


class PathRecord(NamedTuple):
    """Stored path state for one (design, y, grid) solve.

    ``group_active`` is provenance/diagnostics only — see the module
    docstring's safety contract; nothing downstream may adopt it as a
    certificate for a different problem.
    """

    lambdas: np.ndarray          # (T,) grid, largest first
    betas: np.ndarray            # (T, G, ng) primal points (the hints)
    gaps: np.ndarray             # (T,) certified gaps on the SOURCE problem
    epochs: np.ndarray           # (T,)
    group_active: np.ndarray     # (T, G) masks of the SOURCE problem
    certificates_safe: bool
    y_digest: str
    loss_token: str = "LeastSquaresLoss()"
                                 # repr of the loss the path was solved
                                 #   under; a primal point optimised for a
                                 #   different data fidelity must never be
                                 #   offered as a hint (defense-in-depth —
                                 #   the design digest already separates
                                 #   losses via the config cache token)


class WarmHint(NamedTuple):
    """A candidate primal warm start (never a certificate)."""

    beta: np.ndarray             # (G, ng) stored primal point
    lam_src: float               # grid point the hint was solved at
    same_y: bool                 # hint comes from the identical y
    record: PathRecord


class CertificateStore:
    """LRU store of solved paths: exact-repeat results + warm-start hints.

    ``capacity`` bounds both maps (entries, not bytes — records hold
    (T, G, ng) arrays, so size the capacity to the problem scale).
    ``capacity=0`` disables the store entirely (baseline mode).
    """

    def __init__(self, capacity: int = 32):
        self.capacity = int(capacity)
        self._exact: OrderedDict[str, PathResult] = OrderedDict()
        self._exact_digests: "OrderedDict[str, str]" = OrderedDict()
        self._records: OrderedDict[tuple, PathRecord] = OrderedDict()
        self.exact_hits = 0
        self.warm_hits = 0
        self.puts = 0
        self.evictions = 0
        self.loss_rejects = 0
        self.poison_drops = 0

    # -- writes ------------------------------------------------------------

    def put(self, request_digest: str, problem: SGLProblem,
            config: SolverConfig, result: PathResult, *,
            exact: bool = True) -> None:
        """Record a solved path.  ``exact=False`` skips the exact-repeat
        map and keeps only the warm-start record — used for merged-grid
        slices, which match the request's solo output to solver tolerance
        rather than bit-exactly and so must never satisfy the verbatim
        exact-repeat short-circuit."""
        if self.capacity <= 0:
            return
        self.puts += 1
        if exact:
            self._exact[request_digest] = result
            self._exact.move_to_end(request_digest)
            self._exact_digests[request_digest] = _result_digest(result)
            self._exact_digests.move_to_end(request_digest)
            # Chaos hook: post-storage bit-rot — the poison lands AFTER
            # the digest was recorded, so verification must catch it.
            for s in _fire_fault("store.record"):
                if s.kind == "poison":
                    bad = np.array(result.betas, copy=True)
                    if bad.size:
                        bad.flat[0] += 1.0
                    self._exact[request_digest] = result._replace(
                        betas=bad
                    )
        dkey = design_digest(problem, config)
        ydig = array_digest(problem.y)
        rkey = (dkey, ydig, array_digest(np.asarray(result.lambdas)))
        self._records[rkey] = PathRecord(
            lambdas=np.asarray(result.lambdas),
            betas=np.asarray(result.betas),
            gaps=np.asarray(result.gaps),
            epochs=np.asarray(result.epochs),
            group_active=np.asarray(result.group_active),
            certificates_safe=bool(result.certificates_safe),
            y_digest=ydig,
            loss_token=repr(resolve_loss(config.loss)),
        )
        self._records.move_to_end(rkey)
        while len(self._exact) > self.capacity:
            dig, _ = self._exact.popitem(last=False)
            self._exact_digests.pop(dig, None)
            self.evictions += 1
        while len(self._records) > self.capacity:
            self._records.popitem(last=False)
            self.evictions += 1

    # -- reads -------------------------------------------------------------

    def exact(self, request_digest: str) -> Optional[PathResult]:
        """The stored result of an identical earlier request, or None.

        Integrity-checked: the entry's payload digest (recorded at put
        time) is re-verified before serving.  A mismatch means the record
        rotted in place — the entry is dropped (``poison_drops``) and the
        caller falls through to a fresh solve instead of serving
        corrupted betas verbatim.
        """
        res = self._exact.get(request_digest)
        if res is None:
            return None
        want = self._exact_digests.get(request_digest)
        if want is not None and _result_digest(res) != want:
            del self._exact[request_digest]
            del self._exact_digests[request_digest]
            self.poison_drops += 1
            return None
        self._exact.move_to_end(request_digest)
        self.exact_hits += 1
        return res

    def warm_hint(self, problem: SGLProblem, config: SolverConfig,
                  lambdas: np.ndarray) -> Optional[WarmHint]:
        """Best stored primal point for a solve of ``problem`` starting at
        ``lambdas[0]`` — same-design records only, same-``y`` preferred,
        nearest stored lambda (in log space) to the new path's start."""
        dkey = design_digest(problem, config)
        ydig = array_digest(problem.y)
        loss_token = repr(resolve_loss(config.loss))
        candidates = []
        for k, r in self._records.items():
            if k[0] != dkey:
                continue
            if r.loss_token != loss_token:
                # Should be unreachable (the design digest hashes the
                # config cache token, loss included) — counted, never
                # served: a hint optimised under another data fidelity is
                # an anti-warm start at best.
                self.loss_rejects += 1
                continue
            candidates.append((k, r))
        if not candidates:
            return None
        same = [(k, r) for k, r in candidates if r.y_digest == ydig]
        pool = same if same else candidates
        lam0 = float(np.asarray(lambdas, float)[0])
        best = None
        for key, rec in pool:
            d = np.abs(np.log(np.maximum(rec.lambdas, 1e-300))
                       - np.log(max(lam0, 1e-300)))
            i = int(np.argmin(d))
            if best is None or d[i] < best[0]:
                best = (d[i], key, rec, i)
        _, key, rec, i = best
        self._records.move_to_end(key)
        self.warm_hits += 1
        return WarmHint(
            beta=rec.betas[i],
            lam_src=float(rec.lambdas[i]),
            same_y=rec.y_digest == ydig,
            record=rec,
        )

    # -- reporting ---------------------------------------------------------

    def stats(self) -> dict:
        return {
            "records": len(self._records),
            "exact_entries": len(self._exact),
            "capacity": self.capacity,
            "exact_hits": self.exact_hits,
            "warm_hits": self.warm_hits,
            "puts": self.puts,
            "evictions": self.evictions,
            "loss_rejects": self.loss_rejects,
            "poison_drops": self.poison_drops,
        }


# ----------------------------------------------------------------------------
# Static-analysis hook (see repro.analysis.entrypoints for the template)
# ----------------------------------------------------------------------------

from ..analysis.registry import register_traceable  # noqa: E402

register_traceable("serve_warm_eval", warm_eval,
                   module=__name__, kind="jit")
