"""Compacted certified rounds: exactness, fallback policy, and path safety.

The compact round (repro.core.solver._screen_round_compact) runs the whole
certified gap + Theorem-1 round on the gathered (n, p_active) buffer,
bounding screened groups' dual-norm terms from the last full round's cached
reference.  These tests pin the three safety claims:

(a) a compact round's certificate is never looser than the full round's at
    the same (beta, lambda) — any group/feature it screens, the full round
    screens too;
(b) the fallback triggers when the screened-group bound crosses the active
    max (and full_round_every <= 0 disables compact rounds outright);
(c) the path-safety invariant (nothing screened is nonzero in a tight-tol
    unscreened reference) holds with compact rounds enabled, on both solve
    and solve_path, and the compact engine's trajectory is identical to the
    full-round engine's.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SGLSession, SolverConfig, make_problem
from repro.core.solver import RoundResult
from repro.data.synthetic import make_synthetic


@pytest.fixture(scope="module")
def prob():
    X, y, _, sizes = make_synthetic(n=40, p=240, n_groups=24, gamma1=3,
                                    gamma2=3, seed=5)
    return make_problem(X, y, sizes, tau=0.3)


@pytest.fixture(scope="module")
def warm(prob):
    """A converged session state with a nonempty screened set and a fresh
    compact-round reference (the convergence-confirming full round set it
    at the final beta)."""
    session = SGLSession(prob, SolverConfig(tol=1e-9, max_epochs=30_000))
    lam = 0.12 * session.lam_max
    res = session.solve(lam)
    assert float(res.gap) <= 1e-9
    assert not res.group_active.all()          # something screened
    return session, lam, res


def test_compact_round_never_looser_than_full(prob, warm):
    """(a): at the same (beta, lambda), the compact round's gap matches the
    full round's and its screens are a subset of the full round's."""
    session, lam, res = warm
    dtype = prob.X.dtype
    beta = jnp.asarray(res.beta)
    cert_c = session._compact_round(
        beta, jnp.asarray(lam, dtype), res.group_active, res.feat_active,
        session.caches,
    )
    assert isinstance(cert_c, RoundResult) and cert_c.compact
    cert_f = session.screen(lam, res.beta)     # full round, same point
    np.testing.assert_allclose(float(cert_c.gap), float(cert_f.gap),
                               rtol=1e-9, atol=1e-14)
    np.testing.assert_allclose(np.asarray(cert_c.theta),
                               np.asarray(cert_f.theta), atol=1e-12)
    # Restricted to the currently-active groups (screened ones hold a
    # permanent certificate and come back False from the compact round by
    # construction): compact screens => full screens.
    c_scr_g = ~np.asarray(cert_c.group_active) & res.group_active
    f_scr_g = ~np.asarray(cert_f.group_active) & res.group_active
    assert not np.any(c_scr_g & ~f_scr_g)
    c_scr_f = ~np.asarray(cert_c.feat_active) & res.feat_active
    f_scr_f = ~np.asarray(cert_f.feat_active) & res.feat_active
    assert not np.any(c_scr_f & ~f_scr_f)


def test_fallback_triggers_when_bound_crosses(prob, warm):
    """(b): a reference residual far from the current one blows the
    screened-group bound past the active max — the compact round must
    refuse (return None) and count a fallback."""
    session, lam, res = warm
    caches = session.caches
    dtype = prob.X.dtype
    beta = jnp.asarray(res.beta)
    resid_ref0, ref_terms0 = caches.resid_ref, caches.ref_terms
    try:
        # A huge shift makes every screened group's bound cross any active
        # max while ref_terms stay consistent with *some* reference point —
        # exactly the drift the validity test guards.
        caches.resid_ref = caches.resid_ref + 1e6
        fb0 = session.compact_fallbacks
        out = session._compact_round(
            beta, jnp.asarray(lam, dtype), res.group_active,
            res.feat_active, caches,
        )
        assert out is None
        assert session.compact_fallbacks == fb0 + 1
    finally:
        caches.resid_ref, caches.ref_terms = resid_ref0, ref_terms0


def test_full_round_every_zero_disables_compact(prob):
    session = SGLSession(prob, SolverConfig(tol=1e-8, full_round_every=0,
                                            max_epochs=30_000))
    res = session.solve(0.12 * session.lam_max)
    assert float(res.gap) <= 1e-8
    assert session.compact_rounds == 0
    assert session.full_rounds > 0


def test_solve_identical_to_full_round_engine(prob):
    """(c, solve): compact rounds are exact — identical beta, epochs and
    masks versus the full-round engine, with compact rounds exercised."""
    lam_frac = 0.1
    s_c = SGLSession(prob, SolverConfig(tol=1e-9, max_epochs=30_000))
    s_f = SGLSession(prob, SolverConfig(tol=1e-9, max_epochs=30_000,
                                        compact_rounds=False))
    lam = lam_frac * s_c.lam_max
    r_c = s_c.solve(lam)
    r_f = s_f.solve(lam)
    assert s_c.compact_rounds > 0
    assert s_f.compact_rounds == 0
    np.testing.assert_allclose(np.asarray(r_c.beta), np.asarray(r_f.beta),
                               atol=1e-12)
    assert r_c.n_epochs == r_f.n_epochs
    assert np.array_equal(r_c.group_active, r_f.group_active)
    assert np.array_equal(r_c.feat_active, r_f.feat_active)
    # the final reported round is always full: the last full round happened
    # at or after the last compact round
    assert s_c.full_rounds > 0


def test_converged_round_is_always_full(prob):
    """With the periodic full-round refresh disabled, full rounds can only
    come from sequential screens, fallbacks, oversized buffers, and the
    converged-round confirmation — so the floor below pins the invariant
    that every lambda's REPORTED gap comes from a full round (deleting the
    confirmation in SGLSession.solve fails this)."""
    session = SGLSession(prob, SolverConfig(tol=1e-8, max_epochs=30_000,
                                            full_round_every=10 ** 9))
    path = session.solve_path(T=6, delta=2.0)
    assert (path.gaps <= 1e-8).all()
    assert path.n_compact_rounds > 0
    worked = int((path.epochs > 0).sum())
    assert worked > 0
    assert path.n_full_rounds >= len(path.lambdas) + worked


def test_path_safety_with_compact_rounds(prob):
    """(c, solve_path): compact rounds exercised along the path; the
    reported gaps are full-problem certified; nothing screened is nonzero
    in a tight-tol unscreened reference; counters match the full-round
    engine exactly."""
    session = SGLSession(prob, SolverConfig(tol=1e-8, max_epochs=30_000))
    path = session.solve_path(T=6, delta=2.0)
    assert (path.gaps <= 1e-8).all()
    assert path.n_compact_rounds > 0
    # every lambda's converged round is full (sequential rounds add more)
    assert path.n_full_rounds >= len(path.lambdas)
    assert path.n_rounds == path.n_compact_rounds + path.n_full_rounds
    # compact rounds actually made rounds cheaper than full-round-only
    full_equiv = path.n_rounds * 4.0 * prob.n * prob.G * prob.ng
    assert 0 < path.round_flops < full_equiv

    full_engine = SGLSession(prob, SolverConfig(tol=1e-8, max_epochs=30_000,
                                                compact_rounds=False))
    path_f = full_engine.solve_path(T=6, delta=2.0)
    np.testing.assert_allclose(path.betas, path_f.betas, atol=1e-12)
    assert np.array_equal(path.epochs, path_f.epochs)
    assert np.array_equal(path.seq_screened, path_f.seq_screened)
    assert np.array_equal(path.dyn_screened, path_f.dyn_screened)
    assert np.array_equal(path.group_active, path_f.group_active)
    assert path_f.n_compact_rounds == 0

    # path safety vs an unscreened tight-tol reference
    feat_mask = np.asarray(prob.feat_mask)
    ref_session = SGLSession(prob, SolverConfig(tol=1e-10, rule="none",
                                                max_epochs=60_000))
    beta_ref = jnp.zeros((prob.G, prob.ng), prob.X.dtype)
    for t, lam_ in enumerate(path.lambdas):
        ref = ref_session.solve(float(lam_), beta0=beta_ref)
        beta_ref = ref.beta
        screened = ~path.feat_active[t] & feat_mask
        leaked = np.abs(np.asarray(ref.beta))[screened]
        assert leaked.size == 0 or leaked.max() < 1e-8, (t, leaked.max())
