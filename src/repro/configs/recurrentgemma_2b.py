"""recurrentgemma-2b — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,   # 26 residual blocks in pattern (rec, rec, attn) truncated
    d_model=2_560,
    n_heads=10,
    n_kv=1,
    d_ff=7_680,
    vocab=256_000,
    window=2_048,               # local attention window
    hybrid_pattern=("rec", "rec", "attn"),
    ssm_state=0,                # RG-LRU state == d_rnn (handled in model)
    conv_width=4,
    subquadratic=True,          # linear recurrence + windowed attention
    notes="RG-LRU + local MQA (kv=1), 1:2 pattern",
)
