"""Unit tests for the epsilon-norm machinery (paper Alg. 1, Prop. 9).

Hypothesis-based property tests live in test_properties.py so this module
collects and runs in environments without hypothesis installed.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    epsilon_norm,
    lam,
    lam_bisect,
)


def residual(x, alpha, R, nu):
    """Defining equation residual: sum S_{nu a}(x)^2 - (nu R)^2."""
    return np.sum(np.maximum(np.abs(x) - nu * alpha, 0.0) ** 2) - (nu * R) ** 2


class TestLambdaExact:
    def test_solves_defining_equation(self, rng):
        for _ in range(50):
            d = int(rng.integers(1, 64))
            x = rng.standard_normal(d) * rng.uniform(0.01, 100)
            alpha = rng.uniform(0.01, 1.0)
            R = rng.uniform(0.01, 3.0)
            nu = float(lam(jnp.asarray(x), alpha, R))
            rel = residual(x, alpha, R, nu) / max((nu * R) ** 2, 1e-30)
            assert abs(rel) < 1e-10

    def test_special_cases(self, rng):
        x = rng.standard_normal(9)
        assert np.isclose(float(lam(jnp.asarray(x), 0.6, 0.0)),
                          np.abs(x).max() / 0.6)
        assert np.isclose(float(lam(jnp.asarray(x), 0.0, 0.8)),
                          np.linalg.norm(x) / 0.8)
        assert float(lam(jnp.zeros(5), 0.5, 0.5)) == 0.0
        assert np.isinf(float(lam(jnp.asarray(x), 0.0, 0.0)))

    def test_batched_matches_loop(self, rng):
        xs = rng.standard_normal((7, 13))
        alphas = rng.uniform(0.1, 0.9, size=7)
        Rs = rng.uniform(0.1, 2.0, size=7)
        batched = np.asarray(lam(jnp.asarray(xs), jnp.asarray(alphas), jnp.asarray(Rs)))
        single = np.array(
            [float(lam(jnp.asarray(xs[i]), alphas[i], Rs[i])) for i in range(7)]
        )
        np.testing.assert_allclose(batched, single, rtol=1e-12)

    def test_bisection_matches_exact(self, rng):
        for _ in range(20):
            d = int(rng.integers(1, 40))
            x = rng.standard_normal(d)
            alpha = rng.uniform(0.05, 0.95)
            R = rng.uniform(0.05, 2.0)
            a = float(lam(jnp.asarray(x), alpha, R))
            b = float(lam_bisect(jnp.asarray(x), alpha, R))
            np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12)


def test_norm_properties(rng):
    """epsilon-norm is a norm: homogeneity + triangle inequality (sampled)."""
    eps = 0.35
    for _ in range(20):
        x = rng.standard_normal(12)
        y = rng.standard_normal(12)
        c = rng.uniform(0.1, 5.0)
        nx = float(epsilon_norm(jnp.asarray(x), eps))
        ny = float(epsilon_norm(jnp.asarray(y), eps))
        nxy = float(epsilon_norm(jnp.asarray(x + y), eps))
        ncx = float(epsilon_norm(jnp.asarray(c * x), eps))
        assert nxy <= nx + ny + 1e-9
        np.testing.assert_allclose(ncx, c * nx, rtol=1e-9)


def test_interpolates_l2_linf(rng):
    """eps->1: ||x||_eps -> ||x||; eps->0: -> ||x||_inf."""
    x = rng.standard_normal(10)
    n1 = float(epsilon_norm(jnp.asarray(x), 0.999999))
    n0 = float(epsilon_norm(jnp.asarray(x), 1e-9))
    np.testing.assert_allclose(n1, np.linalg.norm(x), rtol=1e-4)
    np.testing.assert_allclose(n0, np.abs(x).max(), rtol=1e-4)


class TestEdgeCases:
    """Limits and degenerate inputs, cross-checked against the
    kernels/ref.py oracle (deterministic twins of the hypothesis
    properties in test_properties.py, so the edge cases stay covered in
    environments without hypothesis)."""

    def test_alpha_zero_is_l2_over_R(self, rng):
        x = jnp.asarray(rng.standard_normal(12))
        want = float(jnp.linalg.norm(x)) / 0.7
        np.testing.assert_allclose(float(lam(x, 0.0, 0.7)), want, rtol=1e-10)
        np.testing.assert_allclose(float(lam_bisect(x, 0.0, 0.7)), want,
                                   rtol=1e-10)
        # continuity: tiny alpha approaches the branch value
        np.testing.assert_allclose(float(lam(x, 1e-9, 0.7)), want, rtol=1e-6)

    def test_R_zero_is_linf_over_alpha(self, rng):
        x = jnp.asarray(rng.standard_normal(12))
        want = float(jnp.max(jnp.abs(x))) / 0.8
        np.testing.assert_allclose(float(lam(x, 0.8, 0.0)), want, rtol=1e-10)
        np.testing.assert_allclose(float(lam_bisect(x, 0.8, 0.0)), want,
                                   rtol=1e-10)
        np.testing.assert_allclose(float(lam(x, 0.8, 1e-9)), want, rtol=1e-6)

    def test_epsilon_norm_interpolates_l2_linf(self, rng):
        x = jnp.asarray(rng.standard_normal(9) * 3.0)
        l2 = float(jnp.linalg.norm(x))
        linf = float(jnp.max(jnp.abs(x)))
        np.testing.assert_allclose(float(epsilon_norm(x, 1e-12)), linf,
                                   rtol=1e-6)
        np.testing.assert_allclose(float(epsilon_norm(x, 1 - 1e-12)), l2,
                                   rtol=1e-6)
        for eps in (0.1, 0.4, 0.9):
            nu = float(epsilon_norm(x, eps))
            assert linf - 1e-10 <= nu <= l2 + 1e-10

    def test_single_element_group_closed_form(self):
        from repro.kernels.ref import dual_norm_ref

        # d = 1: S_{nu alpha}(|x|) = nu R  =>  nu = |x| / (alpha + R)
        for xval, alpha, R in [(3.0, 0.6, 0.4), (-7.5, 0.25, 1.5),
                               (0.1, 0.99, 0.01)]:
            x = jnp.asarray([xval])
            want = abs(xval) / (alpha + R)
            np.testing.assert_allclose(float(lam(x, alpha, R)), want,
                                       rtol=1e-10)
            np.testing.assert_allclose(float(lam_bisect(x, alpha, R)), want,
                                       rtol=1e-9)
            np.testing.assert_allclose(float(dual_norm_ref(x, alpha, R)),
                                       want, rtol=1e-10)

    def test_zero_vector_every_branch(self):
        z = jnp.zeros(5)
        for alpha, R in [(0.5, 0.5), (0.0, 0.7), (0.8, 0.0), (0.0, 0.0)]:
            assert float(lam(z, alpha, R)) == 0.0
            assert float(lam_bisect(z, alpha, R)) == 0.0

    def test_bisect_matches_exact_on_batch(self, rng):
        x = jnp.asarray(rng.standard_normal((32, 8)) * 5.0)
        alpha = jnp.asarray(rng.uniform(0.05, 0.95, 32))
        R = jnp.asarray(rng.uniform(0.05, 1.5, 32))
        np.testing.assert_allclose(np.asarray(lam_bisect(x, alpha, R)),
                                   np.asarray(lam(x, alpha, R)),
                                   rtol=1e-9, atol=1e-12)
