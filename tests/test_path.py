"""Path-engine tests: sequential-screening safety (Thm 1/2 along a path),
engine/naive-loop equivalence, option plumbing, and backend parity."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    lambda_max,
    make_problem,
    screen_round,
    sequential_sphere,
    solve,
    solve_path,
)
from repro.core.screening import screen
from repro.data.synthetic import make_synthetic


@pytest.fixture(scope="module")
def prob():
    X, y, _, sizes = make_synthetic(n=30, p=120, n_groups=15, gamma1=3,
                                    gamma2=3, seed=9)
    return make_problem(X, y, sizes, tau=0.3)


@pytest.fixture(scope="module")
def engine_path(prob):
    return solve_path(prob, T=8, delta=2.0, tol=1e-8, rule="gap")


def test_path_screening_is_safe(prob, engine_path):
    """Safety invariant of Thm 1/2 across the whole path: no variable
    screened out (sequentially or dynamically) may be non-zero in a
    high-precision unscreened reference solution."""
    feat_mask = np.asarray(prob.feat_mask)
    beta_ref = jnp.zeros((prob.G, prob.ng), prob.X.dtype)
    for t, lam_ in enumerate(engine_path.lambdas):
        ref = solve(prob, float(lam_), beta0=beta_ref, tol=1e-11,
                    rule="none", max_epochs=60_000)
        beta_ref = ref.beta
        screened = ~engine_path.feat_active[t] & feat_mask
        leaked = np.abs(np.asarray(ref.beta))[screened]
        assert leaked.size == 0 or leaked.max() < 1e-8, (t, leaked.max())


def test_engine_matches_naive_loop(prob, engine_path):
    naive = solve_path(prob, T=8, delta=2.0, tol=1e-8, rule="gap",
                       sequential=False, check_every=None)
    np.testing.assert_allclose(engine_path.betas, naive.betas, atol=1e-4)
    assert (engine_path.gaps <= 1e-8).all()
    # The per-epoch early exit removes whole-block overshoot, but screening
    # at different iterates can perturb a trajectory by a few passes — allow
    # one block of slack rather than asserting strict dominance.
    assert engine_path.epochs.sum() <= naive.epochs.sum() + 10


def test_sequential_screening_zero_work_at_lambda_max(engine_path):
    # lambda_0 = lambda_max: warm gap is already 0 => zero BCD epochs, and
    # the radius-0 GAP sphere screens out non-equicorrelated groups.
    assert engine_path.epochs[0] == 0
    assert engine_path.seq_screened[0] > 0
    assert float(np.abs(engine_path.betas[0]).max()) == 0.0
    # counters are consistent: seq + dyn never exceeds G
    assert ((engine_path.seq_screened + engine_path.dyn_screened)
            <= engine_path.betas.shape[1]).all()
    assert (engine_path.dyn_screened >= 0).all()


def test_cache_carrying_reduces_gathers(prob, engine_path):
    naive = solve_path(prob, T=8, delta=2.0, tol=1e-8, rule="gap",
                       sequential=False, check_every=None)
    assert engine_path.n_gathers <= naive.n_gathers


def test_solve_path_forwards_compact_and_inner_rounds(prob):
    res_c = solve_path(prob, T=5, delta=1.5, tol=1e-7, rule="gap",
                       compact=True, inner_rounds=2)
    res_f = solve_path(prob, T=5, delta=1.5, tol=1e-7, rule="gap",
                       compact=False)
    np.testing.assert_allclose(res_c.betas, res_f.betas, atol=1e-4)
    assert (res_c.gaps <= 1e-7).all() and (res_f.gaps <= 1e-7).all()


def test_sequential_sphere_is_safe(prob):
    """The sequential GAP sphere built at a new lambda from the previous
    lambda's solution must contain the new dual optimum (Thm 2)."""
    lmax = float(lambda_max(prob))
    prev = solve(prob, 0.5 * lmax, tol=1e-10, rule="none", max_epochs=40_000)
    lam_new = 0.4 * lmax
    sph = sequential_sphere(prob, prev.beta, lam_new)
    opt = solve(prob, lam_new, tol=1e-12, rule="none", max_epochs=60_000)
    dist = float(jnp.linalg.norm(opt.theta - sph.center))
    assert dist <= float(sph.radius) + 1e-8
    # and screening with it keeps every support variable of the optimum
    res = screen(prob, sph)
    support = np.abs(np.asarray(opt.beta)) > 1e-8
    assert not np.any(support & ~np.asarray(res.feat_active))


def test_screen_round_backends_agree(prob):
    """Pallas-kernel round (interpret mode off-TPU) == XLA einsum round."""
    lmax = float(lambda_max(prob))
    res = solve(prob, 0.3 * lmax, tol=1e-8, rule="gap")
    out_x = screen_round(prob, res.beta, 0.25 * lmax, rule="gap",
                         backend="xla")
    out_p = screen_round(prob, res.beta, 0.25 * lmax, rule="gap",
                         backend="pallas")
    np.testing.assert_allclose(float(out_x[0]), float(out_p[0]), rtol=1e-8)
    np.testing.assert_allclose(np.asarray(out_x[1]), np.asarray(out_p[1]),
                               atol=1e-12)
    assert np.array_equal(np.asarray(out_x[2]), np.asarray(out_p[2]))
    assert np.array_equal(np.asarray(out_x[3]), np.asarray(out_p[3]))


def test_solve_path_pallas_backend_end_to_end(prob):
    res = solve_path(prob, T=4, delta=1.5, tol=1e-7, rule="gap",
                     screen_backend="pallas")
    assert (res.gaps <= 1e-7).all()
