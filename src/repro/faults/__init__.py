"""repro.faults — deterministic fault injection + graceful degradation.

Three pieces:

* **Harness** — :class:`FaultPlan` / :class:`FaultSpec` value objects and
  the :func:`inject` context manager: seeded, site-addressable faults
  (numeric corruption, kernel-launch failure, stalls, worker kills,
  checkpoint truncation/bit-flips, store poisoning) with per-site firing
  schedules, so chaos runs are reproducible bit-for-bit.
* **Error taxonomy** — :class:`Degraded`, :class:`ServeError`,
  :class:`WorkerCrash`, :class:`NumericsError`,
  :class:`KernelLaunchError`, :class:`CheckpointCorrupt` (plus
  :class:`repro.serve.Preempted`): every failure a future can resolve to.
* **Budgets** — :class:`SolveBudget`: per-request deadlines and epoch
  caps checked at host-synced round boundaries.

``python -m repro.faults --check`` runs the seeded chaos matrix (the
executable spec of the safety contract: every registered fault ends in
bit-identical-after-recovery betas, a certified-honest degraded result,
or a typed error — never an unsafe certificate, never a hung future).

The chaos runner itself lives in :mod:`repro.faults.chaos` and is NOT
imported here: it imports the solver and serve layers, which in turn
import this package's leaf modules (errors/plan/inject/budget).
"""
from .budget import SolveBudget
from .errors import (
    CheckpointCorrupt,
    Degraded,
    KernelLaunchError,
    NumericsError,
    ServeError,
    WorkerCrash,
)
from .inject import FaultLog, FiredEvent, active_plan, fire, inject
from .plan import KINDS, SITES, FaultPlan, FaultSpec

__all__ = [
    "FaultPlan",
    "FaultSpec",
    "FaultLog",
    "FiredEvent",
    "SITES",
    "KINDS",
    "inject",
    "fire",
    "active_plan",
    "SolveBudget",
    "Degraded",
    "ServeError",
    "WorkerCrash",
    "NumericsError",
    "KernelLaunchError",
    "CheckpointCorrupt",
]
