"""Serving layer: coalescing parity, caches, warm-start safety, resume.

The three contracts worth defending with bits, not tolerances:

* a coalesced request's betas are identical to a solo solve (exactly one
  solve runs, per-request solver caches are reset);
* stored state warm-starts but never certifies — even an adversarially
  poisoned store record cannot make the server report a stale discard;
* an interrupted + resumed chunked path is identical to an uninterrupted
  chunked run with the same segmenting.
"""
import os
import signal
import time

import numpy as np
import pytest

from repro import ckpt
from repro.core import sgl
from repro.core.session import SGLSession, SolverConfig, lambda_grid
from repro.data.synthetic import make_synthetic
from repro.kernels import ops as kops
from repro.serve import (
    CertificateStore,
    PathRequest,
    Preempted,
    ServeConfig,
    SessionCache,
    SGLServer,
    coalesce,
)
from repro.serve.queue import RequestQueue
from repro.serve.store import PathRecord
from repro.serve.types import array_digest, problem_digest

CFG = SolverConfig(tol=1e-7, max_epochs=5_000)


def _problem(seed=0, n=32, p=128, groups=16, tau=0.3, y_noise=0.0):
    X, y, _beta, sizes = make_synthetic(
        n=n, p=p, n_groups=groups, gamma1=3, gamma2=3, seed=seed)
    if y_noise:
        y = y + y_noise * np.random.default_rng(99).standard_normal(y.shape)
    return sgl.make_problem(X, y, sizes, tau=tau)


def _grid(problem, T=5, delta=1.5):
    return lambda_grid(float(sgl.lambda_max(problem)), T=T, delta=delta)


def _drain_queue(q, default, n):
    out = []
    while len(out) < n:
        got = q.drain(max_batch=n, window_s=0.05)
        assert got is not None
        out.extend(got)
    return out


# ---------------------------------------------------------------------------
# value identities: cache_token, digests
# ---------------------------------------------------------------------------

def test_cache_token_equal_and_hashable():
    a, b = SolverConfig(tol=1e-6), SolverConfig(tol=1e-6)
    assert a.cache_token() == b.cache_token()
    assert hash(a.cache_token()) == hash(b.cache_token())
    assert {a.cache_token(): 1}[b.cache_token()] == 1
    assert a.cache_token() != SolverConfig(tol=1e-5).cache_token()
    # rule objects resolve to a stable repr, so "gap" the string and the
    # resolved rule object produce the same token
    assert (SolverConfig(rule="gap").cache_token()
            == SolverConfig().cache_token())


def test_problem_digest_is_value_identity():
    p1, p2 = _problem(seed=0), _problem(seed=0)
    assert p1.X is not p2.X  # distinct buffers, equal values
    assert problem_digest(p1, CFG) == problem_digest(p2, CFG)
    p3 = _problem(seed=0, y_noise=1e-3)
    assert problem_digest(p1, CFG) != problem_digest(p3, CFG)
    assert array_digest(np.arange(4)) != array_digest(np.arange(4.0))


# ---------------------------------------------------------------------------
# queue + coalescing
# ---------------------------------------------------------------------------

def test_coalesce_identical_requests_collapse():
    prob = _problem()
    grid = _grid(prob)
    q = RequestQueue()
    for i in range(3):
        q.submit(PathRequest(f"t{i}", prob, grid), CFG)
    q.submit(PathRequest("t3", prob, grid[:3]), CFG)  # different grid
    groups = coalesce(_drain_queue(q, CFG, 4), CFG)
    assert [len(g.members) for g in groups] == [3, 1]
    assert not groups[0].merged
    np.testing.assert_array_equal(groups[0].lambdas, grid)
    for idx in groups[0].member_index:
        np.testing.assert_array_equal(idx, np.arange(len(grid)))


def test_coalesce_merge_grids_union():
    prob = _problem()
    grid = _grid(prob, T=6)
    g1, g2 = grid[::2], grid[1::2]
    q = RequestQueue()
    q.submit(PathRequest("t0", prob, g1), CFG)
    q.submit(PathRequest("t1", prob, g2), CFG)
    (group,) = coalesce(_drain_queue(q, CFG, 2), CFG, merge_grids=True)
    assert group.merged and len(group.members) == 2
    np.testing.assert_array_equal(group.lambdas, grid)  # descending union
    np.testing.assert_array_equal(group.lambdas[group.member_index[0]], g1)
    np.testing.assert_array_equal(group.lambdas[group.member_index[1]], g2)


def test_queue_close_rejects_and_drains_none():
    q = RequestQueue()
    q.close()
    with pytest.raises(RuntimeError):
        q.submit(PathRequest("t", _problem(), [1.0]), CFG)
    assert q.drain(window_s=0.0) is None


# ---------------------------------------------------------------------------
# the serve loop: parity, store, cache
# ---------------------------------------------------------------------------

def _server(**kw):
    kw.setdefault("default_solver", CFG)
    kw.setdefault("coalesce_window_s", 0.2)
    return SGLServer(ServeConfig(**kw)).start()


def test_coalesced_bit_identical_to_solo():
    prob = _problem(seed=1)
    grid = _grid(prob)
    server = _server()
    try:
        futs = [server.submit(PathRequest(f"t{i}", prob, grid))
                for i in range(3)]
        resps = [f.result(timeout=600) for f in futs]
    finally:
        server.stop()
    assert all(r.served_from == "coalesced" and r.coalesced_n == 3
               for r in resps)
    assert server.counters["path_solves"] == 1
    solo = SGLSession(prob, CFG).solve_path(grid)
    for r in resps:
        np.testing.assert_array_equal(r.result.betas, solo.betas)
        np.testing.assert_array_equal(r.result.epochs, solo.epochs)


def test_store_serves_exact_repeat_bit_identically():
    prob = _problem(seed=2)
    grid = _grid(prob)
    server = _server()
    try:
        first = server.submit(PathRequest("t0", prob, grid)).result(600)
        again = server.submit(PathRequest("t1", prob, grid)).result(600)
    finally:
        server.stop()
    assert not first.store_hit
    assert again.store_hit and again.served_from == "store"
    assert server.counters["path_solves"] == 1
    np.testing.assert_array_equal(again.result.betas, first.result.betas)


def test_cached_session_repeat_has_zero_retraces():
    """The cache's correctness check, asserted through the kernels.ops
    audit: an exact repeat served from a session-cache hit must not grow
    any registered jit cache (store disabled to force the re-solve)."""
    prob = _problem(seed=3)
    grid = _grid(prob)
    server = _server(serve_from_store=False)
    try:
        server.submit(PathRequest("t0", prob, grid)).result(600)
        with kops.audit_scope() as audit:
            again = server.submit(PathRequest("t0", prob, grid)).result(600)
        assert again.session_cache_hit
        assert audit.retraces == 0
        assert server.cache.retraces == 0
        assert server.cache.hits >= 1
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# warm starts: engagement and the certificate-safety contract
# ---------------------------------------------------------------------------

def _assert_no_stale_screens(resp, problem, grid):
    """Every group the served path screened must be zero in a tight-tol
    unscreened reference — a nonzero one would be a stale certificate."""
    ref = SGLSession(problem, SolverConfig(
        tol=1e-9, max_epochs=50_000, rule="none")).solve_path(grid)
    for t in range(len(grid)):
        screened = ~np.asarray(resp.result.group_active[t])
        nz = np.linalg.norm(np.asarray(ref.betas[t]), axis=-1) > 1e-8
        assert int((screened & nz).sum()) == 0
    assert resp.result.certificates_safe


def test_perturbed_y_warm_start_is_safe():
    prob = _problem(seed=4)
    grid = _grid(prob, T=6)
    pert = _problem(seed=4, y_noise=0.02)
    tail = grid[3:]
    server = _server()
    try:
        server.submit(PathRequest("t0", prob, grid)).result(600)
        resp = server.submit(PathRequest("t1", pert, tail)).result(600)
    finally:
        server.stop()
    # a mid-path start on a nearby problem must admit the stored hint...
    assert resp.warm_started and resp.warm_source_lam is not None
    # ...and every discard must still come from a fresh GAP round
    _assert_no_stale_screens(resp, pert, tail)


def test_poisoned_store_record_cannot_certify():
    """Adversarial store: records claiming everything screened (and one
    with a garbage primal point) must not corrupt a served result."""
    prob = _problem(seed=5)
    grid = _grid(prob, T=6)
    pert = _problem(seed=5, y_noise=0.02)
    tail = grid[3:]
    server = _server()
    try:
        base = server.submit(PathRequest("t0", prob, grid)).result(600)
        # Poison 1: a valid-looking record whose masks claim every group
        # is screened everywhere.  Masks are diagnostics — the serve path
        # must never read them as certificates.
        for key, rec in list(server.store._records.items()):
            server.store._records[key] = rec._replace(
                group_active=np.zeros_like(rec.group_active))
        # Poison 2: same-design record with a garbage primal point; the
        # measured admission gate must reject it (its gap cannot beat a
        # cold start), never crash or adopt it.
        dkey = next(iter(server.store._records))[0]
        G, ng = np.asarray(base.result.betas).shape[1:]
        server.store._records[(dkey, "poisoned-y", "poisoned-grid")] = \
            PathRecord(
                lambdas=np.asarray(tail),
                betas=1e6 * np.ones((len(tail), G, ng)),
                gaps=np.zeros(len(tail)),
                epochs=np.zeros(len(tail), int),
                group_active=np.zeros((len(tail), G), bool),
                certificates_safe=True,
                y_digest="poisoned-y",
            )
        resp = server.submit(PathRequest("t1", pert, tail)).result(600)
    finally:
        server.stop()
    _assert_no_stale_screens(resp, pert, tail)


def test_merge_grids_tol_level_parity():
    cfg = SolverConfig(tol=1e-8, max_epochs=20_000)
    prob = _problem(seed=6)
    grid = _grid(prob, T=6)
    g1, g2 = grid[::2], grid[1::2]
    server = _server(default_solver=cfg, merge_grids=True,
                     coalesce_window_s=0.5)
    try:
        f1 = server.submit(PathRequest("t0", prob, g1))
        f2 = server.submit(PathRequest("t1", prob, g2))
        r1, r2 = f1.result(600), f2.result(600)
    finally:
        server.stop()
    assert r1.merged_grid and r2.merged_grid
    assert server.counters["path_solves"] == 1
    np.testing.assert_array_equal(r1.result.lambdas, g1)
    np.testing.assert_array_equal(r2.result.lambdas, g2)
    # The union grid changes the warm-start trajectory, so parity with a
    # solo run is tolerance-level, not bit-level (the documented trade).
    for r, g in ((r1, g1), (r2, g2)):
        solo = SGLSession(prob, cfg).solve_path(g)
        np.testing.assert_allclose(r.result.betas, solo.betas, atol=1e-4)


def test_merged_result_not_stored_as_exact_repeat():
    """A merged-grid slice is tolerance-level, so it must never satisfy
    the exact-repeat short-circuit: a later identical solo request gets a
    fresh solve whose betas are bit-identical to a solo run."""
    prob = _problem(seed=13)
    grid = _grid(prob, T=6)
    g1, g2 = grid[::2], grid[1::2]
    server = _server(merge_grids=True, warm_start=False,
                     coalesce_window_s=0.5)
    try:
        f1 = server.submit(PathRequest("t0", prob, g1))
        f2 = server.submit(PathRequest("t1", prob, g2))
        r1 = f1.result(600)
        f2.result(600)
        assert r1.merged_grid
        solo = server.submit(PathRequest("t2", prob, g1)).result(600)
    finally:
        server.stop()
    assert not solo.store_hit and solo.served_from != "store"
    assert not solo.merged_grid
    assert server.counters["path_solves"] == 2
    ref = SGLSession(prob, CFG).solve_path(g1)
    np.testing.assert_array_equal(solo.result.betas, ref.betas)
    # the merged slices still seeded warm-start records (hints are
    # measured and safe either way), just not the exact map
    assert server.store.stats()["records"] > 0
    assert server.store.stats()["exact_entries"] == 1  # the solo result


# ---------------------------------------------------------------------------
# resumable paths: drain -> Preempted -> resume, bit-identical
# ---------------------------------------------------------------------------

def _chunk_cfg(tmpdir, **kw):
    kw.setdefault("default_solver", CFG)
    kw.setdefault("coalesce_window_s", 0.05)
    return ServeConfig(ckpt_dir=str(tmpdir), ckpt_every=2, ckpt_keep=2,
                       **kw)


def test_preempt_resume_bit_identical(tmp_path):
    prob = _problem(seed=7)
    grid = _grid(prob, T=6)
    req = PathRequest("t0", prob, grid)

    # uninterrupted chunked run (same segmenting) = the reference
    ref_server = SGLServer(_chunk_cfg(tmp_path / "ref")).start()
    try:
        ref = ref_server.submit(req).result(600)
    finally:
        ref_server.stop()

    # interrupted run: drain (the SIGTERM path) after the second segment
    bomb_dir = tmp_path / "bomb"
    server = SGLServer(_chunk_cfg(bomb_dir))

    def bomb(digest, cursor, T):
        if cursor >= 4:
            server.drain()

    server.config.on_segment = bomb
    server.start()
    fut = server.submit(req)
    with pytest.raises(Preempted) as ei:
        fut.result(600)
    server.join()
    assert ei.value.cursor == 4
    assert server.counters["preempted"] == 1

    # restart on the same ckpt dir: resumes at the stored cursor and
    # reproduces the uninterrupted run exactly (betas AND epochs)
    server2 = SGLServer(_chunk_cfg(bomb_dir)).start()
    try:
        resumed = server2.submit(req).result(600)
    finally:
        server2.stop()
    assert resumed.resumed_from == 4
    assert server2.counters["resumed"] == 1
    np.testing.assert_array_equal(resumed.result.betas, ref.result.betas)
    np.testing.assert_array_equal(resumed.result.epochs, ref.result.epochs)
    # keep-k GC ran in the request's ckpt dir
    rdir = bomb_dir / resumed.request_digest
    steps = [d for d in os.listdir(rdir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    assert len(steps) <= 2


def test_merged_checkpoint_not_adopted_by_solo_resubmission(tmp_path):
    """The resume guard verifies the solved-grid digest: a merged group
    checkpoints the UNION grid under the lead member's request digest, so
    a preempted union checkpoint (cursor within the solo grid's length)
    must not be adopted by a later solo re-submission of the lead request
    — its prefix arrays belong to union lambda points."""
    prob = _problem(seed=14)
    grid = _grid(prob, T=6)
    g1, g2 = grid[::2], grid[1::2]

    server = SGLServer(_chunk_cfg(tmp_path, merge_grids=True,
                                  coalesce_window_s=0.5))

    def bomb(digest, cursor, T):
        if cursor >= 2:
            server.drain()

    server.config.on_segment = bomb
    server.start()
    f1 = server.submit(PathRequest("t0", prob, g1))
    f2 = server.submit(PathRequest("t1", prob, g2))
    with pytest.raises(Preempted) as ei:
        f1.result(600)
    with pytest.raises(Preempted):
        f2.result(600)
    server.join()
    # preempted mid-union at cursor 2 <= len(g1): digest-compatible —
    # only the grid digest distinguishes this checkpoint from solo state
    assert ei.value.cursor == 2 and ei.value.cursor <= len(g1)
    step, manifest = ckpt.latest(str(tmp_path / ei.value.request_digest))
    assert manifest["extra"]["T"] == len(grid)  # really the union grid

    server2 = SGLServer(_chunk_cfg(tmp_path)).start()
    try:
        solo = server2.submit(PathRequest("t0", prob, g1)).result(600)
    finally:
        server2.stop()
    assert solo.resumed_from is None
    assert server2.counters["resumed"] == 0
    np.testing.assert_array_equal(solo.result.lambdas, g1)
    # bit-identical to an uninterrupted chunked solo run (same segmenting)
    ref_server = SGLServer(_chunk_cfg(tmp_path / "ref")).start()
    try:
        ref = ref_server.submit(PathRequest("t0", prob, g1)).result(600)
    finally:
        ref_server.stop()
    np.testing.assert_array_equal(solo.result.betas, ref.result.betas)
    np.testing.assert_array_equal(solo.result.epochs, ref.result.epochs)


def test_resume_complete_checkpoint_preserves_rule_name(tmp_path):
    """Resuming from a fully-complete checkpoint (stored cursor == T, no
    fresh segments) must report the rule that actually ran, restored from
    the manifest — not a 'gap' default."""
    cfg = SolverConfig(tol=1e-7, max_epochs=5_000, rule="dynamic")
    prob = _problem(seed=15)
    grid = _grid(prob, T=4)
    req = PathRequest("t0", prob, grid)

    server = SGLServer(_chunk_cfg(tmp_path, default_solver=cfg,
                                  serve_from_store=False)).start()
    try:
        first = server.submit(req).result(600)
    finally:
        server.stop()
    assert first.result.rule_name == "dynamic"

    server2 = SGLServer(_chunk_cfg(tmp_path, default_solver=cfg,
                                   serve_from_store=False)).start()
    try:
        resumed = server2.submit(req).result(600)
    finally:
        server2.stop()
    assert resumed.resumed_from == len(grid)
    assert resumed.result.rule_name == "dynamic"
    np.testing.assert_array_equal(resumed.result.betas, first.result.betas)


def test_sigterm_hook_drains(tmp_path):
    server = SGLServer(_chunk_cfg(tmp_path)).start()
    prev = server.install_sigterm_hook()
    try:
        signal.raise_signal(signal.SIGTERM)
        deadline = time.time() + 5
        while not server.draining and time.time() < deadline:
            time.sleep(0.01)
        assert server.draining
        with pytest.raises(RuntimeError):
            server.submit(PathRequest("t", _problem(), [1.0]))
    finally:
        signal.signal(signal.SIGTERM, prev)
        server.join()


# ---------------------------------------------------------------------------
# session-level primitives the server builds on
# ---------------------------------------------------------------------------

def test_solve_path_beta0_prev_epochs_chunked_parity():
    """With compact rounds off (no cross-segment reference state) and no
    lambda batching, manually chunked solve_path calls threaded through
    beta0/prev_epochs reproduce the one-shot run bit-for-bit."""
    cfg = SolverConfig(tol=1e-7, max_epochs=5_000, full_round_every=0)
    prob = _problem(seed=8)
    grid = _grid(prob, T=6)
    one = SGLSession(prob, cfg).solve_path(grid, batch_lambdas=1)

    sess = SGLSession(prob, cfg)
    parts, beta0, prev = [], None, None
    for k in range(0, len(grid), 2):
        pr = sess.solve_path(grid[k:k + 2], beta0=beta0,
                             prev_epochs=prev, batch_lambdas=1)
        parts.append(pr)
        beta0 = pr.betas[-1]
        prev = int(pr.epochs[-1])
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.betas) for p in parts]), one.betas)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.epochs) for p in parts]), one.epochs)


def test_session_xt_pre_adoption_and_validation():
    cfg = SolverConfig(screen_backend="pallas")
    prob = _problem(seed=9)
    xt = kops.prepare_transposed(prob.X)
    s_pre = SGLSession(prob, cfg, xt_pre=xt)
    s_own = SGLSession(prob, cfg)
    grid = _grid(prob, T=3)
    np.testing.assert_array_equal(
        s_pre.solve_path(grid).betas, s_own.solve_path(grid).betas)
    with pytest.raises(ValueError, match="xt_pre"):
        SGLSession(prob, cfg, xt_pre=np.zeros((3, 3)))


def test_session_cache_lru_and_design_sharing():
    cache = SessionCache(capacity=2)
    cfg = SolverConfig(screen_backend="pallas")  # needs the (p, n) design
    probs = [_problem(seed=10, y_noise=k * 0.01) for k in range(3)]
    for p in probs:
        _, hit = cache.get(p, cfg)
        assert not hit
    # same X across the perturbed-y family: the transposed design is
    # built once and shared
    assert cache.design_hits == 2
    assert cache.stats()["sessions"] == 2 and cache.evictions == 1
    _, hit = cache.get(probs[2], cfg)   # still resident
    assert hit
    _, hit = cache.get(probs[0], cfg)   # LRU-evicted above
    assert not hit


def test_session_cache_capacity_zero_disables():
    cache = SessionCache(capacity=0)
    prob = _problem(seed=11)
    s1, hit1 = cache.get(prob, CFG)
    s2, hit2 = cache.get(prob, CFG)
    assert not hit1 and not hit2 and s1 is not s2
    assert cache.stats()["sessions"] == 0


def test_store_capacity_zero_disables():
    store = CertificateStore(capacity=0)
    prob = _problem(seed=12)
    grid = _grid(prob, T=3)
    res = SGLSession(prob, CFG).solve_path(grid)
    store.put("d", prob, CFG, res)
    assert store.exact("d") is None
    assert store.warm_hint(prob, CFG, grid) is None
