"""Screening-rule strategy API: registry round-trips + fail-fast errors,
string/object bit-parity for the GAP rule, the rule-safety matrix
(every is_safe rule vs a tight-tol unscreened reference, single-device and
mesh), unsafe-rule flagging, and the batched driver's compact rounds."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    SGLSession,
    SolverConfig,
    lambda_max,
    make_problem,
    screen_round,
)
from repro.data.synthetic import make_synthetic
from repro.launch import mesh as meshlib
from repro.rules import (
    GapSafeRule,
    NoScreening,
    ScreeningRule,
    StrongSequentialRule,
    available_rules,
    get_rule,
    register_rule,
    resolve_rule,
)


@pytest.fixture(scope="module")
def prob():
    X, y, _, sizes = make_synthetic(n=30, p=120, n_groups=15, gamma1=3,
                                    gamma2=3, seed=9)
    return make_problem(X, y, sizes, tau=0.3)


@pytest.fixture(scope="module")
def ref_path(prob):
    """Tight-tol unscreened warm-started reference down the shared grid."""
    from repro.core.session import lambda_grid

    session = SGLSession(prob, SolverConfig(tol=1e-10, rule="none",
                                            max_epochs=60_000))
    lambdas = lambda_grid(session.lam_max, T=5, delta=1.5)
    betas = []
    beta = jnp.zeros((prob.G, prob.ng), prob.X.dtype)
    for lam_ in lambdas:
        beta = session.solve(float(lam_), beta0=beta).beta
        betas.append(np.asarray(beta))
    return lambdas, np.stack(betas)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_roundtrip():
    names = available_rules()
    assert {"gap", "static", "dynamic", "dst3", "none",
            "strong"} <= set(names)
    for name in names:
        rule = get_rule(name)
        assert rule.name == name
        assert resolve_rule(name) is rule           # string -> singleton
        assert resolve_rule(rule) is rule           # object passes through
    assert isinstance(get_rule("gap"), GapSafeRule)
    # Equal value objects share identity-free equality (jit cache keys).
    assert GapSafeRule() == GapSafeRule()
    assert hash(GapSafeRule()) == hash(GapSafeRule())
    assert StrongSequentialRule(0.25) != StrongSequentialRule(0.5)


def test_unknown_rule_fails_fast_with_registered_list(prob):
    with pytest.raises(ValueError, match="registered rules"):
        get_rule("bogus")
    # ... at session construction (SolverConfig resolution), not deep
    # inside a round:
    with pytest.raises(ValueError, match="registered rules"):
        SGLSession(prob, SolverConfig(rule="bogus"))
    session = SGLSession(prob)
    with pytest.raises(ValueError, match="registered rules"):
        session.screen(1.0, rule="bogus")
    # ... and on the legacy resumable-round API, which used to fall
    # silently into the no-screening branch for unknown names:
    beta = jnp.zeros((prob.G, prob.ng), prob.X.dtype)
    with pytest.raises(ValueError, match="registered rules"):
        screen_round(prob, beta, 1.0, rule="bogus")
    with pytest.raises(TypeError):
        resolve_rule(3.14)


def test_register_rule_guards():
    with pytest.raises(ValueError, match="already registered"):
        register_rule(GapSafeRule())
    with pytest.raises(TypeError):
        register_rule("gap")


def test_custom_rule_registers_and_runs(prob):
    """A user-defined rule plugs into the skeleton with zero solver
    changes: register, solve by name, unregister-by-overwrite semantics
    stay out of the built-ins' way."""
    import dataclasses

    from repro.rules import registry as reg

    @dataclasses.dataclass(frozen=True)
    class WideGap(ScreeningRule):
        # A deliberately looser (still safe) GAP sphere: double radius.
        name = "wide-gap-test"
        is_safe = True
        is_dynamic = True
        supports_sequential = True

        def center_and_radius(self, state):
            r = jnp.sqrt(2.0 * jnp.maximum(state.gap, 0.0)) / state.lam
            return state.theta, 2.0 * r, state.corr / state.scale

    register_rule(WideGap())
    try:
        assert "wide-gap-test" in available_rules()
        lam = 0.25 * float(lambda_max(prob))
        res = SGLSession(prob, SolverConfig(
            tol=1e-8, rule="wide-gap-test")).solve(lam)
        ref = SGLSession(prob, SolverConfig(tol=1e-8)).solve(lam)
        assert float(res.gap) <= 1e-8
        np.testing.assert_allclose(np.asarray(res.beta),
                                   np.asarray(ref.beta), atol=1e-7)
        # A wider sphere can only keep MORE variables than the GAP sphere.
        assert res.feat_active.sum() >= ref.feat_active.sum()
    finally:
        reg._REGISTRY.pop("wide-gap-test", None)


# ---------------------------------------------------------------------------
# String/object parity (acceptance criterion)
# ---------------------------------------------------------------------------


def test_gap_string_object_bit_parity(prob):
    """Legacy rule="gap" string configs are BIT-identical to the
    GapSafeRule() object path: betas, epochs, seq/dyn counters, round
    split."""
    runs = {}
    for key, rule in (("string", "gap"), ("object", GapSafeRule())):
        session = SGLSession(prob, SolverConfig(tol=1e-8, rule=rule))
        runs[key] = (session.solve_path(T=6, delta=2.0), session)
    a, b = runs["string"][0], runs["object"][0]
    np.testing.assert_array_equal(a.betas, b.betas)
    assert np.array_equal(a.epochs, b.epochs)
    assert np.array_equal(a.gaps, b.gaps)
    assert np.array_equal(a.seq_screened, b.seq_screened)
    assert np.array_equal(a.dyn_screened, b.dyn_screened)
    assert np.array_equal(a.group_active, b.group_active)
    assert (a.n_compact_rounds, a.n_full_rounds, a.round_flops) == \
        (b.n_compact_rounds, b.n_full_rounds, b.round_flops)
    assert a.rule_name == b.rule_name == "gap"
    assert a.certificates_safe and b.certificates_safe
    # The resolved rule on the string session IS the registered singleton.
    assert runs["string"][1].rule is get_rule("gap")


# ---------------------------------------------------------------------------
# Rule-safety matrix
# ---------------------------------------------------------------------------


def _assert_path_safe(prob, path, ref_betas, tag):
    feat_mask = np.asarray(prob.feat_mask)
    for t in range(len(path.lambdas)):
        screened = ~path.feat_active[t] & feat_mask
        leaked = np.abs(ref_betas[t])[screened]
        assert leaked.size == 0 or leaked.max() < 1e-7, \
            (tag, t, float(leaked.max()))


@pytest.mark.parametrize("rule_name",
                         ["gap", "static", "dynamic", "dst3", "none"])
def test_safe_rule_matrix_path(prob, ref_path, rule_name):
    """Every registered is_safe rule passes the path-safety invariant on
    solve_path: nothing it screens is nonzero in the tight-tol unscreened
    reference."""
    lambdas, ref_betas = ref_path
    rule = get_rule(rule_name)
    assert rule.is_safe
    session = SGLSession(prob, SolverConfig(tol=1e-7, rule=rule,
                                            max_epochs=30_000))
    path = session.solve_path(lambdas=lambdas)
    assert (path.gaps <= 1e-7).all()
    assert path.certificates_safe
    assert path.rule_name == rule_name
    _assert_path_safe(prob, path, ref_betas, rule_name)


@pytest.mark.parametrize("rule_name",
                         ["gap", "static", "dynamic", "dst3", "none"])
def test_safe_rule_matrix_solve(prob, ref_path, rule_name):
    """Same invariant on a single cold solve at a mid-path lambda."""
    lambdas, ref_betas = ref_path
    t = 3
    session = SGLSession(prob, SolverConfig(tol=1e-8, rule=rule_name,
                                            max_epochs=30_000))
    res = session.solve(float(lambdas[t]))
    assert float(res.gap) <= 1e-8
    screened = ~np.asarray(res.feat_active) & np.asarray(prob.feat_mask)
    leaked = np.abs(ref_betas[t])[screened]
    assert leaked.size == 0 or leaked.max() < 1e-7


def test_safe_rule_matrix_mesh(prob, ref_path):
    """The mesh strategy's one supported rule (gap) passes the same
    invariant through the rule-object config."""
    lambdas, ref_betas = ref_path
    mesh = meshlib.make_test_mesh()
    session = SGLSession(prob, SolverConfig(tol=1e-6, rule=GapSafeRule(),
                                            max_epochs=20_000), mesh=mesh)
    path = session.solve_path(lambdas=lambdas)
    assert (path.gaps <= 1e-6).all()
    assert path.certificates_safe
    _assert_path_safe(prob, path, ref_betas, "mesh-gap")
    # Non-gap rule objects are refused just like non-gap strings.
    with pytest.raises(ValueError, match="rule='gap' only"):
        SGLSession(prob, SolverConfig(rule=StrongSequentialRule()),
                   mesh=mesh)


# ---------------------------------------------------------------------------
# Unsafe rules are flagged, never reported as certificates
# ---------------------------------------------------------------------------


def test_unsafe_rule_refuses_certificates(prob):
    session = SGLSession(prob, SolverConfig(tol=1e-7,
                                            rule=StrongSequentialRule(),
                                            max_epochs=20_000))
    lam = 0.3 * session.lam_max
    cert = session.screen(lam)
    assert not bool(cert.safe)            # flagged at the round level
    gap_cert = session.screen(lam, rule="gap")
    assert bool(gap_cert.safe)            # per-call safe rule stays safe
    path = session.solve_path(T=5, delta=1.5)
    assert path.rule_name == "strong"
    assert not path.certificates_safe     # flagged at the path level
    # The unsafe heuristic really screens (otherwise the flag is vacuous).
    assert (path.seq_screened.sum() + path.dyn_screened.sum()) > 0
    # Gaps stay honest: whatever converged did so on the FULL problem.
    conv = path.gaps <= 1e-7
    assert conv.any()


def test_safe_solve_rejects_unsafe_first_round(prob):
    """A safe-rule solve must refuse to adopt an unsafe rule's round as
    its injected certificate (its masks would be applied monotonically
    and reported as zero-certificates)."""
    session = SGLSession(prob, SolverConfig(tol=1e-8))
    lam = 0.3 * session.lam_max
    beta0 = np.zeros((prob.G, prob.ng))
    cert = session.screen(lam, beta0, rule=StrongSequentialRule())
    with pytest.raises(ValueError, match="unsafe rule"):
        session.solve(lam, beta0=beta0, first_round=cert)
    # An unsafe-rule session injecting its OWN flagged rounds stays legal
    # (everything it reports is flagged certificates_safe=False).
    s_unsafe = SGLSession(prob, SolverConfig(tol=1e-7,
                                             rule=StrongSequentialRule(),
                                             max_epochs=20_000))
    res = s_unsafe.solve(lam, beta0=beta0, first_round=cert)
    assert np.isfinite(float(res.gap))


def test_strong_rule_never_screens_less_than_gap(prob):
    """The corrupted radius can only shrink the sphere, so at the same
    state the strong rule keeps a subset of what GAP keeps."""
    from repro.core import screen_round as sr

    res = SGLSession(prob, SolverConfig(tol=1e-8)).solve(
        0.3 * float(lambda_max(prob)))
    out_gap = sr(prob, res.beta, 0.25 * float(lambda_max(prob)),
                 rule="gap")
    out_strong = sr(prob, res.beta, 0.25 * float(lambda_max(prob)),
                    rule=StrongSequentialRule(shrink=0.5))
    g_gap = np.asarray(out_gap.group_active)
    g_strong = np.asarray(out_strong.group_active)
    assert not np.any(g_strong & ~g_gap)
    assert bool(out_gap.safe) and not bool(out_strong.safe)


# ---------------------------------------------------------------------------
# Batched driver: compact cadence rounds + Pallas-routed reduced gaps
# ---------------------------------------------------------------------------


def test_batched_driver_uses_compact_rounds(prob):
    """PR 4 leftover: the batched-lambda BCD driver's cadence rounds run
    on the compacted union buffer (satellite: `_solve_batch_bcd` via
    `_screen_round_compact`), with results matching the per-lambda XLA
    reference at tolerance and the convergence gaps full-problem exact."""
    tol = 1e-7
    dense = dict(T=10, delta=0.5, batch_lambdas=4)
    ref = SGLSession(prob, SolverConfig(
        tol=tol, max_epochs=20_000, full_round_every=10 ** 9,
    )).solve_path(T=10, delta=0.5, batch_lambdas=1)
    # inner_rounds=1 makes the batch cadence (f_ce * inner_rounds) short
    # enough that dense warm batches actually reach cadence rounds.
    knobs = dict(tol=tol, max_epochs=20_000, solver_backend="pallas",
                 inner_rounds=1)
    sess = SGLSession(prob, SolverConfig(**knobs))
    res = sess.solve_path(**dense)
    assert res.batched_lambdas > 0, "no batch engaged on the dense grid"
    assert sess.compact_rounds > 0, "batched driver dispatched no " \
        "compact rounds"
    assert (res.gaps <= tol).all()
    # The compact cadence rounds are EXACT: the full-round-only twin
    # (full_round_every=0 kill switch) walks the identical trajectory.
    sess_full = SGLSession(prob, SolverConfig(**knobs, full_round_every=0))
    res_full = sess_full.solve_path(**dense)
    assert sess_full.compact_rounds == 0
    np.testing.assert_array_equal(res.betas, res_full.betas)
    assert np.array_equal(res.epochs, res_full.epochs)
    # vs the per-lambda XLA reference only tolerance-level equality holds:
    # batched lambdas warm-start from the batch-entry beta, so the two
    # converged iterates agree within the gap<=tol basin, not bitwise.
    np.testing.assert_allclose(res.betas, ref.betas, atol=1e-4)
