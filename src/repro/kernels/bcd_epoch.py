"""Fused Pallas BCD *epoch* mega-kernel: whole blocks of cyclic BCD passes in
ONE kernel launch, with the residual carried in VMEM.

Why a mega-kernel
-----------------
The solver's hot loop (Algorithm 2) is cyclic block coordinate descent over
the compacted active groups: per group a tiny (n x ng) correlation, the fused
two-level prox, and a rank-one residual update.  As a ``jax.lax.scan`` over
groups (:func:`repro.core.solver.bcd_epochs`) every step is far too small to
feed the MXU, and the carried (n,) residual makes an HBM round trip between
steps — on the synthetic paper config the path engine runs ~150k of these
epochs, so the per-step dispatch/round-trip overhead dominates wall clock
even after screening has shrunk the math itself.  This kernel runs
``n_epochs`` full cyclic passes inside one ``pallas_call``:

* the (n,) **residual** and the whole (Gb, ng) **coefficient block** live in
  VMEM for the entire launch (output blocks whose index map ignores the
  epoch/group-tile grid axes stay resident — the standard accumulation
  pattern — and are flushed to HBM once per lambda);
* the compacted (Gb, n, ng) **design** is streamed tile-by-tile by the
  group-tile grid axis (``block_g`` groups per tile), so VMEM holds one
  design tile + the carried state, never the full buffer;
* the two-level prox (the ``sgl_prox`` math) is fused into each group
  update — no coefficient ever leaves VMEM between the gradient step and
  the group soft-threshold.

Grid layout: ``(B, n_epochs, Gb // block_g)`` with the group-tile axis
innermost, then epochs, then the **lambda batch** B outermost.  The leading
batch axis lets consecutive lambda-path points whose certified active sets
coincide share ONE launch (and one streaming pass over the design per epoch):
each lambda carries its own beta / residual / feature mask / threshold, while
the design tiles and Lipschitz constants are batch-invariant.

VMEM residency budget (per grid step, f64): the design tile
``block_g * n * ng * 8`` bytes dominates; the carried state adds
``(Gb * ng + n) * 8`` bytes (+ the same again for the warm-start inputs) and
the per-tile scalars are noise.  With the default ``block_g = 8`` a bucket
of Gb = 256 groups of ng = 16 features at n = 1024 samples costs ~1.0 MB
tile + ~0.1 MB state — comfortably inside a ~16 MB VMEM even double-buffered.
Buckets whose *tile* does not fit should lower ``block_g`` (the wrapper in
:mod:`repro.kernels.ops` exposes it); the carried state only becomes a
concern past Gb * ng ~ 10^5 active features, where the compacted buffer
itself would no longer be "compact".

Numerics: each group update is line-for-line the math of
:func:`repro.core.solver.bcd_epochs` (same operations, same order, same
guards), so interpret-mode f64 results are bit-identical to the
``lax.scan`` reference — asserted by ``tests/test_bcd_kernel.py``.  Masked
and bucket-padded groups ride along with ``Lg <= 0`` and a zero feature
mask: their coefficients are left untouched and their residual delta is an
exact zero, so duplicate-alias ``take`` slots are inert.

On CPU this executes with ``interpret=True`` (bit-parity reference mode); on
TPU the same code lowers to Mosaic.  TPU tiling note: ``ng`` rides the lane
axis and ``n`` the sublane axis of the streamed tile — pad to (8, 128)
multiples for aligned layouts (the interpret-mode wrapper intentionally does
NOT pad, so CPU parity tests see the exact reference shapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import ArraySpec, LaunchSpec, block_specs, default_interpret, out_shapes


def bcd_epoch_launch_spec(
    B: int,
    Gb: int,
    n: int,
    ng: int,
    n_epochs: int,
    *,
    block_g: int = 8,
    dtype="float64",
) -> LaunchSpec:
    """Auditable launch geometry of :func:`bcd_epoch_pallas`.

    Both outputs are VMEM-resident across the epoch (axis 1) and group-tile
    (axis 2) grid axes — the carried-state pattern the module docstring
    describes — hence ``carried=((1, 2), (1, 2))``.
    """
    return LaunchSpec(
        name="bcd_epoch",
        grid=(B, n_epochs, Gb // block_g),
        inputs=(
            ArraySpec((Gb, n, ng), (block_g, n, ng),
                      lambda b, e, g: (g, 0, 0), dtype),        # design tile
            ArraySpec((Gb, 1), (block_g, 1),
                      lambda b, e, g: (g, 0), dtype),           # Lg
            ArraySpec((Gb, 1), (block_g, 1),
                      lambda b, e, g: (g, 0), dtype),           # w
            ArraySpec((B, Gb, ng), (1, block_g, ng),
                      lambda b, e, g: (b, g, 0), dtype),        # feat mask
            ArraySpec((B, 1), (1, 1),
                      lambda b, e, g: (b, 0), dtype),           # lam
            ArraySpec((1, 1), (1, 1),
                      lambda b, e, g: (0, 0), dtype),           # tau
            ArraySpec((B, Gb, ng), (1, Gb, ng),
                      lambda b, e, g: (b, 0, 0), dtype),        # beta0
            ArraySpec((B, n), (1, n),
                      lambda b, e, g: (b, 0), dtype),           # resid0
        ),
        outputs=(
            ArraySpec((B, Gb, ng), (1, Gb, ng),
                      lambda b, e, g: (b, 0, 0), dtype),        # beta
            ArraySpec((B, n), (1, n),
                      lambda b, e, g: (b, 0), dtype),           # resid
        ),
        carried=((1, 2), (1, 2)),
        note="fused BCD epoch mega-kernel; VMEM-carried beta/resid",
    )


def _bcd_epoch_kernel(
    xt_ref,       # (block_g, n, ng) design tile (streamed by g)
    lg_ref,       # (block_g, 1)     block Lipschitz constants (<= 0: inert)
    w_ref,        # (block_g, 1)     group weights
    fm_ref,       # (1, block_g, ng) per-lambda float feature mask tile
    lam_ref,      # (1, 1)           this lambda
    tau_ref,      # (1, 1)           SGL mixing parameter
    beta0_ref,    # (1, Gb, ng)      warm-start coefficients
    resid0_ref,   # (1, n)           warm-start residual
    beta_ref,     # (1, Gb, ng)      OUT, VMEM-resident across (e, g)
    resid_ref,    # (1, n)           OUT, VMEM-resident across (e, g)
    *,
    block_g: int,
):
    e = pl.program_id(1)
    g = pl.program_id(2)

    @pl.when((e == 0) & (g == 0))
    def _init():
        # First step of this lambda: adopt the warm start.  From here on the
        # carried state never leaves VMEM until the batch index changes.
        beta_ref[...] = beta0_ref[...]
        resid_ref[...] = resid0_ref[...]

    lam_ = lam_ref[0, 0]
    tau = tau_ref[0, 0]
    base = g * block_g
    resid = resid_ref[0, :]

    def group_update(i, resid):
        # Line-for-line the update of repro.core.solver.bcd_epochs
        # (bit-parity contract — see the module docstring).
        Xg = xt_ref[i]                                   # (n, ng)
        L = lg_ref[i, 0]
        lv = (L > 0).astype(resid.dtype)
        safe_L = jnp.where(L > 0, L, 1.0)
        step = lam_ / safe_L
        t1 = tau * step
        t2 = (1.0 - tau) * w_ref[i, 0] * step
        m = fm_ref[0, i]                                 # (ng,)
        bg = beta_ref[0, base + i]                       # (ng,)
        grad_step = (Xg.T @ resid) / safe_L
        z = (bg + grad_step) * m
        z = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t1, 0.0)
        nrm = jnp.linalg.norm(z)
        z = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0) * z
        new_bg = jnp.where(lv > 0, z, bg)
        beta_ref[0, base + i] = new_bg
        return resid + Xg @ (bg - new_bg)

    resid = jax.lax.fori_loop(0, block_g, group_update, resid)
    resid_ref[0, :] = resid


def bcd_epoch_pallas(
    Xt: jax.Array,        # (Gb, n, ng) compacted group-major design
    Lg: jax.Array,        # (Gb,)  block Lipschitz constants (* gmask)
    w: jax.Array,         # (Gb,)  group weights
    fmask: jax.Array,     # (B, Gb, ng) float feature masks (0 = inert)
    lam_b: jax.Array,     # (B,)   per-lambda regularisation
    tau: jax.Array,       # ()     SGL mixing parameter
    beta: jax.Array,      # (B, Gb, ng) warm-start coefficients
    resid: jax.Array,     # (B, n) warm-start residuals
    n_epochs: int,
    *,
    block_g: int = 8,
    interpret: bool | None = None,
):
    """Run ``n_epochs`` cyclic BCD passes for B lambdas in ONE launch.

    Returns ``(beta, resid)`` of the same shapes.  ``Gb`` must be a multiple
    of ``block_g`` (the :mod:`repro.kernels.ops` wrapper pads).
    """
    if interpret is None:
        interpret = default_interpret()
    B, Gb, ng = beta.shape
    n = Xt.shape[1]
    assert Xt.shape == (Gb, n, ng), (Xt.shape, beta.shape)
    assert Gb % block_g == 0, (Gb, block_g)
    spec = bcd_epoch_launch_spec(B, Gb, n, ng, n_epochs, block_g=block_g,
                                 dtype=beta.dtype)
    return pl.pallas_call(
        functools.partial(_bcd_epoch_kernel, block_g=block_g),
        grid=spec.grid,
        in_specs=block_specs(spec.inputs),
        out_specs=block_specs(spec.outputs),
        out_shape=out_shapes(spec.outputs),
        interpret=interpret,
    )(
        Xt,
        Lg[:, None],
        w[:, None],
        fmask,
        lam_b[:, None],
        jnp.reshape(tau, (1, 1)),
        beta,
        resid,
    )


# ----------------------------------------------------------------------------
# Logistic variant: the VMEM carry is the linear predictor z = X beta
# ----------------------------------------------------------------------------

def bcd_epoch_logistic_launch_spec(
    B: int,
    Gb: int,
    n: int,
    ng: int,
    n_epochs: int,
    *,
    block_g: int = 8,
    dtype="float64",
) -> LaunchSpec:
    """Auditable launch geometry of :func:`bcd_epoch_logistic_pallas`.

    Same grid/streaming layout as :func:`bcd_epoch_launch_spec`, with the
    carried (n,) state being the linear predictor instead of the lsq
    residual, plus the batch-invariant (n,) response ``y`` as one extra
    streamed-once input (its index map ignores the whole grid).
    """
    return LaunchSpec(
        name="bcd_epoch_logistic",
        grid=(B, n_epochs, Gb // block_g),
        inputs=(
            ArraySpec((Gb, n, ng), (block_g, n, ng),
                      lambda b, e, g: (g, 0, 0), dtype),        # design tile
            ArraySpec((Gb, 1), (block_g, 1),
                      lambda b, e, g: (g, 0), dtype),           # Lg
            ArraySpec((Gb, 1), (block_g, 1),
                      lambda b, e, g: (g, 0), dtype),           # w
            ArraySpec((B, Gb, ng), (1, block_g, ng),
                      lambda b, e, g: (b, g, 0), dtype),        # feat mask
            ArraySpec((B, 1), (1, 1),
                      lambda b, e, g: (b, 0), dtype),           # lam
            ArraySpec((1, 1), (1, 1),
                      lambda b, e, g: (0, 0), dtype),           # tau
            ArraySpec((1, n), (1, n),
                      lambda b, e, g: (0, 0), dtype),           # y (labels)
            ArraySpec((B, Gb, ng), (1, Gb, ng),
                      lambda b, e, g: (b, 0, 0), dtype),        # beta0
            ArraySpec((B, n), (1, n),
                      lambda b, e, g: (b, 0), dtype),           # z0
        ),
        outputs=(
            ArraySpec((B, Gb, ng), (1, Gb, ng),
                      lambda b, e, g: (b, 0, 0), dtype),        # beta
            ArraySpec((B, n), (1, n),
                      lambda b, e, g: (b, 0), dtype),           # z
        ),
        carried=((1, 2), (1, 2)),
        note="logistic BCD mega-kernel; VMEM-carried beta/linear predictor",
    )


def _bcd_epoch_logistic_kernel(
    xt_ref,       # (block_g, n, ng) design tile (streamed by g)
    lg_ref,       # (block_g, 1)     block spectral norms ||X_g||_2^2
    w_ref,        # (block_g, 1)     group weights
    fm_ref,       # (1, block_g, ng) per-lambda float feature mask tile
    lam_ref,      # (1, 1)           this lambda
    tau_ref,      # (1, 1)           SGL mixing parameter
    y_ref,        # (1, n)           {0,1} labels (batch-invariant)
    beta0_ref,    # (1, Gb, ng)      warm-start coefficients
    z0_ref,       # (1, n)           warm-start linear predictor X beta
    beta_ref,     # (1, Gb, ng)      OUT, VMEM-resident across (e, g)
    z_ref,        # (1, n)           OUT, VMEM-resident across (e, g)
    *,
    block_g: int,
):
    e = pl.program_id(1)
    g = pl.program_id(2)

    @pl.when((e == 0) & (g == 0))
    def _init():
        beta_ref[...] = beta0_ref[...]
        z_ref[...] = z0_ref[...]

    lam_ = lam_ref[0, 0]
    tau = tau_ref[0, 0]
    y = y_ref[0, :]
    base = g * block_g
    z = z_ref[0, :]

    def group_update(i, z):
        # Line-for-line the update of repro.core.solver.bcd_epochs_loss
        # for LogisticLoss (bit-parity contract, tests/test_losses.py):
        # majorized step with block bound nu*Lg = Lg/4, fresh gradient
        # rho = y - sigmoid(z) per group, rank-one predictor update.
        Xg = xt_ref[i]                                   # (n, ng)
        L = lg_ref[i, 0]
        lv = (L > 0).astype(z.dtype)
        Lmaj = 0.25 * L                                  # nu * Lg
        safe_L = jnp.where(L > 0, Lmaj, 1.0)
        step = lam_ / safe_L
        t1 = tau * step
        t2 = (1.0 - tau) * w_ref[i, 0] * step
        m = fm_ref[0, i]                                 # (ng,)
        bg = beta_ref[0, base + i]                       # (ng,)
        rho = y - jax.nn.sigmoid(z)                      # (n,)
        grad_step = (Xg.T @ rho) / safe_L
        u = (bg + grad_step) * m
        u = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t1, 0.0)
        nrm = jnp.linalg.norm(u)
        u = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0) * u
        new_bg = jnp.where(lv > 0, u, bg)
        beta_ref[0, base + i] = new_bg
        return z + Xg @ (new_bg - bg)

    z = jax.lax.fori_loop(0, block_g, group_update, z)
    z_ref[0, :] = z


def bcd_epoch_logistic_pallas(
    Xt: jax.Array,        # (Gb, n, ng) compacted group-major design
    Lg: jax.Array,        # (Gb,)  block spectral norms (* gmask)
    w: jax.Array,         # (Gb,)  group weights
    fmask: jax.Array,     # (B, Gb, ng) float feature masks (0 = inert)
    lam_b: jax.Array,     # (B,)   per-lambda regularisation
    tau: jax.Array,       # ()     SGL mixing parameter
    y: jax.Array,         # (n,)   {0,1} labels
    beta: jax.Array,      # (B, Gb, ng) warm-start coefficients
    z: jax.Array,         # (B, n) warm-start linear predictors
    n_epochs: int,
    *,
    block_g: int = 8,
    interpret: bool | None = None,
):
    """Logistic twin of :func:`bcd_epoch_pallas`: ``n_epochs`` majorized
    cyclic BCD passes for B lambdas in one launch, carrying the linear
    predictor in VMEM.  Returns ``(beta, z)``."""
    if interpret is None:
        interpret = default_interpret()
    B, Gb, ng = beta.shape
    n = Xt.shape[1]
    assert Xt.shape == (Gb, n, ng), (Xt.shape, beta.shape)
    assert Gb % block_g == 0, (Gb, block_g)
    spec = bcd_epoch_logistic_launch_spec(
        B, Gb, n, ng, n_epochs, block_g=block_g, dtype=beta.dtype)
    return pl.pallas_call(
        functools.partial(_bcd_epoch_logistic_kernel, block_g=block_g),
        grid=spec.grid,
        in_specs=block_specs(spec.inputs),
        out_specs=block_specs(spec.outputs),
        out_shape=out_shapes(spec.outputs),
        interpret=interpret,
    )(
        Xt,
        Lg[:, None],
        w[:, None],
        fmask,
        lam_b[:, None],
        jnp.reshape(tau, (1, 1)),
        y[None, :],
        beta,
        z,
    )
