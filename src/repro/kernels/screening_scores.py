"""Fused correlation + screening-statistics Pallas kernel.

Computes, in one pass over the design matrix tiles:

    corr = X^T theta                      (p,)   — needed by the feature test
    st2  = S_tau(corr)^2                  (p,)   — summed per group by the
                                                   wrapper for the group test

The matvec is blocked (bp x bn) with the K (sample) axis as the innermost
sequential grid dimension; the correlation block accumulates in the output
VMEM tile across K steps (standard Pallas accumulation pattern), and the
soft-thresholded square is computed on the final K step while the block is
still resident — the correlation never makes an HBM round trip before
thresholding.  MXU-friendly when bp, bn are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import default_interpret


def _screening_kernel(xt_ref, theta_ref, corr_ref, st2_ref, *, tau: float, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        corr_ref[...] = jnp.zeros_like(corr_ref)

    corr_ref[...] += xt_ref[...] @ theta_ref[...]      # (bp, bn) @ (bn, 1)

    @pl.when(k == nk - 1)
    def _finalize():
        c = corr_ref[...]
        st = jnp.maximum(jnp.abs(c) - tau, 0.0)
        st2_ref[...] = st * st


def screening_scores_pallas(
    Xt: jax.Array,       # (p, n) design matrix transposed
    theta: jax.Array,    # (n,)
    tau: float,
    *,
    block_p: int = 256,
    block_n: int = 128,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    p, n = Xt.shape
    assert p % block_p == 0 and n % block_n == 0, (p, n, block_p, block_n)
    nk = n // block_n
    grid = (p // block_p, nk)
    corr, st2 = pl.pallas_call(
        functools.partial(_screening_kernel, tau=float(tau), nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, block_n), lambda i, k: (i, k)),
            pl.BlockSpec((block_n, 1), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_p, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((block_p, 1), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, 1), Xt.dtype),
            jax.ShapeDtypeStruct((p, 1), Xt.dtype),
        ],
        interpret=interpret,
    )(Xt, theta[:, None])
    return corr[:, 0], st2[:, 0]
