"""Name -> :class:`ScreeningRule` registry.

The registry is what keeps legacy string configs working
(``SolverConfig(rule="gap")`` resolves here) and what lets new rule
families plug in without touching the solver: ``register_rule`` an
instance and every front-end — ``SGLSession``, ``screen_round``, the
``benchmarks/sweep_rules.py`` comparison harness — picks it up by name.

Unknown names fail FAST with the registered list (at session/config
resolution time, never deep inside a jitted round).
"""
from __future__ import annotations

from typing import Dict, List, Union

from .base import ScreeningRule

__all__ = [
    "available_rules",
    "get_rule",
    "register_rule",
    "resolve_rule",
]

_REGISTRY: Dict[str, ScreeningRule] = {}


def register_rule(rule: ScreeningRule, *,
                  overwrite: bool = False) -> ScreeningRule:
    """Register ``rule`` under ``rule.name``; returns it (decorator-able).

    Re-registering an existing name requires ``overwrite=True`` so a typo
    in a new rule's ``name`` cannot silently shadow a built-in.
    """
    if not isinstance(rule, ScreeningRule):
        raise TypeError(f"expected a ScreeningRule instance, got {rule!r}")
    if rule.name in _REGISTRY and not overwrite:
        raise ValueError(
            f"screening rule {rule.name!r} is already registered "
            f"({_REGISTRY[rule.name]!r}); pass overwrite=True to replace it"
        )
    _REGISTRY[rule.name] = rule
    return rule


def available_rules() -> List[str]:
    """Sorted names of every registered rule."""
    return sorted(_REGISTRY)


def get_rule(name: str) -> ScreeningRule:
    """Look up a registered rule by name; unknown names fail fast."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown screening rule {name!r}; registered rules: "
            f"{available_rules()}"
        ) from None


def resolve_rule(rule: Union[str, ScreeningRule]) -> ScreeningRule:
    """Resolve a config value — legacy string name or rule object — to a
    :class:`ScreeningRule` instance.

    This is the compatibility shim for string ``rule=`` configs: strings
    remain supported as registry keys (``"gap"`` resolves to the
    :class:`repro.rules.GapSafeRule` singleton, bit-identical behavior),
    but new rule families should be passed — and registered — as objects.
    """
    if isinstance(rule, ScreeningRule):
        return rule
    if isinstance(rule, str):
        return get_rule(rule)
    raise TypeError(
        f"rule must be a registered name or a ScreeningRule, got {rule!r}"
    )
