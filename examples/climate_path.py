"""Climate regression (paper Section 7.1, Figures 3-4, reduced scale).

    PYTHONPATH=src python examples/climate_path.py

Fits the Sparse-Group Lasso path on the climate-like dataset (groups = grid
points, 7 physical variables each) through the **session API**, comparing
the GAP safe rule against no screening, and prints the "support map" —
which grid regions predict the target, the paper's Figure 4.

Migration note: the legacy ``solve_path(problem, lambdas=..., tol=...,
rule=..., max_epochs=...)`` kwargs became :class:`SolverConfig` fields of
the same names on an :class:`SGLSession`; the grid stays on
``session.solve_path(lambdas=...)``.  One session per rule keeps each
rule's gather caches (and, on TPU, the persistent transposed design)
across everything that session solves.
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import numpy as np

from repro.core import SGLSession, SolverConfig, make_problem, lambda_grid
from repro.data.climate import make_climate_like

N_LON, N_LAT = 16, 8


def main():
    X, y, beta_true, sizes = make_climate_like(
        n=256, n_lon=N_LON, n_lat=N_LAT, seed=0
    )
    problem = make_problem(X, y, sizes, tau=0.4)  # paper's tau* = 0.4
    sessions = {
        rule: SGLSession(
            problem, SolverConfig(tol=1e-6, rule=rule, max_epochs=2000)
        )
        for rule in ("gap", "none")
    }
    lam_max = sessions["gap"].lam_max
    lambdas = lambda_grid(lam_max, T=20, delta=2.5)

    times = {}
    for rule, session in sessions.items():
        t0 = time.perf_counter()
        res = session.solve_path(lambdas=lambdas)
        times[rule] = time.perf_counter() - t0
        print(f"rule={rule:5s}: path time {times[rule]:7.2f}s, "
              f"total epochs {int(res.epochs.sum())}")
        if rule == "gap":
            print(f"             sequential screen discarded "
                  f"{int(res.seq_screened.sum())} group certificates, "
                  f"{int((res.epochs == 0).sum())}/{len(lambdas)} lambdas "
                  f"needed zero epochs, {res.n_gathers} design gathers, "
                  f"{res.n_rounds} certified rounds "
                  f"({res.n_transpose_copies} transposed copies of X)")
    print(f"GAP speed-up over no screening: "
          f"{times['none'] / times['gap']:.2f}x")

    # Support map at the sparsest informative lambda (Figure 4 analogue).
    # Reusing the "gap" session keeps its caches warm for the partial grid.
    res = sessions["gap"].solve_path(lambdas=lambdas[:8])
    beta = np.asarray(res.betas[-1])          # (G, ng)
    strength = np.abs(beta).max(axis=1).reshape(N_LON, N_LAT)

    print("\nsupport map (max |coef| per grid point; '#'=strong, '.'=zero):")
    q = strength.max() or 1.0
    for j in range(N_LAT - 1, -1, -1):
        row = "".join(
            "#" if strength[i, j] > 0.5 * q
            else "+" if strength[i, j] > 0.05 * q
            else "." for i in range(N_LON)
        )
        print("   " + row)
    n_active = int((strength > 0).sum())
    print(f"\nactive grid points: {n_active}/{N_LON * N_LAT}")


if __name__ == "__main__":
    main()
