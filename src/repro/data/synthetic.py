"""Synthetic dataset of the paper (Section 7.1).

y = X beta + 0.01 eps,  eps ~ N(0, Id_n),
X ~ N(0, Sigma) with corr(X_i, X_j) = rho^{|i-j|} (AR(1) Toeplitz),
p features in G equal groups; gamma1 groups active; within each, gamma2
coordinates set to sign(xi) * U, U ~ Unif[0.5, 10], xi ~ Unif[-1, 1].

Paper defaults: n=100, p=10000, 1000 groups of 10, rho=0.5,
gamma1=10, gamma2=4, tau=0.2.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_synthetic"]


def make_synthetic(
    n: int = 100,
    p: int = 10_000,
    n_groups: int = 1_000,
    rho: float = 0.5,
    gamma1: int = 10,
    gamma2: int = 4,
    noise: float = 0.01,
    seed: int = 0,
    dtype=np.float64,
):
    """Returns (X, y, beta_true, group_sizes)."""
    assert p % n_groups == 0
    ng = p // n_groups
    rng = np.random.default_rng(seed)

    # AR(1) process has exactly the rho^{|i-j|} correlation and is O(n p).
    z = rng.standard_normal((n, p))
    X = np.empty((n, p))
    X[:, 0] = z[:, 0]
    c = np.sqrt(1.0 - rho * rho)
    for j in range(1, p):
        X[:, j] = rho * X[:, j - 1] + c * z[:, j]

    beta = np.zeros(p)
    active_groups = rng.choice(n_groups, size=gamma1, replace=False)
    for g in active_groups:
        coords = rng.choice(ng, size=min(gamma2, ng), replace=False)
        u = rng.uniform(0.5, 10.0, size=len(coords))
        s = np.sign(rng.uniform(-1.0, 1.0, size=len(coords)))
        beta[g * ng + coords] = s * u

    y = X @ beta + noise * rng.standard_normal(n)
    return (
        X.astype(dtype),
        y.astype(dtype),
        beta.astype(dtype),
        [ng] * n_groups,
    )
