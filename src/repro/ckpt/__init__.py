from .checkpoint import (
    CheckpointManager,
    gc_keep_k,
    latest,
    latest_step,
    restore,
    save,
)

__all__ = ["CheckpointManager", "save", "restore", "latest", "latest_step",
           "gc_keep_k"]
