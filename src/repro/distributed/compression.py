"""Gradient/residual compression with error feedback (distributed tricks).

Top-k sparsification with error feedback (Stich et al.): transmit only the
k largest-magnitude entries, accumulate the rest locally into the error
buffer added back next round.  Used for the dense residual reduction in the
SGL solver when the interconnect is the bottleneck, and available to the LM
train loop for gradient all-reduce.

Also int8 stochastic-rounding quantisation for 4x collective volume cuts.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: jax.Array


def topk_compress(x: jax.Array, frac: float, ef: EFState) -> Tuple[jax.Array, EFState]:
    """Error-feedback top-k: returns (sparse dense-format tensor, new state).

    The returned tensor has the same shape with only k = frac*size nonzeros
    (what would actually be transmitted); x - sent is kept in the error
    buffer.
    """
    flat = (x + ef.error).reshape(-1)
    k = max(1, int(flat.size * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    sent = jnp.zeros_like(flat).at[idx].set(flat[idx])
    new_error = flat - sent
    return sent.reshape(x.shape), EFState(error=new_error.reshape(x.shape))


def ef_init(x: jax.Array) -> EFState:
    return EFState(error=jnp.zeros_like(x))


def int8_quantize(x: jax.Array, key) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor scale + int8 with stochastic rounding. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    y = x / scale
    noise = jax.random.uniform(key, x.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
