"""Shared helpers for the benchmark harness.

Every benchmark emits rows through ``emit`` so ``benchmarks.run`` can
aggregate a single CSV:  benchmark,case,metric,value

``write_json`` additionally dumps the emitted rows (plus environment
metadata) to a machine-readable JSON file — the perf-trajectory record
(e.g. ``BENCH_pr4.json``) future PRs diff against instead of prose in
CHANGES.md.
"""
from __future__ import annotations

import json
import platform
import time
from typing import Callable

import jax

from repro.obs.export import env_meta

# The convex-optimization core targets the paper's 1e-8 duality-gap
# tolerance, which needs f64 (same switch the tests flip in conftest.py).
jax.config.update("jax_enable_x64", True)

_ROWS: list[tuple[str, str, str, float]] = []


def emit(bench: str, case: str, metric: str, value) -> None:
    _ROWS.append((bench, case, metric, float(value)))
    print(f"{bench},{case},{metric},{value}")


def rows():
    return list(_ROWS)


def write_json(path: str, extra: dict | None = None) -> None:
    """Dump every row emitted so far (plus environment metadata) as JSON.

    Schema: ``{"meta": {...}, "rows": [{benchmark, case, metric, value}]}``
    — flat rows rather than nesting so a diff tool can join on
    (benchmark, case, metric) without knowing any benchmark's shape.
    """
    # Environment metadata comes from the one shared exporter
    # (repro.obs.export.env_meta); the historical key names and the OS
    # platform string are layered on top so existing diff tooling keeps
    # joining on the same fields.
    meta = env_meta()
    meta.update({
        "jax_version": jax.__version__,
        "platform": platform.platform(),
        **(extra or {}),
    })
    payload = {
        "meta": meta,
        "rows": [
            {"benchmark": b, "case": c, "metric": m, "value": v}
            for b, c, m, v in _ROWS
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(_ROWS)} rows -> {path}")


def timeit(fn: Callable, *args, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall-clock seconds for ``fn(*args)`` (blocks on jax arrays)."""
    def run():
        out = fn(*args)
        jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        run()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def header() -> None:
    print("benchmark,case,metric,value")
