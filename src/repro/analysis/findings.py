"""Structured findings: the one result type every analysis pass emits.

A finding is a machine-readable fact ("this eqn demotes a certificate
value to f32 at ...") with enough location/detail payload to render the
markdown report and to let tests assert that a specific lint fired on a
specific fixture.  Severity semantics:

* ``error``   — gate-failing: the invariant the pass guarantees is broken.
* ``warning`` — suspicious but not gate-failing (reported, exit code 0).
* ``info``    — context the report should carry (e.g. a skipped pass).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

__all__ = ["Finding", "summarize", "to_payload"]

SCHEMA = "repro.analysis/v1"


@dataclasses.dataclass
class Finding:
    pass_name: str                # "jaxpr" | "pallas" | "cert" | "meta"
    code: str                     # stable lint code, e.g. "JX001"
    message: str
    severity: str = "error"       # "error" | "warning" | "info"
    location: str = ""            # "path:line", entry-point or kernel name
    details: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        loc = f" [{self.location}]" if self.location else ""
        return f"{self.code} ({self.severity}){loc}: {self.message}"


def summarize(findings: List[Finding]) -> Dict[str, int]:
    out = {"errors": 0, "warnings": 0, "infos": 0}
    for f in findings:
        key = {"error": "errors", "warning": "warnings"}.get(f.severity,
                                                             "infos")
        out[key] += 1
    return out


def to_payload(findings: List[Finding], *,
               passes: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
    """Assemble the JSON report payload (``--report``): raw findings plus
    per-pass context, so the markdown can be re-rendered from the saved
    JSON without re-running any analysis (the launch/report.py pattern)."""
    summary = summarize(findings)
    return {
        "schema": SCHEMA,
        "passes": passes,
        "findings": [f.to_dict() for f in findings],
        "summary": summary,
        "ok": summary["errors"] == 0,
    }
