"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report artifacts/dryrun

Prints markdown: the §Dry-run status matrix and the §Roofline single-pod
table (three terms, bottleneck, useful-flops ratio) plus per-cell notes on
what would move the dominant term.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(out_dir: str):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def _fmt_t(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def _hint(cell) -> str:
    r = cell.get("roofline") or {}
    b = r.get("bottleneck")
    kind = cell.get("kind")
    if b == "memory":
        if kind == "train":
            return "less remat / fuse optimizer+cast to cut HBM traffic"
        return "KV-cache layout + quantization to cut HBM reads"
    if b == "collective":
        return "re-shard to cut all-gathers; overlap collectives with compute"
    return "already compute-bound; larger per-chip tile helps MXU util"


def dryrun_matrix(cells):
    print("\n### Dry-run status matrix (compile on 16x16=256 and "
          "2x16x16=512 meshes)\n")
    keyed = {}
    for c in cells:
        keyed[(c["arch"], c["shape"], c.get("multi_pod", False))] = c
    archs = sorted({c["arch"] for c in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "solve",
              "fista+screen"]
    shapes = [s for s in shapes
              if any(c["shape"].startswith(s.split("+")[0]) or c["shape"] == s
                     for c in cells)]
    hdr = "| arch | " + " | ".join(
        f"{s} (1pod/2pod)" for s in shapes) + " |"
    print(hdr)
    print("|" + "---|" * (len(shapes) + 1))
    for a in archs:
        row = [a]
        for s in shapes:
            marks = []
            for mp in (False, True):
                c = keyed.get((a, s, mp))
                if c is None:
                    cands = [v for (aa, ss, m), v in keyed.items()
                             if aa == a and m == mp and ss.startswith(s[:5])]
                    c = cands[0] if cands else None
                if c is None:
                    marks.append("·")
                else:
                    st = c.get("status")
                    marks.append({"ok": "✓", "skipped": "skip",
                                  "error": "✗", "timeout": "T"}.get(st, "?"))
            row.append("/".join(marks))
        print("| " + " | ".join(row) + " |")


def roofline_table(cells, multi_pod=False):
    title = "multi-pod (512 chips)" if multi_pod else "single-pod (256 chips)"
    print(f"\n### Roofline — {title}\n")
    print("| arch | shape | t_compute | t_memory | t_collective | bound |"
          " model/HLO flops | roofline frac | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("multi_pod") != multi_pod or c.get("status") != "ok":
            continue
        r = c.get("roofline")
        if not r:
            # sgl-paper cell stores one entry per kernel variant
            subs = [k for k in c
                    if isinstance(c.get(k), dict) and "roofline" in c[k]]
            for sub in subs:
                if sub in c:
                    rr = c[sub]["roofline"]
                    print(f"| {c['arch']} | {sub} | "
                          f"{_fmt_t(rr['t_compute_s'])} | "
                          f"{_fmt_t(rr['t_memory_s'])} | "
                          f"{_fmt_t(rr['t_collective_s'])} | "
                          f"{rr['bottleneck']} | "
                          f"{(rr.get('useful_flops_ratio') or 0):.3f} | "
                          f"{rr['roofline_fraction']:.4f} | "
                          f"{_hint({'roofline': rr, 'kind': 'solve'})} |")
            continue
        print(f"| {c['arch']} | {c['shape']} | "
              f"{_fmt_t(r['t_compute_s'])} | {_fmt_t(r['t_memory_s'])} | "
              f"{_fmt_t(r['t_collective_s'])} | {r['bottleneck']} | "
              f"{(r.get('useful_flops_ratio') or 0):.3f} | "
              f"{r['roofline_fraction']:.4f} | {_hint(c)} |")


def memory_table(cells):
    print("\n### Per-device memory (single-pod, from "
          "compiled.memory_analysis())\n")
    print("| arch | shape | args | temps | peak |")
    print("|---|---|---|---|---|")
    gb = 1 << 30
    for c in cells:
        if c.get("multi_pod") or c.get("status") != "ok":
            continue
        m = c.get("memory")
        if not m:
            continue
        print(f"| {c['arch']} | {c['shape']} | "
              f"{(m.get('argument_bytes') or 0) / gb:.2f} GiB | "
              f"{(m.get('temp_bytes') or 0) / gb:.2f} GiB | "
              f"{(m.get('peak_bytes') or 0) / gb:.2f} GiB |")


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    cells = load(out_dir)
    ok = sum(1 for c in cells if c.get("status") == "ok")
    sk = sum(1 for c in cells if c.get("status") == "skipped")
    err = len(cells) - ok - sk
    print(f"# Dry-run report: {ok} ok / {sk} skipped / {err} failed "
          f"({len(cells)} cells)")
    dryrun_matrix(cells)
    roofline_table(cells, multi_pod=False)
    roofline_table(cells, multi_pod=True)
    memory_table(cells)


if __name__ == "__main__":
    main()
