"""The injection registry: site hooks production code calls into.

Production call sites invoke :func:`fire` (or one of the typed helpers
below) at their registered site.  With no plan active the hooks are a
single ``None`` check — the fault layer costs nothing when it is off.
Under :func:`inject`, each call counts one *hit* at its site and returns
the specs whose schedule includes that hit; the caller then applies the
fault (corrupt an array, raise, sleep, flip bits) at host level —
injection never reaches inside a jitted function, where a raise would
fire at trace time and a corruption would bake into the cached program.

The active plan is process-global and lock-guarded (NOT thread-local):
the serve worker runs on its own thread, and a chaos test activating a
plan on the main thread must see its faults fire inside the worker.
Exactly one plan may be active at a time.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..obs import metrics as _obs_metrics
from .errors import WorkerCrash
from .plan import FaultPlan, FaultSpec

_M_FIRED = _obs_metrics.REGISTRY.counter(
    "faults.fired",
    help="Injected fault specs that actually fired at a site "
         "(process-wide tally across all inject() activations)")

__all__ = ["inject", "fire", "active_plan", "FaultLog", "FiredEvent",
           "corrupt_file", "maybe_kill"]


class FiredEvent(NamedTuple):
    site: str
    hit: int
    kind: str
    field: str


class FaultLog:
    """What actually fired during one :func:`inject` activation."""

    def __init__(self) -> None:
        self.events: List[FiredEvent] = []

    def count(self, site: Optional[str] = None) -> int:
        return sum(1 for e in self.events if site is None or e.site == site)


class _Active:
    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.log = FaultLog()
        self.hits: Dict[str, int] = {}
        self.lock = threading.Lock()
        self.rng = np.random.default_rng(plan.seed)


_STATE_LOCK = threading.Lock()
_ACTIVE: Optional[_Active] = None


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Activate ``plan`` for the dynamic extent of the block.

    Yields the :class:`FaultLog` recording every fault that fired, so
    tests can assert a scheduled fault actually hit its site (a chaos
    scenario whose fault never fired proves nothing).
    """
    global _ACTIVE
    state = _Active(plan)
    with _STATE_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a fault plan is already active")
        _ACTIVE = state
    try:
        yield state.log
    finally:
        with _STATE_LOCK:
            _ACTIVE = None


def active_plan() -> Optional[FaultPlan]:
    a = _ACTIVE
    return a.plan if a is not None else None


def fire(site: str) -> Tuple[FaultSpec, ...]:
    """Count one hit at ``site``; return the specs firing on this hit.

    The fast path (no plan active) is one global read.  Hit counting is
    lock-guarded so concurrent threads (serve worker + tenants) each get
    a distinct hit index.
    """
    a = _ACTIVE
    if a is None:
        return ()
    with a.lock:
        a.hits[site] = a.hits.get(site, 0) + 1
        idx = a.hits[site] - 1
    matched = tuple(s for s in a.plan.specs
                    if s.site == site and idx in s.hits)
    if matched:
        with a.lock:
            a.log.events.extend(
                FiredEvent(site, idx, s.kind, s.field) for s in matched
            )
        _M_FIRED.inc(len(matched))
    for s in matched:
        if s.kind == "stall":
            time.sleep(s.stall_s)
    return matched


def maybe_kill(site: str) -> None:
    """Raise :class:`WorkerCrash` if a kill fault fires at ``site``."""
    for s in fire(site):
        if s.kind == "kill":
            raise WorkerCrash(f"injected worker kill at {site}")


def corrupt_file(path: str, specs: Tuple[FaultSpec, ...]) -> bool:
    """Apply truncate/bitflip specs to a file on disk; True if touched.

    The bit-flip offset comes from the active plan's seeded rng, so the
    corruption is deterministic per (plan, firing order).
    """
    a = _ACTIVE
    touched = False
    for s in specs:
        if s.kind == "truncate":
            with open(path, "rb") as f:
                data = f.read()
            with open(path, "wb") as f:
                f.write(data[: len(data) // 2])
            touched = True
        elif s.kind == "bitflip":
            with open(path, "rb") as f:
                data = bytearray(f.read())
            if data:
                rng = a.rng if a is not None else np.random.default_rng(0)
                off = int(rng.integers(len(data)))
                data[off] ^= 1 << int(rng.integers(8))
                with open(path, "wb") as f:
                    f.write(bytes(data))
                touched = True
    return touched
