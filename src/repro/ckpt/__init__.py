from .checkpoint import (
    CheckpointManager,
    gc_keep_k,
    latest,
    latest_step,
    quarantine_count,
    restore,
    save,
)
from repro.faults.errors import CheckpointCorrupt

__all__ = ["CheckpointManager", "save", "restore", "latest", "latest_step",
           "gc_keep_k", "quarantine_count", "CheckpointCorrupt"]
