"""Pallas launch auditor: static BlockSpec/grid evaluation + VMEM budget.

Consumes the :class:`repro.kernels._util.LaunchSpec` objects the kernel
wrappers execute from (registered in ``kernels/ops.py``), so the audited
geometry IS the executed geometry.  For each spec the index maps are
evaluated over the full grid with plain python ints (Pallas index maps
must be pure shape arithmetic, so this is exact):

* **PL001** out-of-bounds block index on any operand at any grid point —
  at runtime an OOB read returns garbage-padded tiles (or traps).
* **PL002** an output block never written over the non-carried grid axes
  (a gap: stale/undefined memory shipped as a result).
* **PL003** two non-carried grid points writing the same output block (an
  overlap: silent last-writer-wins).
* **PL005** carried-axis declarations that do not match reality: a
  declared-carried axis the index map actually varies with, or an
  undeclared axis it is invariant to (an accumulation pattern the
  analyzer was not told about — every revisit re-fetches the block).
* **PL004** per-grid-step VMEM footprint (sum of all operand block sizes
  × dtype width) over the backend budget — 16 MiB, the per-core VMEM of
  current TPUs.  An over-budget tile today just OOMs at runtime on the
  compiled path; this is the pre-check for the ROADMAP's compiled-TPU
  autotuner direction.

Grids larger than ``max_points`` are bounds-checked on an axis-corner
subsample and skip the exactly-once coverage proof (reported as an info
finding — no silent cap).
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Tuple

from .findings import Finding

__all__ = ["DEFAULT_VMEM_BUDGET", "audit_launch_spec", "run"]

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024   # bytes; ~VMEM per TPU core


def _grid_points(grid: Tuple[int, ...], max_points: int):
    """Full grid enumeration, or axis-corner subsample past ``max_points``.

    Returns ``(points, full)``.
    """
    total = 1
    for g in grid:
        total *= g
    if total <= max_points:
        return list(itertools.product(*(range(g) for g in grid))), True
    corners = [sorted({0, g // 2, g - 1}) for g in grid]
    return list(itertools.product(*corners)), False


def audit_launch_spec(spec, *, vmem_budget: int = DEFAULT_VMEM_BUDGET,
                      max_points: int = 200_000,
                      name: str = "") -> List[Finding]:
    name = name or spec.name
    findings: List[Finding] = []

    vmem = spec.vmem_bytes
    if vmem > vmem_budget:
        findings.append(Finding(
            pass_name="pallas", code="PL004",
            message=(f"VMEM-resident footprint {vmem / 2**20:.2f} MiB per "
                     f"grid step exceeds the "
                     f"{vmem_budget / 2**20:.0f} MiB budget"),
            location=name,
            details={"vmem_bytes": vmem, "budget_bytes": vmem_budget,
                     "grid": list(spec.grid)},
        ))

    points, full = _grid_points(spec.grid, max_points)
    if not full:
        findings.append(Finding(
            pass_name="pallas", code="PL006", severity="info",
            message=(f"grid {spec.grid} too large to enumerate "
                     f"(> {max_points} points); bounds checked on axis "
                     f"corners only, coverage proof skipped"),
            location=name,
        ))

    carried = spec.carried or tuple(() for _ in spec.outputs)
    operands = ([("in", i, a, None) for i, a in enumerate(spec.inputs)]
                + [("out", i, a, carried[i] if i < len(carried) else ())
                   for i, a in enumerate(spec.outputs)])

    # per-output bookkeeping for coverage/overlap/invariance
    seen: List[Dict[tuple, tuple]] = [dict() for _ in spec.outputs]
    inv_violated = [False] * len(spec.outputs)
    varies = [set() for _ in spec.outputs]  # grid axes the map varies with
    prev_by_rest: List[Dict[tuple, Dict[int, tuple]]] = [
        dict() for _ in spec.outputs
    ]

    for pt in points:
        for kind, i, arr, car in operands:
            idx = tuple(arr.index_map(*pt))
            nb = arr.nblocks
            if len(idx) != len(nb) or any(
                    not (0 <= idx[d] < nb[d]) for d in range(len(nb))):
                findings.append(Finding(
                    pass_name="pallas", code="PL001",
                    message=(f"{kind}[{i}] block index {idx} out of bounds "
                             f"for {nb} blocks at grid point {pt}"),
                    location=name,
                    details={"grid_point": list(pt), "block_index": list(idx),
                             "nblocks": list(nb)},
                ))
                continue
            if kind != "out":
                continue
            # which grid axes does this output's map vary with?
            for ax in range(len(pt)):
                key_rest = tuple(v for d, v in enumerate(pt) if d != ax)
                slot = prev_by_rest[i].setdefault(key_rest, {})
                if ax in slot and slot[ax] != idx:
                    varies[i].add(ax)
                slot[ax] = idx
            free_key = tuple(v for d, v in enumerate(pt) if d not in car)
            if free_key in seen[i]:
                if seen[i][free_key] != idx:
                    inv_violated[i] = True
            else:
                seen[i][free_key] = idx

    for i, arr in enumerate(spec.outputs):
        car = carried[i] if i < len(carried) else ()
        if inv_violated[i]:
            findings.append(Finding(
                pass_name="pallas", code="PL005",
                message=(f"out[{i}] index map varies along a grid axis "
                         f"declared carried {tuple(car)}"),
                location=name,
                details={"declared_carried": list(car),
                         "varies_with": sorted(varies[i])},
            ))
            continue
        undeclared = [ax for ax in range(len(spec.grid))
                      if ax not in car and ax not in varies[i]
                      and spec.grid[ax] > 1]
        if undeclared:
            findings.append(Finding(
                pass_name="pallas", code="PL005",
                message=(f"out[{i}] index map is invariant to grid "
                         f"axes {undeclared} but they are not declared "
                         f"carried — undeclared accumulation/carry"),
                location=name,
                details={"declared_carried": list(car),
                         "undeclared_invariant": undeclared},
            ))
        if not full:
            continue
        # exactly-once coverage over the non-carried projection
        written = {}
        for free_key, idx in seen[i].items():
            if idx in written:
                findings.append(Finding(
                    pass_name="pallas", code="PL003",
                    message=(f"out[{i}] block {idx} written by distinct "
                             f"non-carried grid points {written[idx]} and "
                             f"{free_key}"),
                    location=name,
                    details={"block_index": list(idx)},
                ))
            else:
                written[idx] = free_key
        nb = arr.nblocks
        missing = [idx for idx in itertools.product(
            *(range(b) for b in nb)) if idx not in written]
        if missing:
            findings.append(Finding(
                pass_name="pallas", code="PL002",
                message=(f"out[{i}] has {len(missing)} never-written "
                         f"blocks (first: {missing[0]}) — coverage gap"),
                location=name,
                details={"missing": [list(m) for m in missing[:8]],
                         "n_missing": len(missing)},
            ))
    return findings


def run(audits=None, *, vmem_budget: int = DEFAULT_VMEM_BUDGET
        ) -> List[Finding]:
    """Audit every registered kernel launch spec (or the given mapping)."""
    if audits is None:
        import repro.kernels.ops  # noqa: F401  (registers the builders)
        from .registry import kernel_audits

        audits = kernel_audits()
    findings: List[Finding] = []
    for name, builder in sorted(audits.items()):
        try:
            spec = builder()
        except Exception as e:
            findings.append(Finding(
                pass_name="pallas", code="PL000",
                message=(f"launch-spec builder failed: "
                         f"{type(e).__name__}: {e}"),
                location=name,
            ))
            continue
        findings.extend(
            audit_launch_spec(spec, vmem_budget=vmem_budget, name=name)
        )
    return findings
