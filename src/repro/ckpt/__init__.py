from .checkpoint import CheckpointManager, restore, save

__all__ = ["CheckpointManager", "save", "restore"]
