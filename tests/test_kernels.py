"""Per-kernel shape/dtype sweeps asserting allclose against the ref.py oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


SHAPES_GROUPED = [(8, 8), (32, 10), (256, 7), (100, 16), (512, 128), (33, 5)]
DTYPES = [np.float32, np.float64]


@pytest.mark.parametrize("G,ng", SHAPES_GROUPED)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sgl_prox_kernel(G, ng, dtype, rng):
    beta = rng.standard_normal((G, ng)).astype(dtype)
    step = rng.uniform(0.01, 2.0, G).astype(dtype)
    w = rng.uniform(0.5, 3.0, G).astype(dtype)
    tau, lam = 0.3, 0.7
    out = ops.sgl_prox(jnp.asarray(beta), jnp.asarray(step), jnp.asarray(w),
                       tau, lam)
    want = ref.sgl_prox_ref(jnp.asarray(beta), jnp.asarray(step),
                            jnp.asarray(w), tau, lam)
    rtol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=rtol,
                               atol=rtol)


@pytest.mark.parametrize("tau", [0.0, 0.2, 0.9, 1.0])
def test_sgl_prox_kernel_tau_extremes(tau, rng):
    beta = rng.standard_normal((64, 12))
    step = rng.uniform(0.1, 1.0, 64)
    w = np.sqrt(12.0) * np.ones(64)
    out = ops.sgl_prox(jnp.asarray(beta), jnp.asarray(step), jnp.asarray(w),
                       tau, 0.5)
    want = ref.sgl_prox_ref(jnp.asarray(beta), jnp.asarray(step),
                            jnp.asarray(w), tau, 0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-10,
                               atol=1e-12)


@pytest.mark.parametrize("G,ng", SHAPES_GROUPED)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dual_norm_kernel(G, ng, dtype, rng):
    x = (rng.standard_normal((G, ng)) * rng.uniform(0.1, 10)).astype(dtype)
    eps = rng.uniform(0.05, 0.95, G).astype(dtype)
    alpha, R = (1 - eps), eps
    out = ops.dual_norm_groups(jnp.asarray(x), jnp.asarray(alpha),
                               jnp.asarray(R))
    want = ref.dual_norm_ref(jnp.asarray(x.astype(np.float64)),
                             jnp.asarray(alpha.astype(np.float64)),
                             jnp.asarray(R.astype(np.float64)))
    rtol = 3e-5 if dtype == np.float32 else 1e-9
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=rtol)


def test_dual_norm_kernel_special_rows(rng):
    x = rng.standard_normal((16, 9))
    x[3] = 0.0  # zero row
    alpha = np.full(16, 0.5)
    R = np.full(16, 0.5)
    R[5] = 0.0        # R=0 -> linf/alpha
    alpha[7] = 0.0    # alpha=0 -> l2/R
    out = np.asarray(ops.dual_norm_groups(jnp.asarray(x), jnp.asarray(alpha),
                                          jnp.asarray(R)))
    assert out[3] == 0.0
    np.testing.assert_allclose(out[5], np.abs(x[5]).max() / 0.5, rtol=1e-9)
    np.testing.assert_allclose(out[7], np.linalg.norm(x[7]) / 0.5, rtol=1e-9)


@pytest.mark.parametrize("p,n", [(256, 128), (100, 40), (512, 256), (64, 100)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_screening_scores_kernel(p, n, dtype, rng):
    Xt = rng.standard_normal((p, n)).astype(dtype) / np.sqrt(n)
    theta = rng.standard_normal(n).astype(dtype)
    tau = 0.35
    corr, st2 = ops.screening_scores(jnp.asarray(Xt), jnp.asarray(theta), tau)
    corr_w, st2_w = ref.screening_scores_ref(jnp.asarray(Xt),
                                             jnp.asarray(theta), tau)
    rtol = 2e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(corr), np.asarray(corr_w),
                               rtol=rtol, atol=rtol)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st2_w),
                               rtol=rtol, atol=rtol)


def test_fused_dual_norm_matches_core(rng):
    """Kernel-based Omega^D == core sgl_dual_norm on grouped correlations."""
    from repro.core.sgl import sgl_dual_norm

    G, ng = 40, 11
    corr = jnp.asarray(rng.standard_normal((G, ng)))
    w = jnp.asarray(np.sqrt(ng) * np.ones(G))
    tau = 0.45
    a = float(ops.sgl_dual_norm_fused(corr, tau, w))
    b = float(sgl_dual_norm(corr, tau, w))
    np.testing.assert_allclose(a, b, rtol=1e-9)


def test_sgl_prox_batched_matches_per_lambda(rng):
    """Batched-lambda prox == per-lambda reference prox, row by row."""
    B, G, ng = 3, 16, 8
    beta = jnp.asarray(rng.standard_normal((B, G, ng)), jnp.float32)
    lam_b = jnp.asarray([0.2, 0.7, 1.5], jnp.float32)
    L = jnp.asarray(2.0, jnp.float32)
    w = jnp.sqrt(jnp.full((G,), float(ng), jnp.float32))
    tau = 0.4

    out = ops.sgl_prox_batched(beta, lam_b, L, w, tau=tau)
    for b in range(B):
        step = jnp.full((G,), float(lam_b[b] / L), jnp.float32)
        want = ref.sgl_prox_ref(beta[b], step, w, tau, 1.0)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(want),
                                   atol=1e-6)
