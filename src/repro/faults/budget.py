"""Per-request solve budgets: deadlines + epoch caps, checked at
host-synced round boundaries.

A :class:`SolveBudget` is attached to an :class:`~repro.core.session.
SGLSession` (``session.budget``) for the duration of one request.  The
solver checks it only where it already synchronizes with the host (the
``float(gap)`` read after every certified round, and between path
lambdas), so budgets add zero device round-trips.  A tripped budget never
invents an answer: the solve returns the prefix it actually certified,
with the last certified full-problem gap — the serving layer surfaces
that as a typed :class:`~repro.faults.errors.Degraded`.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

__all__ = ["SolveBudget"]


class SolveBudget:
    """Monotonic deadline + total-epoch cap for one request.

    ``deadline_s`` is relative to construction time (the moment the
    server starts serving the request); ``max_epochs`` caps the total BCD
    epochs across every lambda of the path.  ``clock`` is injectable for
    deterministic tests.
    """

    def __init__(self, deadline_s: Optional[float] = None,
                 max_epochs: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        if deadline_s is None and max_epochs is None:
            raise ValueError("a SolveBudget needs a deadline_s and/or "
                             "max_epochs")
        self._clock = clock
        self._deadline = (clock() + float(deadline_s)
                          if deadline_s is not None else None)
        self.max_epochs = int(max_epochs) if max_epochs is not None else None
        self.epochs = 0

    def note_epochs(self, n: int) -> None:
        self.epochs += int(n)

    def exceeded(self) -> Optional[str]:
        """The trip reason ("deadline" | "epoch_budget"), or None."""
        if self._deadline is not None and self._clock() > self._deadline:
            return "deadline"
        if self.max_epochs is not None and self.epochs >= self.max_epochs:
            return "epoch_budget"
        return None
