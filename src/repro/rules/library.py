"""The registered screening-rule implementations (paper Section 7.1 + §2).

Each rule is one safe-sphere construction plugged into the shared skeleton
(see :mod:`repro.rules.base`); the Fig. 2/3 comparison of the paper is
exactly this family run side by side (``benchmarks/sweep_rules.py``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import RuleState, ScreeningRule

__all__ = [
    "GapSafeRule",
    "StaticSafeRule",
    "DynamicSafeRule",
    "Dst3Rule",
    "NoScreening",
    "StrongSequentialRule",
]


@dataclasses.dataclass(frozen=True)
class GapSafeRule(ScreeningRule):
    """GAP safe sphere (this paper, Thm 2): B(theta, sqrt(2 gap)/lambda).

    Safe from ANY dual feasible theta — which is what makes it both
    sequential (valid at a new lambda from the previous primal point via
    the Eq. 15 rescaling) and dynamic (the radius shrinks with the gap as
    the solver converges).  The center is the skeleton's rescaled dual
    point and the sphere correlation is the residual correlation over the
    dual scale, so the round pays no extra O(n p) work.

    Loss-generic: for a nu-smooth data fidelity the radius generalizes to
    ``sqrt(2 nu gap) / lambda`` (journal follow-up, arXiv 1611.05780) —
    ``state.nu`` is a trace-time Python float, so the default 1.0
    (squared loss) folds away and the historical graph is unchanged.
    """

    name = "gap"
    is_safe = True
    is_dynamic = True
    supports_sequential = True
    supports_compact = True

    def center_and_radius(self, state: RuleState):
        radius = (jnp.sqrt(2.0 * state.nu * jnp.maximum(state.gap, 0.0))
                  / state.lam)
        return state.theta, radius, state.corr / state.scale


@dataclasses.dataclass(frozen=True)
class StaticSafeRule(ScreeningRule):
    """Static safe sphere [El Ghaoui et al. 2012]:
    B(y/lambda, ||y/lambda_max - y/lambda||), applied ONCE before the
    first epoch.  Safe but never refined — the paper's Fig. 2 baseline
    whose screened set stays frozen while GAP keeps shrinking."""

    name = "static"
    is_safe = True
    pre_screens = True
    needs_lam_max = True
    # The y/lambda-centered sphere is quadratic-dual geometry: lsq only.
    supported_losses = ("lsq",)

    def pre_solve_sphere(self, problem, lam_, lam_max):
        # Delegate to the canonical construction in core (lazy import —
        # see Dst3Rule) so the rule object and direct screening calls can
        # never compute different spheres for the same name.
        from repro.core.screening import static_sphere

        sph = static_sphere(problem, lam_, lam_max)
        return sph.center, sph.radius


@dataclasses.dataclass(frozen=True)
class DynamicSafeRule(ScreeningRule):
    """Dynamic safe sphere [Bonnefoy et al. 2014]:
    B(y/lambda, ||theta_k - y/lambda||) refined at every certified round
    from the current dual feasible point.  Safe, but the radius does not
    converge to zero (it stops at ||theta_hat - y/lambda||), and the
    sphere carries nothing across lambdas — no sequential transfer."""

    name = "dynamic"
    is_safe = True
    is_dynamic = True
    supported_losses = ("lsq",)  # y/lambda center: quadratic dual only

    def center_and_radius(self, state: RuleState):
        from repro.core.screening import dynamic_sphere

        sph = dynamic_sphere(state.problem, state.theta, state.lam)
        return sph.center, sph.radius, None


@dataclasses.dataclass(frozen=True)
class Dst3Rule(ScreeningRule):
    """DST3 sphere [Xiang et al. 2011 / Bonnefoy et al. 2014], extended to
    the SGL in the paper's App. C (Prop. 11): the dynamic sphere refined
    by the hyperplane supporting the dual feasible set at y/lambda_max."""

    name = "dst3"
    is_safe = True
    is_dynamic = True
    needs_lam_max = True
    supported_losses = ("lsq",)  # hyperplane at y/lam_max: lsq dual only

    def center_and_radius(self, state: RuleState):
        # Lazy import: repro.core.solver imports this package at module
        # import time; the method only runs at trace time, when the core
        # package is fully initialised.
        from repro.core.screening import dst3_sphere

        sph = dst3_sphere(state.problem, state.theta, state.lam,
                          state.lam_max)
        return sph.center, sph.radius, None


@dataclasses.dataclass(frozen=True)
class NoScreening(ScreeningRule):
    """No screening at all — the paper's unscreened baseline.

    Vacuously safe (it never discards anything).  ``supports_sequential``
    is True because the sequential round still carries a valid gap
    certificate (with all-true masks): the path engine uses it for the
    warm-start early exit, so a lambda whose warm gap is already under
    tolerance costs zero epochs even without screening.
    """

    name = "none"
    is_safe = True
    supports_sequential = True


@dataclasses.dataclass(frozen=True)
class StrongSequentialRule(ScreeningRule):
    """EXPLICITLY UNSAFE sequential heuristic (the paper's corrupted-rule
    comparison, §2 / Fig. 3).

    Classical sequential rules (sequential SAFE, strong rules) screen at
    lambda_t from the *previous* lambda's solution **as if that solution
    were exact** — the assumption the paper shows breaks safety, since in
    practice only an approximation of theta_hat(lambda_{t-1}) is known.
    This rule reproduces that failure mode inside the shared sphere
    skeleton: it takes the GAP sphere's center (the Eq. 15 rescaled dual
    point) but *corrupts* the Thm-2 radius by ``shrink``.  ``shrink=0.0``
    is the pure point test (the current feasible point treated as the
    exact dual optimum — so aggressive it routinely wipes out the true
    support from any warm start); the default 0.5 is the milder classical
    flavour that screens noticeably more than GAP and is usually right —
    until it is not.  With ``shrink=1.0`` it degenerates to the safe GAP
    rule; anything below forfeits the containment proof.

    ``is_safe=False`` propagates everywhere: every round it produces is
    flagged (``RoundResult.safe=False``), path results carry
    ``certificates_safe=False``, and nothing it discards is ever reported
    as a zero-certificate.  A wrong discard is permanent (masks are
    monotone), so the full-problem duality gap — always computed on the
    full problem, never trusted to the rule — stalls above tolerance and
    the solve saturates ``max_epochs`` with an honest gap: the failure is
    visible, not silent.
    """

    shrink: float = 0.5

    name = "strong"
    is_safe = False
    is_dynamic = True
    supports_sequential = True

    def center_and_radius(self, state: RuleState):
        r_gap = (jnp.sqrt(2.0 * state.nu * jnp.maximum(state.gap, 0.0))
                 / state.lam)
        return state.theta, self.shrink * r_gap, state.corr / state.scale
