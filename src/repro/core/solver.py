"""ISTA-BC (block coordinate descent) with dynamic safe screening — Algorithm 2.

Faithful reproduction of the paper's solver:

* cyclic block coordinate descent over *active* groups, block Lipschitz
  steps  L_g = ||X_g||_2^2, two-level prox (soft-threshold then group
  soft-threshold),
* duality gap computed every ``f_ce`` passes (paper: f_ce = 10), giving the
  dual feasible point via residual rescaling (Eq. 15) and the GAP safe
  sphere (Thm 2), from which groups/features are screened (Thm 1),
* alternative spheres (static / dynamic / DST3 / none / unsafe strong) for
  the paper's comparison experiments (Fig. 2/3) — pluggable
  :mod:`repro.rules` strategy objects sharing the one round skeleton
  (:func:`_screen_round`), which owns everything rule-independent and asks
  a rule only for its sphere.

TPU/XLA adaptation (see DESIGN.md §3): screened variables are removed by
**gathering the surviving groups into a dense buffer padded to power-of-two
buckets**, so the inner jitted BCD epochs only touch active data; XLA
recompiles at most log2(G) times and the compile cache is shared across the
lambda path.  Screening certificates are permanent (safe), so active sets
shrink monotonically.

Compacted certified rounds: the paper keeps the gap/screening round's
correlation X^T theta on the *full* problem every f_ce passes, which stays
O(n p) even when 99% of groups hold a permanent certificate — exactly the
cost the rule exists to kill.  Since certificates are permanent, screened
groups never need exact correlations again; they re-enter only through the
dual scaling Omega^D(X^T resid) (Eq. 15).  :func:`_screen_round_compact`
therefore runs the whole round — residual, correlation, dual norm, gap,
Theorem-1 tests — on the gathered (n, p_active) buffer and *bounds* the
screened groups' dual-norm terms from the last full round's cached
reference (``SolveCaches.resid_ref`` / ``ref_terms``; bound proof in
:mod:`repro.core.screening`).  When the bound stays below
max(lambda, active-term max) the compact round is EXACT; otherwise the
driver falls back to the full :func:`_screen_round` (which also refreshes
the reference).  The driver additionally forces a full round every
``full_round_every`` rounds and always re-confirms convergence with a full
round, so every *reported* gap/certificate is full-problem exact.

Fused BCD epochs: the inner epochs themselves dispatch on
``SolverConfig.solver_backend`` (resolved by :func:`resolve_solver_backend`,
the same auto/xla/pallas policy as the screening backend) — ``"pallas"``
replaces the per-group ``lax.scan`` of :func:`bcd_epochs` with the
:mod:`repro.kernels.bcd_epoch` mega-kernel, which runs whole epoch blocks in
ONE launch with the residual carried in VMEM and a lambda-batch grid axis
(consecutive path points with coinciding certified active sets solve
together; see :meth:`repro.core.session.SGLSession.solve_path`).  The
``lax.scan`` path stays as the XLA fallback and the bit-parity reference:
interpret-mode f64 results of the fused kernel are bit-identical to it.
(The *epoch math* parity is structural; the Pallas reduced-gap correlation
used between blocks accumulates per n-tile, so the early-exit heuristic
can differ from the einsum in the last ulp — end-to-end path equality
therefore additionally requires that no reduced gap lands within ~1e-13
relative of ``tol``, which the CI smoke config pins deterministically.)

This module holds the jitted machinery (``bcd_epochs``, ``_inner_rounds``,
``_screen_round``, ``_gather_static``) plus the round/caches primitives; the
outer drivers live on :class:`repro.core.session.SGLSession` and the
module-level :func:`solve` is a thin deprecated wrapper delegating there.

Path-engine hooks (used by :meth:`repro.core.session.SGLSession.solve_path`):

* :func:`screen_round` is the public resumable-round API — one certified
  gap + Theorem-1 screening round, returned as a :class:`RoundResult`.
  The path engine calls it at a new ``lambda_t`` with the previous
  lambda's ``beta`` (the paper's *sequential* rule) and hands the result
  to the solve as ``first_round`` so the round is not recomputed.
* the hot correlation ``X^T resid`` and the SGL dual norm inside the round
  are routed through the Pallas kernels (:mod:`repro.kernels.ops`) when
  ``screen_backend`` resolves to ``"pallas"`` (the default on TPU).
* :class:`SolveCaches` carries the compacted gather buffers *across* calls:
  a path engine passes one instance for the whole lambda path, so
  consecutive lambdas whose certified active set is unchanged skip the
  (n x p_active) re-gather and share the jit cache.
* ``check_every`` controls the granularity of the reduced-gap early-exit
  inside the jitted inner loop; the path engine uses 1 (check after every
  BCD pass) so warm-started lambdas stop after exactly the epochs they
  need instead of a full ``f_ce`` block.
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import screening as scr
from . import sgl
from .sgl import SGLProblem
from ..kernels import _util as kernel_util
from ..kernels import ops as kops
from ..losses import Loss, resolve_loss
from ..obs import metrics as obs_metrics
from ..rules import RuleState, ScreeningRule, resolve_rule

_M_GATHERS = obs_metrics.REGISTRY.counter(
    "solver.gathers",
    help="Compacted gather-buffer rebuilds (certified active set shrank) "
         "across all SolveCaches instances in the process")

__all__ = [
    "SolveResult",
    "SolveCaches",
    "RoundResult",
    "solve",
    "bcd_epochs",
    "bcd_epochs_loss",
    "screen_round",
    "resolve_backend",
    "resolve_screen_backend",
    "resolve_solver_backend",
    "check_rule_loss",
]


class RoundResult(NamedTuple):
    """One certified gap + Theorem-1 screening round (GAP-sphere certificate).

    Replaces the bare ``(gap, theta, group_active, feat_active)`` 4-tuple the
    round family used to hand around by positional index; being a tuple
    subclass, positional unpacking still works (slice ``[:4]`` for the
    legacy quartet).  ``theta`` is None on the distributed strategy (the
    dual point stays sharded on the mesh).  ``compact`` marks a round
    evaluated on the compacted active buffer (exact, but the driver always
    confirms convergence with a full round before reporting — see
    :meth:`repro.core.session.SGLSession.solve`).
    """

    gap: jax.Array                   # certified duality gap at (beta, lam)
    theta: Optional[jax.Array]       # (n,) dual feasible point (Eq. 15)
    group_active: jax.Array          # (G,) bool — False = certified zero
    feat_active: jax.Array           # (G, ng) bool — False = certified zero
    compact: bool = False            # round ran on the compacted buffer
    safe: bool = True                # masks are certificates; False for
                                     #   rounds produced by an unsafe rule
                                     #   (repro.rules ScreeningRule.is_safe
                                     #   False) — heuristic discards, never
                                     #   reported as zero-certificates


class SolveResult(NamedTuple):
    beta: jax.Array            # (G, ng) grouped coefficients
    theta: jax.Array           # (n,) dual feasible point
    gap: jax.Array             # final duality gap
    n_epochs: int              # BCD passes performed
    group_active: np.ndarray   # (G,) final active mask
    feat_active: np.ndarray    # (G, ng) final active mask
    gap_history: list
    active_history: list       # [(epoch, n_groups_active, n_feats_active)]
    degraded: Optional[str] = None  # budget-trip reason ("deadline" |
                                    #   "epoch_budget"); gap stays the
                                    #   honest last-certified value


class SolveCaches:
    """Mutable cross-call caches for :func:`solve`.

    Holds the compacted gather buffers keyed on the certified active-group
    set.  Within one ``solve`` the active set only shrinks, so the gather is
    redone a handful of times; across a lambda path the previous lambda's
    active set is usually a subset of the next one's *certified* set, and on
    dense grids it is frequently identical — passing one ``SolveCaches`` down
    the whole path (see :func:`repro.core.path.solve_path`) skips those
    re-gathers entirely and keeps XLA's compile cache warm (same power-of-two
    bucket shapes).

    Also carries the compact-round reference state: the residual and the
    per-group dual-norm terms of the last *full* certified round
    (``resid_ref`` / ``ref_terms``, refreshed by
    :meth:`repro.core.session.SGLSession._certified_round`), which let
    :func:`_screen_round_compact` bound the screened groups' dual-norm
    contribution without touching their columns, plus (Pallas backend) the
    active-row slice of the persistent transposed design keyed on the same
    active-set bytes as the gather.

    Entries are keyed on problem identity + active-set bytes, so sharing an
    instance across problems degrades to a miss instead of serving stale
    buffers; one instance per lambda path is the intended use.
    """

    __slots__ = ("gather_key", "gather_val", "n_gathers", "_problem",
                 "xt_rows_key", "xt_rows_val", "resid_ref", "ref_terms")

    def __init__(self) -> None:
        self.gather_key: Optional[bytes] = None
        self.gather_val = None
        self.n_gathers: int = 0
        self._problem: Optional[SGLProblem] = None
        self.xt_rows_key: Optional[bytes] = None
        self.xt_rows_val = None
        self.resid_ref: Optional[jax.Array] = None
        self.ref_terms: Optional[jax.Array] = None

    def _sync_problem(self, problem: SGLProblem) -> None:
        if problem is not self._problem:
            # A different problem with a byte-identical mask must be a cache
            # MISS, not silently-served stale buffers; reference residuals
            # of another problem are meaningless here.
            self._problem = problem
            self.gather_key = None
            self.xt_rows_key = None
            self.resid_ref = None
            self.ref_terms = None

    def gather(self, problem: SGLProblem, group_active: np.ndarray):
        self._sync_problem(problem)
        key = group_active.tobytes()
        if key != self.gather_key:
            self.gather_val = _gather_static(problem, group_active)
            self.gather_key = key
            self.n_gathers += 1
            _M_GATHERS.inc()
        return self.gather_val

    def gather_xt_rows(self, problem: SGLProblem, group_active: np.ndarray,
                       xt_pre: jax.Array):
        """Active-row slice of the persistent transposed design (Pallas
        compact rounds), keyed on the same active-set bytes as ``gather``
        — a row *gather*, never an on-the-fly transpose."""
        self._sync_problem(problem)
        key = group_active.tobytes()
        if key != self.xt_rows_key:
            _, take, *_ = self.gather(problem, group_active)
            self.xt_rows_val = kops.gather_transposed_rows(
                xt_pre, take, problem.ng
            )
            self.xt_rows_key = key
        return self.xt_rows_val

    def set_refs(self, problem: SGLProblem, resid: jax.Array,
                 terms: jax.Array) -> None:
        """Adopt a full round's residual + per-group dual-norm terms as the
        compact-round reference point."""
        self._sync_problem(problem)
        self.resid_ref = resid
        self.ref_terms = terms


# ----------------------------------------------------------------------------
# Inner jitted BCD epochs over a compacted active buffer
# ----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_epochs",), donate_argnums=(4, 5))
def bcd_epochs(
    Xt: jax.Array,         # (Gb, n, ng) compacted design (group-major)
    Lg: jax.Array,         # (Gb,)
    w: jax.Array,          # (Gb,)
    feat_mask: jax.Array,  # (Gb, ng) float mask (0 also encodes screened feats)
    beta: jax.Array,       # (Gb, ng)
    resid: jax.Array,      # (n,)
    tau: jax.Array,
    lam_: jax.Array,
    n_epochs: int,
):
    """Run ``n_epochs`` cyclic BCD passes, carrying the residual.

    Update for group g (paper Section 6):
        z      = beta_g + X_g^T resid / L_g            (gradient step)
        z      = S_{tau lam / L_g}(z)                  (feature prox)
        beta_g = S^gp_{(1-tau) w_g lam / L_g}(z)       (group prox)
        resid += X_g (beta_g_old - beta_g_new)
    Inactive (padded / screened) groups have feat_mask == 0 and Lg <= 0 and
    are skipped via masking.
    """
    live = (Lg > 0).astype(beta.dtype)                # (Gb,)
    safe_L = jnp.where(Lg > 0, Lg, 1.0)
    step = lam_ / safe_L                              # alpha_g = lam / L_g
    thr1 = tau * step                                 # (Gb,)
    thr2 = (1.0 - tau) * w * step                     # (Gb,)

    def group_update(resid, inputs):
        Xg, bg, L, t1, t2, m, lv = inputs
        grad_step = (Xg.T @ resid) / L                # (ng,)
        z = (bg + grad_step) * m
        z = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t1, 0.0)
        nrm = jnp.linalg.norm(z)
        z = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0) * z
        new_bg = jnp.where(lv > 0, z, bg)
        resid = resid + Xg @ (bg - new_bg)
        return resid, new_bg

    def epoch(carry, _):
        beta, resid = carry
        resid, beta = jax.lax.scan(
            group_update, resid, (Xt, beta, safe_L, thr1, thr2, feat_mask, live)
        )
        return (beta, resid), None

    (beta, resid), _ = jax.lax.scan(epoch, (beta, resid), None, length=n_epochs)
    return beta, resid


@functools.partial(jax.jit, static_argnames=("loss", "n_epochs"),
                   donate_argnums=(4, 5))
def bcd_epochs_loss(
    Xt: jax.Array,         # (Gb, n, ng) compacted design (group-major)
    Lg: jax.Array,         # (Gb,)
    w: jax.Array,          # (Gb,)
    feat_mask: jax.Array,  # (Gb, ng) float mask
    beta: jax.Array,       # (Gb, ng)
    z: jax.Array,          # (n,) linear predictor X beta (the loss carry)
    tau: jax.Array,
    lam_: jax.Array,
    y: jax.Array,          # (n,) response (the loss gradient needs it)
    loss: Loss,
    n_epochs: int,
):
    """Loss-generic twin of :func:`bcd_epochs`: majorized BCD carrying the
    linear predictor ``z = X beta`` instead of the lsq residual.

    Per group (majorize-minimize; arXiv 1611.05780 §4):
        rho    = -grad F(z) = loss.neg_grad(y, z)     (fresh each group)
        z_g    = beta_g + X_g^T rho / (nu L_g)        (gradient step)
        beta_g = two-level prox at step lam / (nu L_g)
        z     += X_g (beta_g_new - beta_g_old)
    ``nu L_g`` upper-bounds the block Hessian ``X_g^T diag(f'') X_g``
    (per-sample curvature <= nu), so every epoch decreases the primal.
    For ``loss="lsq"`` (nu=1, rho = y - z) this is algebraically the
    :func:`bcd_epochs` update — but the carry differs (z vs resid), so the
    lsq solver keeps the original function; this one serves non-quadratic
    losses and the parity tests.
    """
    live = (Lg > 0).astype(beta.dtype)                # (Gb,)
    Lmaj = loss.nu * Lg                               # block majorization
    safe_L = jnp.where(Lg > 0, Lmaj, 1.0)
    step = lam_ / safe_L
    thr1 = tau * step                                 # (Gb,)
    thr2 = (1.0 - tau) * w * step                     # (Gb,)

    def group_update(z, inputs):
        Xg, bg, L, t1, t2, m, lv = inputs
        rho = loss.neg_grad(y, z)                     # (n,)
        grad_step = (Xg.T @ rho) / L                  # (ng,)
        u = (bg + grad_step) * m
        u = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t1, 0.0)
        nrm = jnp.linalg.norm(u)
        u = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0) * u
        new_bg = jnp.where(lv > 0, u, bg)
        z = z + Xg @ (new_bg - bg)
        return z, new_bg

    def epoch(carry, _):
        beta, z = carry
        z, beta = jax.lax.scan(
            group_update, z, (Xt, beta, safe_L, thr1, thr2, feat_mask, live)
        )
        return (beta, z), None

    (beta, z), _ = jax.lax.scan(epoch, (beta, z), None, length=n_epochs)
    return beta, z


# ----------------------------------------------------------------------------
# Certified gap + screening round (resumable-round API)
# ----------------------------------------------------------------------------

def resolve_backend(backend: str, *, what: str = "backend") -> str:
    """Shared backend resolution for every Pallas/XLA dispatch knob.

    ``"auto"`` picks the Pallas kernels on TPU and plain XLA elsewhere
    (where Pallas would run interpreted); ``"xla"``/``"pallas"`` force.
    ``what`` only labels the error message (``screen backend`` /
    ``solver backend``).
    """
    if backend == "auto":
        return "pallas" if kernel_util.on_tpu() else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(f"unknown {what}: {backend!r}")
    return backend


def resolve_screen_backend(backend: str) -> str:
    """Resolve the screening correlation/dual-norm backend."""
    return resolve_backend(backend, what="screen backend")


def resolve_solver_backend(backend: str) -> str:
    """Resolve the BCD-epoch solver backend (``SolverConfig.solver_backend``):
    ``"pallas"`` runs the inner epochs through the fused
    :mod:`repro.kernels.bcd_epoch` mega-kernel, ``"xla"`` keeps the
    ``lax.scan`` reference (the bit-parity fallback)."""
    return resolve_backend(backend, what="solver backend")


def _corr_grouped(problem: SGLProblem, v: jax.Array, backend: str,
                  xt_pre: Optional[jax.Array]) -> jax.Array:
    """Backend-routed grouped correlation X^T v — the shared skeleton's one
    correlation primitive.  ``"pallas"`` runs the corr-only Pallas matvec
    over the persistent transposed design (on-the-fly transposes are
    audit-counted); ``"xla"`` the plain einsum."""
    if backend == "pallas":
        return kops.screening_corr_grouped(problem.X, v, xt_pre=xt_pre)
    return jnp.einsum("ngk,n->gk", problem.X, v)


def check_rule_loss(rule: ScreeningRule, loss: Loss) -> None:
    """Fail fast on a rule x loss pairing the rule's sphere cannot prove.

    Mirrors the rule x mesh gate in :class:`repro.core.session.SGLSession`:
    rules whose geometry is least-squares-specific declare
    ``supported_losses=("lsq",)`` and any other loss is rejected at
    construction time, never silently screened unsafely.
    """
    if rule.supported_losses is not None and (
            loss.name not in rule.supported_losses):
        raise ValueError(
            f"rule={rule.name!r} supports losses "
            f"{list(rule.supported_losses)}, not loss={loss.name!r} "
            f"(its sphere is built from the quadratic dual's y/lambda "
            f"geometry); use the GAP family for non-lsq losses"
        )


@functools.partial(jax.jit, static_argnames=("rule", "backend", "loss"))
def _screen_round(problem: SGLProblem, beta: jax.Array, lam_: jax.Array,
                  lam_max: jax.Array, rule: ScreeningRule,
                  backend: str = "xla",
                  xt_pre: Optional[jax.Array] = None,
                  loss: Optional[Loss] = None):
    """One fused FULL gap + screening round (single XLA program) — the
    shared sphere-test SKELETON every :class:`repro.rules.ScreeningRule`
    plugs into.

    The eager version of this round cost ~50 small dispatches; fusing it is
    what makes screening overhead negligible per round (see EXPERIMENTS.md
    §Perf, solver iteration 1).  The skeleton owns everything
    rule-independent — the residual, the Eq. 15 dual scaling, the duality
    gap, the Theorem-1 tests, and the Pallas corr/dual-norm kernel routing
    (fed from the persistent transposed design, so the transpose audit
    covers every rule) — and asks the rule only for its sphere via
    ``rule.center_and_radius`` (a hashable static argument: equal rule
    instances share one compiled program).  A rule that cannot supply
    ``X^T center`` for free gets it from the SAME backend-routed
    correlation primitive, so e.g. the dynamic sphere's second correlation
    also runs on the Pallas kernel on TPU.

    Returns ``(RoundResult, resid, terms)`` where ``resid``/``terms`` (the
    residual and the per-group dual-norm terms) are the reference state the
    compacted round (:func:`_screen_round_compact`) bounds screened groups
    from — the session stores them on :class:`SolveCaches` after every full
    round.  For rules that do not screen dynamically the masks are
    all-true; rounds from unsafe rules come back flagged ``safe=False``.

    ``backend="pallas"`` computes the hot X^T resid correlation through the
    corr-only Pallas matvec kernel and the SGL dual norm through the Pallas
    bisection kernel (kernels.ops); ``"xla"`` uses plain einsums.
    ``xt_pre`` is the persistent (p, n) transposed design from
    :func:`repro.kernels.ops.prepare_transposed` — without it every
    Pallas-backed round materialises a fresh transposed copy of X.

    ``loss`` (static): a :class:`repro.losses.Loss`, or None for the
    historical squared loss.  The skeleton generalizes by swapping the
    residual for ``rho = -grad F(X beta)`` (Eq. 15 is otherwise verbatim)
    and the gap for the loss's primal/dual pair; the lsq branch keeps the
    original arithmetic untouched so the default loss stays bit-identical.
    The sphere test sees the loss only through ``RuleState.nu``.
    """
    lsq = loss is None or loss.name == "lsq"
    if lsq:
        resid = problem.y - jnp.einsum("ngk,gk->n", problem.X, beta)
    else:
        z = jnp.einsum("ngk,gk->n", problem.X, beta)
        resid = loss.neg_grad(problem.y, z)   # generalized residual rho
    corr = _corr_grouped(problem, resid, backend, xt_pre)
    if backend == "pallas":
        terms = kops.sgl_dual_norm_terms_fused(corr, problem.tau, problem.w)
    else:
        terms = sgl.sgl_dual_norm_terms(corr, problem.tau, problem.w)
    dual_norm = jnp.max(terms)
    scale = jnp.maximum(lam_, dual_norm)
    theta = resid / scale
    if lsq:
        gap = sgl.duality_gap(problem, beta, theta, lam_)
    else:
        primal = loss.value(problem.y, z) + lam_ * sgl.sgl_norm(
            beta, problem.tau, problem.w)
        gap = primal - loss.dual_obj(problem.y, theta, lam_)

    if rule.is_dynamic:
        state = RuleState(
            problem=problem, beta=beta, resid=resid, corr=corr, scale=scale,
            theta=theta, gap=gap, lam=lam_, lam_max=lam_max,
            nu=1.0 if lsq else float(loss.nu),
        )
        center, radius, corr_c = rule.center_and_radius(state)
        if corr_c is None:
            corr_c = _corr_grouped(problem, center, backend, xt_pre)
        res = scr.screen_with_corr(
            problem, scr.Sphere(center, radius), corr_c
        )
    else:  # "none" / "static" — no dynamic screening, gap-only round
        res = scr.ScreenResult(
            jnp.ones((problem.G,), bool),
            jnp.asarray(problem.feat_mask),
            scr.Sphere(theta, jnp.inf),
        )
    round_res = RoundResult(gap, theta, res.group_active, res.feat_active,
                            safe=rule.is_safe)
    return round_res, resid, terms


@functools.partial(jax.jit, static_argnames=("backend",))
def _screen_round_compact(
    problem: SGLProblem,
    Xt: jax.Array,            # (Gb, n, ng) gathered active design
    take: jax.Array,          # (Gb,) group indices (padded slots alias 0)
    gmask: jax.Array,         # (Gb,) float, 0 on padded slots
    beta: jax.Array,          # (G, ng) full coefficients (0 off the buffer)
    feat_active: jax.Array,   # (G, ng) bool current mask
    group_active: jax.Array,  # (G,) bool current mask
    ref_terms: jax.Array,     # (G,) dual-norm terms at resid_ref
    resid_ref: jax.Array,     # (n,) residual of the last full round
    lam_: jax.Array,
    backend: str = "xla",
    xt_rows: Optional[jax.Array] = None,
):
    """Certified gap + Theorem-1 round on the compacted active buffer.

    O(n * p_active) instead of O(n * p): the residual, the correlation, the
    dual norm, the gap, and the Theorem-1 tests all touch only the gathered
    groups.  Screened groups enter solely through the dual scaling
    (Eq. 15), where their eps-norm terms are *bounded* from the cached
    reference (:func:`repro.core.screening.screened_dual_bound`):

        term_g(resid) <= ref_terms_g + rate_g * ||resid - resid_ref||.

    ``valid`` is True iff that bound stays <= max(lambda, active-term max),
    in which case the full dual norm provably equals the active-term max
    and every returned quantity is EXACT (bit-level identical up to einsum
    reduction order) — not an approximation.  On ``valid=False`` the caller
    must discard the result and fall back to :func:`_screen_round`.

    Returns ``(gap, theta, group_keep, feat_keep, valid)`` with full-size
    (G,) / (G, ng) masks; groups outside the buffer come back False (they
    hold a permanent certificate and the caller's masks are intersected
    monotonically).

    ``backend="pallas"`` routes the correlation through the corr-only
    kernel over ``xt_rows`` (the active-row slice of the persistent
    transposed design, :func:`repro.kernels.ops.gather_transposed_rows`)
    and the per-group dual terms through the bisection kernel.
    """
    dtype = Xt.dtype
    tau = problem.tau
    Gb, ng = Xt.shape[0], Xt.shape[2]

    fmask_sub = (jnp.take(feat_active, take, axis=0).astype(dtype)
                 * gmask[:, None])
    bsub = jnp.take(beta, take, axis=0) * fmask_sub
    resid = problem.y - jnp.einsum("gnk,gk->n", Xt, bsub)
    shift = jnp.linalg.norm(resid - resid_ref)

    if backend == "pallas":
        corr = kops.screening_corr(xt_rows, resid)[: Gb * ng]
        corr = corr.reshape(Gb, ng)
    else:
        corr = jnp.einsum("gnk,n->gk", Xt, resid)
    corr = corr * gmask[:, None]          # padded slots alias group 0

    w_sub = jnp.take(problem.w, take)
    if backend == "pallas":
        terms_sub = kops.sgl_dual_norm_terms_fused(corr, tau, w_sub)
    else:
        terms_sub = sgl.sgl_dual_norm_terms(corr, tau, w_sub)
    gact_sub = jnp.take(group_active, take) & (gmask > 0)
    dual_active = jnp.max(jnp.where(gact_sub, terms_sub, 0.0))
    scale = jnp.maximum(lam_, dual_active)

    real_grp = jnp.any(problem.feat_mask, axis=-1)
    screened = real_grp & ~group_active
    bound = scr.screened_dual_bound(
        ref_terms, scr.screened_group_rate(problem), shift, screened
    )
    valid = bound <= scale

    theta = resid / scale
    # sgl.primal on the buffer: beta is exactly zero off it, so the
    # residual and the SGL norm restricted to the gathered groups ARE the
    # full primal; the dual is O(n) and reused verbatim.
    primal = (0.5 * jnp.sum(resid * resid)
              + lam_ * sgl.sgl_norm(bsub, tau, w_sub))
    gap = primal - sgl.dual(problem, theta, lam_)

    # Theorem-1 tests on the buffer: the SAME shared formulas as the full
    # round (screening.theorem1_tests), on the gathered slices.
    r = jnp.sqrt(2.0 * jnp.maximum(gap, 0.0)) / lam_
    corr_s = corr / scale
    fm_real_sub = (jnp.take(problem.feat_mask, take, axis=0)
                   & (gmask[:, None] > 0))
    xg = jnp.take(problem.Xnorm_grp, take)
    xc = jnp.take(problem.Xnorm_col, take, axis=0)
    g_keep_sub, f_keep_sub = scr.theorem1_tests(
        corr_s, r, xg, xc, w_sub, fm_real_sub, tau
    )
    g_keep_sub = g_keep_sub & gact_sub
    f_keep_sub = f_keep_sub & g_keep_sub[:, None] & fm_real_sub

    # Scatter back to full-size masks; padded slots carry False and .add
    # with int values keeps duplicate (aliased) indices harmless.
    G = problem.feat_mask.shape[0]
    g_keep = jnp.zeros((G,), jnp.int32).at[take].add(
        g_keep_sub.astype(jnp.int32)) > 0
    f_keep = jnp.zeros(problem.feat_mask.shape, jnp.int32).at[take].add(
        f_keep_sub.astype(jnp.int32)) > 0
    return gap, theta, g_keep, f_keep, valid


def screen_round(
    problem: SGLProblem,
    beta: jax.Array,
    lam_: float,
    lam_max: float = 0.0,
    rule="gap",
    backend: str = "auto",
    xt_pre: Optional[jax.Array] = None,
    loss="lsq",
) -> RoundResult:
    """Public resumable-round API: one certified gap + screening round.

    Returns a :class:`RoundResult` — a GAP-sphere certificate valid at
    ``lam_``.  Calling this at a *new* lambda with the *previous* lambda's
    ``beta`` is exactly the paper's sequential screening rule; the result
    can be fed to :func:`solve` as ``first_round`` so the solve starts on
    the reduced problem with zero duplicated work.

    ``rule``: a registered rule name or a :class:`repro.rules.ScreeningRule`
    object; unknown names fail fast here with the registered list (they
    used to fall silently into the no-screening branch of the round).
    ``rule="dst3"`` needs the true ``lam_max`` (its sphere divides by it).
    ``xt_pre``: persistent transposed design (Pallas backend only) — see
    :meth:`repro.core.session.SGLSession.screen`, which supplies it
    automatically.
    ``loss``: a registered :mod:`repro.losses` name or ``Loss`` object
    (default ``"lsq"``); rule x loss pairings the rule cannot prove fail
    fast here (``supported_losses``).
    """
    rule = resolve_rule(rule)
    loss = resolve_loss(loss)
    if loss.multi_output:
        raise ValueError(
            f"loss={loss.name!r} is multi-output (matrix-valued beta); "
            "the round skeleton supports single-output losses — use the "
            "repro.core.sgl.multitask_* helpers"
        )
    check_rule_loss(rule, loss)
    if rule.pre_screens:
        # Checked BEFORE needs_lam_max: this refusal is terminal, so a
        # static-rule caller must not first be told to pass lambda_max.
        # The static screen is applied once inside solve(), not per round;
        # _screen_round would return all-true masks that LOOK like a valid
        # certificate while screening nothing.
        raise ValueError(
            f"rule={rule.name!r} has no per-round certificate; use "
            "screening.static_sphere + screening.screen, or solve()"
        )
    if rule.needs_lam_max and not lam_max > 0.0:
        raise ValueError(
            f"rule={rule.name!r} requires lam_max > 0 (pass lambda_max)"
        )
    dtype = problem.X.dtype
    res, _resid, _terms = _screen_round(
        problem,
        jnp.asarray(beta, dtype),
        jnp.asarray(lam_, dtype),
        jnp.asarray(lam_max, dtype),
        rule,
        resolve_screen_backend(backend),
        xt_pre,
        loss=None if loss.name == "lsq" else loss,
    )
    return res


def _bucket(n: int, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit,
                   static_argnames=("block_epochs", "max_blocks", "backend"))
def _inner_rounds(Xt, Lg, w, y, beta, feat_active, take, gmask, tau, lam_,
                  tol, block_epochs, max_blocks, backend="xla",
                  xt_rows=None):
    """Up to ``max_blocks`` blocks of ``block_epochs`` BCD epochs in ONE
    jitted call.

    Between blocks the *reduced-problem* duality gap (dual norm over the
    compacted buffer only) is checked for early exit.  This gap is exact
    for the reduced problem but may under-estimate the full certified gap,
    so it is used ONLY as a work heuristic — the caller always recomputes
    the full-problem gap (paper Eq. 15/Thm 2) before stopping or screening.
    Amortises the full X^T rho correlation and the host sync over
    ~max_blocks x block_epochs epochs instead of one block (see
    EXPERIMENTS.md §Perf).  The path engine runs with ``block_epochs=1`` so
    a warm-started lambda stops after exactly the passes it needs.

    ``backend="pallas"`` runs each epoch block through the fused
    :mod:`repro.kernels.bcd_epoch` mega-kernel (one launch per block,
    residual carried in VMEM) instead of the ``lax.scan`` over groups, and
    routes the between-block reduced-gap correlation through the Pallas
    corr kernel over ``xt_rows`` (the active-row slice of the persistent
    transposed design from
    :func:`repro.kernels.ops.gather_transposed_rows`) — previously the gap
    check always paid the XLA einsum even on TPU, and with
    ``block_epochs=1`` it runs after every single pass.

    ``take`` may contain padded slots aliasing group 0; the scatter uses a
    masked *delta* with .add so duplicate indices contribute zero and the
    real group-0 row is preserved.
    """
    dtype = beta.dtype
    Gb, ng = Xt.shape[0], Xt.shape[2]
    fmask = (jnp.take(feat_active, take, axis=0).astype(dtype)
             * gmask[:, None])
    bsub0 = jnp.take(beta, take, axis=0) * fmask
    resid0 = y - jnp.einsum("gnk,gk->n", Xt, bsub0)
    y2half = 0.5 * jnp.sum(y * y)

    def reduced_gap(bsub, resid):
        if backend == "pallas" and xt_rows is not None:
            corr = kops.screening_corr(xt_rows, resid)[: Gb * ng]
            corr = corr.reshape(Gb, ng) * fmask
        else:
            corr = jnp.einsum("gnk,n->gk", Xt, resid) * fmask
        dn = sgl.sgl_dual_norm(corr, tau, w)
        theta = resid / jnp.maximum(lam_, dn)
        primal = (0.5 * jnp.sum(resid * resid)
                  + lam_ * sgl.sgl_norm(bsub, tau, w))
        diff = theta - y / lam_
        dual = y2half - 0.5 * lam_ * lam_ * jnp.sum(diff * diff)
        return primal - dual

    def cond(c):
        bsub, resid, k, gap = c
        return (k < max_blocks) & (gap > tol)

    def body(c):
        bsub, resid, k, gap = c
        if backend == "pallas":
            bsub_b, resid_b = kops.bcd_epochs_fused(
                Xt, Lg * gmask, w, fmask[None], bsub[None], resid[None],
                tau, jnp.reshape(lam_, (1,)), block_epochs
            )
            bsub, resid = bsub_b[0], resid_b[0]
        else:
            bsub, resid = bcd_epochs(
                Xt, Lg * gmask, w, fmask, bsub, resid, tau, lam_,
                block_epochs
            )
        return bsub, resid, k + 1, reduced_gap(bsub, resid)

    bsub, resid, k, gap = jax.lax.while_loop(
        cond, body, (bsub0, resid0, jnp.zeros((), jnp.int32),
                     jnp.asarray(jnp.inf, dtype))
    )
    delta = (bsub - bsub0) * fmask
    return beta.at[take].add(delta), k, gap


@functools.partial(
    jax.jit,
    static_argnames=("loss", "block_epochs", "max_blocks", "backend"))
def _inner_rounds_loss(Xt, Lg, w, y, beta, feat_active, take, gmask, tau,
                       lam_, tol, loss, block_epochs, max_blocks,
                       backend="xla", xt_rows=None):
    """Loss-generic twin of :func:`_inner_rounds`: blocked majorized BCD
    epochs + reduced-gap early exit for any single-output loss.

    The carry is the linear predictor ``z = X beta`` (the loss-defined
    state that replaces the lsq residual); between blocks the reduced gap
    is built from ``rho = -grad F(z)`` through the same Eq. 15 scaling and
    the loss's conjugate dual.  Exact for the reduced problem, heuristic
    for the full one — the caller always re-certifies with a full
    :func:`_screen_round` before stopping or screening, same contract as
    the lsq path.

    ``backend="pallas"`` with ``loss="logistic"`` routes each epoch block
    through the fused :func:`repro.kernels.ops.bcd_epochs_logistic_fused`
    mega-kernel (z carried in VMEM) and the reduced-gap correlation
    through the Pallas corr kernel; other losses fall back to the
    ``lax.scan`` epochs, which are the bit-parity reference either way.
    """
    dtype = beta.dtype
    Gb, ng = Xt.shape[0], Xt.shape[2]
    fmask = (jnp.take(feat_active, take, axis=0).astype(dtype)
             * gmask[:, None])
    bsub0 = jnp.take(beta, take, axis=0) * fmask
    # beta is exactly zero off the buffer, so this IS the full predictor.
    z0 = jnp.einsum("gnk,gk->n", Xt, bsub0)

    def reduced_gap(bsub, z):
        rho = loss.neg_grad(y, z)
        if backend == "pallas" and xt_rows is not None:
            corr = kops.screening_corr(xt_rows, rho)[: Gb * ng]
            corr = corr.reshape(Gb, ng) * fmask
        else:
            corr = jnp.einsum("gnk,n->gk", Xt, rho) * fmask
        dn = sgl.sgl_dual_norm(corr, tau, w)
        theta = rho / jnp.maximum(lam_, dn)
        primal = loss.value(y, z) + lam_ * sgl.sgl_norm(bsub, tau, w)
        return primal - loss.dual_obj(y, theta, lam_)

    def cond(c):
        bsub, z, k, gap = c
        return (k < max_blocks) & (gap > tol)

    def body(c):
        bsub, z, k, gap = c
        if backend == "pallas" and loss.name == "logistic":
            bsub_b, z_b = kops.bcd_epochs_logistic_fused(
                Xt, Lg * gmask, w, fmask[None], bsub[None], z[None],
                y, tau, jnp.reshape(lam_, (1,)), block_epochs
            )
            bsub, z = bsub_b[0], z_b[0]
        else:
            bsub, z = bcd_epochs_loss(
                Xt, Lg * gmask, w, fmask, bsub, z, tau, lam_, y,
                loss, block_epochs
            )
        return bsub, z, k + 1, reduced_gap(bsub, z)

    bsub, z, k, gap = jax.lax.while_loop(
        cond, body, (bsub0, z0, jnp.zeros((), jnp.int32),
                     jnp.asarray(jnp.inf, dtype))
    )
    delta = (bsub - bsub0) * fmask
    return beta.at[take].add(delta), k, gap


def _gather_static(problem: SGLProblem, group_active):
    """Gather the active groups' design slices into a power-of-two padded
    buffer.  Depends only on the active-group set, so :class:`SolveCaches`
    caches the result between rounds — and between lambdas on a path — (the
    (n x p_active) copy of X is the expensive part); per-round masks are
    applied by the caller.

    Masked/padded groups are *not* zeroed in Xt: ``bcd_epochs`` masks their
    updates (feat_mask, live) so their columns never contribute.
    """
    idx = np.nonzero(np.asarray(group_active))[0]
    Gb = _bucket(max(len(idx), 1))
    pad = Gb - len(idx)
    take = np.concatenate([idx, np.zeros(pad, np.int64)])
    gmask = np.concatenate([np.ones(len(idx)), np.zeros(pad)])

    take_j = jnp.asarray(take)
    Xt = jnp.transpose(jnp.take(problem.X, take_j, axis=1), (1, 0, 2))
    Lg = jnp.take(problem.Lg, take_j)
    w = jnp.take(problem.w, take_j)
    gmask_j = jnp.asarray(gmask, problem.X.dtype)
    return idx, take_j, Xt, Lg, w, gmask_j


# ----------------------------------------------------------------------------
# Outer driver
# ----------------------------------------------------------------------------

def solve(
    problem: SGLProblem,
    lam_: float,
    beta0: Optional[jax.Array] = None,
    tol: float = 1e-8,
    max_epochs: int = 10_000,
    f_ce: int = 10,
    rule="gap",
    lam_max: Optional[float] = None,
    compact: bool = True,
    inner_rounds: int = 5,
    check_every: Optional[int] = None,
    first_round: Optional[tuple] = None,
    caches: Optional[SolveCaches] = None,
    screen_backend: str = "auto",
    solver_backend: str = "auto",
) -> SolveResult:
    """Solve one SGL instance at regularisation ``lam_``.

    .. deprecated::
        Thin wrapper over the session API — loose kwargs map onto
        :class:`repro.core.session.SolverConfig` fields of the same names
        and the solve delegates to
        :meth:`repro.core.session.SGLSession.solve`.  Prefer::

            session = SGLSession(problem, SolverConfig(tol=1e-8))
            res = session.solve(lam_)

        A session additionally keeps a persistent transposed design for the
        Pallas-backed rounds and carries the gather cache across calls.

    ``rule``: a registered :mod:`repro.rules` name ({"gap", "static",
    "dynamic", "dst3", "none", "strong"}) or a
    :class:`repro.rules.ScreeningRule` object.
    ``tol`` is the duality-gap stopping threshold (paper uses 1e-8).
    ``inner_rounds``: how many f_ce-epoch blocks run inside one jitted
    call between certified (full-problem) gap/screening rounds; the inner
    early-exit uses the reduced-problem gap, so safety is unaffected.
    ``check_every``: epochs between reduced-gap early-exit checks inside
    the jitted inner loop (default ``f_ce``, i.e. one check per block; the
    path engine passes 1).  With ``compact=False`` the solver runs plain
    ``f_ce``-epoch blocks and ``inner_rounds``/``check_every`` are ignored.
    ``first_round``: a :class:`RoundResult` from :func:`screen_round`
    evaluated at (``beta0``, ``lam_``), consumed as the first certified
    round.  ``caches``: a :class:`SolveCaches` shared across calls.
    """
    if isinstance(check_every, str):
        raise ValueError(
            "check_every must be an int or None for solve(); "
            "'auto' scheduling exists only on solve_path()"
        )
    from .session import SGLSession, SolverConfig

    warnings.warn(
        "repro.core.solve() is deprecated; use "
        "SGLSession(problem, SolverConfig(...)).solve(lam_)",
        DeprecationWarning, stacklevel=2,
    )
    cfg = SolverConfig(
        tol=tol, max_epochs=max_epochs, f_ce=f_ce, rule=rule,
        compact=compact, inner_rounds=inner_rounds, check_every=check_every,
        screen_backend=screen_backend, solver_backend=solver_backend,
    )
    session = SGLSession(problem, cfg, caches=caches)
    return session.solve(
        lam_, beta0=beta0, first_round=first_round, lam_max=lam_max
    )


# ----------------------------------------------------------------------------
# Static-analysis hooks: expose the jitted entry points to the jaxpr lints
# (repro.analysis.registry is a leaf import — no cycle).  Each name pairs
# with a shape template in repro.analysis.entrypoints.
# ----------------------------------------------------------------------------

from ..analysis.registry import register_traceable  # noqa: E402

register_traceable("screen_round", _screen_round,
                   module=__name__, kind="jit")
register_traceable("screen_round_compact", _screen_round_compact,
                   module=__name__, kind="jit")
register_traceable("inner_rounds", _inner_rounds,
                   module=__name__, kind="jit")
register_traceable("bcd_epochs", bcd_epochs,
                   module=__name__, kind="jit")
register_traceable("inner_rounds_loss", _inner_rounds_loss,
                   module=__name__, kind="jit")
register_traceable("bcd_epochs_loss", bcd_epochs_loss,
                   module=__name__, kind="jit")
