"""Shared helpers for the benchmark harness.

Every benchmark emits rows through ``emit`` so ``benchmarks.run`` can
aggregate a single CSV:  benchmark,case,metric,value
"""
from __future__ import annotations

import time
from typing import Callable

import jax

# The convex-optimization core targets the paper's 1e-8 duality-gap
# tolerance, which needs f64 (same switch the tests flip in conftest.py).
jax.config.update("jax_enable_x64", True)

_ROWS: list[tuple[str, str, str, float]] = []


def emit(bench: str, case: str, metric: str, value) -> None:
    _ROWS.append((bench, case, metric, float(value)))
    print(f"{bench},{case},{metric},{value}")


def rows():
    return list(_ROWS)


def timeit(fn: Callable, *args, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall-clock seconds for ``fn(*args)`` (blocks on jax arrays)."""
    def run():
        out = fn(*args)
        jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        run()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def header() -> None:
    print("benchmark,case,metric,value")
