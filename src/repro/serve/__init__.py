"""repro.serve — multi-tenant path-solve serving layer.

Public surface:

* :class:`PathRequest` / :class:`PathResponse` — the request model;
* :class:`SGLServer` / :class:`ServeConfig` — the serve loop (request
  queue, coalescing, session cache, certificate store, resumable paths);
* :class:`SessionCache`, :class:`CertificateStore`, :class:`RequestQueue`
  — the building blocks, usable standalone;
* :class:`Preempted` — raised into futures when the server drains;
* :class:`Degraded` / :class:`ServeError` / :class:`WorkerCrash`
  (re-exported from :mod:`repro.faults`) — the rest of the typed error
  taxonomy a future can resolve to (README "Fault tolerance &
  degradation").

See the README "Serving" section for the coalescing compatibility rules,
the cache key, and the certificate-reuse safety contract.
"""
from ..faults.errors import Degraded, ServeError, WorkerCrash
from .cache import SessionCache
from .queue import CoalescedGroup, RequestQueue, coalesce
from .server import Preempted, ServeConfig, SGLServer
from .store import CertificateStore, WarmHint, warm_eval
from .types import (
    PathRequest,
    PathResponse,
    array_digest,
    compat_signature,
    design_digest,
    problem_digest,
)

__all__ = [
    "SGLServer",
    "ServeConfig",
    "Preempted",
    "Degraded",
    "ServeError",
    "WorkerCrash",
    "PathRequest",
    "PathResponse",
    "SessionCache",
    "CertificateStore",
    "WarmHint",
    "warm_eval",
    "RequestQueue",
    "CoalescedGroup",
    "coalesce",
    "array_digest",
    "compat_signature",
    "design_digest",
    "problem_digest",
]
