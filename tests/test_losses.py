"""Pluggable data-fidelity Loss strategy: registry/fail-fast wiring,
lsq bit-identity with the pre-loss solver, logistic GAP certificates and
Thm-1 screen-then-verify safety per rule x loss, kernel bit parity, the
multi-task math layer, and the serve-layer loss-identity guards.

The hypothesis property section (conjugate Fenchel-Young, Eq. 15 dual
feasibility, randomized screen-then-verify) is skipped cleanly when
hypothesis is absent, like tests/test_properties.py; everything above it
is deterministic tier-1 coverage.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SGLSession, SolverConfig, make_problem, sgl
from repro.core.solver import bcd_epochs_loss, check_rule_loss
from repro.data.synthetic import make_synthetic
from repro.kernels import ops, ref
from repro.losses import (
    LeastSquaresLoss,
    LogisticLoss,
    MultiTaskLoss,
    available_losses,
    get_loss,
    resolve_loss,
)
from repro.rules import available_rules, get_rule

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _problem(loss="lsq", n=24, p=40, n_groups=8, seed=7, tau=0.3):
    X, y, _, sizes = make_synthetic(n=n, p=p, n_groups=n_groups,
                                    gamma1=3, gamma2=3, seed=seed)
    problem = make_problem(X, y, sizes, tau=tau)
    if loss == "logistic":
        y01 = np.asarray(problem.y) > np.median(np.asarray(problem.y))
        problem = problem._replace(y=jnp.asarray(y01, problem.X.dtype))
    return problem


@pytest.fixture(scope="module")
def prob_lsq():
    return _problem("lsq")


@pytest.fixture(scope="module")
def prob_logistic():
    return _problem("logistic")


# ---------------------------------------------------------------------------
# Registry + fail-fast wiring
# ---------------------------------------------------------------------------

def test_registry_contents_and_resolution():
    assert available_losses() == ["logistic", "lsq", "multitask"]
    assert isinstance(get_loss("lsq"), LeastSquaresLoss)
    assert isinstance(resolve_loss("logistic"), LogisticLoss)
    ll = LogisticLoss()
    assert resolve_loss(ll) is ll
    assert resolve_loss("lsq") == LeastSquaresLoss()  # frozen value object
    assert hash(resolve_loss("lsq")) == hash(LeastSquaresLoss())


def test_unknown_loss_fails_fast_everywhere():
    with pytest.raises(ValueError, match="huber"):
        resolve_loss("huber")
    # ... and already at config construction, listing what IS registered.
    with pytest.raises(ValueError, match="logistic"):
        SolverConfig(loss="huber")


def test_loss_metadata():
    assert resolve_loss("lsq").nu == 1.0
    assert resolve_loss("logistic").nu == 0.25
    assert resolve_loss("multitask").multi_output
    assert not resolve_loss("lsq").multi_output
    # nu must be a Python float: it constant-folds at trace time so the
    # lsq radius graph stays bit-identical to the pre-loss code.
    assert type(resolve_loss("lsq").nu) is float
    assert type(resolve_loss("logistic").nu) is float


def test_cache_token_separates_losses(prob_lsq):
    default = SolverConfig().cache_token()
    explicit = SolverConfig(loss="lsq").cache_token()
    obj = SolverConfig(loss=LeastSquaresLoss()).cache_token()
    logistic = SolverConfig(loss="logistic").cache_token()
    assert default == explicit == obj
    assert logistic != default


def test_rule_x_loss_gate(prob_logistic):
    logistic = resolve_loss("logistic")
    for name in ("static", "dynamic", "dst3"):
        with pytest.raises(ValueError, match="lsq"):
            check_rule_loss(get_rule(name), logistic)
        with pytest.raises(ValueError, match=name):
            SGLSession(prob_logistic,
                       SolverConfig(rule=name, loss="logistic"))
    # The GAP family holds for every nu-smooth loss.
    for name in ("gap", "none", "strong"):
        check_rule_loss(get_rule(name), logistic)


def test_session_rejects_multitask(prob_lsq):
    with pytest.raises(ValueError, match="multi-output"):
        SGLSession(prob_lsq, SolverConfig(loss="multitask"))


def test_mesh_rejects_non_lsq(prob_logistic):
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("b",))
    with pytest.raises(ValueError, match="lsq"):
        SGLSession(prob_logistic, SolverConfig(loss="logistic"), mesh=mesh)


# ---------------------------------------------------------------------------
# lsq bit-identity: the default loss IS the pre-loss solver
# ---------------------------------------------------------------------------

def test_lsq_default_string_object_bit_identical(prob_lsq):
    """Acceptance criterion: default config, loss="lsq" string, and
    LeastSquaresLoss() object produce bit-identical paths — betas,
    epochs, screening counters, and the compact/full round split."""
    runs = []
    for loss in (None, "lsq", LeastSquaresLoss()):
        cfg = SolverConfig(tol=1e-7) if loss is None else \
            SolverConfig(tol=1e-7, loss=loss)
        runs.append(SGLSession(prob_lsq, cfg).solve_path(T=5, delta=2.0))
    a = runs[0]
    for b in runs[1:]:
        np.testing.assert_array_equal(a.betas, b.betas)
        assert (a.epochs == b.epochs).all()
        assert np.array_equal(a.seq_screened, b.seq_screened)
        assert np.array_equal(a.dyn_screened, b.dyn_screened)
        assert (a.n_compact_rounds, a.n_full_rounds) == \
            (b.n_compact_rounds, b.n_full_rounds)


# ---------------------------------------------------------------------------
# Logistic: certificates, lam_max, and the full-rounds-only gating
# ---------------------------------------------------------------------------

def test_logistic_solve_certified(prob_logistic):
    session = SGLSession(prob_logistic, SolverConfig(tol=1e-8,
                                                     loss="logistic"))
    lam = 0.5 * float(session.lam_max)
    res = session.solve(lam)
    assert float(res.gap) <= 1e-8
    # the certified gap is a true duality gap: recompute it from the
    # loss-generalized primal/dual at the Eq. 15 scaled dual point.
    loss = resolve_loss("logistic")
    theta = sgl.dual_scale_loss(prob_logistic, loss, res.beta, lam)
    gap = float(sgl.duality_gap_loss(prob_logistic, loss, res.beta,
                                     theta, lam))
    assert gap >= -1e-12
    assert gap <= 1e-7


def test_logistic_lam_max(prob_logistic):
    """lam_max = Omega^D(X^T (y - 1/2)): beta = 0 is optimal at and
    above it (zero gap at beta = 0), and NOT just below it."""
    loss = resolve_loss("logistic")
    lmax = float(sgl.lambda_max_loss(prob_logistic, loss))
    session = SGLSession(prob_logistic, SolverConfig(tol=1e-9,
                                                     loss="logistic"))
    assert float(session.lam_max) == pytest.approx(lmax, rel=1e-12)
    res = session.solve(1.01 * lmax)
    assert float(jnp.abs(res.beta).max()) == 0.0
    res = session.solve(0.8 * lmax)
    assert float(jnp.abs(res.beta).max()) > 0.0


def test_logistic_path_full_rounds_only(prob_logistic):
    """Non-lsq solves take the certified full-round path only: the
    compact gather/scatter and batched-lambda fast paths are lsq-only."""
    session = SGLSession(prob_logistic, SolverConfig(tol=1e-7,
                                                     loss="logistic"))
    res = session.solve_path(T=5, delta=2.0)
    assert res.n_compact_rounds == 0
    assert res.n_full_rounds > 0
    assert bool(res.certificates_safe)
    assert float(np.max(res.gaps)) <= 1e-7


# ---------------------------------------------------------------------------
# Thm-1 screen-then-verify: every is_safe rule x supported loss
# ---------------------------------------------------------------------------

def _safe_matrix():
    cells = []
    for loss_name in ("lsq", "logistic"):
        for rule_name in available_rules():
            r = get_rule(rule_name)
            if not r.is_safe:
                continue
            if r.supported_losses is not None and \
                    loss_name not in r.supported_losses:
                continue
            cells.append((loss_name, rule_name))
    return cells


@pytest.fixture(scope="module")
def tight_refs(prob_lsq, prob_logistic):
    """Tight-tol unscreened reference paths per loss (the safety oracle),
    solved once and shared across the rule matrix."""
    from repro.core.session import lambda_grid

    refs = {}
    for loss_name, problem in (("lsq", prob_lsq),
                               ("logistic", prob_logistic)):
        session = SGLSession(problem, SolverConfig(
            tol=1e-10, rule="none", loss=loss_name, max_epochs=40_000))
        lambdas = lambda_grid(session.lam_max, T=4, delta=2.0)
        betas, beta = [], jnp.zeros((problem.G, problem.ng),
                                    problem.X.dtype)
        for lam_ in lambdas:
            beta = session.solve(float(lam_), beta0=beta).beta
            betas.append(np.asarray(beta))
        refs[loss_name] = np.stack(betas)
    return refs


@pytest.mark.parametrize("loss_name,rule_name", _safe_matrix())
def test_screen_then_verify_safety(loss_name, rule_name, prob_lsq,
                                   prob_logistic, tight_refs):
    """Thm 1: a variable screened by a safe rule is zero at the optimum —
    checked against the tight-tol unscreened reference, per rule x loss."""
    problem = prob_lsq if loss_name == "lsq" else prob_logistic
    session = SGLSession(problem, SolverConfig(
        tol=1e-6, rule=rule_name, loss=loss_name, max_epochs=20_000))
    res = session.solve_path(T=4, delta=2.0, keep_results=True)
    assert bool(res.certificates_safe)
    beta_ref = tight_refs[loss_name]
    feat_mask = np.asarray(problem.feat_mask).astype(bool)
    for t in range(4):
        screened = ~res.feat_active[t] & feat_mask
        assert (np.abs(beta_ref[t])[screened] <= 1e-7).all(), (
            f"rule={rule_name} loss={loss_name}: screened a variable "
            f"that is nonzero at the optimum (lambda index {t})"
        )


# ---------------------------------------------------------------------------
# Kernel parity: logistic fused mega-kernel == carry reference == XLA
# ---------------------------------------------------------------------------

def _logistic_state(rng, Gb=8, n=20, ng=4, B=1):
    Xt = rng.standard_normal((Gb, n, ng))
    Lg = rng.uniform(0.5, 3.0, Gb)
    Lg[-1] = 0.0                       # one dead (screened/padded) slot
    fm = (rng.random((B, Gb, ng)) < 0.85).astype(float)
    fm[:, -1] = 0.0
    w = np.sqrt(ng) * np.ones(Gb)
    beta = rng.standard_normal((B, Gb, ng)) * fm
    z = np.einsum("gnk,bgk->bn", Xt, beta)
    y = (rng.random(n) < 0.5).astype(float)
    return (jnp.asarray(Xt), jnp.asarray(Lg), jnp.asarray(w),
            jnp.asarray(fm), jnp.asarray(beta), jnp.asarray(z),
            jnp.asarray(y))


def test_logistic_fused_kernel_bit_identical_to_carry(rng):
    """f64 interpret-mode logistic mega-kernel == the lax.scan carry
    reference == the solver's bcd_epochs_loss, bit for bit."""
    Xt, Lg, w, fm, beta, z, y = _logistic_state(rng)
    tau, lam = jnp.asarray(0.3), jnp.asarray(0.4)
    loss = resolve_loss("logistic")
    want_b, want_z = bcd_epochs_loss(Xt, Lg, w, fm[0], beta[0], z[0],
                                     tau, lam, y, loss, 3)
    ref_b, ref_z = ref.bcd_epochs_logistic_ref(Xt, Lg, w, fm, beta, z, y,
                                               tau, jnp.reshape(lam, (1,)),
                                               3)
    got_b, got_z = ops.bcd_epochs_logistic_fused(Xt, Lg, w, fm, beta, z, y,
                                                 tau,
                                                 jnp.reshape(lam, (1,)), 3)
    np.testing.assert_array_equal(np.asarray(ref_b[0]), np.asarray(want_b))
    np.testing.assert_array_equal(np.asarray(ref_z[0]), np.asarray(want_z))
    np.testing.assert_array_equal(np.asarray(got_b[0]), np.asarray(want_b))
    np.testing.assert_array_equal(np.asarray(got_z[0]), np.asarray(want_z))


def test_logistic_session_pallas_reproduces_xla(prob_logistic):
    """Session pin: solver_backend="pallas" (interpret on CPU) reproduces
    the XLA logistic path bit for bit."""
    paths = {}
    for backend in ("xla", "pallas"):
        session = SGLSession(prob_logistic, SolverConfig(
            tol=1e-7, loss="logistic", solver_backend=backend))
        paths[backend] = session.solve_path(T=4, delta=2.0)
    np.testing.assert_array_equal(paths["xla"].betas,
                                  paths["pallas"].betas)
    assert (paths["xla"].epochs == paths["pallas"].epochs).all()


# ---------------------------------------------------------------------------
# Multi-task math layer (arXiv 1506.03736)
# ---------------------------------------------------------------------------

def test_multitask_math_properties(rng):
    n, G, ng, K = 16, 5, 3, 4
    X = jnp.asarray(rng.standard_normal((n, G, ng)))
    Y = jnp.asarray(rng.standard_normal((n, K)))
    w = jnp.ones(G)
    tau = 0.4
    lmax = float(sgl.multitask_lambda_max(X, Y, tau, w))
    assert lmax > 0

    # K=1 reduces to the vector machinery exactly.
    beta1 = jnp.asarray(rng.standard_normal((G, ng, 1)))
    assert float(sgl.multitask_norm(beta1, tau, w)) == pytest.approx(
        float(sgl.sgl_norm(beta1[..., 0], tau, w)), rel=1e-12)
    xi1 = jnp.asarray(rng.standard_normal((G, ng, 1)))
    assert float(sgl.multitask_dual_norm(xi1, tau, w)) == pytest.approx(
        float(sgl.sgl_dual_norm(xi1[..., 0], tau, w)), rel=1e-12)

    # Eq. 15 scaled point is dual-feasible and its gap is nonnegative.
    beta = jnp.asarray(rng.standard_normal((G, ng, K)) * 0.1)
    lam = 0.5 * lmax
    theta = sgl.multitask_dual_scale(X, Y, beta, tau, w, lam)
    corr = jnp.einsum("ngk,nt->gkt", X, theta)
    assert float(sgl.multitask_dual_norm(corr, tau, w)) <= 1 + 1e-10
    gap = float(sgl.multitask_duality_gap(X, Y, beta, theta, tau, w, lam))
    assert gap >= -1e-10

    # At lam >= lam_max, beta = 0 is optimal: zero gap at the scaled point.
    beta0 = jnp.zeros((G, ng, K))
    lam_hi = 1.5 * lmax
    theta0 = sgl.multitask_dual_scale(X, Y, beta0, tau, w, lam_hi)
    gap0 = float(sgl.multitask_duality_gap(X, Y, beta0, theta0, tau, w,
                                           lam_hi))
    assert abs(gap0) <= 1e-9 * max(1.0, float(jnp.sum(Y * Y)))


# ---------------------------------------------------------------------------
# Serve layer: loss identity guards (defense-in-depth)
# ---------------------------------------------------------------------------

def test_certificate_store_rejects_cross_loss_hints(prob_lsq):
    from repro.serve.store import CertificateStore

    cfg = SolverConfig(tol=1e-6)
    session = SGLSession(prob_lsq, cfg)
    res = session.solve_path(T=3, delta=2.0)
    store = CertificateStore(capacity=4)
    store.put("req0", prob_lsq, cfg, res)

    hint = store.warm_hint(prob_lsq, cfg, np.asarray(res.lambdas))
    assert hint is not None
    assert hint.record.loss_token == "LeastSquaresLoss()"

    # A logistic request never sees the lsq record (the design digest
    # already separates losses via the config cache token).
    cfg_log = SolverConfig(tol=1e-6, loss="logistic")
    assert store.warm_hint(prob_lsq, cfg_log,
                           np.asarray(res.lambdas)) is None
    assert store.stats()["loss_rejects"] == 0

    # Defense-in-depth: even if the keying regressed and a record landed
    # under this design with a foreign loss token, it is never served.
    (k, rec), = [(k, r) for k, r in store._records.items()]
    store._records[k] = rec._replace(loss_token="LogisticLoss()")
    assert store.warm_hint(prob_lsq, cfg, np.asarray(res.lambdas)) is None
    assert store.stats()["loss_rejects"] == 1


def test_session_cache_refuses_cross_loss_collision(prob_lsq):
    from repro.serve.cache import SessionCache

    cache = SessionCache(capacity=4)
    cfg = SolverConfig(tol=1e-6)
    sess, hit = cache.get(prob_lsq, cfg)
    assert not hit
    _, hit = cache.get(prob_lsq, cfg)
    assert hit

    # Defense-in-depth: plant a key collision across losses and the
    # cache must refuse to serve the mismatched session.
    cfg_log = SolverConfig(tol=1e-6, loss="logistic")
    cache._sessions[cache.key(prob_lsq, cfg_log)] = sess
    with pytest.raises(RuntimeError, match="collision across losses"):
        cache.get(prob_lsq, cfg_log)
    assert cache.stats()["loss_rejects"] == 1


# ---------------------------------------------------------------------------
# Hypothesis properties (skipped cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st
    import hypothesis.extra.numpy as hnp

    @settings(max_examples=60, deadline=None)
    @given(
        z=hnp.arrays(np.float64, 12,
                     elements=st.floats(-30, 30, allow_nan=False)),
        t=hnp.arrays(np.float64, 12,
                     elements=st.floats(-30, 30, allow_nan=False)),
        ybits=hnp.arrays(np.bool_, 12),
    )
    def test_property_logistic_fenchel_young(z, t, ybits):
        """F(z) + F*(u) >= <u, z> for u in the conjugate domain, with
        equality at u = grad F(z) — the identity every logistic GAP
        certificate rests on."""
        loss = LogisticLoss()
        y = jnp.asarray(ybits, jnp.float64)
        zj = jnp.asarray(z)
        # u = grad F at predictor t: always strictly inside the domain.
        u = jax.nn.sigmoid(jnp.asarray(t)) - y
        F = float(loss.value(y, zj))
        Fstar = float(loss.conjugate(y, u))
        inner = float(jnp.vdot(u, zj))
        assert F + Fstar >= inner - 1e-8 * (1 + abs(inner))
        # Fenchel-Young equality at u = grad F(z):
        ustar = jax.nn.sigmoid(zj) - y
        eq = float(loss.value(y, zj) + loss.conjugate(y, ustar)
                   - jnp.vdot(ustar, zj))
        assert abs(eq) <= 1e-7 * (1 + F)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        lam_frac=st.floats(0.05, 2.0),
        scale=st.floats(0.0, 2.0),
    )
    def test_property_logistic_dual_scaling_feasible(seed, lam_frac,
                                                     scale):
        """The Eq. 15 scaled dual point is feasible (Omega^D <= 1) and
        yields a finite, nonnegative gap at EVERY primal point — the
        dynamic-screening precondition."""
        problem = _problem("logistic", n=16, p=20, n_groups=4, seed=seed)
        loss = LogisticLoss()
        rng_ = np.random.default_rng(seed)
        beta = jnp.asarray(
            scale * rng_.standard_normal((problem.G, problem.ng)))
        lam = lam_frac * float(sgl.lambda_max_loss(problem, loss))
        theta = sgl.dual_scale_loss(problem, loss, beta, lam)
        corr = jnp.einsum("ngk,n->gk", problem.X, theta)
        assert float(sgl.sgl_dual_norm(corr, problem.tau,
                                       problem.w)) <= 1 + 1e-10
        gap = float(sgl.duality_gap_loss(problem, loss, beta, theta, lam))
        assert np.isfinite(gap)
        assert gap >= -1e-10

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), loss_name=st.sampled_from(
        ["lsq", "logistic"]))
    def test_property_screen_then_verify(seed, loss_name):
        """Randomized Thm-1 audit: the GAP rule's screened set on a
        random problem is zero at a tight-tol unscreened optimum."""
        problem = _problem(loss_name, n=16, p=24, n_groups=6, seed=seed)
        session = SGLSession(problem, SolverConfig(
            tol=1e-6, loss=loss_name, max_epochs=20_000))
        lam = 0.4 * float(session.lam_max)
        res = session.solve(lam)
        ref = SGLSession(problem, SolverConfig(
            tol=1e-10, rule="none", loss=loss_name, max_epochs=40_000))
        beta_ref = np.asarray(ref.solve(lam).beta)
        feat_mask = np.asarray(problem.feat_mask).astype(bool)
        screened = ~np.asarray(res.feat_active) & feat_mask
        assert (np.abs(beta_ref)[screened] <= 1e-7).all()
