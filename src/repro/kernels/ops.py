"""Jitted dispatch wrappers for the Pallas kernels.

Handles padding to TPU-aligned block shapes and exposes the kernels with the
grouped-layout signatures the solver uses.  Interpret-vs-compile policy lives
in kernels/_util.py (the kernel entry points default to it).
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from ..analysis.registry import register_kernel_audit
from ..obs import metrics as obs_metrics
from .bcd_epoch import (
    bcd_epoch_launch_spec,
    bcd_epoch_logistic_launch_spec,
    bcd_epoch_logistic_pallas,
    bcd_epoch_pallas,
)
from .dual_norm import dual_norm_launch_spec, dual_norm_pallas
from .screening_scores import (
    screening_corr_launch_spec,
    screening_corr_pallas,
    screening_scores_launch_spec,
    screening_scores_pallas,
)
from .sgl_prox import sgl_prox_launch_spec, sgl_prox_pallas


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("tau", "lam", "block_g"))
def sgl_prox(beta, step, w, tau: float, lam: float, block_g: int = 256):
    """Fused two-level prox; beta (G, ng), step/w (G,). Any G, ng."""
    G, ng = beta.shape
    bg = min(block_g, max(8, G))
    b = _pad_to(beta, 0, bg)
    s = _pad_to(step, 0, bg, value=1.0)
    ww = _pad_to(w, 0, bg, value=1.0)
    out = sgl_prox_pallas(b, s, ww, tau, lam, block_g=bg)
    return out[:G]


@functools.partial(jax.jit, static_argnames=("n_iter", "block_g"))
def dual_norm_groups(x, alpha, R, n_iter: int = 64, block_g: int = 256):
    """Per-group Lambda(x_g, alpha_g, R_g); x (G, ng), alpha/R (G,) -> (G,)."""
    G, ng = x.shape
    bg = min(block_g, max(8, G))
    xp = _pad_to(x, 0, bg)
    ap = _pad_to(alpha, 0, bg, value=1.0)
    Rp = _pad_to(R, 0, bg, value=1.0)
    out = dual_norm_pallas(xp, ap, Rp, n_iter=n_iter, block_g=bg)
    return out[:G]


def _corr_blocks(p: int, n: int, block_p: int = 256, block_n: int = 128):
    """Block shapes the correlation kernels tile (p, n) with — shared by the
    on-the-fly pad path and :func:`prepare_transposed` so a persistent
    transposed design is always laid out exactly as the kernel expects."""
    return min(block_p, max(8, p)), min(block_n, max(8, n))


@functools.partial(jax.jit, static_argnames=("tau", "block_p", "block_n"))
def screening_scores(Xt, theta, tau: float, block_p: int = 256,
                     block_n: int = 128):
    """Fused corr = X^T theta and S_tau(corr)^2; Xt (p, n), theta (n,)."""
    p, n = Xt.shape
    bp, bn = _corr_blocks(p, n, block_p, block_n)
    Xp = _pad_to(_pad_to(Xt, 0, bp), 1, bn)
    tp = _pad_to(theta, 0, bn)
    corr, st2 = screening_scores_pallas(
        Xp, tp, tau, block_p=bp, block_n=bn
    )
    return corr[:p], st2[:p]


@functools.partial(jax.jit, static_argnames=("block_p", "block_n"))
def screening_corr(Xt, theta, block_p: int = 256, block_n: int = 128):
    """Corr-only Pallas matvec: Xt (p, n), theta (n,) -> (p,).

    Unlike :func:`screening_scores` there is no S_tau(corr)^2 output — this
    is the right entry point for the certified gap round, whose correlation
    is rescaled by the (corr-dependent) dual scale before any thresholding.
    ``Xt`` may be pre-padded to the kernel blocks (see
    :func:`prepare_transposed`); padding rows/cols are zero and inert.
    """
    p, n = Xt.shape
    bp, bn = _corr_blocks(p, n, block_p, block_n)
    Xp = _pad_to(_pad_to(Xt, 0, bp), 1, bn)
    tp = _pad_to(theta, 0, bn)
    corr = screening_corr_pallas(Xp, tp, block_p=bp, block_n=bn)
    return corr[:p]


@functools.partial(jax.jit, static_argnames=("block_p", "block_n"))
def screening_corr_batched(Xt, thetas, block_p: int = 256,
                           block_n: int = 128):
    """Batch-vmapped corr-only Pallas matvec: Xt (p, n), thetas (B, n)
    -> (B, p).

    One padded design shared by the whole batch; the kernel is lifted over
    the batch axis with ``jax.vmap`` (Pallas batching rule: a leading grid
    dimension), so every lambda of a batched-lambda run pays the same
    tiled kernel as the per-lambda drivers instead of falling back to an
    XLA einsum (the ``_batch_reduced_gaps`` PR 4 leftover).  Per-row
    results are bit-identical to :func:`screening_corr` on the same
    ``Xt`` — the row kernel is the SAME program, just batched.
    """
    p, n = Xt.shape
    bp, bn = _corr_blocks(p, n, block_p, block_n)
    Xp = _pad_to(_pad_to(Xt, 0, bp), 1, bn)
    tp = _pad_to(thetas, 1, bn)
    corr = jax.vmap(
        lambda v: screening_corr_pallas(Xp, v, block_p=bp, block_n=bn)
    )(tp)
    return corr[:, :p]


def prepare_transposed(X: jax.Array) -> jax.Array:
    """Materialise the (p, n) transposed design ONCE, padded to the
    correlation-kernel blocks.

    X (n, G, ng) grouped design -> (p_pad, n_pad) array suitable as the
    ``xt_pre`` argument of :func:`screening_corr_grouped`.  The Pallas
    correlation kernels need the feature-major layout; without a persistent
    copy, every certified screening round's ``X.reshape(n, p).T`` forces XLA
    to materialise a fresh (p, n) transpose per call (ROADMAP perf item).
    An :class:`repro.core.session.SGLSession` builds this once and reuses it
    across every round of a whole lambda path.
    """
    n, G, ng = X.shape
    p = G * ng
    bp, bn = _corr_blocks(p, n)
    Xt = X.reshape(n, p).T
    return _pad_to(_pad_to(Xt, 0, bp), 1, bn)


# Audit hook: number of times (jit traces for jitted callers, eager calls
# otherwise) an on-the-fly (p, n) transposed copy of X was materialised
# because no persistent design was supplied.  A session-driven path must
# leave this untouched — if the xt_pre wiring ever regressed, the first
# certified round would build a transposing trace and move this counter,
# which is exactly what tests/benchmarks watch for.  Each such trace
# re-executes its transpose on every call, so any nonzero delta means
# per-round copies are back.  Every fallback path that builds the transpose
# must go through :func:`transposed_design` (or bump the counter itself) so
# the audit cannot under-report.
#
# Since PR 10 the three audit counters are typed repro.obs Counters on the
# global metrics registry; everything below (count accessors, note_* hooks,
# audit_scope) is the stable back-compat surface over them.
_M_TRANSPOSE = obs_metrics.REGISTRY.counter(
    "kernels.transpose_traces",
    help="On-the-fly (p, n) transposed design copies (should stay 0 on "
         "session-driven paths; see kernels.ops.transposed_design)")

# Companion audit counter: jit retraces observed by the analysis harness
# (repro.analysis.jaxpr_lints.retrace_harness) — a registered entry point
# compiled TWICE for dtype-identical inputs (weak-type literal splits, an
# unhashable static argument, shape-dependent python branching...).  Like
# the transpose counter it only ever moves when the hazard is real.
_M_RETRACE = obs_metrics.REGISTRY.counter(
    "kernels.retraces",
    help="Observed jit retraces for dtype-identical inputs (retrace "
         "harness + SessionCache.watch_retraces)")

# Kernel demotions: a Pallas launch failed and the caller fell back to the
# XLA/lax.scan reference path for that dispatch.  Bit-parity between the
# backends keeps results identical, but a demotion trades the fused
# kernel's throughput for the reference path's — the fused-launch audit
# surfaces the count so a degraded serving node is visible, not silent.
_M_DEMOTION = obs_metrics.REGISTRY.counter(
    "kernels.demotions",
    help="Pallas launches demoted to the XLA/lax.scan reference path "
         "after a launch failure (bit-identical, slower)")

_AUDIT_METRICS = ("kernels.transpose_traces", "kernels.retraces",
                  "kernels.demotions")


def transpose_trace_count() -> int:
    return _M_TRANSPOSE.value


def retrace_count() -> int:
    return _M_RETRACE.value


def note_retrace(n: int = 1) -> None:
    """Record ``n`` observed jit retraces (analysis harness hook)."""
    _M_RETRACE.inc(int(n))


def kernel_demotion_count() -> int:
    return _M_DEMOTION.value


def note_kernel_demotion(n: int = 1) -> None:
    """Record ``n`` pallas→reference fallbacks after failed launches."""
    _M_DEMOTION.inc(int(n))


class AuditCounters:
    """Live view of the audit counters inside an :func:`audit_scope`.

    While the scope is open the properties read the registry counters
    (which the scope zeroed on entry); on exit the final values are frozen
    onto the instance so assertions after the ``with`` block keep working.
    """

    __slots__ = ("_frozen", "_transpose", "_retrace", "_demotions")

    def __init__(self) -> None:
        self._frozen = False
        self._transpose = 0
        self._retrace = 0
        self._demotions = 0

    @property
    def transpose_traces(self) -> int:
        return self._transpose if self._frozen else _M_TRANSPOSE.value

    @property
    def retraces(self) -> int:
        return self._retrace if self._frozen else _M_RETRACE.value

    @property
    def kernel_demotions(self) -> int:
        return self._demotions if self._frozen else _M_DEMOTION.value

    def _freeze(self) -> None:
        self._transpose = _M_TRANSPOSE.value
        self._retrace = _M_RETRACE.value
        self._demotions = _M_DEMOTION.value
        self._frozen = True


@contextlib.contextmanager
def audit_scope():
    """Exception-safe, test-isolated window onto the audit counters.

    A thin veneer over ``obs.metrics.REGISTRY.scope`` (which generalized
    this idiom in PR 10): zeroes the audit counters on entry and restores
    the surrounding values on exit (try/finally — an assertion failure
    inside the scope cannot leak state into the next test), yielding an
    :class:`AuditCounters` whose ``transpose_traces`` / ``retraces`` read
    the in-scope deltas::

        with kops.audit_scope() as audit:
            session.solve_path(...)
        assert audit.transpose_traces == 0

    Counter bumps observed inside the scope are intentionally NOT
    propagated to the outer scope: a scope is a measurement boundary, and
    an enclosing baseline must not see another test's traffic.
    """
    counters = AuditCounters()
    with obs_metrics.REGISTRY.scope(_AUDIT_METRICS):
        try:
            yield counters
        finally:
            counters._freeze()


def transposed_design(X: jax.Array) -> jax.Array:
    """On-the-fly (p, n) transposed copy of a grouped design — COUNTED.

    The counted fallback twin of :func:`prepare_transposed` (which builds
    the persistent copy once per session and intentionally does NOT count).
    ``screening.screen(backend="pallas")`` with ``xt_pre=None`` used to
    build this reshape inline and bypass the audit, leaving a
    session-wiring regression on that path invisible.
    """
    _M_TRANSPOSE.inc()
    n, G, ng = X.shape
    return X.reshape(n, G * ng).T


def screening_corr_grouped(X: jax.Array, v: jax.Array,
                           xt_pre: jax.Array | None = None) -> jax.Array:
    """Grouped correlation X^T v via the corr-only Pallas matvec kernel.

    X (n, G, ng) zero-padded grouped design, v (n,) -> (G, ng).  Padded
    feature columns are zero in X, so their correlations come out zero and
    stay inert downstream — same contract as the einsum path.  This is the
    hot half of the solver's certified screening round (solver.screen_round
    with backend="pallas").

    ``xt_pre``: persistent transposed design from :func:`prepare_transposed`.
    When given, the kernel consumes it directly and the per-call (p, n)
    transposed copy of X is eliminated; when None, the transpose is
    materialised on the fly (legacy behavior, counted by the audit).
    """
    n, G, ng = X.shape
    p = G * ng
    Xt = transposed_design(X) if xt_pre is None else xt_pre
    corr = screening_corr(Xt, v)
    return corr[:p].reshape(G, ng)


def gather_transposed_rows(xt_pre: jax.Array, take, ng: int) -> jax.Array:
    """Active-row slice of the persistent transposed design for the
    compacted certified round.

    ``take``: (Gb,) active-group indices from the solver's gather (padded
    slots alias group 0 — their duplicated correlations are masked by the
    caller's ``gmask``).  Row ``take[i]*ng + k`` of ``xt_pre`` is feature k
    of the i-th gathered group, so the slice is the (p_active, n) layout the
    corr kernel wants, re-padded to its block shape.  This is a gather (one
    (p_active, n) copy), NOT a transpose — it is keyed on the active set by
    :class:`repro.core.solver.SolveCaches` exactly like the BCD gather
    buffers, so it is rebuilt only when the certified active set shrinks.
    """
    take = jnp.asarray(take)
    rows = (take[:, None] * ng + jnp.arange(ng)[None, :]).reshape(-1)
    sl = jnp.take(xt_pre, rows, axis=0)
    bp, _ = _corr_blocks(sl.shape[0], xt_pre.shape[1])
    return _pad_to(sl, 0, bp)


def sgl_dual_norm_terms_fused(corr_grouped, tau, w, n_iter: int = 64):
    """Per-group Omega^D terms via the Pallas bisection kernel (drop-in for
    sgl.sgl_dual_norm_terms; the compact round caches these per group)."""
    from repro.core.sgl import epsilons, group_weight_total

    eps = epsilons(tau, w)
    scale = group_weight_total(tau, w)
    per_group = dual_norm_groups(corr_grouped, 1.0 - eps, eps, n_iter=n_iter)
    return per_group / scale


def sgl_dual_norm_fused(corr_grouped, tau, w, n_iter: int = 64):
    """Omega^D via the Pallas bisection kernel (drop-in for sgl.sgl_dual_norm)."""
    return jnp.max(sgl_dual_norm_terms_fused(corr_grouped, tau, w,
                                             n_iter=n_iter))


@functools.partial(jax.jit, static_argnames=("n_epochs", "block_g"))
def bcd_epochs_fused(Xt, Lg, w, fmask, beta, resid, tau, lam_b,
                     n_epochs: int, block_g: int = 8):
    """Whole blocks of cyclic BCD epochs in ONE fused kernel launch.

    Batched-lambda drop-in for a per-lambda loop over
    :func:`repro.core.solver.bcd_epochs`: ``Xt (Gb, n, ng)`` / ``Lg`` / ``w``
    are the shared compacted buffers, while ``fmask (B, Gb, ng)``,
    ``beta (B, Gb, ng)``, ``resid (B, n)`` and ``lam_b (B,)`` carry one row
    per lambda (B = 1 for a plain single-lambda solve).  The residual and
    coefficient block stay VMEM-resident across all ``n_epochs`` passes and
    the design streams tile-by-tile — see :mod:`repro.kernels.bcd_epoch`
    for the kernel and its bit-parity contract with the ``lax.scan``
    reference.

    The group axis is padded to a ``block_g`` multiple with inert rows
    (``Lg = 0``, zero masks), which leave both outputs bit-unchanged; in
    interpret mode nothing else is padded so parity tests see the exact
    reference shapes.
    """
    B, Gb, ng = beta.shape
    if n_epochs <= 0:
        return beta, resid
    bg = max(1, min(block_g, Gb))
    Xp = _pad_to(Xt, 0, bg)
    Lp = _pad_to(Lg, 0, bg)                      # pad 0.0 -> inert groups
    wp = _pad_to(w, 0, bg, value=1.0)
    fp = _pad_to(fmask, 1, bg)
    bp = _pad_to(beta, 1, bg)
    beta_out, resid_out = bcd_epoch_pallas(
        Xp, Lp, wp, fp, lam_b, tau, bp, resid, n_epochs, block_g=bg
    )
    return beta_out[:, :Gb], resid_out


@functools.partial(jax.jit, static_argnames=("n_epochs", "block_g"))
def bcd_epochs_logistic_fused(Xt, Lg, w, fmask, beta, z, y, tau, lam_b,
                              n_epochs: int, block_g: int = 8):
    """Logistic twin of :func:`bcd_epochs_fused`: whole blocks of majorized
    cyclic BCD epochs in one fused launch, with the linear predictor
    ``z (B, n)`` as the VMEM carry and the {0,1} labels ``y (n,)`` as one
    extra batch-invariant input.  Same group-axis padding contract (inert
    ``Lg = 0`` rows leave both outputs bit-unchanged); bit-parity reference
    is :func:`repro.core.solver.bcd_epochs_loss` with ``LogisticLoss``
    (asserted by tests/test_losses.py in f64 interpret mode).
    """
    B, Gb, ng = beta.shape
    if n_epochs <= 0:
        return beta, z
    bg = max(1, min(block_g, Gb))
    Xp = _pad_to(Xt, 0, bg)
    Lp = _pad_to(Lg, 0, bg)                      # pad 0.0 -> inert groups
    wp = _pad_to(w, 0, bg, value=1.0)
    fp = _pad_to(fmask, 1, bg)
    bp = _pad_to(beta, 1, bg)
    beta_out, z_out = bcd_epoch_logistic_pallas(
        Xp, Lp, wp, fp, lam_b, tau, y, bp, z, n_epochs, block_g=bg
    )
    return beta_out[:, :Gb], z_out


def sgl_prox_batched(beta, lam_b, L, w, tau: float, block_g: int = 256):
    """Two-level prox over a batched-lambda state (B, G, ng).

    Each (b, g) row is an independent prox at threshold lam_b / L — exactly
    the per-row layout ``sgl_prox_pallas`` tiles, so the batched case
    reuses the same kernel on the flattened (B*G, ng) view. This is the
    prox step of the batched-lambda FISTA kernel (EXPERIMENTS.md §Perf,
    sgl-paper iterations 3-4).
    """
    B, G, ng = beta.shape
    flat = beta.reshape(B * G, ng)
    step = jnp.broadcast_to((lam_b / L)[:, None], (B, G)).reshape(-1)
    w_flat = jnp.broadcast_to(w[None, :], (B, G)).reshape(-1)
    bg = min(block_g, max(8, B * G))
    b = _pad_to(flat, 0, bg)
    s = _pad_to(step, 0, bg, value=1.0)
    ww = _pad_to(w_flat, 0, bg, value=1.0)
    out = sgl_prox_pallas(b, s, ww, tau, 1.0, block_g=bg)
    return out[: B * G].reshape(B, G, ng)


# ---------------------------------------------------------------------------
# Static-analysis registration: every kernel this module dispatches exposes
# its launch geometry to repro.analysis.pallas_audit through representative
# configs.  The builders return the SAME LaunchSpec objects the pallas_call
# wrappers execute from (see kernels/_util.py), so what the auditor checks
# is what runs.  Configs mirror the shapes the solver actually produces:
# the BCD mega-kernel's docstring bucket, the default _corr_blocks tiling,
# and the paper's group size ng = 8 (configs/sgl_paper.py).
# ---------------------------------------------------------------------------

register_kernel_audit(
    "bcd_epoch/bucket",
    lambda: bcd_epoch_launch_spec(B=4, Gb=256, n=1024, ng=16, n_epochs=3,
                                  block_g=8, dtype="float64"),
)
register_kernel_audit(
    "bcd_epoch/paper-ng8",
    lambda: bcd_epoch_launch_spec(B=1, Gb=64, n=2048, ng=8, n_epochs=2,
                                  block_g=8, dtype="float64"),
)
register_kernel_audit(
    "bcd_epoch_logistic/bucket",
    lambda: bcd_epoch_logistic_launch_spec(B=4, Gb=256, n=1024, ng=16,
                                           n_epochs=3, block_g=8,
                                           dtype="float64"),
)
register_kernel_audit(
    "screening_scores/default",
    lambda: screening_scores_launch_spec(p=4096, n=1024, block_p=256,
                                         block_n=128, dtype="float64"),
)
register_kernel_audit(
    "screening_corr/default",
    lambda: screening_corr_launch_spec(p=4096, n=1024, block_p=256,
                                       block_n=128, dtype="float64"),
)
register_kernel_audit(
    "dual_norm/paper-ng8",
    lambda: dual_norm_launch_spec(G=4096, ng=8, block_g=256,
                                  dtype="float64"),
)
register_kernel_audit(
    "sgl_prox/paper-ng8",
    lambda: sgl_prox_launch_spec(G=4096, ng=8, block_g=256,
                                 dtype="float64"),
)
