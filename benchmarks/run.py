"""Benchmark harness entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # reduced sizes
    PYTHONPATH=src python -m benchmarks.run --full     # paper dimensions

Emits a consolidated CSV (benchmark,case,metric,value) on stdout and writes
it to artifacts/bench_results.csv.
"""
from __future__ import annotations

import argparse
import os
import time

from .common import header, rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dimensions (slow on CPU)")
    ap.add_argument("--only", nargs="*",
                    choices=["dual_norm", "screening", "active_sets",
                             "path", "kernels"],
                    help="run a subset")
    args = ap.parse_args()
    only = set(args.only or
               ["dual_norm", "screening", "active_sets", "path", "kernels"])

    header()
    t0 = time.time()

    if "dual_norm" in only:
        from . import bench_dual_norm
        bench_dual_norm.main()
    if "kernels" in only:
        from . import bench_kernels
        bench_kernels.main()
        bench_kernels.bcd_epoch_case()
    if "active_sets" in only:
        from . import bench_active_sets
        bench_active_sets.main()
    if "screening" in only:
        from . import bench_screening
        bench_screening.main(full=args.full)
    if "path" in only:
        from . import bench_path
        if args.full:
            bench_path.main(n=814, n_lon=144, n_lat=73, T=100)
        else:
            bench_path.main()
        bench_path.pallas_case()

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench_results.csv", "w") as f:
        f.write("benchmark,case,metric,value\n")
        for b, c, m, v in rows():
            f.write(f"{b},{c},{m},{v}\n")
    print(f"# total {time.time() - t0:.1f}s; "
          f"wrote artifacts/bench_results.csv ({len(rows())} rows)")


if __name__ == "__main__":
    main()
