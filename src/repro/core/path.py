"""Sequential-screening lambda-path engine (paper Section 7.1, Fig. 2/3).

lambda_t = lambda_max * 10^(-delta * t / (T - 1)),  t = 0..T-1
(default delta = 3, T = 100, matching GLMNET practice cited by the paper).

The paper's headline wall-clock result comes from the *warm-started path*,
where the GAP safe rule screens both **sequentially** and **dynamically**.
This engine threads state across the grid instead of treating each lambda as
an independent solve:

1. **Sequential GAP screening** — before the first epoch at ``lambda_t`` a
   certified :func:`repro.core.solver.screen_round` is evaluated at the new
   lambda with the previous lambda's ``beta_{t-1}`` (residual-rescaled dual
   point, Eq. 15 + Thm 2).  Groups failing the Theorem-1 test are discarded
   with **zero BCD work**; if the warm-started gap is already below ``tol``
   the lambda costs zero epochs outright.  The round is handed to
   :func:`solve` as ``first_round`` so it is never recomputed.
2. **Active warm start + cache carrying** — one
   :class:`repro.core.solver.SolveCaches` instance is passed down the whole
   path, so the compacted (n x p_active) gather of the design matrix is
   reused whenever consecutive lambdas certify the same active set, and XLA
   recompiles only when the power-of-two bucket actually changes
   (< log2(G) times for the whole path, not per lambda).
3. **Sequential-gap-adaptive work schedule** — the sequential round's gap
   is known *before* any BCD work at the new lambda, so the engine picks
   the inner early-exit granularity from it: warm lambdas (gap within
   ``1e3 * tol``) check the reduced gap after every epoch and stop after
   exactly the passes they need, cold lambdas keep the cheap ``f_ce``-block
   cadence so the extra per-epoch gap evaluations never slow the hard tail.
4. **Pallas-backed rounds** — the certified rounds' X^T resid correlation
   and SGL dual norm route through the fused Pallas kernels on TPU
   (``screen_backend="auto"``).

``sequential=False, check_every=None`` reproduces the legacy per-instance
loop exactly (used by ``benchmarks/bench_path.py`` as the baseline).

:class:`PathResult` is dense — one (T, G, ng) coefficient array plus per-
lambda gap/epoch/active/screen-counter vectors — directly consumable by the
benchmarks.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import numpy as np
import jax.numpy as jnp

from . import sgl
from .sgl import SGLProblem
from .solver import SolveCaches, screen_round, solve

__all__ = ["lambda_grid", "PathResult", "solve_path"]


def lambda_grid(lam_max: float, T: int = 100, delta: float = 3.0) -> np.ndarray:
    t = np.arange(T)
    return lam_max * 10.0 ** (-delta * t / max(T - 1, 1))


class PathResult(NamedTuple):
    """Dense path outputs; leading axis is the lambda grid (length T)."""

    lambdas: np.ndarray            # (T,)
    betas: np.ndarray              # (T, G, ng) coefficients
    gaps: np.ndarray               # (T,) final certified duality gaps
    epochs: np.ndarray             # (T,) int, BCD passes per lambda
    group_active_frac: np.ndarray  # (T,)
    feat_active_frac: np.ndarray   # (T,)
    group_active: np.ndarray       # (T, G) bool, certified active masks
                                   #   (solver-final intersected with the
                                   #   sequential certificate).  False is a
                                   #   certificate of zero at the optimum,
                                   #   NOT a support indicator of betas[t]:
                                   #   a lambda converged on its sequential
                                   #   round keeps beta un-zeroed there.
    feat_active: np.ndarray        # (T, G, ng) bool, same semantics
    seq_screened: np.ndarray       # (T,) int, groups the sequential round
                                   #   certified inactive before any epoch
    dyn_screened: np.ndarray       # (T,) int, further groups screened out
                                   #   during the solve (dynamic rule)
    n_gathers: int                 # design re-gathers across the whole path
    results: list                  # per-lambda SolveResult (keep_results=True)


def solve_path(
    problem: SGLProblem,
    lambdas: Optional[Sequence[float]] = None,
    T: int = 100,
    delta: float = 3.0,
    tol: float = 1e-8,
    max_epochs: int = 10_000,
    f_ce: int = 10,
    rule: str = "gap",
    compact: bool = True,
    inner_rounds: int = 5,
    check_every: Union[int, None, str] = "auto",
    sequential: bool = True,
    screen_backend: str = "auto",
    keep_results: bool = False,
    warm_gap_factor: float = 1e3,
) -> PathResult:
    """Solve the whole lambda path with sequential + dynamic screening.

    ``compact`` / ``inner_rounds`` / ``check_every`` are forwarded to
    :func:`solve` for every grid point.  ``check_every="auto"`` schedules
    from the sequential certificate: a lambda whose warm-start gap is
    already within ``warm_gap_factor * tol`` runs with per-epoch early-exit
    checks (it will stop within a handful of passes), everything else keeps
    the ``f_ce``-block cadence.  ``sequential=False`` together with
    ``check_every=None`` reproduces the legacy naive loop (fresh caches and
    no pre-solve screening per lambda).
    """
    lam_max = float(sgl.lambda_max(problem))
    if lambdas is None:
        lambdas = lambda_grid(lam_max, T=T, delta=delta)
    lambdas = np.asarray(lambdas, float)
    T_ = len(lambdas)

    G, ng = problem.G, problem.ng
    dtype = problem.X.dtype
    n_feat = int(np.asarray(problem.feat_mask).sum())
    n_groups = int(np.asarray(jnp.any(problem.feat_mask, axis=-1)).sum())

    # One cache for the whole path: the gather (and its jit cache) survives
    # across lambdas whose certified active set is unchanged.  The naive
    # mode gets a fresh cache per lambda (seed behavior) but still totals
    # its gather count for the benchmark comparison.
    caches = SolveCaches() if sequential else None
    n_gathers_total = 0

    beta = jnp.zeros((G, ng), dtype)
    betas = np.zeros((T_, G, ng), np.dtype(dtype))  # problem dtype, no up-cast
    gaps = np.zeros(T_, float)
    epochs = np.zeros(T_, np.int64)
    gfrac = np.zeros(T_, float)
    ffrac = np.zeros(T_, float)
    g_act = np.zeros((T_, G), bool)
    f_act = np.zeros((T_, G, ng), bool)
    seq_scr = np.zeros(T_, np.int64)
    dyn_scr = np.zeros(T_, np.int64)
    results: list = []

    screening_rule = rule in ("gap", "dynamic", "dst3")
    for t, lam_ in enumerate(lambdas):
        first_round = None
        n_seq_active = n_groups
        if sequential and rule != "static":
            # Sequential rule: certified round at the NEW lambda from the
            # PREVIOUS lambda's primal point, before any epoch here.  The
            # static rule is excluded: solve() applies its up-front static
            # screen to beta before any round, which would invalidate an
            # injected certificate evaluated at the un-masked warm start.
            first_round = screen_round(
                problem, beta, float(lam_), lam_max, rule=rule,
                backend=screen_backend,
            )
            if screening_rule:
                n_seq_active = int(np.asarray(first_round[2]).sum())
                seq_scr[t] = n_groups - n_seq_active

        if check_every == "auto":
            # Warm lambdas finish in a handful of passes, so per-epoch
            # early-exit checks beat the f_ce-block floor; cold lambdas keep
            # the cheap block cadence.  Warmness is read off the sequential
            # certificate (gap already near tol), or predicted from the path
            # itself: the previous lambda's epoch count, when positive and
            # within four f_ce-blocks, marks a warm region (warmness varies
            # smoothly along a geometric grid).  A zero count (lambda_max,
            # or a user grid jumping far from the last point) carries no
            # signal and must not force per-epoch checks on a cold lambda.
            warm = (first_round is not None
                    and float(first_round[0]) <= warm_gap_factor * tol)
            warm |= t > 0 and 0 < epochs[t - 1] <= 4 * f_ce
            check_t = 1 if warm else None
        else:
            check_t = check_every

        lam_caches = caches if caches is not None else SolveCaches()
        res = solve(
            problem,
            float(lam_),
            beta0=beta,
            tol=tol,
            max_epochs=max_epochs,
            f_ce=f_ce,
            rule=rule,
            lam_max=lam_max,
            compact=compact,
            inner_rounds=inner_rounds,
            check_every=check_t,
            first_round=first_round,
            caches=lam_caches,
            screen_backend=screen_backend,
        )
        beta = res.beta
        if caches is None:
            n_gathers_total += lam_caches.n_gathers

        betas[t] = np.asarray(res.beta)
        gaps[t] = float(res.gap)
        epochs[t] = res.n_epochs
        g_act[t] = np.asarray(res.group_active)
        f_act[t] = np.asarray(res.feat_active)
        if first_round is not None and screening_rule:
            if np.dtype(dtype).itemsize >= 8:
                # Report the sequential certificate even when solve converged
                # on that very round without applying it (beta is untouched —
                # only the REPORTED masks reflect the certificate; see the
                # converged-round note in solve()).  For lambdas where solve
                # did apply screens this intersection is a no-op (final masks
                # are already subsets).  Without it, Fig 2a/2b-style outputs
                # read 1.0 active exactly at the lambdas screening handled
                # outright.
                g_act[t] &= np.asarray(first_round[2])
                f_act[t] &= np.asarray(first_round[3]) & g_act[t][:, None]
            elif res.n_epochs == 0:
                # In low precision the converged gap's cancellation error can
                # undershoot the GAP radius enough to mis-certify borderline
                # groups, so the certificate is neither applied nor reported
                # — zero the counter too, keeping counters and masks
                # consistent (all-active, nothing discarded).
                seq_scr[t] = 0
                n_seq_active = n_groups
        gfrac[t] = g_act[t].sum() / max(n_groups, 1)
        ffrac[t] = f_act[t].sum() / max(n_feat, 1)
        if screening_rule:
            # g_act already includes the sequential certificate, so this is
            # non-negative; max() guards rounding of future refactors only.
            dyn_scr[t] = max(0, n_seq_active - int(g_act[t].sum()))
        if keep_results:
            results.append(res)

    return PathResult(
        lambdas=lambdas,
        betas=betas,
        gaps=gaps,
        epochs=epochs,
        group_active_frac=gfrac,
        feat_active_frac=ffrac,
        group_active=g_act,
        feat_active=f_act,
        seq_screened=seq_scr,
        dyn_screened=dyn_scr,
        n_gathers=caches.n_gathers if caches is not None else n_gathers_total,
        results=results,
    )
