"""Solver correctness: optimality conditions, reference agreement, warm starts.

Hypothesis-based property tests live in test_properties.py; path-engine
tests (sequential screening, cache carrying) in test_path.py.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    lambda_max,
    make_problem,
    primal,
    sgl_prox,
    solve,
    solve_path,
    lambda_grid,
)
from repro.data import make_climate_like, make_synthetic


def prox_grad_reference(X, y, sizes, tau, lam_, w=None, iters=30_000):
    """Plain full-gradient ISTA in numpy — an independent oracle."""
    n, p = X.shape
    ng = sizes[0]
    G = len(sizes)
    w = np.sqrt(ng) * np.ones(G) if w is None else w
    L = np.linalg.norm(X, 2) ** 2
    beta = np.zeros(p)
    for _ in range(iters):
        grad = X.T @ (X @ beta - y)
        z = beta - grad / L
        z = np.sign(z) * np.maximum(np.abs(z) - tau * lam_ / L, 0.0)
        zg = z.reshape(G, ng)
        nrm = np.linalg.norm(zg, axis=1, keepdims=True)
        scale = np.maximum(1 - ((1 - tau) * w[:, None] * lam_ / L) / np.maximum(nrm, 1e-30), 0)
        beta = (scale * zg).ravel()
    return beta


@pytest.fixture(scope="module")
def tiny():
    X, y, bt, sizes = make_synthetic(n=25, p=60, n_groups=12, gamma1=2,
                                     gamma2=2, seed=11)
    return X, y, sizes


def test_matches_independent_ista(tiny):
    X, y, sizes = tiny
    tau = 0.4
    prob = make_problem(X, y, sizes, tau=tau)
    lam_ = 0.2 * float(lambda_max(prob))
    ref = prox_grad_reference(X, y, sizes, tau, lam_, iters=20_000)
    res = solve(prob, lam_, tol=1e-12, rule="gap", max_epochs=50_000)
    ours = np.asarray(res.beta).reshape(-1)[: X.shape[1]]
    np.testing.assert_allclose(ours, ref, atol=5e-6)


def test_fixed_point_of_prox(tiny):
    """At the optimum, beta = prox(beta - grad/L) per group (Fermat)."""
    X, y, sizes = tiny
    prob = make_problem(X, y, sizes, tau=0.25)
    lam_ = 0.15 * float(lambda_max(prob))
    res = solve(prob, lam_, tol=1e-12, rule="gap", max_epochs=50_000)
    beta = res.beta
    resid = prob.y - jnp.einsum("ngk,gk->n", prob.X, beta)
    grad = -jnp.einsum("ngk,n->gk", prob.X, resid)
    step = 1.0 / prob.Lg
    z = beta - grad * step[:, None]
    fixed = sgl_prox(z, step, prob.tau, prob.w, lam_)
    np.testing.assert_allclose(
        np.asarray(fixed * prob.feat_mask), np.asarray(beta), atol=1e-6
    )


def test_screening_identical_solutions(tiny):
    X, y, sizes = tiny
    prob = make_problem(X, y, sizes, tau=0.5)
    lam_ = 0.1 * float(lambda_max(prob))
    sols = {}
    for rule in ("gap", "none", "dynamic"):
        res = solve(prob, lam_, tol=1e-10, rule=rule, max_epochs=40_000)
        sols[rule] = np.asarray(res.beta)
    np.testing.assert_allclose(sols["gap"], sols["none"], atol=1e-5)
    np.testing.assert_allclose(sols["dynamic"], sols["none"], atol=1e-5)


def test_gap_decreases_epochs_vs_no_screening(tiny):
    """Screening must never *increase* the number of epochs to tolerance."""
    X, y, sizes = tiny
    prob = make_problem(X, y, sizes, tau=0.3)
    lam_ = 0.3 * float(lambda_max(prob))
    e_gap = solve(prob, lam_, tol=1e-9, rule="gap", max_epochs=40_000).n_epochs
    e_none = solve(prob, lam_, tol=1e-9, rule="none", max_epochs=40_000).n_epochs
    assert e_gap <= e_none + 10  # same epoch grid, allow one f_ce round slack


def test_path_warm_start_active_fracs():
    X, y, _, sizes = make_synthetic(n=30, p=200, n_groups=20, gamma1=3,
                                    gamma2=3, seed=5)
    prob = make_problem(X, y, sizes, tau=0.2)
    path = solve_path(prob, T=10, delta=2.0, tol=1e-7)
    assert np.all(path.gaps <= 1e-7)
    # active fraction grows (weakly) as lambda decreases (index 0 is
    # lambda_max where beta=0 converges before any screening round runs)
    assert path.feat_active_frac[1] <= path.feat_active_frac[-1] + 1e-9
    # first lambda = lambda_max keeps beta = 0
    assert float(jnp.abs(path.betas[0]).max()) == 0.0


def test_unequal_group_sizes():
    rng = np.random.default_rng(2)
    n, sizes = 30, [3, 7, 5, 10, 2, 13]
    p = sum(sizes)
    X = rng.standard_normal((n, p))
    beta = np.zeros(p)
    beta[3:7] = 2.0
    y = X @ beta + 0.01 * rng.standard_normal(n)
    prob = make_problem(X, y, sizes, tau=0.35)
    lam_ = 0.2 * float(lambda_max(prob))
    ref_rule_none = solve(prob, lam_, tol=1e-10, rule="none", max_epochs=40_000)
    res = solve(prob, lam_, tol=1e-10, rule="gap", max_epochs=40_000)
    np.testing.assert_allclose(
        np.asarray(res.beta), np.asarray(ref_rule_none.beta), atol=1e-5
    )
    screened = ~np.asarray(res.feat_active) & np.asarray(prob.feat_mask)
    assert np.all(np.abs(np.asarray(ref_rule_none.beta)[screened]) < 1e-8)


def test_climate_like_generator_solves():
    X, y, _, sizes = make_climate_like(n=60, n_lon=6, n_lat=4, seed=1)
    prob = make_problem(X, y, sizes, tau=0.4)
    lam_ = 0.3 * float(lambda_max(prob))
    res = solve(prob, lam_, tol=1e-7, rule="gap", max_epochs=20_000)
    assert float(res.gap) <= 1e-7
    assert res.feat_active.sum() < np.asarray(prob.feat_mask).sum()


def test_lambda_grid_matches_paper():
    g = lambda_grid(100.0, T=100, delta=3.0)
    assert g[0] == 100.0
    np.testing.assert_allclose(g[-1], 100.0 * 10 ** -3.0)
    assert len(g) == 100
