"""Distributed solver + compression: correctness on the single-device mesh
with production axis names (the multi-pod path is covered by the dry-run)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import make_problem, lambda_max, solve
from repro.data.synthetic import make_synthetic
from repro.distributed import compression as comp
from repro.distributed.solver_dist import solve_distributed
from repro.launch import mesh as meshlib


@pytest.fixture(scope="module")
def small():
    X, y, beta_true, sizes = make_synthetic(
        n=40, p=160, n_groups=16, gamma1=3, gamma2=3, seed=3,
        dtype=np.float64,  # FISTA's f32 gap floor is ~1e-4; tests want 1e-7
    )
    return X, y, sizes


def test_distributed_matches_single_solver(small):
    X, y, sizes = small
    n, p = X.shape
    G = len(sizes)
    ng = p // G
    tau = 0.3

    problem = make_problem(X, y, sizes, tau=tau)
    lam = float(lambda_max(problem)) / 10.0
    ref = solve(problem, lam, tol=1e-8, rule="gap")

    mesh = meshlib.make_test_mesh()
    Xg = jnp.asarray(X.reshape(n, G, ng))
    w = jnp.sqrt(jnp.full((G,), float(ng), jnp.float64))
    L = float(np.linalg.norm(X, 2) ** 2)
    beta, gap, gaps, mask = solve_distributed(
        mesh, Xg, jnp.asarray(y), w, tau=tau, lam_=lam, L=L,
        tol=1e-7, max_steps=20_000,
    )
    assert gap <= 1e-6
    np.testing.assert_allclose(
        np.asarray(beta), np.asarray(ref.beta), atol=5e-3
    )


def test_distributed_screening_is_safe(small):
    X, y, sizes = small
    n, p = X.shape
    G, ng = len(sizes), p // len(sizes)
    tau = 0.3
    problem = make_problem(X, y, sizes, tau=tau)
    lam = float(lambda_max(problem)) / 10.0
    ref = solve(problem, lam, tol=1e-10, rule="none", max_epochs=30_000)

    mesh = meshlib.make_test_mesh()
    Xg = jnp.asarray(X.reshape(n, G, ng))
    w = jnp.sqrt(jnp.full((G,), float(ng), jnp.float64))
    L = float(np.linalg.norm(X, 2) ** 2)
    beta, gap, gaps, mask = solve_distributed(
        mesh, Xg, jnp.asarray(y), w, tau=tau, lam_=lam, L=L,
        tol=1e-6, max_steps=20_000,
    )
    # no group that is nonzero at the (tight) reference optimum may have
    # been masked by the distributed screening
    ref_nonzero = np.any(np.abs(np.asarray(ref.beta)) > 1e-7, axis=1)
    kept = np.asarray(jnp.any(mask > 0, axis=1))
    assert np.all(kept[ref_nonzero])


def test_topk_error_feedback_recovers_signal():
    """EF guarantee: sum(sent) = k*x + e_0 - e_k with e_k bounded, so the
    running mean converges to x at rate O(1/k)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)

    def mean_err(k):
        ef = comp.ef_init(x)
        acc = jnp.zeros_like(x)
        for _ in range(k):
            sent, ef = comp.topk_compress(x, 0.1, ef)
            acc = acc + sent
        return float(jnp.max(jnp.abs(acc / k - x)))

    e25, e100 = mean_err(25), mean_err(100)
    assert e100 < e25 / 2.5          # ~O(1/k) decay
    assert e100 < 0.25               # and absolutely small


def test_topk_sparsity_budget():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                    jnp.float32)
    sent, ef = comp.topk_compress(x, 0.05, comp.ef_init(x))
    assert int(jnp.sum(sent != 0)) <= 50 + 1
    # error buffer holds exactly the residual
    np.testing.assert_allclose(
        np.asarray(sent + ef.error), np.asarray(x), rtol=1e-6
    )


def test_int8_quantize_roundtrip():
    x = jnp.asarray(np.random.default_rng(2).standard_normal(512) * 3,
                    jnp.float32)
    q, scale = comp.int8_quantize(x, jax.random.PRNGKey(0))
    back = comp.int8_dequantize(q, scale)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(scale) * 1.01)


def test_batched_lambda_fista_converges(small):
    """The batched-lambda kernel (the §Perf headline variant) must reach
    gaps comparable to the sequential solver at each path point."""
    X, y, sizes = small
    n, p = X.shape
    G, ng = len(sizes), p // len(sizes)
    tau = 0.3
    problem = make_problem(X, y, sizes, tau=tau)
    lam_max = float(lambda_max(problem))
    lams = np.array([lam_max / 5, lam_max / 10, lam_max / 20, lam_max / 40])
    B = len(lams)

    mesh = meshlib.make_test_mesh()
    from repro.distributed.solver_dist import make_dist_step
    kernels = make_dist_step(mesh, tau=tau)
    fista_b = jax.jit(kernels.fista_batch)

    Xg = jnp.asarray(X.reshape(n, G, ng))
    yj = jnp.asarray(y)
    w = jnp.sqrt(jnp.full((G,), float(ng), jnp.float64))
    L = float(np.linalg.norm(X, 2) ** 2)

    beta = jnp.zeros((B, G, ng), jnp.float64)
    z = jnp.zeros_like(beta)
    mask = jnp.ones_like(beta)
    t = jnp.ones((B,))
    lam_j = jnp.asarray(lams)
    for _ in range(3000):
        beta, z, t = fista_b(Xg, yj, beta, z, mask, w, t, lam_j,
                             jnp.asarray(L))

    # per-lambda duality gap via the single-problem machinery
    from repro.core import duality_gap, dual_scale
    for b, lam in enumerate(lams):
        resid = yj - jnp.einsum("ngk,gk->n", Xg, beta[b])
        theta = dual_scale(problem, resid, jnp.asarray(lam))
        gap = float(duality_gap(problem, beta[b], theta, jnp.asarray(lam)))
        rel = gap / (0.5 * float(jnp.sum(yj * yj)))
        assert rel < 1e-6, (b, lam, gap, rel)
