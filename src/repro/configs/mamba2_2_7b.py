"""mamba2-2.7b — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2_560,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_heads=80,      # d_inner = 2*d_model = 5120, head_dim 64
    ssm_head_dim=64,
    ssm_chunk=128,   # VMEM/HBM-friendly chunk (see EXPERIMENTS.md §Perf)
    conv_width=4,
    subquadratic=True,
    notes="SSD recurrence, d_inner=2*d_model, 80 heads x 64, N=128",
)
