"""Safe screening rules for the Sparse-Group Lasso (paper Section 4 + App. C).

A *safe sphere* B(theta_c, r) is any ball guaranteed to contain the dual
optimum theta_hat.  Given one, Theorem 1 gives the two-level tests:

group level:    T_g < (1 - tau) w_g             =>  beta_g = 0
   T_g = ||S_tau(X_g^T theta_c)|| + r ||X_g||_2     if ||X_g^T theta_c||_inf > tau
       = (||X_g^T theta_c||_inf + r ||X_g||_2 - tau)_+   otherwise
feature level:  |X_j^T theta_c| + r ||X_j|| < tau  =>  beta_j = 0

Spheres implemented (paper Section 7.1):
* GAP        — B(theta, sqrt(2 gap / lambda^2))        [this paper, Thm 2]
* static     — B(y/lambda, ||y/lambda_max - y/lambda||) [El Ghaoui et al. 12]
* dynamic    — B(y/lambda, ||theta_k - y/lambda||)      [Bonnefoy et al. 14]
* DST3       — sphere refined by the most-correlated-group hyperplane
               [Xiang 11 / Bonnefoy 14, extended to SGL in App. C]

All tests operate on the grouped layout of :mod:`repro.core.sgl` and return a
:class:`ScreenResult` with boolean *active* masks (True = keep).  Safety means
a screened-out (False) variable is *provably* zero at the optimum.

This module holds the sphere *constructions* and the Theorem-1 *tests*;
the strategy objects that plug them into the solver's shared round
skeleton (center/radius per rule + safety metadata) live in
:mod:`repro.rules`, and the solver consumes rules through that API.

Bounded dual-norm terms (compacted certified rounds)
----------------------------------------------------
Certificates are permanent, so a screened group's exact correlation
``X_g^T resid`` is never needed again for *screening* — it only re-enters
through the dual scaling ``Omega^D(X^T resid)`` (Eq. 15), which maxes the
per-group eps-norm terms over ALL groups.  :func:`screened_dual_bound`
bounds the screened groups' part of that max from a cached reference:

    ||X_g^T resid||_eps  <=  ||X_g^T resid_ref||_eps
                             + ||X_g||_2 * ||resid - resid_ref||_2

by the triangle inequality (the eps-norm is a norm) plus
``||v||_eps <= ||v||_2`` and Cauchy-Schwarz.  The l2-domination holds
because coordinatewise ``(|v_i| - c)_+ <= |v_i| (||v||_2 - c)_+ / ||v||_2``
for any c >= 0, so at nu = ||v||_2 the defining equation's left side
``sum S_{(1-eps)nu}(v)^2 <= (eps nu)^2`` already — the root is <= ||v||_2.
Whenever the bound stays below ``max(lambda, active-group max)``, the full
dual norm provably equals the active-group max and a round computed on the
compacted active buffer alone is *exact* (see
:mod:`repro.core.solver`).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import sgl
from .epsilon_norm import epsilon_norm, epsilon_norm_dual
from .sgl import SGLProblem, soft_threshold

__all__ = [
    "ScreenResult",
    "Sphere",
    "gap_sphere",
    "sequential_sphere",
    "static_sphere",
    "dynamic_sphere",
    "dst3_sphere",
    "screen",
    "screen_with_corr",
    "screened_dual_bound",
    "screened_group_rate",
    "theorem1_tests",
]


class Sphere(NamedTuple):
    center: jax.Array  # (n,)
    radius: jax.Array  # scalar


class ScreenResult(NamedTuple):
    group_active: jax.Array  # (G,) bool
    feat_active: jax.Array   # (G, ng) bool — False => provably zero
    sphere: Sphere


# ----------------------------------------------------------------------------
# Safe spheres
# ----------------------------------------------------------------------------

def gap_sphere(
    problem: SGLProblem, beta: jax.Array, theta: jax.Array, lam_
) -> Sphere:
    """GAP safe sphere (Theorem 2): r = sqrt(2 (P - D) / lambda^2)."""
    gap = jnp.maximum(sgl.duality_gap(problem, beta, theta, lam_), 0.0)
    return Sphere(theta, jnp.sqrt(2.0 * gap) / lam_)


def sequential_sphere(
    problem: SGLProblem, beta_prev: jax.Array, lam_new
) -> Sphere:
    """Sequential GAP safe sphere at a *new* lambda on a path (paper §7.1).

    Before any epoch at ``lam_new``, the previous lambda's primal point
    ``beta_prev`` yields a dual feasible point at the new lambda by residual
    rescaling (Eq. 15); Theorem 2 then gives a GAP sphere valid at
    ``lam_new``, so most groups are discarded with zero BCD work.  This is
    the paper's "sequential" rule; the path engine evaluates the same round
    through the jitted/Pallas-backed :func:`repro.core.solver.screen_round`
    and this helper is the reference/one-shot form of it.
    """
    resid = problem.y - jnp.einsum("ngk,gk->n", problem.X, beta_prev)
    theta = sgl.dual_scale(problem, resid, lam_new)
    return gap_sphere(problem, beta_prev, theta, lam_new)


def static_sphere(problem: SGLProblem, lam_, lam_max) -> Sphere:
    center = problem.y / lam_
    radius = jnp.linalg.norm(problem.y / lam_max - problem.y / lam_)
    return Sphere(center, radius)


def dynamic_sphere(problem: SGLProblem, theta_k: jax.Array, lam_) -> Sphere:
    center = problem.y / lam_
    radius = jnp.linalg.norm(theta_k - center)
    return Sphere(center, radius)


def dst3_sphere(
    problem: SGLProblem, theta_k: jax.Array, lam_, lam_max
) -> Sphere:
    """DST3 sphere (paper App. C, Prop. 11), extended to the SGL.

    Uses the hyperplane supporting the dual feasible set at y/lambda_max,
    normal to the gradient of the eps-norm of the most-correlated group.
    """
    y, tau, w = problem.y, problem.tau, problem.w
    corr = jnp.einsum("ngk,n->gk", problem.X, y)  # X^T y, grouped
    eps = sgl.epsilons(tau, w)
    scale = sgl.group_weight_total(tau, w)
    per_group = epsilon_norm(corr, eps) / scale
    g_star = jnp.argmax(per_group)

    xg = jnp.take(corr, g_star, axis=0) / lam_max       # X_{g*}^T y / lam_max
    eps_s = jnp.take(eps, g_star)
    nu = epsilon_norm(xg, eps_s)
    xi_star = soft_threshold(xg, (1.0 - eps_s) * nu)    # eps-part of gradient
    denom = epsilon_norm_dual(xi_star, eps_s)
    Xg_star = jnp.take(problem.X, g_star, axis=1)       # (n, ng)
    eta = Xg_star @ xi_star / jnp.maximum(denom, 1e-30)

    c_level = jnp.take(scale, g_star)                    # tau + (1-tau) w_{g*}
    yl = y / lam_
    shift = (jnp.dot(eta, y) / lam_ - c_level) / jnp.maximum(
        jnp.dot(eta, eta), 1e-30
    )
    theta_c = yl - shift * eta
    r2 = jnp.sum((yl - theta_k) ** 2) - jnp.sum((yl - theta_c) ** 2)
    return Sphere(theta_c, jnp.sqrt(jnp.maximum(r2, 0.0)))


# ----------------------------------------------------------------------------
# Bounded dual-norm terms for compacted certified rounds
# ----------------------------------------------------------------------------

def screened_group_rate(problem: SGLProblem) -> jax.Array:
    """Per-group growth rate of the dual-norm term under a residual shift:
    ``||X_g||_2 / (tau + (1-tau) w_g)`` — the Lipschitz constant of
    ``resid -> ||X_g^T resid||_eps / scale_g`` (see the module docstring).
    Constants of the problem; (G,)."""
    return problem.Xnorm_grp / sgl.group_weight_total(problem.tau, problem.w)


def screened_dual_bound(
    ref_terms: jax.Array,
    rate: jax.Array,
    resid_shift: jax.Array,
    screened: jax.Array,
) -> jax.Array:
    """Upper bound on ``max_{g screened} ||X_g^T resid||_eps / scale_g``.

    ``ref_terms``: (G,) per-group dual-norm terms at a reference residual
    (:func:`repro.core.sgl.sgl_dual_norm_terms` of ``X^T resid_ref``);
    ``rate``: (G,) from :func:`screened_group_rate`;
    ``resid_shift``: scalar ``||resid - resid_ref||_2``;
    ``screened``: (G,) bool, True for the groups to bound.

    Safety: by the triangle inequality on the eps-norm and
    ``||X_g^T d||_eps <= ||X_g^T d||_2 <= ||X_g||_2 ||d||_2`` (module
    docstring), every screened group's true term at ``resid`` is <= its
    bound, so if the returned max is <= max(lambda, max over *exact* active
    terms), the full-problem dual norm equals the active-term max exactly.
    Returns 0 when nothing is screened (the bound then constrains nothing).
    """
    b = ref_terms + rate * resid_shift
    return jnp.max(jnp.where(screened, b, 0.0))


# ----------------------------------------------------------------------------
# Screening tests (Theorem 1)
# ----------------------------------------------------------------------------

def theorem1_tests(
    corr: jax.Array,       # (..., ng) X^T theta_c, grouped
    radius,                # sphere radius r
    Xnorm_grp: jax.Array,  # (...,) ||X_g||_2 (any safe upper bound)
    Xnorm_col: jax.Array,  # (..., ng) column norms
    w: jax.Array,          # (...,) group weights
    feat_mask: jax.Array,  # (..., ng) bool, real features
    tau,
    st_norm: Optional[jax.Array] = None,
):
    """Raw Theorem-1 keep-tests; the ONE implementation of the paper's
    group/feature test formulas.

    Shared by the full round (:func:`screen_with_corr`) and the compacted
    round (:func:`repro.core.solver._screen_round_compact`), whose safety
    contract is exact agreement with the full round on the gathered groups
    — keeping a single copy of the formulas is what guarantees they cannot
    drift apart.  Operates on any leading batch shape (full (G, ...) or a
    gathered (Gb, ...) buffer).  Returns ``(group_keep, feat_keep)``
    *before* the caller's extra masking (group wipe-out of features,
    feat_mask, already-screened groups).

    ``st_norm``: optional precomputed ||S_tau(corr)|| per group (e.g. from
    the fused Pallas kernel's S_tau(corr)^2 output).
    """
    if st_norm is None:
        ste = soft_threshold(corr, tau)
        st_norm = jnp.linalg.norm(ste, axis=-1)                 # ||S_tau(.)||
    inf_norm = jnp.max(jnp.abs(jnp.where(feat_mask, corr, 0.0)), axis=-1)

    Tg_out = st_norm + radius * Xnorm_grp
    Tg_in = jnp.maximum(inf_norm + radius * Xnorm_grp - tau, 0.0)
    Tg = jnp.where(inf_norm > tau, Tg_out, Tg_in)
    group_keep = Tg >= (1.0 - tau) * w                          # keep if test fails

    feat_keep = jnp.abs(corr) + radius * Xnorm_col >= tau
    return group_keep, feat_keep


def screen_with_corr(
    problem: SGLProblem, sphere: Sphere, corr: jax.Array,
    st2: Optional[jax.Array] = None,
) -> ScreenResult:
    """Theorem 1 tests given precomputed correlations corr = X^T theta_c
    in grouped layout (G, ng).

    ``st2``: optional precomputed S_tau(corr)^2, e.g. the second output of
    the fused Pallas kernel (:func:`repro.kernels.ops.screening_scores`),
    which thresholds the correlation while the block is still resident in
    VMEM.  When given, the group test consumes it directly instead of
    re-thresholding ``corr`` — previously that half of every fused kernel
    call was discarded and recomputed here (ROADMAP item).
    """
    st_norm = None if st2 is None else jnp.sqrt(jnp.sum(st2, axis=-1))
    group_active, feat_active = theorem1_tests(
        corr, sphere.radius, problem.Xnorm_grp, problem.Xnorm_col,
        problem.w, problem.feat_mask, problem.tau, st_norm=st_norm,
    )
    # Feature-level screening only has bite for tau > 0; for tau == 0 the
    # test |.| < 0 never fires, which the >= comparison already encodes.
    # Screened groups wipe all their features; padding is always inactive.
    feat_active = feat_active & group_active[:, None] & problem.feat_mask
    group_active = group_active & jnp.any(problem.feat_mask, axis=-1)
    return ScreenResult(group_active, feat_active, sphere)


def screen(problem: SGLProblem, sphere: Sphere, backend: str = "xla",
           xt_pre: Optional[jax.Array] = None) -> ScreenResult:
    """Theorem-1 tests against ``sphere``.

    ``backend="pallas"`` routes the correlation through the *fused*
    screening-scores kernel — here the threshold ``tau`` applies to
    ``X^T center`` directly (no dual rescaling), so the kernel's fused
    S_tau(corr)^2 output is handed to :func:`screen_with_corr` and the
    group test never re-thresholds.  Requires a concrete (un-traced)
    problem because ``tau`` is a static kernel parameter.

    ``xt_pre``: persistent transposed design from
    :func:`repro.kernels.ops.prepare_transposed`; without it every
    Pallas-backed call materialises a fresh (p, n) transposed copy of X
    (the per-call copy the session API exists to eliminate) — built through
    the counted :func:`repro.kernels.ops.transposed_design` so the
    transpose audit sees this path too.
    """
    if backend == "pallas":
        from ..kernels import ops as kops

        n, G, ng = problem.X.shape
        p = G * ng
        Xt = kops.transposed_design(problem.X) if xt_pre is None else xt_pre
        corr_f, st2_f = kops.screening_scores(
            Xt, sphere.center, tau=float(problem.tau)
        )
        return screen_with_corr(
            problem, sphere, corr_f[:p].reshape(G, ng),
            st2=st2_f[:p].reshape(G, ng)
        )
    corr = jnp.einsum("ngk,n->gk", problem.X, sphere.center)
    return screen_with_corr(problem, sphere, corr)
