"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation happens here: params/optimizer/cache shapes come from
``jax.eval_shape`` over the real init functions, and batch inputs are
ShapeDtypeStructs.  The same builders drive the dry-run, the roofline
analysis, and the launch scripts.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, SHAPES_BY_NAME
from repro.models import build
from repro.train import optimizer as opt


class CellSpecs(NamedTuple):
    kind: str                 # train | prefill | decode
    args: tuple               # ShapeDtypeStruct pytrees, in call order
    in_specs: tuple           # logical PartitionSpec pytrees
    fn: Any                   # the function to lower
    donate: tuple             # donated arg indices


def _batch_logical(batch: int, dp: int) -> P:
    return P("data") if batch % dp == 0 else P(None)


def _seq_logical(batch: int, dp: int, extra=(None,)) -> P:
    first = "data" if batch % dp == 0 else None
    return P(first, *extra)


def param_structs(api, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(api.init_params, dtype=dtype), jax.random.PRNGKey(0)
    )


def build_cell(
    cfg: ArchConfig,
    shape: ShapeSpec,
    *,
    dp: int,
    model_axis: int,
    dtype=jnp.bfloat16,
    q_chunk: int = 512,
):
    """Returns a CellSpecs for one (arch x shape) cell."""
    from repro.models import layers as L

    api = build(cfg)
    B, S = shape.global_batch, shape.seq_len
    p_structs = param_structs(api, dtype)
    p_specs = api.param_specs(model_axis)
    # activation-sharding hint: lets the model steer the partitioner on
    # dims whose natural axis (heads) doesn't divide the mesh axis
    L.set_activation_mesh({"data": dp, "model": model_axis})

    F = cfg.frontend_tokens
    needs_embeds = cfg.family in ("vlm", "encdec")
    tok_len = S - F if cfg.family == "vlm" else S

    tokens = jax.ShapeDtypeStruct((B, tok_len), jnp.int32)
    embeds = (
        jax.ShapeDtypeStruct((B, F, cfg.d_model), dtype) if needs_embeds else None
    )
    bspec = _batch_logical(B, dp)
    tok_spec = _seq_logical(B, dp)
    emb_spec = _seq_logical(B, dp, (None, None))

    if shape.kind == "train":
        from repro.train.train_step import make_train_step

        init_state, train_step = make_train_step(api, q_chunk=q_chunk)
        o_structs = jax.eval_shape(init_state, p_structs)
        o_specs = opt.state_specs(p_specs)
        batch = {"tokens": tokens}
        batch_specs = {"tokens": tok_spec}
        if needs_embeds:
            batch["embeds"] = embeds
            batch_specs["embeds"] = emb_spec
        return CellSpecs(
            kind="train",
            args=(p_structs, o_structs, batch),
            in_specs=(p_specs, o_specs, batch_specs),
            fn=train_step,
            donate=(0, 1),
        )

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return api.prefill(params, batch["tokens"], batch.get("embeds"),
                               q_chunk=q_chunk, dtype=dtype)

        batch = {"tokens": tokens}
        batch_specs = {"tokens": tok_spec}
        if needs_embeds:
            batch["embeds"] = embeds
            batch_specs["embeds"] = emb_spec
        return CellSpecs(
            kind="prefill",
            args=(p_structs, batch),
            in_specs=(p_specs, batch_specs),
            fn=prefill_fn,
            donate=(),
        )

    # decode: one new token against a seq_len KV cache / recurrent state
    cache_structs = jax.eval_shape(
        lambda: api.init_cache(B, S, dtype=dtype)
    )
    cache_specs = api.cache_specs(model_axis)
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, token, pos):
        return api.decode_step(params, cache, token, pos)

    return CellSpecs(
        kind="decode",
        args=(p_structs, cache_structs, token, pos),
        in_specs=(p_specs, cache_specs, bspec, P()),
        fn=serve_step,
        donate=(1,),
    )
