"""Shape/dtype templates for the jaxpr lints.

Each :class:`EntryPointSpec` pairs one *registered* traceable (see
:func:`repro.analysis.registry.register_traceable`, called at the bottom
of ``core/solver.py`` / ``core/session.py`` / ``distributed/
solver_dist.py``) with a template builder that produces ``(fn, args,
kwargs)`` ready to trace and execute.  The templates are scaled-down
``configs/sgl_paper.py`` shapes (same group size ``ng`` and ``tau``, tiny
``n``/``G``) so tracing is cheap while every structural property the
lints check — dtypes, transposes, gathers, static-argument hashing — is
identical to the production shapes.

Several specs can exercise the same traceable under different static
arguments (rule, backend); :func:`pairing_findings` emits RG001 when a
registered traceable has no spec at all, or a spec names a traceable
nobody registered — so a new jitted entry point cannot silently escape
the gate, and a stale template cannot silently audit nothing.

The one sanctioned sub-f64 program is the mesh strategy's f32 FISTA
(``dist_fista/f32-mesh`` below, ``min_float_bits=32``): its low-precision
rounds are never adopted as certificates at runtime (the session re-
certifies in f64), so float narrowing inside it is by design — the spec
documents the exemption instead of hiding the program from the lints.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

from .findings import Finding

__all__ = ["EntryPointSpec", "default_entry_specs", "pairing_findings"]

# Scaled-down sgl-paper template: same ng/tau as configs/sgl_paper.py.
_N, _G, _NG = 32, 16, 8
_P = _G * _NG
_DESIGN_ELEMS = _N * _G * _NG


@dataclasses.dataclass(frozen=True)
class EntryPointSpec:
    """One traceable entry point + the template that drives it.

    ``build()`` returns ``(fn, args, kwargs)``; it is called fresh for
    every trace/execution so donated buffers are never reused.
    """

    name: str                           # report label, e.g. screen_round/gap-xla
    traceable: str                      # registered-traceable name this drives
    build: Callable[[], Tuple[Callable, tuple, dict]]
    min_float_bits: int = 64            # JX001 threshold on float narrowing
    design_elements: int = _DESIGN_ELEMS  # JX002/JX003 size threshold
    allow_design_transpose: bool = False
    check_retrace: bool = True
    note: str = ""


@functools.lru_cache(maxsize=None)
def _template():
    """Shared template problem (built once; never donated)."""
    from repro.configs.sgl_paper import CONFIG
    from repro.core import lambda_max, make_problem
    from repro.data.synthetic import make_synthetic

    X, y, _beta, sizes = make_synthetic(
        n=_N, p=_P, n_groups=_G, gamma1=4, gamma2=2, seed=0
    )
    problem = make_problem(X, y, sizes, tau=float(CONFIG.tau))
    lmax = lambda_max(problem)
    return problem, lmax


def _registered(name: str) -> Callable:
    """The registered jitted object itself — never a re-wrap, so the
    retrace harness watches the real cache."""
    import repro.core.session  # noqa: F401  (registers core traceables)
    import repro.distributed.solver_dist  # noqa: F401  (dist factory)
    import repro.serve.store  # noqa: F401  (registers serve_warm_eval)
    from .registry import traceables

    entry = traceables().get(name)
    if entry is None:
        raise KeyError(
            f"traceable {name!r} is not registered; "
            f"known: {sorted(traceables())}"
        )
    return entry["fn"]


def _fresh_state(dtype=None):
    """Loose per-call arrays, rebuilt for every build() invocation."""
    import jax.numpy as jnp

    problem, lmax = _template()
    dtype = dtype or problem.X.dtype
    beta = jnp.zeros((_G, _NG), dtype)
    lam = jnp.asarray(0.6, dtype) * jnp.asarray(lmax, dtype)
    return problem, jnp.asarray(lmax, dtype), beta, lam


# --------------------------------------------------------------------------
# Builders
# --------------------------------------------------------------------------

def _build_screen_round(rule_name: str, backend: str):
    def build():
        from repro.kernels import ops as kops
        from repro.rules import resolve_rule

        problem, lmax, beta, lam = _fresh_state()
        fn = _registered("screen_round")
        kwargs: Dict[str, Any] = {
            "rule": resolve_rule(rule_name), "backend": backend,
        }
        if backend == "pallas":
            kwargs["xt_pre"] = kops.prepare_transposed(problem.X)
        return fn, (problem, beta, lam, lmax), kwargs

    return build


def _compact_state(backend: str):
    """Reference state for the compact round: one full round + gather."""
    import jax.numpy as jnp

    from repro.core import solver as core_solver
    from repro.kernels import ops as kops
    from repro.rules import resolve_rule

    problem, lmax, beta, lam = _fresh_state()
    rule = resolve_rule("gap")
    rr, resid_ref, ref_terms = core_solver._screen_round(
        problem, beta, lam, lmax, rule=rule, backend="xla"
    )
    group_active = np.asarray(rr.group_active)
    # keep at least one group in the buffer even if everything screens
    if not group_active.any():
        group_active = group_active.copy()
        group_active[0] = True
    caches = core_solver.SolveCaches()
    _idx, take, Xt, _Lg, _w, gmask = caches.gather(problem, group_active)
    xt_rows = None
    if backend == "pallas":
        xt_pre = kops.prepare_transposed(problem.X)
        xt_rows = caches.gather_xt_rows(problem, group_active, xt_pre)
    feat_active = jnp.asarray(np.asarray(rr.feat_active))
    return (problem, Xt, take, gmask, beta, feat_active,
            jnp.asarray(group_active), ref_terms, resid_ref, lam, xt_rows)


def _build_screen_round_compact(backend: str):
    def build():
        (problem, Xt, take, gmask, beta, feat_active, group_active,
         ref_terms, resid_ref, lam, xt_rows) = _compact_state(backend)
        fn = _registered("screen_round_compact")
        return fn, (problem, Xt, take, gmask, beta, feat_active,
                    group_active, ref_terms, resid_ref, lam), {
                        "backend": backend, "xt_rows": xt_rows}

    return build


def _build_inner_rounds(backend: str):
    def build():
        import jax.numpy as jnp

        from repro.core import solver as core_solver
        from repro.kernels import ops as kops

        problem, _lmax, beta, lam = _fresh_state()
        group_active = np.ones(_G, bool)
        caches = core_solver.SolveCaches()
        _idx, take, Xt, Lg, w, gmask = caches.gather(problem, group_active)
        xt_rows = None
        if backend == "pallas":
            xt_pre = kops.prepare_transposed(problem.X)
            xt_rows = caches.gather_xt_rows(problem, group_active, xt_pre)
        fn = _registered("inner_rounds")
        tol = jnp.asarray(1e-8, beta.dtype)
        return fn, (Xt, Lg, w, problem.y, beta, problem.feat_mask, take,
                    gmask, problem.tau, lam, tol), {
                        "block_epochs": 2, "max_blocks": 2,
                        "backend": backend, "xt_rows": xt_rows}

    return build


def _build_bcd_epochs():
    def build():
        import jax.numpy as jnp

        from repro.core import solver as core_solver

        problem, _lmax, _beta, lam = _fresh_state()
        dtype = problem.X.dtype
        group_active = np.ones(_G, bool)
        caches = core_solver.SolveCaches()
        _idx, _take, Xt, Lg, w, gmask = caches.gather(problem, group_active)
        fmask = problem.feat_mask.astype(dtype)
        # beta/resid are donated (donate_argnums) — fresh every build()
        beta = jnp.zeros((_G, _NG), dtype)
        resid = jnp.array(problem.y, copy=True)
        fn = _registered("bcd_epochs")
        return fn, (Xt, Lg * gmask, w, fmask, beta, resid, problem.tau,
                    lam), {"n_epochs": 2}

    return build


def _build_batch_reduced_gaps():
    def build():
        import jax.numpy as jnp

        from repro.core import solver as core_solver

        problem, lmax, _beta, _lam = _fresh_state()
        dtype = problem.X.dtype
        B = 2
        group_active = np.ones(_G, bool)
        caches = core_solver.SolveCaches()
        _idx, _take, Xt, _Lg, w, _gmask = caches.gather(
            problem, group_active)
        fmask_b = jnp.broadcast_to(
            problem.feat_mask.astype(dtype)[None], (B, _G, _NG))
        bsub = jnp.zeros((B, _G, _NG), dtype)
        resid = jnp.broadcast_to(problem.y[None], (B, _N))
        lam_b = jnp.asarray([0.6, 0.3], dtype) * jnp.asarray(lmax, dtype)
        fn = _registered("batch_reduced_gaps")
        return fn, (Xt, fmask_b, bsub, resid, w, problem.y, problem.tau,
                    lam_b), {"backend": "xla"}

    return build


def _build_serve_warm_eval():
    def build():
        import jax.numpy as jnp

        problem, _lmax, beta, lam = _fresh_state()
        # A warm (nonzero) hint point, as the serving layer feeds it.
        beta = beta.at[0, 0].set(jnp.asarray(0.1, beta.dtype))
        fn = _registered("serve_warm_eval")
        return fn, (problem, beta, lam), {}

    return build


def _logistic_state():
    """Template problem re-labelled with a {0, 1} response plus the
    logistic loss and ITS lambda_max (the loss builders' shared state)."""
    import jax.numpy as jnp

    from repro.core import sgl
    from repro.losses import resolve_loss

    problem, _lmax = _template()
    loss = resolve_loss("logistic")
    y01 = np.asarray(problem.y) > np.median(np.asarray(problem.y))
    problem = problem._replace(y=jnp.asarray(y01, problem.X.dtype))
    lmax = sgl.lambda_max_loss(problem, loss)
    beta = jnp.zeros((_G, _NG), problem.X.dtype)
    lam = jnp.asarray(0.6, beta.dtype) * jnp.asarray(lmax, beta.dtype)
    return problem, loss, beta, lam, jnp.asarray(lmax, beta.dtype)


def _build_screen_round_logistic():
    def build():
        from repro.rules import resolve_rule

        problem, loss, beta, lam, lmax = _logistic_state()
        fn = _registered("screen_round")
        return fn, (problem, beta, lam, lmax), {
            "rule": resolve_rule("gap"), "backend": "xla", "loss": loss}

    return build


def _build_inner_rounds_loss():
    def build():
        import jax.numpy as jnp

        from repro.core import solver as core_solver

        problem, loss, beta, lam, _lmax = _logistic_state()
        group_active = np.ones(_G, bool)
        caches = core_solver.SolveCaches()
        _idx, take, Xt, Lg, w, gmask = caches.gather(problem, group_active)
        fn = _registered("inner_rounds_loss")
        tol = jnp.asarray(1e-8, beta.dtype)
        return fn, (Xt, Lg, w, problem.y, beta, problem.feat_mask, take,
                    gmask, problem.tau, lam, tol), {
                        "loss": loss, "block_epochs": 2, "max_blocks": 2,
                        "backend": "xla"}

    return build


def _build_bcd_epochs_loss():
    def build():
        import jax.numpy as jnp

        from repro.core import solver as core_solver

        problem, loss, _beta, lam, _lmax = _logistic_state()
        dtype = problem.X.dtype
        group_active = np.ones(_G, bool)
        caches = core_solver.SolveCaches()
        _idx, _take, Xt, Lg, w, gmask = caches.gather(problem, group_active)
        fmask = problem.feat_mask.astype(dtype)
        # beta/z are donated (donate_argnums) — fresh every build()
        beta = jnp.zeros((_G, _NG), dtype)
        z = jnp.zeros((_N,), dtype)
        fn = _registered("bcd_epochs_loss")
        return fn, (Xt, Lg * gmask, w, fmask, beta, z, problem.tau,
                    lam, problem.y), {"loss": loss, "n_epochs": 2}

    return build


def _build_serve_warm_eval_logistic():
    def build():
        import jax.numpy as jnp

        problem, loss, beta, lam, _lmax = _logistic_state()
        beta = beta.at[0, 0].set(jnp.asarray(0.1, beta.dtype))
        fn = _registered("serve_warm_eval")
        return fn, (problem, beta, lam), {"loss": loss}

    return build


def _build_screen_round_warm():
    def build():
        import jax.numpy as jnp

        from repro.rules import resolve_rule

        problem, lmax, beta, lam = _fresh_state()
        # The serving layer's re-certification round: a stored primal
        # hint (nonzero beta) freshly screened at the new lambda.
        beta = beta.at[0, 0].set(jnp.asarray(0.1, beta.dtype))
        fn = _registered("screen_round")
        return fn, (problem, beta, lam, lmax), {
            "rule": resolve_rule("gap"), "backend": "xla"}

    return build


def _build_dist_fista(np_dtype):
    def build():
        import jax.numpy as jnp

        from repro.launch.mesh import make_test_mesh

        problem, lmax, _beta, lam = _fresh_state()
        mesh = make_test_mesh()
        fn = _registered("dist_step_factory")(
            mesh, tau=float(problem.tau))
        dtype = jnp.dtype(np_dtype)
        X = problem.X.astype(dtype)
        y = problem.y.astype(dtype)
        beta = jnp.zeros((_G, _NG), dtype)
        z = jnp.zeros((_G, _NG), dtype)
        fmask = problem.feat_mask.astype(dtype)
        w = problem.w.astype(dtype)
        t = jnp.asarray(1.0, dtype)
        L = jnp.asarray(float(_N), dtype)
        return fn.fista, (X, y, beta, z, fmask, w, t,
                          jnp.asarray(lam, dtype), L), {}

    return build


# --------------------------------------------------------------------------
# The default spec set + registry pairing check
# --------------------------------------------------------------------------

def default_entry_specs() -> List[EntryPointSpec]:
    """Every entry point the jaxpr lints trace, with its template."""
    return [
        EntryPointSpec(
            name="screen_round/gap-xla", traceable="screen_round",
            build=_build_screen_round("gap", "xla"),
            note="full certified round, GAP safe sphere (Thm 1/2)",
        ),
        EntryPointSpec(
            name="screen_round/gap-pallas", traceable="screen_round",
            build=_build_screen_round("gap", "pallas"),
            note="Pallas corr/dual-norm routing over xt_pre",
        ),
        EntryPointSpec(
            name="screen_round/dynamic-xla", traceable="screen_round",
            build=_build_screen_round("dynamic", "xla"),
            note="dynamic-rule variant of the shared skeleton",
        ),
        EntryPointSpec(
            name="screen_round_compact/xla",
            traceable="screen_round_compact",
            build=_build_screen_round_compact("xla"),
            note="O(n p_active) certified round, screened-bound fallback",
        ),
        EntryPointSpec(
            name="screen_round_compact/pallas",
            traceable="screen_round_compact",
            build=_build_screen_round_compact("pallas"),
        ),
        EntryPointSpec(
            name="inner_rounds/xla", traceable="inner_rounds",
            build=_build_inner_rounds("xla"),
            note="blocked BCD epochs + reduced-gap early exit",
        ),
        EntryPointSpec(
            name="inner_rounds/pallas", traceable="inner_rounds",
            build=_build_inner_rounds("pallas"),
            note="fused bcd_epoch mega-kernel path",
        ),
        EntryPointSpec(
            name="bcd_epochs", traceable="bcd_epochs",
            build=_build_bcd_epochs(),
            note="lax.scan reference epochs (donated beta/resid)",
        ),
        EntryPointSpec(
            name="batch_reduced_gaps", traceable="batch_reduced_gaps",
            build=_build_batch_reduced_gaps(),
            note="batched-lambda work heuristic",
        ),
        EntryPointSpec(
            name="serve_warm_eval", traceable="serve_warm_eval",
            build=_build_serve_warm_eval(),
            note="serving-layer warm-start admission: duality gap of a "
                 "stored primal hint on the new problem (repro.serve)",
        ),
        EntryPointSpec(
            name="screen_round/serve-warm", traceable="screen_round",
            build=_build_screen_round_warm(),
            note="cache-keyed serving round: fresh GAP re-certification "
                 "of a warm-start hint (stored certs are never reused)",
        ),
        EntryPointSpec(
            name="screen_round/gap-logistic-xla", traceable="screen_round",
            build=_build_screen_round_logistic(),
            note="loss-generic certified round: GAP sphere from the "
                 "generalized residual rho = -grad F(X beta), nu-scaled "
                 "radius (repro.losses strategy)",
        ),
        EntryPointSpec(
            name="inner_rounds_loss/logistic-xla",
            traceable="inner_rounds_loss",
            build=_build_inner_rounds_loss(),
            note="blocked majorized-BCD epochs + loss reduced-gap exit "
                 "(linear-predictor carry)",
        ),
        EntryPointSpec(
            name="bcd_epochs_loss/logistic", traceable="bcd_epochs_loss",
            build=_build_bcd_epochs_loss(),
            note="lax.scan reference majorized epochs (donated beta/z; "
                 "bit-parity oracle of the fused logistic kernel)",
        ),
        EntryPointSpec(
            name="serve_warm_eval/logistic", traceable="serve_warm_eval",
            build=_build_serve_warm_eval_logistic(),
            note="loss-aware warm-start admission: the hint gap is "
                 "measured under the request's data fidelity",
        ),
        EntryPointSpec(
            name="dist_fista/f64-mesh", traceable="dist_step_factory",
            build=_build_dist_fista(np.float64),
            check_retrace=False,   # shard_map kernel: no jit cache to watch
            note="mesh FISTA step on a (1,1) test mesh, full precision",
        ),
        EntryPointSpec(
            name="dist_fista/f32-mesh", traceable="dist_step_factory",
            build=_build_dist_fista(np.float32),
            min_float_bits=32, check_retrace=False,
            note="sanctioned sub-f64 path: f32 mesh solves are never "
                 "adopted as certificates (session re-certifies in f64)",
        ),
    ]


def pairing_findings(specs=None) -> List[Finding]:
    """RG001: registered traceables and templates must pair one-to-one
    (a traceable may back several specs, but never zero)."""
    import repro.core.session  # noqa: F401
    import repro.distributed.solver_dist  # noqa: F401
    import repro.serve.store  # noqa: F401
    from .registry import traceables

    specs = default_entry_specs() if specs is None else specs
    registered = set(traceables())
    templated = {s.traceable for s in specs}
    findings: List[Finding] = []
    for name in sorted(registered - templated):
        findings.append(Finding(
            pass_name="jaxpr", code="RG001",
            message=(f"registered traceable {name!r} has no template in "
                     f"analysis.entrypoints — it escapes the jaxpr lints"),
            location=name,
        ))
    for name in sorted(templated - registered):
        findings.append(Finding(
            pass_name="jaxpr", code="RG001",
            message=(f"template references traceable {name!r} but nothing "
                     f"registered it — stale spec audits nothing"),
            location=name,
        ))
    return findings
