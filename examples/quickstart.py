"""Quickstart: solve one Sparse-Group Lasso instance with GAP safe screening.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop on a small synthetic instance through the
**session API**: builds the problem, opens an :class:`SGLSession` (which
owns the solver configuration, the screening backend, and the persistent
transposed design for the Pallas kernels), computes lambda_max via the
epsilon-norm trick (Eq. 22), solves at lambda = lambda_max / 20 with
Algorithm 2 (ISTA-BC + GAP safe rules), and reports the duality gap, the
screening statistics, and support recovery.

Migration note: the legacy ``solve(problem, lam, tol=..., rule=..., ...)``
kwargs became :class:`SolverConfig` fields with the same names (``tol``,
``max_epochs``, ``f_ce``, ``rule``, ``compact``, ``inner_rounds``,
``check_every``, ``screen_backend``, ``warm_gap_factor``); the lambda and
warm-start state stay on ``session.solve(lam, beta0=...)``.

Migration note (rule objects): ``SolverConfig.rule`` now takes a
:mod:`repro.rules` **strategy object** — ``rule=GapSafeRule()`` below —
with string names (``"gap"``, ``"static"``, ``"dynamic"``, ``"dst3"``,
``"none"``, ``"strong"``) kept as registry aliases resolving to the same
singletons, bit-identically for ``"gap"``.  Unknown names now fail at
session construction with the registered list.  New rule families
subclass :class:`repro.rules.ScreeningRule` (one sphere construction) and
``register_rule`` themselves — the solver, the path engine, and the
Fig. 2/3 sweep harness (``benchmarks/sweep_rules.py``) pick them up
unchanged.  Unsafe heuristics (``StrongSequentialRule``) are flagged:
their rounds carry ``safe=False`` and paths ``certificates_safe=False``.

``SolverConfig.solver_backend`` (new) picks the inner-epoch engine:
``"auto"`` (default) fuses whole BCD epoch blocks into ONE Pallas kernel
launch on TPU (``kernels/bcd_epoch.py`` — VMEM-resident residual, and a
lambda-batch axis that solves coinciding-active-set path points together)
and keeps the ``lax.scan`` reference elsewhere; force ``"pallas"`` /
``"xla"`` to override.  The fused kernel's epoch math is bit-identical to
the scan in f64, so switching is a performance choice, not a numerics one
(the backends' between-block early-exit heuristics can in principle differ
in the last ulp; the CI smoke pins end-to-end equality on its config).
On warm path stretches whose certified active sets coincide, the Pallas
backend additionally batches consecutive lambdas through the kernel's
lambda-batch axis (``solve_path(batch_lambdas=...)``) — results there are
tol-level equivalent, not bit-equal; pass ``batch_lambdas=1`` for exact
per-lambda reproduction.
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro.core import SGLSession, SolverConfig, make_problem
from repro.data.synthetic import make_synthetic
from repro.rules import GapSafeRule


def main():
    X, y, beta_true, sizes = make_synthetic(
        n=100, p=1000, n_groups=100, gamma1=5, gamma2=4, seed=0
    )
    problem = make_problem(X, y, sizes, tau=0.2)
    # rule= takes a repro.rules strategy object; the string "gap" remains
    # a registry alias resolving to this same singleton (bit-identical).
    session = SGLSession(problem, SolverConfig(tol=1e-8,
                                               rule=GapSafeRule()))

    lam_max = session.lam_max
    lam = lam_max / 20.0
    print(f"lambda_max = {lam_max:.4f}  (Eq. 22, epsilon-norm Algorithm 1)")
    print(f"solving at lambda = lambda_max/20 = {lam:.4f}, tol = 1e-8")

    res = session.solve(lam)

    G, ng = problem.G, problem.ng
    beta = np.asarray(res.beta).reshape(-1)
    true_groups = {
        g for g in range(G) if np.any(beta_true[g * ng:(g + 1) * ng] != 0)
    }
    found_groups = {
        g for g in range(G) if np.any(np.abs(beta[g * ng:(g + 1) * ng]) > 1e-10)
    }

    print(f"\nconverged: duality gap = {float(res.gap):.3e} "
          f"after {res.n_epochs} BCD epochs "
          f"({session.rounds} certified screening rounds)")
    print(f"active groups at solution: {int(res.group_active.sum())}/{G} "
          f"(GAP rule screened out {G - int(res.group_active.sum())})")
    print(f"active features: {int(res.feat_active.sum())}/{G * ng}")
    print(f"true support: {sorted(true_groups)}")
    print(f"recovered   : {sorted(found_groups)}")

    # GAP screening is SAFE: no group with a nonzero optimal coefficient
    # may ever be screened out.
    for g in found_groups:
        assert res.group_active[g], f"unsafe screen of group {g}!"
    print("\nsafety check passed: every nonzero group survived screening")

    # The session is warm: a second solve nearby reuses the gather caches
    # and (on TPU) the persistent transposed design, and can be seeded with
    # a sequential certificate — the paper's sequential screening rule.
    cert = session.screen(lam / 2.0, res.beta)
    res2 = session.solve(lam / 2.0, beta0=res.beta, first_round=cert)
    print(f"warm re-solve at lambda/2: sequential certificate screened "
          f"{G - int(np.asarray(cert.group_active).sum())}/{G} groups "
          f"up front; gap {float(res2.gap):.3e} "
          f"in {res2.n_epochs} epochs")
    assert float(res2.gap) <= 1e-8


if __name__ == "__main__":
    main()
