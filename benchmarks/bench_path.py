"""Figure 3b: whole-path computation time on the climate-like dataset as a
function of the prescribed duality-gap accuracy, GAP rule vs no screening —
plus the sequential path-engine vs the legacy naive per-lambda loop.

Paper: NCEP/NCAR Reanalysis 1, n=814, p=73577 (groups of 7 variables per
grid point), delta=2.5, tau*=0.4.  The offline generator reproduces the
group structure and preprocessing; the default grid is reduced so the
harness completes in CPU-minutes (``--full`` restores 144x73).

Modes:
* ``naive``  — the seed loop: warm-started beta only, fresh caches and a
  full active-set re-derivation at every lambda, f_ce-block epoch counts.
* ``engine`` — sequential GAP screening before the first epoch of each
  lambda, carried gather cache, sequential-gap-adaptive early exit.
"""
from __future__ import annotations

import time

from repro.core import sgl
from repro.core.path import lambda_grid, solve_path
from repro.data.climate import make_climate_like

from .common import emit

MODES = {
    "naive": dict(sequential=False, check_every=None),
    "engine": dict(sequential=True, check_every="auto"),
}


def main(n=256, n_lon=16, n_lat=8, T=20, delta=2.5, tau=0.4,
         tols=(1e-4, 1e-6, 1e-8), max_epochs=3000) -> None:
    X, y, _, sizes = make_climate_like(n=n, n_lon=n_lon, n_lat=n_lat)
    problem = sgl.make_problem(X, y, sizes, tau=tau)
    lam_max = float(sgl.lambda_max(problem))
    lambdas = lambda_grid(lam_max, T=T, delta=delta)

    for rule in ("gap", "none"):
        for tol in tols:
            for mode, kwargs in MODES.items():
                t0 = time.perf_counter()
                res = solve_path(problem, lambdas=lambdas, tol=tol,
                                 max_epochs=max_epochs, rule=rule, **kwargs)
                dt = time.perf_counter() - t0
                case = f"{rule}_{mode}_tol{tol:g}"
                emit("path_fig3b", case, "path_seconds", dt)
                emit("path_fig3b", case, "total_epochs", int(res.epochs.sum()))
                emit("path_fig3b", case, "zero_epoch_lambdas",
                     int((res.epochs == 0).sum()))
                emit("path_fig3b", case, "gathers", res.n_gathers)
                if rule == "gap":
                    emit("path_fig3b", case, "seq_screened_groups",
                         int(res.seq_screened.sum()))
                    emit("path_fig3b", case, "dyn_screened_groups",
                         int(res.dyn_screened.sum()))


if __name__ == "__main__":
    import argparse

    from .common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    header()
    if args.full:
        main(n=814, n_lon=144, n_lat=73, T=100)
    else:
        main()
