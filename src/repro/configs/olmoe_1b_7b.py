"""olmoe-1b-7b — MoE, 64 experts top-8. [arXiv:2409.02060; hf]"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2_048,
    n_heads=16,
    n_kv=16,
    d_ff=1_024,
    vocab=50_304,
    moe=MoEConfig(n_experts=64, top_k=8),
    subquadratic=False,
    notes="64 experts, top-8, d_ff(expert)=1024",
)
