"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone; modality
frontend is a STUB (precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,          # decoder layers
    n_enc_layers=24,      # encoder layers
    d_model=1_024,
    n_heads=16,
    n_kv=16,
    d_ff=8_192,
    vocab=256_206,
    frontend_tokens=1_024,   # stub audio frame embeddings fed to encoder
    subquadratic=False,
    notes="enc-dec; audio frontend stubbed as precomputed frame embeddings",
)
