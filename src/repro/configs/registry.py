"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen2.5-14b",
    "codeqwen1.5-7b",
    "qwen3-8b",
    "llama3-405b",
    "recurrentgemma-2b",
    "olmoe-1b-7b",
    "mixtral-8x7b",
    "mamba2-2.7b",
    "seamless-m4t-large-v2",
    "llava-next-mistral-7b",
    "sgl-paper",
]

_MODULES = {
    "qwen2.5-14b": "qwen2_5_14b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen3-8b": "qwen3_8b",
    "llama3-405b": "llama3_405b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "sgl-paper": "sgl_paper",
}


def get(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_archs():
    return list(ARCH_IDS)
