"""Fig. 2/3 screening-rule sweep: every registered rule strategy, side by
side, on the paper's configs.

The paper's headline result is a *comparison* — GAP safe (sequential +
dynamic) against the static safe sphere [El Ghaoui et al. 12], the plain
dynamic safe sphere [Bonnefoy et al. 14], DST3, no screening, and an
unsafe sequential heuristic — and this harness runs exactly that matrix
through the pluggable :mod:`repro.rules` strategy API: the synthetic paper
config (n=100, p=2000, 200 groups) and a climate-like config, across all
registered rules x T x tol, through one ``SGLSession.solve_path`` per
cell.

Outputs (the ``BENCH_pr5.json`` record):

* flat metric rows (``benchmarks.common.emit`` schema) for diff tooling;
* a ``curves`` section per (config, rule, T, tol): active-fraction-vs-
  lambda arrays (Fig. 2a/2b), an active-fraction-vs-epoch curve at a fixed
  lambda (Fig. 2c, from the per-round ``active_history``), epochs/gaps/
  counters/round-split/wall (Fig. 3);
* a markdown report rendered by
  :func:`repro.launch.report.render_sweep_markdown` — re-renderable from
  the JSON alone via ``python -m repro.launch.reanalyze --sweep``.

Every run also asserts the API-migration acceptance criterion: the legacy
``rule="gap"`` *string* config is BIT-IDENTICAL (betas, epochs, seq/dyn
counters, compact/full round split) to the ``GapSafeRule()`` object
config.

``--smoke`` runs a reduced matrix and additionally asserts what the CI
watches: every ``is_safe`` rule's path masks are SAFE against a tight-tol
unscreened reference (nothing screened is nonzero at the optimum), the
GAP rule dominates the static and dynamic spheres on screened fraction,
and unsafe rules are flagged (``certificates_safe=False``) with their
heuristic discards counted — then exits.

``--loss`` (default ``lsq``) selects the data-fidelity term through
``SolverConfig.loss`` and is recorded as a column on every row/curve.
``--loss logistic`` binarizes each config's response and restricts the
rule matrix to the rules whose spheres hold off-lsq (the GAP family plus
the unsafe heuristics); the smoke invariants then assert the safety
matrix per rule and GAP dominance over the unscreened baseline — the
CI's ``sweep_rules --smoke --loss logistic`` step (``BENCH_pr8.json``).
"""
from __future__ import annotations

import json
import platform
import time

import numpy as np

import jax

from repro.core import SGLSession, SolverConfig, make_problem
from repro.data.climate import make_climate_like
from repro.data.synthetic import make_synthetic
from repro.launch.report import render_sweep_markdown
from repro.losses import available_losses
from repro.rules import GapSafeRule, available_rules, get_rule

from .common import emit, header, rows


def for_loss(problem, cfg_name, loss):
    """Adapt a config to a data-fidelity loss: logistic needs a {0,1}
    response, so binarize at the median (balanced classes by design);
    the loss is folded into the config label so lsq and logistic cells
    never collide in the curves/report grouping."""
    if loss == "lsq":
        return problem, cfg_name
    import jax.numpy as jnp

    y01 = np.asarray(problem.y) > np.median(np.asarray(problem.y))
    problem = problem._replace(y=jnp.asarray(y01, problem.X.dtype))
    return problem, f"{cfg_name}-{loss}"


def rules_for_loss(loss):
    """The rules whose spheres are provable under this loss (the lsq-only
    geometries — static/dynamic/DST3 — are excluded off-lsq exactly as
    ``SolverConfig`` would reject them)."""
    names = []
    for name in available_rules():
        r = get_rule(name)
        if r.supported_losses is None or loss in r.supported_losses:
            names.append(name)
    return names


def synthetic_paper_problem(smoke: bool = False):
    """The synthetic paper config (AR(1) design, equal groups, tau=0.2):
    n=100, p=2000, 200 groups — the problem of the PR 1-4 trajectory —
    or a CI-seconds reduction for ``--smoke``.  The --paper/default split
    lives in main()'s grid knobs (T, tols, max_epochs), not here."""
    if smoke:
        kw = dict(n=30, p=120, n_groups=15, seed=9)
    else:
        kw = dict(n=100, p=2000, n_groups=200, seed=42)
    X, y, _, sizes = make_synthetic(gamma1=3, gamma2=3, **kw)
    return make_problem(X, y, sizes, tau=0.2), "synthetic"


def climate_problem(smoke: bool = False):
    """Reduced climate-like config (NCEP/NCAR-style 7-variable groups)."""
    if smoke:
        X, y, _, sizes = make_climate_like(n=48, n_lon=4, n_lat=3, seed=1)
    else:
        X, y, _, sizes = make_climate_like(n=128, n_lon=8, n_lat=4, seed=1)
    return make_problem(X, y, sizes, tau=0.4), "climate"


def _unscreened_reference(problem, lambdas, tol=1e-10, max_epochs=60_000,
                          loss="lsq"):
    """Tight-tol, rule='none' warm-started reference path — the safety
    oracle every safe rule's masks are checked against."""
    import jax.numpy as jnp

    ref = SGLSession(problem, SolverConfig(tol=tol, rule="none",
                                           max_epochs=max_epochs,
                                           loss=loss))
    betas = []
    beta = jnp.zeros((problem.G, problem.ng), problem.X.dtype)
    for lam_ in lambdas:
        beta = ref.solve(float(lam_), beta0=beta).beta
        betas.append(np.asarray(beta))
    return np.stack(betas)


def _fig2_curve(problem, result, T):
    """Fig. 2c raw curve at the chosen lambda index: the per-round
    ``active_history`` of that lambda's solve as [epoch, gfrac, ffrac],
    normalised by the problem's REAL group/feature counts (1.0 = nothing
    screened yet)."""
    t_star = max(0, min(T - 1, int(round(0.6 * (T - 1)))))
    res = result.results[t_star] if result.results else None
    curve = []
    if res is not None and res.active_history:
        feat_mask = np.asarray(problem.feat_mask)
        n_groups = max(1, int(feat_mask.any(axis=-1).sum()))
        n_feats = max(1, int(feat_mask.sum()))
        for epoch, g_act, f_act in res.active_history:
            curve.append([int(epoch),
                          float(g_act) / n_groups,
                          float(f_act) / n_feats])
    return {"lambda_index": t_star, "epoch_curve": curve}


def run_cell(problem, cfg_name, rule_name, T, delta, tol, max_epochs,
             beta_ref=None, loss="lsq"):
    """One (config, loss, rule, T, tol) sweep cell -> (curve, PathResult)."""
    rule = get_rule(rule_name)
    session = SGLSession(problem, SolverConfig(
        tol=tol, max_epochs=max_epochs, rule=rule, loss=loss,
    ))
    t0 = time.perf_counter()
    res = session.solve_path(T=T, delta=delta, keep_results=True)
    wall = time.perf_counter() - t0

    case = f"{cfg_name}_{rule_name}_T{T}_tol{tol:g}"
    conv = int((res.gaps <= tol).sum())
    emit("sweep_rules", case, "wall_seconds", wall)
    emit("sweep_rules", case, "total_epochs", int(res.epochs.sum()))
    emit("sweep_rules", case, "converged_lambdas", conv)
    emit("sweep_rules", case, "mean_active_feat_frac",
         float(res.feat_active_frac.mean()))
    emit("sweep_rules", case, "mean_active_group_frac",
         float(res.group_active_frac.mean()))
    emit("sweep_rules", case, "seq_screened", int(res.seq_screened.sum()))
    emit("sweep_rules", case, "dyn_screened", int(res.dyn_screened.sum()))
    emit("sweep_rules", case, "compact_rounds", res.n_compact_rounds)
    emit("sweep_rules", case, "full_rounds", res.n_full_rounds)
    emit("sweep_rules", case, "round_flops", res.round_flops)
    emit("sweep_rules", case, "certificates_safe",
         int(res.certificates_safe))

    curve = {
        "config": cfg_name,
        "loss": loss,
        "rule": rule_name,
        "safe": bool(rule.is_safe),
        "T": T,
        "tol": tol,
        "delta": delta,
        "lambdas": [float(v) for v in res.lambdas],
        "active_group_frac": [float(v) for v in res.group_active_frac],
        "active_feat_frac": [float(v) for v in res.feat_active_frac],
        "epochs": [int(v) for v in res.epochs],
        "gaps": [float(v) for v in res.gaps],
        "seq_screened": [int(v) for v in res.seq_screened],
        "dyn_screened": [int(v) for v in res.dyn_screened],
        "converged_lambdas": conv,
        "wall_seconds": wall,
        "n_compact_rounds": res.n_compact_rounds,
        "n_full_rounds": res.n_full_rounds,
        "round_flops": res.round_flops,
        "fig2": _fig2_curve(problem, res, T),
    }
    if beta_ref is not None:
        # Safety audit vs the unscreened tight-tol reference: a variable
        # this rule screened that is nonzero at the optimum is a VIOLATION
        # (must be 0 for every is_safe rule; >0 flags the unsafe rule's
        # erroneous discards, the paper's Fig. 3 failure mode).
        feat_mask = np.asarray(problem.feat_mask)
        viol = 0
        for t in range(T):
            screened = ~res.feat_active[t] & feat_mask
            viol += int((np.abs(beta_ref[t])[screened] > 1e-7).sum())
        curve["safety_violations"] = viol
        emit("sweep_rules", case, "safety_violations", viol)
    return curve, res


def gap_string_object_parity(problem, T, delta, tol, max_epochs,
                             loss="lsq") -> None:
    """Acceptance criterion: legacy ``rule="gap"`` strings are BIT-identical
    to the ``GapSafeRule()`` object config — betas, epochs, seq/dyn
    counters, and the compact/full round split."""
    runs = {}
    for key, rule in (("string", "gap"), ("object", GapSafeRule())):
        session = SGLSession(problem, SolverConfig(
            tol=tol, max_epochs=max_epochs, rule=rule, loss=loss,
        ))
        runs[key] = session.solve_path(T=T, delta=delta)
    a, b = runs["string"], runs["object"]
    np.testing.assert_array_equal(a.betas, b.betas)
    assert (a.epochs == b.epochs).all(), "epoch counts diverged"
    assert np.array_equal(a.seq_screened, b.seq_screened)
    assert np.array_equal(a.dyn_screened, b.dyn_screened)
    assert np.array_equal(a.group_active, b.group_active)
    assert (a.n_compact_rounds, a.n_full_rounds) == \
        (b.n_compact_rounds, b.n_full_rounds), "round split diverged"
    assert a.rule_name == b.rule_name == "gap"
    emit("sweep_rules", f"parity_T{T}_tol{tol:g}", "gap_string_object_ok", 1)


def build_payload(curves: dict, config_note: str) -> dict:
    return {
        "meta": {
            "config": config_note,
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "x64": bool(jax.config.read("jax_enable_x64")),
        },
        "rows": [
            {"benchmark": b, "case": c, "metric": m, "value": v}
            for b, c, m, v in rows()
        ],
        "curves": curves,
    }


def write_payload(path: str, payload: dict) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(payload['curves'])} curves + "
          f"{len(payload['rows'])} rows -> {path}")


def sweep(problems, T_list, tols, max_epochs, check_safety=False,
          smoke=False, loss="lsq") -> dict:
    curves = {}
    rule_names = rules_for_loss(loss)
    for problem, cfg_name in problems:
        problem, cfg_name = for_loss(problem, cfg_name, loss)
        for T in T_list:
            delta = 2.0 if smoke else 3.0
            gap_string_object_parity(problem, T, delta, max(tols),
                                     max_epochs, loss=loss)
            beta_ref = None
            if check_safety:
                # tol-independent (tight-tol unscreened oracle): computed
                # once per (config, T), shared by every tol cell below.
                from repro.core.session import lambda_grid

                session0 = SGLSession(problem, SolverConfig(loss=loss))
                lambdas = lambda_grid(session0.lam_max, T=T, delta=delta)
                beta_ref = _unscreened_reference(problem, lambdas,
                                                 loss=loss)
            for tol in tols:
                for rule_name in rule_names:
                    key = f"{cfg_name}/{rule_name}/T{T}/tol{tol:g}"
                    curve, _ = run_cell(
                        problem, cfg_name, rule_name, T, delta, tol,
                        max_epochs, beta_ref=beta_ref, loss=loss,
                    )
                    curves[key] = curve
    return curves


def assert_smoke_invariants(curves: dict, loss: str = "lsq") -> None:
    """The CI contract: safe rules are SAFE, GAP dominates the lsq-only
    sphere baselines (or, off-lsq, the unscreened baseline — the only
    safe comparator whose geometry still holds), unsafe rules flagged."""
    by_rule: dict = {}
    for c in curves.values():
        by_rule.setdefault(c["rule"], []).append(c)
    for rule_name, cells in by_rule.items():
        for c in cells:
            if c["safe"]:
                assert c.get("safety_violations", 0) == 0, (
                    f"SAFE rule {rule_name!r} screened a nonzero variable: "
                    f"{c['safety_violations']} violations in {c['config']}"
                )
    baselines = ("static", "dynamic") if loss == "lsq" else ("none",)
    for rule_name in baselines:
        for gap_c, base_c in zip(by_rule["gap"], by_rule[rule_name]):
            gap_act = sum(gap_c["active_feat_frac"])
            # Strict-or-equal: the GAP sphere shrinks with the gap, the
            # baselines don't — at convergence GAP's active set can only
            # be smaller (paper Fig. 2), modulo float ties.
            assert gap_act <= sum(base_c["active_feat_frac"]) + 1e-9, (
                f"GAP did not dominate the {rule_name!r} baseline on "
                f"screened fraction (loss={loss})"
            )
    assert not by_rule["strong"][0]["safe"]
    print(f"SWEEP SMOKE PASS (loss={loss}): safety matrix + GAP dominance "
          "+ unsafe flag")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized matrix asserting the safety/dominance/"
                         "flag invariants")
    ap.add_argument("--paper", action="store_true",
                    help="full synthetic paper grid (T=40, tol down to "
                         "1e-8) — CPU-hours")
    ap.add_argument("--check-safety", action="store_true",
                    help="audit every rule's masks against a tight-tol "
                         "unscreened reference (always on in --smoke)")
    ap.add_argument("--loss", default="lsq",
                    choices=[n for n in available_losses()
                             if n != "multitask"],
                    help="data-fidelity loss (SolverConfig.loss); "
                         "'logistic' binarizes the responses and restricts "
                         "the matrix to rules whose spheres hold off-lsq")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON payload (BENCH_pr5.json schema)")
    ap.add_argument("--md", metavar="PATH", default=None,
                    help="write the Fig. 2/3 markdown report")
    args = ap.parse_args()
    header()

    if args.smoke:
        problems = [synthetic_paper_problem(smoke=True),
                    climate_problem(smoke=True)]
        curves = sweep(problems, T_list=(8,), tols=(1e-7,),
                       max_epochs=20_000, check_safety=True, smoke=True,
                       loss=args.loss)
        note = (f"smoke matrix (reduced synthetic + climate-like), "
                f"loss={args.loss}")
    elif args.paper:
        problems = [synthetic_paper_problem(), climate_problem()]
        curves = sweep(problems, T_list=(40,), tols=(1e-4, 1e-6, 1e-8),
                       max_epochs=10_000,
                       check_safety=args.check_safety, loss=args.loss)
        note = (f"synthetic paper config n=100 p=2000 G=200 (T=40, "
                f"max_epochs=10000) + climate-like, loss={args.loss}")
    else:
        problems = [synthetic_paper_problem(), climate_problem()]
        curves = sweep(problems, T_list=(20,), tols=(1e-4, 1e-6),
                       max_epochs=3000, check_safety=args.check_safety,
                       loss=args.loss)
        note = (f"synthetic paper config n=100 p=2000 G=200 (T=20, "
                f"max_epochs=3000) + climate-like, loss={args.loss}")

    # Artifacts are written BEFORE the smoke assertions run: when a CI
    # invariant fails, the uploaded curves are exactly what explains it.
    payload = build_payload(curves, note)
    if args.json:
        write_payload(args.json, payload)
    if args.md:
        with open(args.md, "w") as f:
            f.write(render_sweep_markdown(payload))
            f.write("\n")
        print(f"wrote {args.md}")
    if args.smoke:
        assert_smoke_invariants(curves, loss=args.loss)


if __name__ == "__main__":
    main()
