"""Serving the sparse-group lasso path solver: start the serve loop,
submit a handful of tenant requests, and watch them coalesce.

    PYTHONPATH=src python examples/serve_sgl.py

Three tenants ask for the identical path (one coalesced solve serves
all three, betas bit-identical to a solo run), a fourth repeats the
request later (served straight from the certificate store, zero solver
work), and a fifth re-solves a perturbed ``y`` on the tail of the grid
(warm-started from the stored path — the stored state seeds the solver
but every screening decision is re-certified by a fresh GAP round, so
the perturbed solve's certificates are its own).
"""
import numpy as np

from repro.core import sgl
from repro.core.session import SolverConfig, lambda_grid
from repro.data.synthetic import make_synthetic
from repro.serve import PathRequest, ServeConfig, SGLServer


def main():
    X, y, _beta, sizes = make_synthetic(
        n=64, p=512, n_groups=64, gamma1=3, gamma2=3, seed=11)
    problem = sgl.make_problem(X, y, sizes, tau=0.3)
    grid = lambda_grid(float(sgl.lambda_max(problem)), T=10, delta=0.5)

    server = SGLServer(ServeConfig(
        default_solver=SolverConfig(tol=1e-7, max_epochs=20_000),
        coalesce_window_s=0.1,
    )).start()
    try:
        # Wave 1: three tenants, identical request -> one solve.
        futs = [server.submit(PathRequest(f"tenant-{i}", problem, grid))
                for i in range(3)]
        wave1 = [f.result(timeout=600) for f in futs]
        for r in wave1:
            print(f"{r.tenant}: served_from={r.served_from} "
                  f"coalesced_n={r.coalesced_n} "
                  f"seq_screened={int(np.sum(r.result.seq_screened))}")
        assert all(np.array_equal(r.result.betas, wave1[0].result.betas)
                   for r in wave1)

        # Wave 2: exact repeat (store hit) + perturbed-y tail re-solve
        # (warm start from the stored path, certificates re-earned).
        rng = np.random.default_rng(0)
        problem2 = sgl.make_problem(
            X, y + 0.02 * rng.standard_normal(y.shape), sizes, tau=0.3)
        repeat = server.submit(PathRequest("tenant-3", problem, grid))
        perturbed = server.submit(
            PathRequest("tenant-4", problem2, grid[len(grid) // 2:]))
        r3, r4 = repeat.result(timeout=600), perturbed.result(timeout=600)
        print(f"{r3.tenant}: served_from={r3.served_from} "
              f"(exact repeat, no solver work)")
        print(f"{r4.tenant}: served_from={r4.served_from} "
              f"warm_started={r4.warm_started} "
              f"warm_source_lam={r4.warm_source_lam} "
              f"certificates_safe={r4.result.certificates_safe}")
        assert r3.store_hit
        assert r4.result.certificates_safe
    finally:
        server.stop()

    stats = server.stats()
    print(f"requests={stats['requests']} "
          f"path_solves={stats['path_solves']} "
          f"coalesced={stats['coalesced_requests']} "
          f"store_served={stats['store_served']} "
          f"warm_started={stats['warm_started']}")
    print(f"session cache: {stats['cache']}")
    print(f"certificate store: {stats['store']}")
    assert stats["path_solves"] < stats["requests"]
    print("serve_sgl OK")


if __name__ == "__main__":
    main()
