"""Sparse-Group Lasso problem definition (paper Sections 3 and 5).

Primal (Eq. 5):   P(beta) = 1/2 ||y - X beta||^2 + lambda Omega_{tau,w}(beta)
Norm  (Eq. 10):   Omega_{tau,w}(beta) = tau ||beta||_1
                                        + (1 - tau) sum_g w_g ||beta_g||
Dual  (Eq. 6):    D(theta) = 1/2 ||y||^2 - lambda^2/2 ||theta - y/lambda||^2
                  over  Delta = {theta : Omega^D(X^T theta) <= 1}.

Group representation
--------------------
Groups are a partition of [p].  The in-memory layout is *grouped*: the design
matrix is carried as ``X`` of shape ``(n, G, ng)`` (groups zero-padded to the
max group size) and coefficients as ``beta`` of shape ``(G, ng)``.  A boolean
``feat_mask`` of shape (G, ng) marks real features.  This makes every
group-level quantity a reduction over the trailing axis — the layout XLA/TPU
wants — and exactly matches the paper's experiments (equal-size groups of 10
and 7).  ``flatten``/``unflatten`` convert to the flat (p,) view.

Everything here is pure and jit-compatible.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .epsilon_norm import lam

__all__ = [
    "SGLProblem",
    "make_problem",
    "problem_from_grouped",
    "flatten",
    "unflatten",
    "sgl_norm",
    "sgl_dual_norm",
    "sgl_dual_norm_terms",
    "primal",
    "dual",
    "duality_gap",
    "dual_scale",
    "lambda_max",
    "primal_loss",
    "dual_loss",
    "duality_gap_loss",
    "dual_scale_loss",
    "lambda_max_loss",
    "multitask_norm",
    "multitask_dual_norm_terms",
    "multitask_dual_norm",
    "multitask_primal",
    "multitask_dual",
    "multitask_duality_gap",
    "multitask_dual_scale",
    "multitask_lambda_max",
    "multitask_group_screen",
    "soft_threshold",
    "group_soft_threshold",
    "sgl_prox",
    "epsilons",
    "group_weight_total",
]


class SGLProblem(NamedTuple):
    """Static data of one SGL instance, in grouped layout."""

    X: jax.Array          # (n, G, ng) zero-padded design matrix
    y: jax.Array          # (n,)
    w: jax.Array          # (G,) group weights (paper: w_g = sqrt(n_g))
    tau: jax.Array        # scalar in [0, 1]
    feat_mask: jax.Array  # (G, ng) bool, True for real features
    Lg: jax.Array         # (G,) block Lipschitz constants ||X_g||_2^2
    Xnorm_col: jax.Array  # (G, ng) column norms ||X_j||
    Xnorm_grp: jax.Array  # (G,) spectral norms ||X_g||_2

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def G(self) -> int:
        return self.X.shape[1]

    @property
    def ng(self) -> int:
        return self.X.shape[2]


def _group_spectral_norms(Xg: jax.Array, n_iter: int = 50) -> jax.Array:
    """||X_g||_2 for each group via power iteration on X_g^T X_g.

    Xg: (n, G, ng) -> (G,).  Deterministic start vector (ones) is fine for
    PSD Gram matrices (converges to top eigenpair unless orthogonal start,
    which the added tiny perturbation avoids).
    """
    G, ng = Xg.shape[1], Xg.shape[2]
    gram = jnp.einsum("nga,ngb->gab", Xg, Xg)  # (G, ng, ng)

    v0 = jnp.ones((G, ng), gram.dtype)
    v0 = v0 + 1e-3 * jnp.arange(ng, dtype=gram.dtype)[None, :]
    v0 = v0 / jnp.linalg.norm(v0, axis=-1, keepdims=True)

    def body(_, v):
        u = jnp.einsum("gab,gb->ga", gram, v)
        nrm = jnp.linalg.norm(u, axis=-1, keepdims=True)
        return u / jnp.maximum(nrm, 1e-30)

    v = jax.lax.fori_loop(0, n_iter, body, v0)
    ev = jnp.einsum("ga,gab,gb->g", v, gram, v)
    return jnp.maximum(ev, 0.0)  # == ||X_g||_2^2 estimate's eigenvalue


def make_problem(
    X_flat: jax.Array,
    y: jax.Array,
    group_sizes,
    tau: float,
    w=None,
) -> SGLProblem:
    """Build an :class:`SGLProblem` from a flat (n, p) design matrix.

    ``group_sizes``: python sequence of ints summing to p (contiguous groups).
    ``w``: group weights; defaults to sqrt(n_g) (paper Section 7.1).
    """
    X_flat = jnp.asarray(X_flat)
    y = jnp.asarray(y, X_flat.dtype)
    sizes = [int(s) for s in group_sizes]
    n, p = X_flat.shape
    assert sum(sizes) == p, (sum(sizes), p)
    G = len(sizes)
    ng = max(sizes)

    Xg = jnp.zeros((n, G, ng), X_flat.dtype)
    mask = jnp.zeros((G, ng), bool)
    off = 0
    for g, s in enumerate(sizes):
        Xg = Xg.at[:, g, :s].set(X_flat[:, off : off + s])
        mask = mask.at[g, :s].set(True)
        off += s

    if w is None:
        w = jnp.sqrt(jnp.asarray(sizes, X_flat.dtype))
    else:
        w = jnp.asarray(w, X_flat.dtype)

    Lg = _group_spectral_norms(Xg)
    # Padded groups/columns: keep Lg > 0 guard at use sites.
    col = jnp.linalg.norm(Xg, axis=0)  # (G, ng)
    return SGLProblem(
        X=Xg,
        y=y,
        w=w,
        tau=jnp.asarray(tau, X_flat.dtype),
        feat_mask=mask,
        Lg=Lg,
        Xnorm_col=col,
        Xnorm_grp=jnp.sqrt(Lg),
    )


def problem_from_grouped(
    X: jax.Array,
    y: jax.Array,
    tau: float,
    w=None,
    feat_mask=None,
) -> SGLProblem:
    """Build an :class:`SGLProblem` directly from a grouped (n, G, ng) design.

    Cheap constructor: column norms are exact, but the per-group spectral
    norm ``Xnorm_grp`` (and hence ``Lg``) uses the Frobenius upper bound
    ``||X_g||_F >= ||X_g||_2`` instead of a power iteration.  An upper bound
    keeps both consumers valid — Theorem-1 tests stay *safe* (larger radius
    term means fewer, never wrong, screens) and block-Lipschitz BCD steps
    stay convergent (smaller steps).  This is the constructor behind the
    raw-array ``solve_distributed`` wrapper, where the mesh kernels
    recompute their own sharded norms anyway.

    ``feat_mask`` defaults to the all-zero-column test (matching the
    zero-padding convention of :func:`make_problem`).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    if feat_mask is None:
        feat_mask = jnp.any(X != 0, axis=0)           # (G, ng)
    else:
        feat_mask = jnp.asarray(feat_mask, bool)
    if w is None:
        w = jnp.sqrt(jnp.sum(feat_mask, axis=-1).astype(X.dtype))
    else:
        w = jnp.asarray(w, X.dtype)
    col = jnp.linalg.norm(X, axis=0)                  # (G, ng)
    fro2 = jnp.sum(X * X, axis=(0, 2))                # ||X_g||_F^2  (G,)
    return SGLProblem(
        X=X,
        y=y,
        w=w,
        tau=jnp.asarray(tau, X.dtype),
        feat_mask=feat_mask,
        Lg=fro2,
        Xnorm_col=col,
        Xnorm_grp=jnp.sqrt(fro2),
    )


def flatten(problem: SGLProblem, beta_g: jax.Array) -> jax.Array:
    """Grouped (G, ng) -> flat (p,) coefficient view."""
    return beta_g[problem.feat_mask]


def unflatten(problem: SGLProblem, beta_flat: jax.Array) -> jax.Array:
    """Flat (p,) -> grouped (G, ng) coefficient view (inverse of
    :func:`flatten`; padded slots come back zero).

    jit-compatible: the scatter is expressed as a cumulative-count gather
    over the static ``feat_mask`` rather than boolean indexing.
    """
    mask = jnp.ravel(problem.feat_mask)
    beta_flat = jnp.asarray(beta_flat)
    pos = jnp.cumsum(mask) - 1                         # flat slot -> (p,) index
    vals = jnp.take(beta_flat, jnp.clip(pos, 0, beta_flat.shape[0] - 1))
    vals = jnp.where(mask, vals, 0)
    return vals.reshape(problem.feat_mask.shape).astype(beta_flat.dtype)


# ----------------------------------------------------------------------------
# Norm, dual norm, objectives
# ----------------------------------------------------------------------------

def epsilons(tau: jax.Array, w: jax.Array) -> jax.Array:
    """eps_g = (1-tau) w_g / (tau + (1-tau) w_g)   (paper Eq. 18)."""
    denom = tau + (1.0 - tau) * w
    return jnp.where(denom > 0, (1.0 - tau) * w / jnp.where(denom > 0, denom, 1.0), 0.0)


def group_weight_total(tau: jax.Array, w: jax.Array) -> jax.Array:
    """tau + (1-tau) w_g — the per-group scaling of the eps-norm duality."""
    return tau + (1.0 - tau) * w


def sgl_norm(beta: jax.Array, tau, w) -> jax.Array:
    """Omega_{tau,w}(beta) for grouped beta (G, ng) (padding must be zero)."""
    l1 = jnp.sum(jnp.abs(beta))
    l2 = jnp.sum(w * jnp.linalg.norm(beta, axis=-1))
    return tau * l1 + (1.0 - tau) * l2


def sgl_dual_norm_terms(xi: jax.Array, tau, w) -> jax.Array:
    """Per-group terms of Omega^D: ||xi_g||_{eps_g} / (tau + (1-tau) w_g).

    The dual norm (Eq. 20) is the max of these; the compacted certified
    round (:mod:`repro.core.solver`) needs them individually — each screened
    group's term at a reference residual is cached so later rounds can bound
    it without re-touching that group's columns.  xi: grouped (G, ng) or any
    (..., ng) batch with w broadcastable to the leading shape.
    """
    xi = jnp.asarray(xi)
    eps = epsilons(tau, xi.dtype.type(1) * jnp.asarray(w, xi.dtype))
    scale = group_weight_total(tau, jnp.asarray(w, xi.dtype))
    return lam(xi, 1.0 - eps, eps) / scale


def sgl_dual_norm(xi: jax.Array, tau, w) -> jax.Array:
    """Omega^D(xi) = max_g ||xi_g||_{eps_g} / (tau + (1-tau) w_g)  (Eq. 20).

    xi: grouped (G, ng) (padded entries must be 0 — they are then inert:
    S_threshold of 0 contributes nothing).
    """
    return jnp.max(sgl_dual_norm_terms(xi, tau, w))


def primal(problem: SGLProblem, beta: jax.Array, lam_: jax.Array) -> jax.Array:
    resid = problem.y - jnp.einsum("ngk,gk->n", problem.X, beta)
    return 0.5 * jnp.sum(resid * resid) + lam_ * sgl_norm(
        beta, problem.tau, problem.w
    )


def dual(problem: SGLProblem, theta: jax.Array, lam_: jax.Array) -> jax.Array:
    d = theta - problem.y / lam_
    return 0.5 * jnp.sum(problem.y * problem.y) - 0.5 * lam_ * lam_ * jnp.sum(d * d)


def duality_gap(
    problem: SGLProblem, beta: jax.Array, theta: jax.Array, lam_: jax.Array
) -> jax.Array:
    return primal(problem, beta, lam_) - dual(problem, theta, lam_)


def dual_scale(problem: SGLProblem, resid: jax.Array, lam_: jax.Array) -> jax.Array:
    """Dual feasible point from a residual (paper Eq. 15):

        theta = resid / max(lambda, Omega^D(X^T resid)).
    """
    corr = jnp.einsum("ngk,n->gk", problem.X, resid)
    scale = jnp.maximum(lam_, sgl_dual_norm(corr, problem.tau, problem.w))
    return resid / scale


def lambda_max(problem: SGLProblem) -> jax.Array:
    """lambda_max = Omega^D(X^T y)   (paper Eq. 22)."""
    corr = jnp.einsum("ngk,n->gk", problem.X, problem.y)
    return sgl_dual_norm(corr, problem.tau, problem.w)


# ----------------------------------------------------------------------------
# Loss-generalized objectives (journal follow-up arXiv 1611.05780)
# ----------------------------------------------------------------------------
#
# The quartet below generalizes primal/dual/gap/lambda_max to any
# registered :class:`repro.losses.Loss`:
#
#     P(beta)  = F(X beta) + lam * Omega_{tau,w}(beta)
#     D(theta) = -F*(-lam * theta)
#     rho      = -grad F(X beta)        (the generalized residual)
#     theta    = rho / max(lam, Omega^D(X^T rho))      (Eq. 15, verbatim)
#     lam_max  = Omega^D(X^T rho_0),  rho_0 = -grad F(0)
#
# The ``loss.name == "lsq"`` branches delegate to the original functions
# above *verbatim* — the default loss must produce bit-identical jitted
# programs to the pre-loss solver (asserted by tests/test_losses.py).

def primal_loss(problem: SGLProblem, loss, beta: jax.Array,
                lam_: jax.Array) -> jax.Array:
    """``F(X beta) + lam * Omega`` for any registered loss."""
    if loss.name == "lsq":
        return primal(problem, beta, lam_)
    z = jnp.einsum("ngk,gk->n", problem.X, beta)
    return loss.value(problem.y, z) + lam_ * sgl_norm(
        beta, problem.tau, problem.w
    )


def dual_loss(problem: SGLProblem, loss, theta: jax.Array,
              lam_: jax.Array) -> jax.Array:
    """``D(theta) = -F*(-lam theta)`` for any registered loss."""
    if loss.name == "lsq":
        return dual(problem, theta, lam_)
    return loss.dual_obj(problem.y, theta, lam_)


def duality_gap_loss(problem: SGLProblem, loss, beta: jax.Array,
                     theta: jax.Array, lam_: jax.Array) -> jax.Array:
    if loss.name == "lsq":
        return duality_gap(problem, beta, theta, lam_)
    return primal_loss(problem, loss, beta, lam_) - dual_loss(
        problem, loss, theta, lam_
    )


def dual_scale_loss(problem: SGLProblem, loss, beta: jax.Array,
                    lam_: jax.Array) -> jax.Array:
    """Dual feasible point from the loss gradient (Eq. 15 generalized):
    ``theta = rho / max(lam, Omega^D(X^T rho))``, ``rho = -grad F(X beta)``.

    The ``>= lam`` floor keeps ``-lam theta`` inside the conjugate's
    domain for bounded-domain losses (logistic), so the gap is finite.
    """
    if loss.name == "lsq":
        resid = problem.y - jnp.einsum("ngk,gk->n", problem.X, beta)
        return dual_scale(problem, resid, lam_)
    z = jnp.einsum("ngk,gk->n", problem.X, beta)
    rho = loss.neg_grad(problem.y, z)
    corr = jnp.einsum("ngk,n->gk", problem.X, rho)
    scale = jnp.maximum(lam_, sgl_dual_norm(corr, problem.tau, problem.w))
    return rho / scale


def lambda_max_loss(problem: SGLProblem, loss) -> jax.Array:
    """``lam_max = Omega^D(X^T rho_0)`` with ``rho_0 = -grad F(0)``
    (lsq: Eq. 22 verbatim; logistic: ``rho_0 = y - 1/2``)."""
    if loss.name == "lsq":
        return lambda_max(problem)
    rho0 = loss.lam_max_rho(problem.y)
    corr = jnp.einsum("ngk,n->gk", problem.X, rho0)
    return sgl_dual_norm(corr, problem.tau, problem.w)


# ----------------------------------------------------------------------------
# Multi-task SGL math (arXiv 1506.03736): matrix-valued beta (G, ng, K)
# ----------------------------------------------------------------------------
#
# The penalty becomes row-group norms:
#
#     Omega(B) = tau * sum_{g,j} ||B[g, j, :]||_2
#                + (1 - tau) * sum_g w_g ||B_g||_F
#
# i.e. the vector SGL norm applied to the matrix of row norms
# R[g, j] = ||B[g, j, :]||_2 — which means the dual norm REDUCES to the
# vector machinery: for a dual variable xi (G, ng, K), the sup over
# {B : Omega(B) <= 1} of <xi, B> factors through rows (each row of B
# only enters via its own l2 norm, and <xi_row, b_row> <= ||xi_row||_2
# * ||b_row||_2 with equality for aligned rows), so
#
#     Omega^D(xi) = vector-SGL-dual-norm of the row-norm matrix
#                   R'[g, j] = ||xi[g, j, :]||_2.
#
# The epsilon-norm only sees |x_j|, so feeding it row norms is exact.
# These helpers take raw arrays (Y is (n, K), beta (G, ng, K)) because
# :class:`SGLProblem` carries a (n,) response; the session-level solver
# threading is future work (SGLSession rejects multi_output losses).

def multitask_norm(beta: jax.Array, tau, w) -> jax.Array:
    """Row-group SGL norm of matrix-valued beta (G, ng, K)."""
    rows = jnp.linalg.norm(beta, axis=-1)           # (G, ng)
    l1 = jnp.sum(rows)
    l2 = jnp.sum(w * jnp.linalg.norm(rows, axis=-1))
    return tau * l1 + (1.0 - tau) * l2


def multitask_dual_norm_terms(xi: jax.Array, tau, w) -> jax.Array:
    """Per-group dual-norm terms of the row-group norm: the vector terms
    (Eq. 20) evaluated on the row-norm matrix (see the reduction above)."""
    rows = jnp.linalg.norm(xi, axis=-1)             # (G, ng)
    return sgl_dual_norm_terms(rows, tau, w)


def multitask_dual_norm(xi: jax.Array, tau, w) -> jax.Array:
    return jnp.max(multitask_dual_norm_terms(xi, tau, w))


def multitask_primal(X: jax.Array, Y: jax.Array, beta: jax.Array,
                     tau, w, lam_) -> jax.Array:
    """``0.5 ||Y - X beta||_F^2 + lam * Omega`` (X (n,G,ng), Y (n,K))."""
    R = Y - jnp.einsum("ngk,gkt->nt", X, beta)
    return 0.5 * jnp.sum(R * R) + lam_ * multitask_norm(beta, tau, w)


def multitask_dual(Y: jax.Array, theta: jax.Array, lam_) -> jax.Array:
    """Quadratic dual at matrix-valued theta (n, K)."""
    d = theta - Y / lam_
    return 0.5 * jnp.sum(Y * Y) - 0.5 * lam_ * lam_ * jnp.sum(d * d)


def multitask_duality_gap(X: jax.Array, Y: jax.Array, beta: jax.Array,
                          theta: jax.Array, tau, w, lam_) -> jax.Array:
    return multitask_primal(X, Y, beta, tau, w, lam_) - multitask_dual(
        Y, theta, lam_
    )


def multitask_dual_scale(X: jax.Array, Y: jax.Array, beta: jax.Array,
                         tau, w, lam_) -> jax.Array:
    """Eq. 15 on the matrix residual: theta = R / max(lam, Omega^D(X^T R))."""
    R = Y - jnp.einsum("ngk,gkt->nt", X, beta)
    corr = jnp.einsum("ngk,nt->gkt", X, R)
    scale = jnp.maximum(lam_, multitask_dual_norm(corr, tau, w))
    return R / scale


def multitask_lambda_max(X: jax.Array, Y: jax.Array, tau, w) -> jax.Array:
    corr = jnp.einsum("ngk,nt->gkt", X, Y)
    return multitask_dual_norm(corr, tau, w)


def multitask_group_screen(corr: jax.Array, radius, Xnorm_grp: jax.Array,
                           tau, w) -> jax.Array:
    """Conservative safe group test for the multi-task GAP sphere.

    For the GAP sphere B(theta, r), group g can be discarded when
    ``sup_{||Z||_F <= r} Omega^D_g(X_g^T (theta + Z)) < 1``.  We bound
    the sup by ``Omega^D_g(X_g^T theta) + r ||X_g||_2 / (tau +
    (1-tau) w_g)`` — the second factor because ``Omega_g(B_g) >= (tau +
    (1-tau) w_g) ||B_g||_F`` (every row contributes at least its own
    norm to both the l1-of-rows and the Frobenius term), hence
    ``Omega^D_g(V) <= ||V||_F / (tau + (1-tau) w_g)``.  Conservative
    (never screens a group the exact test would keep), hence safe.

    ``corr``: X^T theta in grouped layout (G, ng, K).  Returns (G,) bool,
    True = group survives (may be active).
    """
    terms = multitask_dual_norm_terms(corr, tau, w)   # (G,)
    slack = radius * Xnorm_grp / group_weight_total(tau, jnp.asarray(w))
    return terms + slack >= 1.0


# ----------------------------------------------------------------------------
# Proximal operators
# ----------------------------------------------------------------------------

def soft_threshold(x: jax.Array, thr) -> jax.Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - thr, 0.0)


def group_soft_threshold(x: jax.Array, thr) -> jax.Array:
    """S^gp_thr(x) = (1 - thr/||x||)_+ x over the trailing axis."""
    nrm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    scale = jnp.maximum(1.0 - thr / jnp.maximum(nrm, 1e-30), 0.0)
    return jnp.where(nrm > 0, scale * x, 0.0)


def sgl_prox(beta: jax.Array, step, tau, w, lam_) -> jax.Array:
    """prox of step * lambda * Omega_{tau,w} at grouped beta (G, ng):
    two-level soft-thresholding (paper Section 6).

    ``step`` may be a scalar or per-group (G,) array (1/L_g for BCD).
    """
    step = jnp.asarray(step)
    if step.ndim == 1:
        step = step[:, None]
    a = soft_threshold(beta, tau * lam_ * step)
    thr = ((1.0 - tau) * lam_ * jnp.asarray(w))[:, None] * step
    return group_soft_threshold_keep(a, thr)


def group_soft_threshold_keep(x: jax.Array, thr: jax.Array) -> jax.Array:
    """Group soft-threshold with per-group threshold array (G, 1)."""
    nrm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    scale = jnp.maximum(1.0 - thr / jnp.maximum(nrm, 1e-30), 0.0)
    return jnp.where(nrm > 0, scale * x, 0.0)
