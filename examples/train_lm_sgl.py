"""End-to-end driver: train a small LM with the paper's SGL structured
sparsity as a first-class training feature.

    PYTHONPATH=src python examples/train_lm_sgl.py --steps 300

Trains the registry's tiny dense 'demo' transformer on a synthetic
copy-task corpus for a few hundred steps with:

  * AdamW + next-token cross entropy,
  * the SGL two-level prox (train/sgl_regularizer.py) applied to FFN
    neuron groups after each optimizer step — the paper's penalty driving
    *structured* (neuron-level) and unstructured sparsity jointly,
  * checkpoint/restart via ckpt.CheckpointManager (kill it mid-run and
    re-invoke: it resumes from the last checkpoint),
  * group-sparsity telemetry (how many FFN neurons the prox zeroed).
"""
import argparse
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get
from repro.models import build
from repro.train.train_step import make_train_step
from repro.train.sgl_regularizer import SGLRegConfig, group_sparsity


def synthetic_batch(rng, batch, seq, vocab):
    """Copy task: second half of each sequence repeats the first half."""
    half = seq // 2
    first = rng.integers(2, vocab, size=(batch, half))
    toks = np.concatenate([first, first], axis=1)
    return {"tokens": jnp.asarray(toks, jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sgl-lam", type=float, default=3e-4)
    ap.add_argument("--sgl-tau", type=float, default=0.3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_sgl_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get("demo").reduced()
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch=demo: {n_params / 1e6:.2f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model}")

    sgl_cfg = SGLRegConfig(lam=args.sgl_lam, tau=args.sgl_tau)
    init_state, train_step = make_train_step(
        api, lr=args.lr, sgl_cfg=sgl_cfg, q_chunk=args.seq
    )
    opt_state = init_state(params)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))

    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every, keep=2)
    start, restored = mgr.restore_latest((params, opt_state))
    if restored is not None:
        params, opt_state = restored
        print(f"resumed from checkpoint at step {start}")
    start = start or 0

    rng = np.random.default_rng(start)  # deterministic resume
    for step in range(start, args.steps):
        batch = synthetic_batch(rng, args.batch, args.seq, cfg.vocab)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        mgr.maybe_save(step + 1, (params, opt_state))
        if step % 20 == 0 or step == args.steps - 1:
            sp = group_sparsity(params)
            neuron_zero = float(np.mean(list(sp.values()))) if sp else 0.0
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  "
                  f"ffn_neurons_zero {neuron_zero:.1%}")

    final = float(metrics["loss"])
    print(f"\nfinal loss {final:.4f} "
          f"({'converging' if final < 2.0 else 'check hyperparameters'})")


if __name__ == "__main__":
    main()
