"""SGL + Elastic Net via design augmentation (paper Appendix D)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import lambda_max, make_problem, solve, flatten
from repro.core.elastic import elastic_objective, make_elastic_problem
from repro.data.synthetic import make_synthetic


@pytest.fixture(scope="module")
def data():
    return make_synthetic(n=40, p=120, n_groups=12, gamma1=3, gamma2=3,
                          seed=7)


def test_augmented_solution_minimises_elastic_objective(data):
    X, y, _, sizes = data
    tau, lam2 = 0.3, 0.5
    problem = make_elastic_problem(X, y, sizes, tau=tau, lam2=lam2)
    lam1 = float(lambda_max(problem)) / 10.0
    res = solve(problem, lam1, tol=1e-10, rule="gap")
    beta = np.asarray(flatten(problem, res.beta))

    w = np.sqrt([float(s) for s in sizes])
    f_star = float(elastic_objective(X, y, beta, tau, w, lam1, lam2, sizes))

    # perturbations cannot decrease a (strongly convex) optimum
    rng = np.random.default_rng(0)
    for _ in range(10):
        d = rng.standard_normal(beta.shape) * 1e-3
        f_pert = float(elastic_objective(X, y, beta + d, tau, w,
                                         lam1, lam2, sizes))
        assert f_pert >= f_star - 1e-9


def test_ridge_shrinks_coefficients(data):
    X, y, _, sizes = data
    tau = 0.3
    p0 = make_elastic_problem(X, y, sizes, tau=tau, lam2=0.0)
    lam1 = float(lambda_max(p0)) / 10.0
    b0 = solve(p0, lam1, tol=1e-8).beta
    p1 = make_elastic_problem(X, y, sizes, tau=tau, lam2=50.0)
    b1 = solve(p1, lam1, tol=1e-8).beta
    assert float(jnp.linalg.norm(b1)) < float(jnp.linalg.norm(b0))


def test_lam2_zero_matches_plain_sgl(data):
    X, y, _, sizes = data
    tau = 0.3
    pe = make_elastic_problem(X, y, sizes, tau=tau, lam2=0.0)
    pp = make_problem(X, y, sizes, tau=tau)
    lam1 = float(lambda_max(pp)) / 10.0
    be = solve(pe, lam1, tol=1e-10).beta
    bp = solve(pp, lam1, tol=1e-10).beta
    np.testing.assert_allclose(np.asarray(be), np.asarray(bp), atol=1e-6)


def test_screening_safe_under_augmentation(data):
    X, y, _, sizes = data
    problem = make_elastic_problem(X, y, sizes, tau=0.3, lam2=1.0)
    lam1 = float(lambda_max(problem)) / 5.0
    res_g = solve(problem, lam1, tol=1e-10, rule="gap")
    res_n = solve(problem, lam1, tol=1e-10, rule="none")
    np.testing.assert_allclose(
        np.asarray(res_g.beta), np.asarray(res_n.beta), atol=1e-7
    )
