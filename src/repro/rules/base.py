"""Screening-rule strategy protocol: the one sphere-test skeleton.

Every safe screening rule in the GAP-safe literature (and the follow-up
"Gap Safe Screening Rules for Sparsity Enforcing Penalties", Ndiaye et al.
2017) is the SAME two-step test instantiated with a different *safe
sphere*:

1. construct a ball B(theta_c, r) guaranteed (or, for unsafe heuristics,
   hoped) to contain the dual optimum theta_hat;
2. run the Theorem-1 group/feature tests of
   :func:`repro.core.screening.theorem1_tests` against it.

Step 2 — together with the dual scaling (Eq. 15), the duality-gap
computation, the Pallas corr/dual-norm kernel routing, the compacted-round
bound, and the transposed-design audit — lives in the shared round skeleton
(:func:`repro.core.solver._screen_round`); a :class:`ScreeningRule` only
supplies step 1 via :meth:`ScreeningRule.center_and_radius`, so every rule
inherits the whole execution machinery for free.

Rule instances are **frozen, hashable value objects**: the round skeleton
is jitted with the rule as a static argument, so two equal instances share
one compiled program.  They deliberately import nothing from
:mod:`repro.core` at module-import time (the solver imports *us*); a rule
needing core helpers (e.g. the DST3 sphere construction) imports them
lazily inside its method, which runs at trace time when the core package
is fully initialised.

Safety contract
---------------
``is_safe=True`` asserts the sphere returned by ``center_and_radius``
*provably* contains the dual optimum for every state the skeleton can hand
it (any dual-feasible ``theta``, any primal ``beta``).  Everything
downstream trusts this bit: certified masks are permanent, the session
reports them as zero-certificates, and the path recorder intersects them
into :class:`repro.core.session.PathResult`.  A rule that cannot prove
containment MUST set ``is_safe=False`` — the session then flags every
round (:class:`repro.core.solver.RoundResult` ``safe=False``) and the path
result (``certificates_safe=False``) so heuristic discards are never
mistaken for certificates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax

__all__ = ["RuleState", "ScreeningRule"]


class RuleState(NamedTuple):
    """Everything the shared round skeleton has already computed when it
    asks a rule for its sphere — rules read from here instead of paying
    their own O(n p) passes.

    All array members are (possibly traced) jax values; ``problem`` is the
    :class:`repro.core.sgl.SGLProblem` pytree.
    """

    problem: Any          # SGLProblem (y, X, tau, w, feat_mask, norms...)
    beta: jax.Array       # (G, ng) current primal point
    resid: jax.Array      # (n,) y - X beta
    corr: jax.Array       # (G, ng) X^T resid, grouped
    scale: jax.Array      # max(lam, Omega^D(corr)) — Eq. 15 dual scaling
    theta: jax.Array      # (n,) resid / scale, dual feasible
    gap: jax.Array        # duality gap at (beta, theta)
    lam: jax.Array        # regularisation level of this round
    lam_max: jax.Array    # lambda_max (0.0 when the caller does not know it)
    #: sample-wise smoothness constant of the data-fidelity loss
    #: (:attr:`repro.losses.Loss.nu`): the GAP radius generalizes to
    #: ``sqrt(2 * nu * gap) / lam``.  A Python float on purpose — it is a
    #: trace-time constant, so the default 1.0 (squared loss) constant-
    #: folds and leaves the historical radius graph bit-identical.
    nu: float = 1.0


@dataclasses.dataclass(frozen=True)
class ScreeningRule:
    """Base strategy: metadata + the sphere constructor.

    Metadata (plain class attributes, NOT dataclass fields, so frozen
    subclasses stay hashable value objects):

    ``name``
        Registry key; also what legacy ``rule="..."`` strings resolve to.
    ``is_safe``
        The sphere provably contains the dual optimum (see the module
        docstring's safety contract).  Unsafe rules' rounds and paths are
        flagged and never reported as certificates.
    ``is_dynamic``
        The rule screens at every certified round during a solve.  False
        means rounds only certify the gap (all-true masks).
    ``supports_sequential``
        A round evaluated at a *new* lambda from the *previous* lambda's
        primal point is meaningful, so the path engine runs one before any
        epoch (the paper's sequential rule).  True for GAP (the sphere is
        valid from any feasible point) and for :class:`NoScreening` (the
        round is a plain gap check used for the warm-start early exit);
        False for the dynamic/DST3 spheres, which refine *during*
        optimisation but transfer nothing across lambdas.
    ``supports_compact``
        The compacted certified round
        (:func:`repro.core.solver._screen_round_compact`) reproduces this
        rule's sphere exactly on the gathered buffer.  GAP only: the
        compact round hard-codes the Thm-2 radius.
    ``pre_screens``
        The rule screens ONCE, before the first epoch (static sphere);
        :meth:`pre_solve_sphere` must return the sphere.  Such rules have
        no per-round certificate, so ``screen``/``screen_round`` refuse
        them.
    ``needs_lam_max``
        The sphere construction divides by the true lambda_max; callers
        without it must fail fast instead of passing 0.
    ``supported_losses``
        ``None`` means the sphere is valid for every registered
        data-fidelity loss (the GAP family: radius ``sqrt(2 nu gap)/lam``
        holds for any nu-smooth loss).  A tuple of loss names restricts
        the rule to those losses — the static/dynamic/DST3 spheres are
        built from the quadratic dual's ``y/lambda`` geometry and are
        least-squares-only; :class:`repro.core.session.SGLSession` fails
        fast on an unsupported rule x loss pairing, mirroring the
        rule x mesh gate.
    """

    name = "abstract"
    is_safe = False
    is_dynamic = False
    supports_sequential = False
    supports_compact = False
    pre_screens = False
    needs_lam_max = False
    supported_losses = None  # None = every loss; else tuple of names

    def center_and_radius(
        self, state: RuleState
    ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
        """Return ``(center, radius, corr_at_center)`` for this round.

        ``corr_at_center`` is ``X^T center`` in grouped layout when the
        rule can supply it for free (the GAP family reuses the skeleton's
        residual correlation: ``corr / scale``); ``None`` makes the
        skeleton compute it through the backend-routed correlation (Pallas
        kernel over the persistent transposed design on TPU, einsum on
        XLA) — which is how every rule gets the kernel routing without
        knowing it exists.

        Only called when ``is_dynamic`` is True.  Runs at trace time
        inside the jitted round: use ``jax.numpy`` ops on the state.
        """
        raise NotImplementedError(f"{type(self).__name__} is not dynamic")

    def pre_solve_sphere(self, problem, lam_, lam_max):
        """Sphere applied once before the first epoch: ``(center, radius)``.

        Only consulted when ``pre_screens`` is True (static rules) — such
        rules MUST override this; the base raises so a forgotten override
        fails at the extension point, not as an opaque unpack error deep
        inside ``solve()``.
        """
        raise NotImplementedError(
            f"{type(self).__name__} sets pre_screens=True but does not "
            "implement pre_solve_sphere()"
        )

    def __repr__(self) -> str:  # registry/error messages read better
        return f"{type(self).__name__}(name={self.name!r})"
