"""Deterministic fault plans: which fault, where, and on which hit.

A :class:`FaultSpec` names an injection *site* (a registered host-level
hook — see :data:`SITES`), a fault *kind*, and a firing *schedule*: the
0-based hit indices at that site on which the fault fires.  Sites count
hits per :func:`repro.faults.inject.inject` activation, so a plan is a
pure value — replaying the same plan against the same workload fires the
same faults at the same program points, which is what makes the chaos
suite an executable (reproducible) spec rather than a flake generator.

``seed`` feeds the only randomness any injector uses (bit-flip offsets),
so even the "random" corruption is deterministic per plan.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

__all__ = ["FaultSpec", "FaultPlan", "KINDS", "SITES"]

# Fault kinds an injector can dispatch on.
KINDS = (
    "nan",          # multiply the targeted numeric payload by NaN
    "inf",          # multiply the targeted numeric payload by +inf
    "raise",        # raise a typed error at the site (kernel launch, ...)
    "stall",        # sleep stall_s at the site (drives deadline budgets)
    "kill",         # raise WorkerCrash (serve worker / mid-segment)
    "truncate",     # cut a checkpoint payload file in half
    "bitflip",      # flip one bit of a checkpoint payload file
    "poison",       # corrupt a stored certificate-store record in place
)

# Registered injection sites (host-level hooks — a fault must fire at
# dispatch time, never inside a jitted function where a raise would only
# fire at trace time).  The value documents which kinds the site honours
# and what one "hit" means.
SITES = {
    "core.round": (
        "one certified full round (SGLSession._certified_round); kinds "
        "nan/inf corrupt the round's gap plus the field named by "
        "FaultSpec.field (resid | corr | theta), stall sleeps before the "
        "round"
    ),
    "core.epochs": (
        "one inner BCD epoch block in SGLSession.solve; nan/inf corrupt "
        "the iterate beta after the block"
    ),
    "kernels.screen": (
        "one Pallas screening-round dispatch; raise fails the launch "
        "(the session retries once on the XLA reference path)"
    ),
    "kernels.epochs": (
        "one fused Pallas epoch-block dispatch; raise fails the launch "
        "(per-lambda paths fall back to the lax.scan reference; the "
        "batched-lambda driver has no reference twin and surfaces "
        "KernelLaunchError)"
    ),
    "serve.worker": (
        "one request group entering service; kill crashes the worker's "
        "solve loop before the solve starts"
    ),
    "serve.segment": (
        "one checkpoint segment boundary inside a chunked path; kill "
        "crashes the worker mid-path (recovery resumes from the last "
        "intact checkpoint)"
    ),
    "ckpt.payload": (
        "one published checkpoint payload (arrays.npz); truncate/bitflip "
        "corrupt the file after the atomic publish, after its digest was "
        "recorded"
    ),
    "store.record": (
        "one certificate-store put; poison corrupts the stored exact "
        "record after its digest was recorded"
    ),
}


class FaultSpec(NamedTuple):
    """One addressable fault: site + kind + firing schedule."""

    site: str                    # key of SITES
    kind: str                    # member of KINDS
    hits: Tuple[int, ...] = (0,)  # 0-based hit indices that fire
    field: str = ""              # numeric target at core.round
                                 #   (resid | corr | theta; "" = theta)
    stall_s: float = 0.0         # sleep duration for kind="stall"

    def validate(self) -> "FaultSpec":
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; registered sites: "
                f"{sorted(SITES)}"
            )
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; kinds: {list(KINDS)}"
            )
        if not self.hits:
            raise ValueError("FaultSpec.hits must name at least one hit")
        if any(h < 0 for h in self.hits):
            raise ValueError(f"negative hit index in {self.hits}")
        if self.kind == "stall" and self.stall_s <= 0:
            raise ValueError("kind='stall' needs stall_s > 0")
        return self


class FaultPlan:
    """An immutable, seeded set of :class:`FaultSpec` values."""

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs = tuple(
            (s if isinstance(s, FaultSpec) else FaultSpec(*s)).validate()
            for s in specs
        )
        self.seed = int(seed)

    def for_site(self, site: str) -> Tuple[FaultSpec, ...]:
        return tuple(s for s in self.specs if s.site == site)

    def __repr__(self) -> str:  # stable: plans are test/report values
        inner = ", ".join(repr(s) for s in self.specs)
        return f"FaultPlan([{inner}], seed={self.seed})"
