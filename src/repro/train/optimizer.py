"""AdamW implemented from scratch (no optax offline).

Moments can be stored in bf16 to halve optimizer HBM (used by the 405B
config); the update math always runs in f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def state_specs(param_specs) -> AdamWState:
    """Optimizer state shards exactly like the params (ZeRO-1/FSDP)."""
    from jax.sharding import PartitionSpec as P

    return AdamWState(mu=param_specs, nu=param_specs, count=P())


def update(
    grads,
    state: AdamWState,
    params,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * step).astype(p.dtype),
            m32.astype(m.dtype),
            v32.astype(v.dtype),
        )

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(mu=new_mu, nu=new_nu, count=count)
