"""Certificate-safety dataflow lints (AST pass over ``src/repro``).

The safety bit is the product: a ``RoundResult.safe`` / ``PathResult.
certificates_safe`` of True is a *proof claim* (the masks are certified
zeros at the optimum).  These lints make the claim unforgeable at the
source level:

* **CS001** every ``RoundResult(...)`` / ``PathResult(...)`` construction
  must thread ``safe=`` / ``certificates_safe=`` explicitly from rule
  metadata — never a bare ``True`` literal (outside ``rules/library.py``),
  never by omission (the NamedTuple default would silently claim safety).
  Re-wraps that forward an existing result (``RoundResult(*r)``) are
  exempt: the bit travels through the star.
* **CS002** no module under ``core/`` or ``kernels/`` names the unsafe
  ``StrongSequentialRule`` — the solver must only ever see the abstract
  :class:`repro.rules.ScreeningRule` protocol, so an unsafe rule cannot
  be special-cased into a trusted path.
* **CS003** every rule registered with ``is_safe=True`` is exercised by
  the safety-matrix tests in ``tests/test_rules.py`` (the tests that
  assert certified masks match the exact support) — a rule claiming
  safety that no test cross-checks is an unbacked proof claim.
* **CS004** no ``except`` handler under ``core/`` or ``serve/``
  constructs a ``RoundResult``/``PathResult`` or adopts a screen mask
  (``group_active &= ...`` / ``feat_active &= ...``): an exception means
  the round's dataflow is suspect, and the only sound moves are to
  rewind to known-good state or re-raise — never to synthesise a result
  (which would carry a safety claim derived from a broken trajectory).
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence

from .findings import Finding

__all__ = ["run"]

_RESULT_KEYS = {
    "RoundResult": ("safe", 5),          # (keyword, positional index)
    "PathResult": ("certificates_safe", None),
}


def _py_files(root: str, subdirs: Optional[Sequence[str]] = None):
    for dirpath, _dirnames, filenames in os.walk(root):
        rel = os.path.relpath(dirpath, root)
        if subdirs is not None:
            if rel == "." or not any(
                    rel == s or rel.startswith(s + os.sep) for s in subdirs):
                continue
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def _callee_name(func) -> str:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


def _is_true_literal(node) -> bool:
    return isinstance(node, ast.Constant) and node.value is True


def lint_result_constructions(
    src_root: str,
    allow_literal_files: Sequence[str] = ("rules/library.py",),
) -> List[Finding]:
    findings: List[Finding] = []
    allow = {os.path.normpath(p) for p in allow_literal_files}
    for path in _py_files(src_root):
        rel = os.path.normpath(os.path.relpath(path, src_root))
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        allowed = rel in allow
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = _callee_name(node.func)
            if name not in _RESULT_KEYS:
                continue
            key, pos = _RESULT_KEYS[name]
            loc = f"{rel}:{node.lineno}"
            if any(isinstance(a, ast.Starred) for a in node.args):
                continue   # re-wrap: the bit travels through the star
            kw = next((k for k in node.keywords if k.arg == key), None)
            if kw is not None:
                if _is_true_literal(kw.value) and not allowed:
                    findings.append(Finding(
                        pass_name="cert", code="CS001",
                        message=(f"{name}({key}=True) hard-codes the "
                                 f"safety claim; thread it from the "
                                 f"rule's is_safe metadata"),
                        location=loc,
                    ))
                continue
            if pos is not None and len(node.args) > pos:
                if _is_true_literal(node.args[pos]) and not allowed:
                    findings.append(Finding(
                        pass_name="cert", code="CS001",
                        message=(f"{name}(...) passes a literal True in "
                                 f"the {key} position"),
                        location=loc,
                    ))
                continue
            if any(k.arg is None for k in node.keywords):
                continue   # **kwargs forward — bit travels through it
            findings.append(Finding(
                pass_name="cert", code="CS001",
                message=(f"{name}(...) omits {key}= and silently claims "
                         f"safety through the field default"),
                location=loc,
            ))
    return findings


def lint_strong_imports(src_root: str) -> List[Finding]:
    findings: List[Finding] = []
    for path in _py_files(src_root, subdirs=("core", "kernels")):
        rel = os.path.normpath(os.path.relpath(path, src_root))
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        for node in ast.walk(tree):
            hit = None
            if isinstance(node, ast.ImportFrom):
                if any(a.name == "StrongSequentialRule"
                       for a in node.names):
                    hit = "imports"
            elif isinstance(node, (ast.Name, ast.Attribute)):
                ident = (node.id if isinstance(node, ast.Name)
                         else node.attr)
                if ident == "StrongSequentialRule":
                    hit = "references"
            if hit:
                findings.append(Finding(
                    pass_name="cert", code="CS002",
                    message=(f"solver-layer module {hit} the unsafe "
                             f"StrongSequentialRule directly; unsafe "
                             f"rules must stay behind the ScreeningRule "
                             f"protocol"),
                    location=f"{rel}:{node.lineno}",
                ))
    return findings


_MASK_NAMES = {"group_active", "feat_active"}


def lint_exception_paths(
    src_root: str,
    subdirs: Sequence[str] = ("core", "serve"),
) -> List[Finding]:
    """CS004: exception handlers in solver/serve code must rewind or
    re-raise — never construct a result object or adopt a screen mask.

    Re-wraps through a star (``RoundResult(*r)``) are exempt for the
    same reason as CS001: the safety bit travels through an existing,
    already-certified result rather than being synthesised in the
    handler.
    """
    findings: List[Finding] = []
    for path in _py_files(src_root, subdirs=subdirs):
        rel = os.path.normpath(os.path.relpath(path, src_root))
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    name = _callee_name(sub.func)
                    if name not in _RESULT_KEYS:
                        continue
                    if any(isinstance(a, ast.Starred) for a in sub.args):
                        continue
                    findings.append(Finding(
                        pass_name="cert", code="CS004",
                        message=(f"except handler constructs {name}(...); "
                                 f"exception paths must rewind or "
                                 f"re-raise, never synthesise a result"),
                        location=f"{rel}:{sub.lineno}",
                    ))
                elif (isinstance(sub, ast.AugAssign)
                      and isinstance(sub.op, ast.BitAnd)):
                    tgt = sub.target
                    ident = (tgt.id if isinstance(tgt, ast.Name)
                             else tgt.attr if isinstance(tgt, ast.Attribute)
                             else "")
                    if ident in _MASK_NAMES:
                        findings.append(Finding(
                            pass_name="cert", code="CS004",
                            message=(f"except handler intersects screen "
                                     f"mask {ident!r}; a mask narrowed on "
                                     f"an exception path is an uncertified "
                                     f"discard"),
                            location=f"{rel}:{sub.lineno}",
                        ))
    return findings


def lint_safety_matrix(tests_root: str,
                       safe_rule_names: Sequence[str]) -> List[Finding]:
    path = os.path.join(tests_root, "test_rules.py")
    if not os.path.exists(path):
        return [Finding(
            pass_name="cert", code="CS003",
            message="tests/test_rules.py (safety-matrix tests) not found",
            location=path,
        )]
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    covered: set = set()
    n_matrix = 0
    for node in ast.walk(tree):
        if (isinstance(node, ast.FunctionDef)
                and "matrix" in node.name):
            n_matrix += 1
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Constant)
                        and isinstance(sub.value, str)):
                    covered.add(sub.value)
    findings: List[Finding] = []
    if n_matrix == 0:
        findings.append(Finding(
            pass_name="cert", code="CS003",
            message="no safety-matrix test function (name containing "
                    "'matrix') found in tests/test_rules.py",
            location="tests/test_rules.py",
        ))
        return findings
    for name in safe_rule_names:
        if name not in covered:
            findings.append(Finding(
                pass_name="cert", code="CS003",
                message=(f"rule {name!r} is registered is_safe=True but "
                         f"is not exercised by the safety-matrix tests"),
                location="tests/test_rules.py",
                details={"covered": sorted(covered)},
            ))
    return findings


def _default_roots():
    here = os.path.dirname(os.path.abspath(__file__))       # .../src/repro/analysis
    src_root = os.path.dirname(here)                        # .../src/repro
    repo = os.path.dirname(os.path.dirname(src_root))       # repo root
    return src_root, os.path.join(repo, "tests")


def run(src_root: Optional[str] = None,
        tests_root: Optional[str] = None,
        safe_rule_names: Optional[Sequence[str]] = None) -> List[Finding]:
    d_src, d_tests = _default_roots()
    src_root = src_root or d_src
    tests_root = tests_root or d_tests
    if safe_rule_names is None:
        from repro.rules import available_rules, get_rule

        safe_rule_names = [n for n in available_rules()
                           if get_rule(n).is_safe]
    findings = lint_result_constructions(src_root)
    findings += lint_strong_imports(src_root)
    findings += lint_exception_paths(src_root)
    findings += lint_safety_matrix(tests_root, safe_rule_names)
    return findings
