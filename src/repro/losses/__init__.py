"""Pluggable data-fidelity losses for the GAP screening machinery.

The paper's Thm 1/2 + Eq. 15 apply to any smooth data-fidelity term with
a computable Fenchel conjugate (journal follow-ups arXiv 1611.05780,
arXiv 1506.03736).  This package is the loss axis of that observation,
mirroring :mod:`repro.rules` on the rule axis:

* :class:`Loss` (:mod:`repro.losses.base`) — the strategy protocol:
  ``value`` / ``neg_grad`` / ``conjugate`` / ``dual_obj`` plus the
  smoothness constant ``nu`` that generalizes the GAP radius to
  ``sqrt(2 nu gap) / lam``;
* the registered implementations (:mod:`repro.losses.library`):
  :class:`LeastSquaresLoss` (``"lsq"``, the bit-frozen default),
  :class:`LogisticLoss` (``"logistic"``), :class:`MultiTaskLoss`
  (``"multitask"``, math-level only);
* the registry (:mod:`repro.losses.registry`) — ``resolve_loss`` keeps
  string configs working and fails fast on unknown names.

The consumers are the same shared skeletons the rules plug into:
``core/solver._screen_round`` (generalized residual + Eq. 15 scaling),
``_inner_rounds`` (loss-routed reduced gap + majorized BCD),
``kernels/bcd_epoch.py`` (a logistic mega-kernel carrying the linear
predictor in VMEM), and ``SGLSession`` / ``SolverConfig.loss`` — so
every registered rule x every supported loss x backend composes through
one code path.  Rules whose sphere geometry is least-squares-specific
declare ``supported_losses=("lsq",)`` and the session fails fast on the
combination, exactly like unsupported rule x mesh pairings.
"""
from .base import Loss
from .library import LeastSquaresLoss, LogisticLoss, MultiTaskLoss
from .registry import (
    available_losses,
    get_loss,
    register_loss,
    resolve_loss,
)

__all__ = [
    "Loss",
    "LeastSquaresLoss",
    "LogisticLoss",
    "MultiTaskLoss",
    "available_losses",
    "get_loss",
    "register_loss",
    "resolve_loss",
]

# Built-in registrations (singletons; instances are jit static args).
register_loss(LeastSquaresLoss())
register_loss(LogisticLoss())
register_loss(MultiTaskLoss())
