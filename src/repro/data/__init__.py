from .synthetic import make_synthetic
from .climate import make_climate_like

__all__ = ["make_synthetic", "make_climate_like"]
