"""Warm-started lambda path for the Sparse-Group Lasso (paper Section 7.1).

lambda_t = lambda_max * 10^(-delta * t / (T - 1)),  t = 0..T-1
(default delta = 3, T = 100, matching GLMNET practice cited by the paper).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from . import sgl
from .solver import SolveResult, solve
from .sgl import SGLProblem

__all__ = ["lambda_grid", "PathResult", "solve_path"]


def lambda_grid(lam_max: float, T: int = 100, delta: float = 3.0) -> np.ndarray:
    t = np.arange(T)
    return lam_max * 10.0 ** (-delta * t / max(T - 1, 1))


class PathResult(NamedTuple):
    lambdas: np.ndarray
    betas: list              # list of (G, ng) arrays
    gaps: np.ndarray
    epochs: np.ndarray
    group_active_frac: np.ndarray
    feat_active_frac: np.ndarray
    results: list


def solve_path(
    problem: SGLProblem,
    lambdas: Optional[Sequence[float]] = None,
    T: int = 100,
    delta: float = 3.0,
    tol: float = 1e-8,
    max_epochs: int = 10_000,
    f_ce: int = 10,
    rule: str = "gap",
) -> PathResult:
    lam_max = float(sgl.lambda_max(problem))
    if lambdas is None:
        lambdas = lambda_grid(lam_max, T=T, delta=delta)
    lambdas = np.asarray(lambdas, float)

    n_feat = int(np.asarray(problem.feat_mask).sum())
    G = problem.G

    beta = jnp.zeros((problem.G, problem.ng), problem.X.dtype)
    betas, gaps, epochs, gfrac, ffrac, results = [], [], [], [], [], []
    for lam_ in lambdas:
        res = solve(
            problem,
            float(lam_),
            beta0=beta,
            tol=tol,
            max_epochs=max_epochs,
            f_ce=f_ce,
            rule=rule,
            lam_max=lam_max,
        )
        beta = res.beta
        betas.append(res.beta)
        gaps.append(float(res.gap))
        epochs.append(res.n_epochs)
        gfrac.append(res.group_active.sum() / max(G, 1))
        ffrac.append(res.feat_active.sum() / max(n_feat, 1))
        results.append(res)

    return PathResult(
        lambdas=lambdas,
        betas=betas,
        gaps=np.asarray(gaps),
        epochs=np.asarray(epochs),
        group_active_frac=np.asarray(gfrac),
        feat_active_frac=np.asarray(ffrac),
        results=results,
    )
