"""Roofline-term derivation from the compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * peak_FLOPs)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed out of the (post-SPMD) HLO text by summing the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %all-reduce.5 = f32[16,128]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9_]+\[[^\]]*\][^ ]*\s*,?\s*)+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ----------------------------------------------------------------------------
# Trip-count-aware HLO cost analysis
#
# XLA's HloCostAnalysis counts while-loop bodies ONCE (verified empirically:
# a scan of 10 matmuls reports the flops of 1).  Every scanned structure —
# layer stacks, q-chunked attention, SSD sequence chunks — is therefore
# undercounted by its trip count.  This analyzer walks the HLO text, builds
# the computation call graph, reads each while op's
# backend_config known_trip_count, and multiplies costs accordingly.
# ----------------------------------------------------------------------------

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_REFS_RE = re.compile(
    r"(?:calls=|condition=|body=|branch_computations=\{|to_apply=)"
    r"([%\w.\-, ]+)"
)
# type part matched lazily: tuple types contain commas, braces and
# /*index=N*/ comments; the first bare `word(` after it is the opcode.
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\(")
_CONST_INT_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _parse_shape(type_str: str):
    """'f32[256,128]{1,0}' -> (dtype, [256,128]); tuples -> list of both."""
    shapes = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims_l = [int(d) for d in dims.split(",") if d] if dims else []
        shapes.append((dt, dims_l))
    return shapes


def _shape_list_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str):
    """name -> list of op lines (flat text split, brace-delimited)."""
    comps = {}
    cur_name, cur_lines = None, []
    entry = None
    for line in hlo_text.splitlines():
        if cur_name is None:
            s = line.strip()
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{") and "->" in s:
                cur_name = m.group(1)
                if line.lstrip().startswith("ENTRY"):
                    entry = cur_name
                cur_lines = []
        else:
            if line.strip() == "}":
                comps[cur_name] = cur_lines
                cur_name = None
            else:
                cur_lines.append(line)
    return comps, entry


class _CompCost:
    __slots__ = ("flops", "bytes", "coll", "calls")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = {k: 0.0 for k in _COLLECTIVES}
        self.calls = []   # (callee_name, multiplier, kind)


def _analyze_computation(lines, fusion_flops: Dict[str, float],
                         trip_guess: Optional[Dict[str, int]] = None,
                         fusion_io: Optional[Dict[str, float]] = None):
    """One pass over a computation's ops.

    Returns a _CompCost where `bytes` counts operand+result bytes of ops at
    this level (fusion internals excluded — the fusion boundary is what
    touches HBM), `flops` counts dot flops at this level plus the dot flops
    of any kLoop/kOutput fusion bodies it calls, and `calls` lists control-
    flow edges (while/conditional/call) with multipliers.
    """
    cost = _CompCost()
    trip_guess = trip_guess or {}
    fusion_io = fusion_io or {}
    shapes = {}   # op name -> result type string

    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        shapes[name] = type_str

        if opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "copy", "copy-start",
                      "copy-done"):
            # copies of while carries are elided by buffer aliasing on real
            # hardware; counting them would charge the full KV cache / param
            # stack per scan iteration
            continue

        result_shapes = _parse_shape(type_str)
        result_bytes = _shape_list_bytes(result_shapes)

        # operand bytes from the symbol table (parameters included)
        paren = line[line.find(opcode + "(") + len(opcode) + 1:]
        depth = 1
        arglist = []
        for ch in paren:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            arglist.append(ch)
        operand_names = _OPERAND_RE.findall("".join(arglist))
        operand_bytes = sum(
            _shape_list_bytes(_parse_shape(shapes.get(o, "")))
            for o in operand_names
        )

        if opcode in ("dynamic-slice", "gather"):
            # in-place view semantics: traffic = the slice read + written,
            # not the full source tensor XLA's model charges
            cost.bytes += 2 * result_bytes
            continue
        if opcode in ("dynamic-update-slice", "scatter"):
            # traffic = the update slice (operand 1) read + written
            upd = (operand_names[1]
                   if len(operand_names) > 1 else None)
            upd_bytes = _shape_list_bytes(_parse_shape(shapes.get(upd, "")))
            cost.bytes += 2 * (upd_bytes or result_bytes)
            continue

        base_kind = opcode.replace("-start", "").replace("-done", "")
        if base_kind in _COLLECTIVES:
            if not opcode.endswith("-done"):
                cost.coll[base_kind] += result_bytes
            cost.bytes += result_bytes + operand_bytes
            continue

        if opcode == "while":
            trips = None
            t = _TRIP_RE.search(line)
            if t:
                trips = int(t.group(1))
            refs = re.search(r"body=%?([\w.\-]+)", line)
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            if trips is None and cond is not None:
                # fall back to the loop bound in the condition computation
                # (the s32 constant compared against the induction counter)
                trips = trip_guess.get(cond.group(1))
            if trips is None:
                trips = 1
            if refs:
                cost.calls.append((refs.group(1), trips, "while"))
            if cond:
                cost.calls.append((cond.group(1), trips + 1, "while"))
            continue

        if opcode == "conditional":
            for grp in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"true_computation=%?([\w.\-]+)|"
                                  r"false_computation=%?([\w.\-]+))", line):
                for g in grp:
                    if not g:
                        continue
                    for ref in g.split(","):
                        ref = ref.strip().lstrip("%")
                        if ref:
                            cost.calls.append((ref, 1, "cond"))
            continue

        if opcode in ("call", "async-start"):
            r = re.search(r"to_apply=%?([\w.\-]+)", line)
            if r:
                cost.calls.append((r.group(1), 1, "call"))
            cost.bytes += result_bytes + operand_bytes
            continue

        if opcode == "fusion":
            r = re.search(r"calls=%?([\w.\-]+)", line)
            if r:
                cost.flops += fusion_flops.get(r.group(1), 0.0)
                cost.bytes += fusion_io.get(
                    r.group(1), result_bytes + operand_bytes)
            else:
                cost.bytes += result_bytes + operand_bytes
            continue

        if opcode == "dot":
            # flops = 2 * prod(result dims) * prod(contracting dims of LHS)
            lhs = operand_names[0] if operand_names else None
            lhs_shapes = _parse_shape(shapes.get(lhs, ""))
            k = 1
            cm = _CONTRACT_RE.search(line)
            if cm and lhs_shapes:
                dims = lhs_shapes[0][1]
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
            out_elems = 1
            for dt, ds in result_shapes:
                for d in ds:
                    out_elems *= d
            cost.flops += 2.0 * out_elems * k
            cost.bytes += result_bytes + operand_bytes
            continue

        if opcode == "convolution":
            # flops = 2 * out_elems * prod(window dims). Exact for the
            # depthwise convs these models use (mamba/RG-LRU conv1d and
            # their transposed gradients); dense multi-channel convs would
            # need an extra C_in/groups factor, but none appear here.
            win = re.search(r"window=\{size=([0-9x]+)", line)
            wprod = 1
            if win:
                for d in win.group(1).split("x"):
                    wprod *= int(d)
            out_elems = 1
            for dt, ds in result_shapes:
                for d in ds:
                    out_elems *= d
            cost.flops += 2.0 * out_elems * wprod
            cost.bytes += result_bytes + operand_bytes
            continue

        # every other op: memory traffic only (elementwise flops are noise
        # next to matmuls at these shapes)
        cost.bytes += result_bytes + operand_bytes

    return cost


def _dot_flops_only(lines):
    """Dot/conv flops of a fusion body (no bytes — internals stay on-chip)."""
    return _analyze_computation(lines, {}).flops


def _fusion_io_bytes(lines) -> float:
    """HBM traffic estimate of one fusion: bytes actually read from each
    operand + the result write.

    A fusion that internally dynamic-slices/gathers a parameter (the layer's
    slice of a stacked param / KV tensor) only reads the slice, not the full
    operand XLA's boundary model charges.
    """
    shapes = {}
    params = {}
    alias = {}    # view ops resolve to their root param
    sliced = set()
    dus_results = set()
    slice_bytes = 0.0
    root_bytes = 0.0
    root_name = None
    compute_ops = 0

    def root_of(n):
        seen = set()
        while n in alias and n not in seen:
            seen.add(n)
            n = alias[n]
        return n

    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        shapes[name] = type_str
        rb = _shape_list_bytes(_parse_shape(type_str))
        ops = _OPERAND_RE.findall(line[line.find(opcode + "(")::])
        if opcode == "parameter":
            params[name] = rb
        elif opcode in ("bitcast", "copy", "reshape", "transpose",
                        "broadcast", "convert"):
            if ops:
                alias[name] = ops[0]
        elif opcode in ("dynamic-slice", "gather", "slice"):
            src = root_of(ops[0]) if ops else None
            if src in params:
                sliced.add(src)
                slice_bytes += rb
            compute_ops += 1
        elif opcode in ("dynamic-update-slice", "scatter"):
            # in-place update of (a view of) a parameter: traffic is the
            # update slice read + written, not the whole destination
            src = root_of(ops[0]) if ops else None
            upd = ops[1] if len(ops) > 1 else None
            upd_bytes = _shape_list_bytes(_parse_shape(shapes.get(upd, "")))
            if src in params:
                sliced.add(src)
                slice_bytes += 2 * (upd_bytes or rb)
                dus_results.add(name)
            compute_ops += 1
        elif opcode not in ("constant", "get-tuple-element", "tuple"):
            compute_ops += 1
        if line.lstrip().startswith("ROOT"):
            root_bytes = rb
            root_name = name

    if compute_ops == 0:
        # pure dtype/layout-change fusion (e.g. the wholesale bf16->f32
        # cache upcast the CPU backend hoists out of while loops for its
        # f32-only matmuls) — does not exist on TPU, where the MXU consumes
        # bf16 natively and layout changes fuse into consumers.
        return 0.0
    if root_name is not None and root_of(root_name) in dus_results:
        # output aliases the in-place-updated input buffer
        root_bytes = 0.0
    read = slice_bytes + sum(
        b for n, b in params.items() if n not in sliced
    )
    return read + root_bytes


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    """Trip-count-aware totals over the whole module.

    Returns {"flops", "bytes_accessed", "collective_bytes", per-kind...}.
    """
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return {"flops": 0.0, "bytes_accessed": 0.0, "collective_bytes": 0.0}

    # loop-bound constants per computation (while-condition fallback):
    # only constants that feed the ROOT compare count — an unrelated
    # constant elsewhere in the condition must not become the trip count
    trip_guess: Dict[str, int] = {}
    for name, lines in comps.items():
        const_vals: Dict[str, int] = {}
        root_ops: list = []
        for ln in lines:
            m = _CONST_INT_RE.search(ln)
            d = _DEF_RE.match(ln)
            if m and d:
                const_vals[d.group(1)] = int(m.group(1))
            if ln.lstrip().startswith("ROOT") and d:
                paren = ln[ln.find(d.group(3) + "(") + len(d.group(3)) + 1:]
                root_ops = _OPERAND_RE.findall(paren.split("), ")[0])
        feeding = [const_vals[o] for o in root_ops if o in const_vals]
        if feeding:
            trip_guess[name] = max(feeding)
        elif const_vals:
            trip_guess[name] = max(const_vals.values())

    # fusion bodies first (flops attributed at the fusion call site)
    fusion_flops = {name: _dot_flops_only(lines)
                    for name, lines in comps.items()}
    fusion_io = {name: _fusion_io_bytes(lines)
                 for name, lines in comps.items()}
    costs = {name: _analyze_computation(lines, fusion_flops, trip_guess,
                                        fusion_io)
             for name, lines in comps.items()}

    # propagate multipliers from ENTRY through the control-flow call graph
    mult: Dict[str, float] = {}

    def visit(name, m):
        if name not in costs:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, k, kind in costs[name].calls:
            visit(callee, m * k)

    visit(entry, 1.0)

    total_flops = 0.0
    total_bytes = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    for name, m in mult.items():
        c = costs[name]
        total_flops += m * c.flops
        total_bytes += m * c.bytes
        for k in _COLLECTIVES:
            coll[k] += m * c.coll[k]

    out = {"flops": total_flops, "bytes_accessed": total_bytes,
           "collective_bytes": float(sum(coll.values()))}
    out.update({f"coll_{k}": v for k, v in coll.items()})
    return out


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind over the HLO module text.

    ``-start`` ops are counted, matching ``-done`` duplicates are not.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # avoid double counting async pairs
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shapes)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    model_flops: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time — how close the dominant term
        lets us get to the compute roofline."""
        if self.model_flops is None:
            return float("nan")
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound > 0 else float("nan")

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": (
                self.model_flops / self.flops
                if self.model_flops and self.flops else None
            ),
        }


def achieved_vs_peak(flops: float, bytes_accessed: float, measured_s: float,
                     chips: int = 1, collective_bytes: float = 0.0) -> dict:
    """Measured-wall-clock term next to the dry-run model.

    Everything above in this module predicts time from HLO costs; this
    function goes the other way: given a *measured* kernel wall-clock from
    the :mod:`repro.obs.timing` harness (jit-warm + ``block_until_ready``)
    and the kernel's model flops / HBM bytes, report the achieved rates as
    fractions of the hardware-model peaks and of the roofline bound
    itself.  ``achieved_vs_model`` is ``t_bound / measured`` — 1.0 means
    the kernel runs exactly at its modeled roofline, smaller means the
    launch is leaving modeled headroom on the table (interpret-mode CPU
    runs will be far below 1; the point is that BENCH now carries a
    measured column at all, per the ROADMAP compiled-kernel item).
    """
    model = Roofline(flops=flops, bytes_accessed=bytes_accessed,
                     collective_bytes=collective_bytes, chips=chips)
    t_bound = max(model.t_compute, model.t_memory, model.t_collective)
    if measured_s <= 0:
        raise ValueError(f"measured_s must be positive, got {measured_s}")
    return {
        "measured_s": measured_s,
        "achieved_flops_per_s": flops / measured_s,
        "achieved_bytes_per_s": bytes_accessed / measured_s,
        "frac_peak_compute": (flops / measured_s) / (chips * PEAK_FLOPS),
        "frac_peak_memory": (bytes_accessed / measured_s) / (chips * HBM_BW),
        "model_t_compute_s": model.t_compute,
        "model_t_memory_s": model.t_memory,
        "model_bottleneck": model.bottleneck,
        "achieved_vs_model": (t_bound / measured_s) if t_bound > 0 else None,
    }


def count_params(param_structs) -> int:
    import jax
    import numpy as np

    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(param_structs)))


def active_params(cfg, param_structs) -> int:
    """6*N*D uses N_active for MoE (top_k of n_experts expert params)."""
    import jax
    import numpy as np

    total = count_params(param_structs)
    if cfg is None or getattr(cfg, "moe", None) is None:
        return total
    # expert weights: (E, D, F) x3 per layer
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    expert = 3 * cfg.n_layers * E * cfg.d_model * cfg.d_ff
    return total - expert + int(expert * k / E)


def model_flops(cfg, param_structs, shape_kind: str, tokens: int) -> float:
    """6*N*D for training, 2*N*D for inference (per step)."""
    n = active_params(cfg, param_structs)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens
