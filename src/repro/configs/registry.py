"""Architecture registry: --arch <id> resolution.

Pruned to the configs this repository actually solves with: the paper's
own workload (``sgl-paper``) and a tiny dense LM (``demo``) for the
model-zoo smoke paths.  The seed-era LLM zoo configs (qwen*,
llama3-405b, mixtral-8x7b, ...) were scaffolding from the repository
template — no production code path imported them — and were removed;
:func:`get` keeps erroring helpfully on their names so stale scripts
fail with directions instead of an ImportError.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "sgl-paper",
    "demo",
]

_MODULES = {
    "sgl-paper": "sgl_paper",
}

# Seed-era LLM zoo configs removed in the configs prune.  Kept as a name
# set purely for the error message below.
_REMOVED = frozenset({
    "qwen2.5-14b",
    "codeqwen1.5-7b",
    "qwen3-8b",
    "llama3-405b",
    "recurrentgemma-2b",
    "olmoe-1b-7b",
    "mixtral-8x7b",
    "mamba2-2.7b",
    "seamless-m4t-large-v2",
    "llava-next-mistral-7b",
})


def get(name: str):
    if name == "demo":
        from .base import DEMO

        return DEMO
    if name in _REMOVED:
        raise KeyError(
            f"arch {name!r} was removed in the configs prune (the "
            f"seed-era LLM zoo was template scaffolding); use 'demo' for "
            f"a tiny dense LM, 'sgl-paper' for the paper workload, or "
            f"construct an ArchConfig directly via repro.configs.base"
        )
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_archs():
    return list(ARCH_IDS)
