"""The trip-count-aware HLO cost analyzer (launch/roofline.py).

XLA's own cost_analysis counts while bodies once; these tests pin the
corrected semantics on controlled graphs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (
    Roofline, analyze_hlo, parse_collective_bytes,
)

D = 128
WANT = 2 * D ** 3  # flops of one DxD @ DxD matmul


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


@pytest.fixture(scope="module")
def mats():
    W = jnp.zeros((D, D), jnp.float32)
    x = jnp.zeros((D, D), jnp.float32)
    return W, x


def test_single_dot_flops(mats):
    W, x = mats
    a = analyze_hlo(_compile(lambda x: x @ W, x))
    assert a["flops"] == pytest.approx(WANT, rel=0.01)


def test_scan_multiplies_by_trip_count(mats):
    W, x = mats

    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ W, None), x, None, length=12)
        return y

    a = analyze_hlo(_compile(f, x))
    assert a["flops"] == pytest.approx(12 * WANT, rel=0.01)


def test_nested_scan(mats):
    W, x = mats

    def f(x):
        def inner(c, _):
            y, _ = jax.lax.scan(lambda d, _: (d @ W, None), c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y

    a = analyze_hlo(_compile(f, x))
    assert a["flops"] == pytest.approx(15 * WANT, rel=0.01)


def test_scan_bytes_scale_with_trips(mats):
    W, x = mats

    def fk(k):
        def f(x):
            y, _ = jax.lax.scan(
                lambda c, _: (c @ W, None), x, None, length=k)
            return y
        return f

    b4 = analyze_hlo(_compile(fk(4), x))["bytes_accessed"]
    b16 = analyze_hlo(_compile(fk(16), x))["bytes_accessed"]
    # bytes should grow ~linearly in trip count (some fixed overhead ok)
    assert 2.5 < b16 / b4 < 4.5


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12 * 256, bytes_accessed=819e9,
                 collective_bytes=0.0, chips=256, model_flops=197e12 * 128)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0 / 256)
    assert r.bottleneck == "compute"
    assert r.roofline_fraction == pytest.approx(0.5)


def test_parse_collective_bytes_counts_result_shapes():
    hlo = """
ENTRY %main (x: f32[16]) -> f32[16] {
  %x = f32[16]{0} parameter(0)
  %ag = f32[64]{0} all-gather(%x), replica_groups={}
  ROOT %ar = f32[16]{0} all-reduce(%x), to_apply=%add
}
"""
    c = parse_collective_bytes(hlo)
    assert c["all-gather"] == 64 * 4
    assert c["all-reduce"] == 16 * 4
