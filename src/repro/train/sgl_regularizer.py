"""The paper's technique as a first-class training feature: structured
group-sparse regularisation of LM weights with GAP-style safe screening.

Groups = FFN neurons (columns of w1/w3, rows of w2) — or experts for MoE
layers.  After each optimizer step we apply the SGL two-level prox
(proximal-SGD on  loss + lam * Omega_{tau,w}), which is exactly the paper's
per-block update (Section 6) applied to the neuron groups.

Screening: the training loss is non-convex, so Theorem 1 cannot certify
optimal zeros globally.  We apply the paper's GAP test to the *per-step
linearised subproblem* (the prox objective, which IS convex): groups whose
prox input falls below the two-level threshold with margin ``screen_margin``
are masked and their compute can be skipped by the runtime.  This is the
honest adaptation of a convex-solver technique to SGD — documented in
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SGLRegConfig:
    lam: float = 1e-4
    tau: float = 0.3            # paper: mix of l1 and group norms
    screen_margin: float = 2.0  # mask groups this factor below threshold


def _prox_columns(w, lam_step, tau):
    """Two-level prox on the columns of w (D, F): feature = entry,
    group = column."""
    z = jnp.sign(w) * jnp.maximum(jnp.abs(w) - tau * lam_step, 0.0)
    col = jnp.linalg.norm(z.astype(jnp.float32), axis=0, keepdims=True)
    wg = jnp.sqrt(jnp.float32(w.shape[0]))  # w_g = sqrt(n_g), paper §7.1
    scale = jnp.maximum(
        1.0 - (1.0 - tau) * wg * lam_step / jnp.maximum(col, 1e-30), 0.0
    )
    return (z.astype(jnp.float32) * scale).astype(w.dtype)


def apply_prox(params, cfg: SGLRegConfig, lr: float):
    """Apply the SGL prox to every FFN w1/w3 (neuron columns).  Works on both
    the stacked (scan) and per-layer layouts."""
    lam_step = cfg.lam * lr

    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if len(keys) >= 2 and keys[-2] in ("mlp", "moe") and keys[-1] in (
            "w1", "w3"
        ):
            if leaf.ndim == 2:
                return _prox_columns(leaf, lam_step, cfg.tau)
            # stacked: (L, D, F) or MoE (L, E, D, F) — prox the D axis
            return jax.vmap(
                lambda w: _prox_columns(w, lam_step, cfg.tau)
                if w.ndim == 2
                else jax.vmap(lambda e: _prox_columns(e, lam_step, cfg.tau))(w)
            )(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def screen_groups(w, grad_w, cfg: SGLRegConfig, lr: float):
    """GAP-style safe test on the per-step prox subproblem.

    For prox input u = w - lr * grad, a column is zero after the prox iff
    ||S_{tau lam lr}(u_col)|| <= (1-tau) w_g lam lr  (paper Prop. 3 applied
    to the convex per-step objective).  ``screen_margin`` > 1 masks groups
    safely below threshold so the runtime can skip their compute.
    """
    lam_step = cfg.lam * lr
    u = (w - lr * grad_w).astype(jnp.float32)
    z = jnp.sign(u) * jnp.maximum(jnp.abs(u) - cfg.tau * lam_step, 0.0)
    col = jnp.linalg.norm(z, axis=0)
    wg = jnp.sqrt(jnp.float32(w.shape[0]))
    thr = (1.0 - cfg.tau) * wg * lam_step
    return col > thr / cfg.screen_margin   # True = keep


def group_sparsity(params) -> dict:
    """Fraction of exactly-zero FFN neuron groups (reporting metric)."""
    out = {}

    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if len(keys) >= 2 and keys[-2] in ("mlp", "moe") and keys[-1] == "w1":
            w = leaf.reshape(-1, leaf.shape[-2], leaf.shape[-1])
            col = jnp.linalg.norm(w.astype(jnp.float32), axis=1)
            out["/".join(map(str, keys))] = float(jnp.mean(col == 0.0))
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return out
