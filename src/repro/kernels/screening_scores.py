"""Fused correlation + screening-statistics Pallas kernels.

Two variants over the same blocked matvec:

* :func:`screening_scores_pallas` computes, in one pass over the design
  matrix tiles:

      corr = X^T theta                    (p,)   — needed by the feature test
      st2  = S_tau(corr)^2                (p,)   — summed per group by the
                                                   wrapper for the group test

  Used when the screening threshold ``tau`` applies to ``corr`` itself
  (sphere centers, i.e. ``corr = X^T theta_c``): the soft-thresholded
  square never makes an HBM round trip before thresholding, and
  ``screening.screen_with_corr`` consumes ``st2`` directly instead of
  re-thresholding.

* :func:`screening_corr_pallas` is the corr-only variant for the certified
  gap round, where ``corr = X^T resid`` still has to be *rescaled* by the
  (corr-dependent) dual scale before any thresholding — computing st2 there
  would be wasted work that the caller must discard (the pre-PR-2 behavior).

The matvec is blocked (bp x bn) with the K (sample) axis as the innermost
sequential grid dimension; the correlation block accumulates in the output
VMEM tile across K steps (standard Pallas accumulation pattern), and any
finalisation happens on the final K step while the block is still resident.
MXU-friendly when bp, bn are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import ArraySpec, LaunchSpec, block_specs, default_interpret, out_shapes


def _corr_io_specs(p: int, n: int, block_p: int, block_n: int, dtype):
    """Shared (Xt, theta) input + (p, 1) accumulator output geometry of the
    blocked correlation matvec.  The output tile accumulates over the K
    (sample) grid axis — carried axis 1."""
    inputs = (
        ArraySpec((p, n), (block_p, block_n), lambda i, k: (i, k), dtype),
        ArraySpec((n, 1), (block_n, 1), lambda i, k: (k, 0), dtype),
    )
    out = ArraySpec((p, 1), (block_p, 1), lambda i, k: (i, 0), dtype)
    return inputs, out


def screening_scores_launch_spec(p: int, n: int, *, block_p: int = 256,
                                 block_n: int = 128,
                                 dtype="float64") -> LaunchSpec:
    """Auditable launch geometry of :func:`screening_scores_pallas`."""
    inputs, out = _corr_io_specs(p, n, block_p, block_n, dtype)
    return LaunchSpec(
        name="screening_scores",
        grid=(p // block_p, n // block_n),
        inputs=inputs,
        outputs=(out, out),
        carried=((1,), (1,)),
        note="fused corr + S_tau(corr)^2; corr accumulates over K",
    )


def screening_corr_launch_spec(p: int, n: int, *, block_p: int = 256,
                               block_n: int = 128,
                               dtype="float64") -> LaunchSpec:
    """Auditable launch geometry of :func:`screening_corr_pallas`."""
    inputs, out = _corr_io_specs(p, n, block_p, block_n, dtype)
    return LaunchSpec(
        name="screening_corr",
        grid=(p // block_p, n // block_n),
        inputs=inputs,
        outputs=(out,),
        carried=((1,),),
        note="corr-only variant for the certified gap round",
    )


def _screening_kernel(xt_ref, theta_ref, corr_ref, st2_ref, *, tau: float, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        corr_ref[...] = jnp.zeros_like(corr_ref)

    corr_ref[...] += xt_ref[...] @ theta_ref[...]      # (bp, bn) @ (bn, 1)

    @pl.when(k == nk - 1)
    def _finalize():
        c = corr_ref[...]
        st = jnp.maximum(jnp.abs(c) - tau, 0.0)
        st2_ref[...] = st * st


def screening_scores_pallas(
    Xt: jax.Array,       # (p, n) design matrix transposed
    theta: jax.Array,    # (n,)
    tau: float,
    *,
    block_p: int = 256,
    block_n: int = 128,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    p, n = Xt.shape
    assert p % block_p == 0 and n % block_n == 0, (p, n, block_p, block_n)
    nk = n // block_n
    spec = screening_scores_launch_spec(p, n, block_p=block_p,
                                        block_n=block_n, dtype=Xt.dtype)
    corr, st2 = pl.pallas_call(
        functools.partial(_screening_kernel, tau=float(tau), nk=nk),
        grid=spec.grid,
        in_specs=block_specs(spec.inputs),
        out_specs=block_specs(spec.outputs),
        out_shape=out_shapes(spec.outputs),
        interpret=interpret,
    )(Xt, theta[:, None])
    return corr[:, 0], st2[:, 0]


def _corr_kernel(xt_ref, theta_ref, corr_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        corr_ref[...] = jnp.zeros_like(corr_ref)

    corr_ref[...] += xt_ref[...] @ theta_ref[...]      # (bp, bn) @ (bn, 1)


def screening_corr_pallas(
    Xt: jax.Array,       # (p, n) design matrix transposed
    theta: jax.Array,    # (n,)
    *,
    block_p: int = 256,
    block_n: int = 128,
    interpret: bool | None = None,
):
    """Corr-only variant: blocked corr = Xt @ theta without the st2 output.

    The certified gap round rescales corr by the dual scale before
    thresholding, so the fused kernel's S_tau(corr)^2 half is dead weight
    there — this variant skips both its compute and its (p,) HBM write.
    """
    if interpret is None:
        interpret = default_interpret()
    p, n = Xt.shape
    assert p % block_p == 0 and n % block_n == 0, (p, n, block_p, block_n)
    nk = n // block_n
    spec = screening_corr_launch_spec(p, n, block_p=block_p,
                                      block_n=block_n, dtype=Xt.dtype)
    corr = pl.pallas_call(
        functools.partial(_corr_kernel, nk=nk),
        grid=spec.grid,
        in_specs=block_specs(spec.inputs),
        out_specs=block_specs(spec.outputs)[0],
        out_shape=out_shapes(spec.outputs)[0],
        interpret=interpret,
    )(Xt, theta[:, None])
    return corr[:, 0]
