"""Hypothesis property tests (epsilon-norm laws, screening safety).

Split out of test_epsilon_norm.py / test_solver.py so the rest of the suite
collects and runs in environments without hypothesis installed; this module
skips cleanly when it is absent.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import (
    epsilon_decomposition,
    epsilon_norm,
    epsilon_norm_dual,
    lambda_max,
    make_problem,
    solve,
)
from repro.data.synthetic import make_synthetic


def residual(x, alpha, R, nu):
    """Defining equation residual: sum S_{nu a}(x)^2 - (nu R)^2."""
    return np.sum(np.maximum(np.abs(x) - nu * alpha, 0.0) ** 2) - (nu * R) ** 2


@settings(max_examples=80, deadline=None)
@given(
    x=hnp.arrays(
        np.float64,
        st.integers(1, 32),
        elements=st.floats(-50, 50, allow_nan=False),
    ),
    eps=st.floats(0.01, 0.99),
)
def test_property_epsilon_norm_defining_eq(x, eps):
    nu = float(epsilon_norm(jnp.asarray(x), eps))
    if np.all(x == 0):
        assert nu == 0.0
        return
    rel = residual(x, 1.0 - eps, eps, nu)
    assert abs(rel) <= 1e-8 * max((nu * eps) ** 2, 1.0)


@settings(max_examples=60, deadline=None)
@given(
    x=hnp.arrays(np.float64, 16, elements=st.floats(-10, 10, allow_nan=False)),
    y=hnp.arrays(np.float64, 16, elements=st.floats(-10, 10, allow_nan=False)),
    eps=st.floats(0.05, 0.95),
)
def test_property_holder_inequality(x, y, eps):
    """|<x,y>| <= ||x||_eps * ||y||_eps^D  (duality, paper Lemma 4)."""
    ne = float(epsilon_norm(jnp.asarray(x), eps))
    nd = float(epsilon_norm_dual(jnp.asarray(y), eps))
    assert abs(float(x @ y)) <= ne * nd * (1 + 1e-9) + 1e-9


@settings(max_examples=40, deadline=None)
@given(
    x=hnp.arrays(np.float64, 24, elements=st.floats(-10, 10, allow_nan=False)),
    eps=st.floats(0.05, 0.95),
)
def test_property_epsilon_decomposition(x, eps):
    """Lemma 1: x = x_e + x_{1-e}, ||x_e|| = eps*nu, ||x_{1-e}||_inf = (1-eps)*nu."""
    if np.all(x == 0):
        return
    xe, xo, nu = epsilon_decomposition(jnp.asarray(x), eps)
    nu = float(nu)
    np.testing.assert_allclose(np.asarray(xe) + np.asarray(xo), x, atol=1e-12)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(xe)), eps * nu,
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(xo)).max(), (1 - eps) * nu,
                               rtol=1e-8, atol=1e-10)


@settings(max_examples=8, deadline=None)
@given(tau=st.floats(0.05, 0.95), lam_frac=st.floats(0.05, 0.5))
def test_property_gap_rule_never_changes_solution(tau, lam_frac):
    """Safety as a property: for random (tau, lambda) the GAP-screened
    solve must land on the same optimum as the unscreened solve."""
    X, y, _, sizes = make_synthetic(n=25, p=60, n_groups=10, gamma1=2,
                                    gamma2=3, seed=11)
    problem = make_problem(X, y, sizes, tau=tau)
    lam = float(lambda_max(problem)) * lam_frac
    bg = solve(problem, lam, tol=1e-10, rule="gap").beta
    bn = solve(problem, lam, tol=1e-10, rule="none").beta
    np.testing.assert_allclose(np.asarray(bg), np.asarray(bn), atol=1e-6)


# ---------------------------------------------------------------------------
# Epsilon-norm edge cases (limits, degenerate inputs) vs the kernel oracle
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(
    x=hnp.arrays(
        np.float64,
        st.integers(1, 24),
        elements=st.floats(-30, 30, allow_nan=False),
    ),
)
def test_property_epsilon_norm_alpha_limits(x):
    """Closed-form limits of Lambda(x, alpha, R) (paper Alg. 1 special
    cases): alpha -> 0 gives ||x||/R, R -> 0 gives ||x||_inf/alpha —
    exactly (the special-case branches) and continuously (tiny but nonzero
    alpha/R must approach them, not jump)."""
    from repro.core import lam as lam_exact
    from repro.core import lam_bisect

    xj = jnp.asarray(x)
    l2, linf = np.linalg.norm(x), np.abs(x).max(initial=0.0)
    for fn in (lam_exact, lam_bisect):
        np.testing.assert_allclose(float(fn(xj, 0.0, 0.7)), l2 / 0.7,
                                   rtol=1e-8, atol=1e-12)
        np.testing.assert_allclose(float(fn(xj, 0.8, 0.0)), linf / 0.8,
                                   rtol=1e-8, atol=1e-12)
    if linf > 0:
        near0 = float(lam_exact(xj, 1e-9, 0.7))
        np.testing.assert_allclose(near0, l2 / 0.7, rtol=1e-6)
        nearR = float(lam_exact(xj, 0.8, 1e-9))
        np.testing.assert_allclose(nearR, linf / 0.8, rtol=1e-6)


@settings(max_examples=60, deadline=None)
@given(
    x=hnp.arrays(
        np.float64,
        st.integers(1, 24),
        elements=st.floats(-30, 30, allow_nan=False),
    ),
    eps=st.floats(1e-6, 1.0 - 1e-6),
)
def test_property_epsilon_norm_between_l2_and_linf(x, eps):
    """||x||_inf <= ||x||_eps <= ||x||_2 with the eps -> 0 / eps -> 1
    endpoints achieved (Burdakov; paper §5): the eps-norm interpolates the
    two classic norms the sparse-group penalty is built from."""
    nu = float(epsilon_norm(jnp.asarray(x), eps))
    l2, linf = np.linalg.norm(x), np.abs(x).max(initial=0.0)
    assert linf - 1e-10 <= nu <= l2 + max(1e-10, 1e-8 * l2)
    nu0 = float(epsilon_norm(jnp.asarray(x), 1e-12))
    nu1 = float(epsilon_norm(jnp.asarray(x), 1.0 - 1e-12))
    np.testing.assert_allclose(nu0, linf, rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(nu1, l2, rtol=1e-6, atol=1e-9)


@settings(max_examples=40, deadline=None)
@given(
    xval=st.floats(-100, 100, allow_nan=False),
    alpha=st.floats(0.01, 1.0),
    R=st.floats(0.01, 2.0),
)
def test_property_single_element_group_closed_form(xval, alpha, R):
    """d = 1: the defining equation collapses to |x| - nu alpha = nu R,
    i.e. nu = |x| / (alpha + R) — exact for Algorithm 1, the bisection
    kernel formulation, and the kernels/ref.py oracle."""
    from repro.core import lam as lam_exact
    from repro.core import lam_bisect
    from repro.kernels.ref import dual_norm_ref

    x = jnp.asarray([xval])
    want = abs(xval) / (alpha + R)
    for fn in (lam_exact, lam_bisect, dual_norm_ref):
        np.testing.assert_allclose(float(fn(x, alpha, R)), want,
                                   rtol=1e-9, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(1, 16),
    alpha=st.floats(0.0, 1.0),
    R=st.floats(0.0, 2.0),
)
def test_property_zero_vector_maps_to_zero(d, alpha, R):
    """||0||_eps = 0 for every (alpha, R) including the degenerate
    alpha = R = 0 corner (the continuous extension both implementations
    promise in their docstrings)."""
    from repro.core import lam as lam_exact
    from repro.core import lam_bisect

    z = jnp.zeros(d)
    assert float(lam_exact(z, alpha, R)) == 0.0
    assert float(lam_bisect(z, alpha, R)) == 0.0
