"""codeqwen1.5-7b — dense, qwen1.5 arch (MHA kv=heads, QKV bias).
[hf:Qwen/CodeQwen1.5-7B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv=32,
    d_ff=13_440,
    vocab=92_416,
    qkv_bias=True,
    subquadratic=False,
    notes="qwen1.5 arch: full MHA (kv=32), QKV bias",
)
