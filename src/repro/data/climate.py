"""Dimension-faithful stand-in for the NCEP/NCAR Reanalysis 1 experiment.

The paper's real dataset (monthly climate measurements, 1948-2015, 144x73
grid, 7 variables per grid point => X in R^{814 x 73577}, y = air temperature
near Dakar) is not redistributable offline.  This generator reproduces its
*structure*: n monthly samples, G grid-point groups of 7 physical variables
with strong within-group correlation, smooth spatial correlation across
neighbouring grid points, seasonality + trend (then removed, as the paper's
preprocessing does), and a target driven by a small set of nearby groups.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_climate_like"]

VARIABLES = (
    "air_temperature", "precipitable_water", "relative_humidity",
    "pressure", "sea_level_pressure", "horizontal_wind", "vertical_wind",
)


def make_climate_like(
    n: int = 814,
    n_lon: int = 24,
    n_lat: int = 12,
    n_vars: int = 7,
    n_active_regions: int = 6,
    noise: float = 0.05,
    seed: int = 0,
    dtype=np.float64,
):
    """Returns (X, y, beta_true, group_sizes).

    Full-scale paper dims are n_lon=144, n_lat=73 (p = 73577 including the
    target stub); defaults here are reduced for CPU tests, but any size works
    (the benchmark uses larger grids).
    """
    rng = np.random.default_rng(seed)
    G = n_lon * n_lat
    p = G * n_vars
    t = np.arange(n)

    # Latent smooth climate fields: low-rank spatial factors * AR(1) drivers.
    k = 12
    drivers = np.empty((n, k))
    drivers[0] = rng.standard_normal(k)
    for i in range(1, n):
        drivers[i] = 0.8 * drivers[i - 1] + 0.6 * rng.standard_normal(k)

    lon = np.arange(n_lon)[:, None] / n_lon
    lat = np.arange(n_lat)[None, :] / n_lat
    loadings = np.stack(
        [
            np.cos(2 * np.pi * ((i + 1) * lon + (i % 3) * lat)).ravel()
            * np.exp(-(((lon - (i % 5) / 5.0) ** 2 + (lat - (i % 3) / 3.0) ** 2))
                     * 4.0).ravel()
            for i in range(k)
        ],
        axis=1,
    )  # (G, k)

    field = drivers @ loadings.T  # (n, G)
    season = np.sin(2 * np.pi * t / 12.0)[:, None]
    trend = (t / n)[:, None]

    X = np.empty((n, p))
    for v in range(n_vars):
        var_mix = field * (0.7 + 0.3 * rng.random(G)[None, :])
        X[:, v::n_vars] = (
            var_mix
            + 0.8 * season * (1.0 + 0.2 * v)
            + 0.5 * trend
            + 0.3 * rng.standard_normal((n, G))
        )

    # Paper preprocessing: remove seasonality and trend, then standardise.
    month = t % 12
    for m in range(12):
        X[month == m] -= X[month == m].mean(axis=0, keepdims=True)
    X -= np.outer(t - t.mean(), (X * (t - t.mean())[:, None]).sum(0)
                  / ((t - t.mean()) ** 2).sum())
    X /= np.maximum(X.std(axis=0, keepdims=True), 1e-12)

    # Target: sparse group-structured ground truth near a "Dakar" location.
    beta = np.zeros(p)
    target_g = rng.choice(G, size=n_active_regions, replace=False)
    for g in target_g:
        vs = rng.choice(n_vars, size=3, replace=False)
        beta[g * n_vars + vs] = rng.uniform(0.5, 2.0, size=3) * np.sign(
            rng.uniform(-1, 1, size=3)
        )
    y = X @ beta + noise * rng.standard_normal(n)
    y -= y.mean()
    return X.astype(dtype), y.astype(dtype), beta.astype(dtype), [n_vars] * G
