"""Built-in data-fidelity losses: "lsq", "logistic", "multitask".

Each class states its conjugate pair explicitly — the safety of every
GAP certificate built on top rests on these identities (see the proof
obligations in :mod:`repro.losses.base` and the property tests in
``tests/test_losses.py``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import Loss

__all__ = ["LeastSquaresLoss", "LogisticLoss", "MultiTaskLoss"]


def _xlogx(v):
    """``v * log(v)`` with the conventional ``0 * log 0 = 0`` and +inf
    for ``v < 0`` (outside the entropy domain)."""
    safe = jnp.where(v > 0, v, 1.0)
    out = jnp.where(v > 0, v * jnp.log(safe), 0.0)
    return jnp.where(v < 0, jnp.inf, out)


@dataclasses.dataclass(frozen=True)
class LeastSquaresLoss(Loss):
    """``F(z) = 0.5 ||y - z||^2`` — the paper's squared loss.

    Conjugate: ``f_i*(u) = 0.5 u^2 + u y_i``, so ``-F*(-lam theta) =
    lam <theta, y> - 0.5 lam^2 ||theta||^2``.  :meth:`dual_obj` keeps the
    historical equivalent form ``0.5||y||^2 - 0.5 lam^2 ||theta -
    y/lam||^2`` (expand the square — identical algebra) so the default
    loss produces bit-identical programs to the pre-loss solver.
    ``nu = 1``: each ``f_i`` is 1-smooth.
    """

    name = "lsq"
    nu = 1.0

    def value(self, y, z):
        r = y - z
        return 0.5 * jnp.sum(r * r)

    def neg_grad(self, y, z):
        return y - z

    def conjugate(self, y, u):
        return jnp.sum(0.5 * u * u + u * y)

    def dual_obj(self, y, theta, lam_):
        # Historical arithmetic, verbatim (== -conjugate(y, -lam*theta)).
        return (0.5 * jnp.sum(y * y)
                - 0.5 * lam_ ** 2 * jnp.sum((theta - y / lam_) ** 2))

    def lam_max_rho(self, y):
        return y


@dataclasses.dataclass(frozen=True)
class LogisticLoss(Loss):
    """``F(z) = sum_i log(1 + e^{z_i}) - y_i z_i`` with labels in {0, 1}.

    ``rho_i = y_i - sigmoid(z_i)`` lies strictly in ``(y_i - 1, y_i)``,
    and the Eq. 15 scaling (``>= lam``) keeps ``-lam theta_i = -lam
    rho_i / scale`` inside the conjugate domain, so the dual objective is
    finite at every scaled point.  Conjugate (negative binary entropy):
    ``f_i*(u) = (u + y_i) log(u + y_i) + (1 - u - y_i) log(1 - u - y_i)``
    for ``u + y_i`` in ``[0, 1]`` (+inf outside).  ``f_i`` is 1/4-smooth
    (``sigma'(z) <= 1/4``), hence ``nu = 1/4``: the GAP radius tightens
    to ``sqrt(gap / 2) / lam`` and the BCD majorization divides by the
    block bound ``nu * L_g = L_g / 4`` (the logistic Hessian is
    ``diag(sigma')``, bounded by ``I/4``); see ``solver._bcd_epochs_loss``.
    """

    name = "logistic"
    nu = 0.25

    def value(self, y, z):
        # log(1 + e^z) - y z, stable at both tails.
        return jnp.sum(jnp.logaddexp(0.0, z) - y * z)

    def neg_grad(self, y, z):
        return y - jax.nn.sigmoid(z)

    def conjugate(self, y, u):
        v = u + y
        return jnp.sum(_xlogx(v) + _xlogx(1.0 - v))

    def lam_max_rho(self, y):
        return y - 0.5


@dataclasses.dataclass(frozen=True)
class MultiTaskLoss(Loss):
    """``F(Z) = 0.5 ||Y - Z||_F^2`` with ``Y`` of shape (n, K) — the
    multi-task squared loss of arXiv 1506.03736.

    Same quadratic conjugate algebra as :class:`LeastSquaresLoss`, summed
    over the task axis; beta grows to (G, ng, K) and the SGL penalty
    becomes row-group norms (``tau``-weighted row l2 + group Frobenius).
    Supported at the :mod:`repro.core.sgl` math level (norms, primal/
    dual/gap, safe-sphere group test); :class:`SGLSession` rejects it
    until the solver grows a task axis.
    """

    name = "multitask"
    nu = 1.0
    multi_output = True

    def value(self, y, z):
        r = y - z
        return 0.5 * jnp.sum(r * r)

    def neg_grad(self, y, z):
        return y - z

    def conjugate(self, y, u):
        return jnp.sum(0.5 * u * u + u * y)

    def dual_obj(self, y, theta, lam_):
        return (0.5 * jnp.sum(y * y)
                - 0.5 * lam_ ** 2 * jnp.sum((theta - y / lam_) ** 2))

    def lam_max_rho(self, y):
        return y
