"""Sequential-screening lambda-path engine (paper Section 7.1, Fig. 2/3).

lambda_t = lambda_max * 10^(-delta * t / (T - 1)),  t = 0..T-1
(default delta = 3, T = 100, matching GLMNET practice cited by the paper).

The paper's headline wall-clock result comes from the *warm-started path*,
where the GAP safe rule screens both **sequentially** and **dynamically**.
The engine threads state across the grid instead of treating each lambda as
an independent solve:

1. **Sequential GAP screening** — before the first epoch at ``lambda_t`` a
   certified round is evaluated at the new lambda with the previous
   lambda's ``beta_{t-1}`` (residual-rescaled dual point, Eq. 15 + Thm 2).
   Groups failing the Theorem-1 test are discarded with **zero BCD work**;
   if the warm-started gap is already below ``tol`` the lambda costs zero
   epochs outright.  The round is handed to the solve as ``first_round``
   so it is never recomputed.
2. **Active warm start + cache carrying** — one
   :class:`repro.core.solver.SolveCaches` instance is carried down the
   whole path, so the compacted (n x p_active) gather of the design matrix
   is reused whenever consecutive lambdas certify the same active set.
3. **Sequential-gap-adaptive work schedule** — warm lambdas (sequential
   gap within ``warm_gap_factor * tol``) check the reduced gap after every
   epoch; cold lambdas keep the cheap ``f_ce``-block cadence.
4. **Pallas-backed rounds** — the certified rounds' X^T resid correlation
   and SGL dual norm route through the fused Pallas kernels on TPU
   (``screen_backend="auto"``), fed from ONE persistent transposed design
   for the whole path.
5. **Compacted certified rounds** — once groups hold permanent
   certificates, most rounds run on the gathered (n, p_active) buffer with
   the screened groups' dual-norm terms bounded from the last full round's
   cached reference (exact when the bound holds; fallback policy and the
   always-full converged round are described in
   :mod:`repro.core.session`).

The engine itself lives on the session API
(:meth:`repro.core.session.SGLSession.solve_path`); this module keeps the
grid helper, the dense :class:`PathResult` container (re-exported from
:mod:`repro.core.session`), and the legacy keyword front-end
:func:`solve_path`, now a thin deprecated wrapper whose loose kwargs map
onto :class:`repro.core.session.SolverConfig` fields of the same names.

``sequential=False, check_every=None`` reproduces the legacy per-instance
loop exactly (used by ``benchmarks/bench_path.py`` as the baseline).
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence, Union

from .sgl import SGLProblem
from .session import PathResult, SGLSession, SolverConfig, lambda_grid

__all__ = ["lambda_grid", "PathResult", "solve_path"]


def solve_path(
    problem: SGLProblem,
    lambdas: Optional[Sequence[float]] = None,
    T: int = 100,
    delta: float = 3.0,
    tol: float = 1e-8,
    max_epochs: int = 10_000,
    f_ce: int = 10,
    rule="gap",
    compact: bool = True,
    inner_rounds: int = 5,
    check_every: Union[int, None, str] = "auto",
    sequential: bool = True,
    screen_backend: str = "auto",
    solver_backend: str = "auto",
    keep_results: bool = False,
    warm_gap_factor: float = 1e3,
) -> PathResult:
    """Solve the whole lambda path with sequential + dynamic screening.

    .. deprecated::
        Thin wrapper over the session API — prefer::

            session = SGLSession(problem, SolverConfig(tol=1e-8))
            res = session.solve_path(T=100, delta=3.0)

        Solver knobs (``tol``/``max_epochs``/``f_ce``/``rule``/``compact``/
        ``inner_rounds``/``check_every``/``screen_backend``/
        ``solver_backend``/``warm_gap_factor``) are :class:`SolverConfig`
        fields; the grid
        (``lambdas``/``T``/``delta``) and ``sequential``/``keep_results``
        are ``solve_path`` arguments.

    ``check_every="auto"`` schedules from the sequential certificate;
    ``sequential=False`` together with ``check_every=None`` reproduces the
    legacy naive loop (fresh caches, no pre-solve screening per lambda).
    """
    warnings.warn(
        "repro.core.solve_path() is deprecated; use "
        "SGLSession(problem, SolverConfig(...)).solve_path(...)",
        DeprecationWarning, stacklevel=2,
    )
    cfg = SolverConfig(
        tol=tol, max_epochs=max_epochs, f_ce=f_ce, rule=rule,
        compact=compact, inner_rounds=inner_rounds, check_every=check_every,
        screen_backend=screen_backend, solver_backend=solver_backend,
        warm_gap_factor=warm_gap_factor,
    )
    session = SGLSession(problem, cfg)
    return session.solve_path(
        lambdas=lambdas, T=T, delta=delta, sequential=sequential,
        keep_results=keep_results,
    )
