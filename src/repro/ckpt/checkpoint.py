"""Fault-tolerant checkpointing (no orbax offline — built from scratch).

Guarantees:
* **atomic**: writes go to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint;
* **keep-k** garbage collection;
* **elastic restore**: arrays are stored device-agnostic (host numpy) with
  the pytree structure; restore works on ANY mesh/device count — the caller
  re-applies shardings (``jax.device_put`` with the current NamedShardings),
  which is exactly the elastic-rescale path;
* **preemption hook**: ``install_sigterm_hook`` saves on SIGTERM (the
  standard TPU-pod preemption signal) before exiting.

Format: one ``.npz`` per checkpoint with leaves keyed by their tree path +
a JSON manifest (step, leaf paths, dtypes/shapes).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable, Optional

import numpy as np
import jax


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, step: int, tree: Any,
         extra_manifest: Optional[dict] = None) -> str:
    """Atomic checkpoint write; ``extra_manifest`` merges caller metadata
    (JSON-serialisable) into the manifest under ``"extra"`` — the serving
    layer stores its path cursor (lambda index + caches digest) there so
    resume reads one small JSON instead of re-scanning step dirs."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": dict(extra_manifest) if extra_manifest else {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)     # atomic publish
    _write_latest_pointer(directory, step, manifest)
    return final


def _write_latest_pointer(directory: str, step: int, manifest: dict) -> None:
    """Atomic ``latest.json`` next to the step dirs: the newest step and
    its full manifest, so :func:`latest` is one read, no dir scan."""
    tmp = os.path.join(directory, "latest.json.tmp")
    with open(tmp, "w") as f:
        json.dump({"step": step, "manifest": manifest}, f)
    os.replace(tmp, os.path.join(directory, "latest.json"))


def latest(directory: str) -> Optional[tuple]:
    """``(step, manifest)`` of the newest checkpoint, or ``None``.

    Reads the atomic ``latest.json`` pointer written by :func:`save` —
    one small JSON instead of an O(k) step-dir scan — and falls back to
    :func:`latest_step` + the step's own ``manifest.json`` for
    directories written before the pointer existed (or whose pointer was
    deleted).  The pointed-at step dir is verified to still exist, so a
    stale pointer can never resolve to a GC'd checkpoint.
    """
    pointer = os.path.join(directory, "latest.json")
    try:
        with open(pointer) as f:
            data = json.load(f)
        step = int(data["step"])
        if os.path.isdir(os.path.join(directory, f"step_{step:012d}")):
            return step, data["manifest"]
    except (FileNotFoundError, KeyError, ValueError, json.JSONDecodeError):
        pass
    step = latest_step(directory)
    if step is None:
        return None
    with open(os.path.join(directory, f"step_{step:012d}",
                           "manifest.json")) as f:
        return step, json.load(f)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(directory))
        if m
    ]
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    placed directly onto the (possibly different-size) current mesh, which is
    the elastic-rescale path.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:012d}")
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for (pth, like), shard in zip(flat_paths[0], shard_leaves):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pth
        )
        arr = data[key]
        if shard is not None:
            arr = jax.device_put(arr, shard)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_keep_k(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(directory))
        if m
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:012d}"),
                      ignore_errors=True)


class CheckpointManager:
    """save-every-N + keep-k + preemption hook, as used by launch/train.py."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        self._lock = threading.Lock()
        self._latest_provider: Optional[Callable[[], tuple]] = None

    def maybe_save(self, step: int, tree: Any) -> Optional[str]:
        if step % self.every != 0:
            return None
        with self._lock:
            path = save(self.directory, step, tree)
            gc_keep_k(self.directory, self.keep)
            return path

    def install_sigterm_hook(self, provider: Callable[[], tuple]) -> None:
        """provider() -> (step, tree); called on SIGTERM (pod preemption)."""
        self._latest_provider = provider

        def handler(signum, frame):
            if self._latest_provider is not None:
                step, tree = self._latest_provider()
                save(self.directory, step, tree)
            raise SystemExit(143)

        signal.signal(signal.SIGTERM, handler)

    def restore_latest(self, tree_like: Any, shardings: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore(self.directory, tree_like, step, shardings)
