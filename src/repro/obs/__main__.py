"""``python -m repro.obs --check`` — the observability self-audit gate."""
import sys

from .check import main

if __name__ == "__main__":
    sys.exit(main())
