import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes and extract memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch demo --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch sgl-paper --shape solve

The first two lines of this file MUST stay first: jax locks the device count
on first initialisation.  ``--all`` mode runs each cell in a subprocess (so a
pathological cell cannot wedge the sweep and compile memory is returned to
the OS between cells).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import numpy as np


def run_cell(arch: str, shape_name: str, multi_pod: bool, q_chunk: int = 512,
             json_out=None, quiet=False):
    import jax
    import jax.numpy as jnp

    from repro.configs import get
    from repro.configs.base import SHAPES_BY_NAME, shape_applicable
    from repro.launch import mesh as meshlib
    from repro.launch import roofline as rl

    t0 = time.time()
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    if arch == "sgl-paper":
        result = _run_sgl_cell(mesh, multi_pod, chips)
    else:
        cfg = get(arch)
        shape = SHAPES_BY_NAME[shape_name]
        ok, reason = shape_applicable(cfg, shape)
        if not ok:
            result = {"arch": arch, "shape": shape_name,
                      "multi_pod": multi_pod, "status": "skipped",
                      "reason": reason}
            _emit(result, json_out, quiet)
            return result

        from repro.launch import specs as speclib

        cell = speclib.build_cell(
            cfg, shape, dp=meshlib.dp_size(mesh),
            model_axis=meshlib.model_size(mesh), q_chunk=q_chunk,
        )
        in_shardings = tuple(
            meshlib.shardings_for_structs(mesh, s, a, multi_pod=multi_pod)
            for s, a in zip(cell.in_specs, cell.args)
        )
        jitted = jax.jit(
            cell.fn, in_shardings=in_shardings, donate_argnums=cell.donate
        )
        with mesh:
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per device
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # Trip-count-aware analysis: XLA's cost_analysis counts while bodies
        # once, undercounting scanned layer stacks / q-chunk loops by their
        # trip counts (see roofline.analyze_hlo).
        corrected = rl.analyze_hlo(hlo)
        coll = {k[len("coll_"):]: v for k, v in corrected.items()
                if k.startswith("coll_")}
        if json_out:
            import gzip
            with gzip.open(json_out + ".hlo.gz", "wt") as f:
                f.write(hlo)

        # model flops: tokens processed this step
        if cell.kind == "train":
            tokens = shape.global_batch * shape.seq_len
        elif cell.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
        else:
            tokens = shape.global_batch  # one token per sequence
        p_structs = cell.args[0]
        mf = rl.model_flops(cfg, p_structs, cell.kind, tokens)

        # cost_analysis() reports PER-DEVICE numbers for the SPMD-partitioned
        # executable (verified empirically: sharded 4096^3 matmul reports
        # exactly total/n_devices); scale to cluster totals so the roofline
        # formula terms  X / (chips * peak)  are per-chip times.
        roof = rl.Roofline(
            flops=corrected["flops"] * chips,
            bytes_accessed=corrected["bytes_accessed"] * chips,
            collective_bytes=corrected["collective_bytes"] * chips,
            chips=chips,
            model_flops=mf,
        )
        result = {
            "arch": arch,
            "shape": shape_name,
            "multi_pod": multi_pod,
            "status": "ok",
            "kind": cell.kind,
            "chips": chips,
            "seconds": time.time() - t0,
            "params": rl.count_params(p_structs),
            "active_params": rl.active_params(cfg, p_structs),
            "xla_cost_analysis": {   # uncorrected, for reference
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            "collectives": coll,
            "roofline": roof.as_dict(),
        }

    _emit(result, json_out, quiet)
    return result


def _run_sgl_cell(mesh, multi_pod, chips):
    """The paper's own workload on the production mesh: one distributed
    FISTA step + one screening round, lowered from ShapeDtypeStructs."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get
    from repro.distributed.solver_dist import make_dist_step
    from repro.launch import roofline as rl

    cfg = get("sgl-paper")
    n, G, ng = cfg.n_samples, cfg.n_groups, cfg.group_size
    kernels = make_dist_step(mesh, tau=cfg.tau, multi_pod=multi_pod)
    f32, bf16 = jnp.float32, jnp.bfloat16
    # batched-lambda width: 256 path points per X pass with bf16 FISTA
    # state (iterate precision only — certified screen rounds stay f32).
    # Swept in §Perf: B=16/64/128 f32-state -> frac 0.065/0.129/0.249;
    # B=128/256 bf16-state -> 0.480/0.875. B=256 peaks at 4 GiB/device.
    B = 256
    X = jax.ShapeDtypeStruct((n, G, ng), f32)
    Xh = jax.ShapeDtypeStruct((n, G, ng), bf16)   # mixed-precision FISTA
    y = jax.ShapeDtypeStruct((n,), f32)
    gv = jax.ShapeDtypeStruct((G, ng), f32)
    bv = jax.ShapeDtypeStruct((B, G, ng), bf16)   # bf16 iterate state
    sv = jax.ShapeDtypeStruct((G,), f32)
    sc = jax.ShapeDtypeStruct((), f32)
    scB = jax.ShapeDtypeStruct((B,), f32)

    with mesh:
        comp_f = jax.jit(kernels.fista).lower(
            X, y, gv, gv, gv, sv, sc, sc, sc).compile()
        comp_fh = jax.jit(kernels.fista).lower(
            Xh, y, gv, gv, gv, sv, sc, sc, sc).compile()
        comp_fb = jax.jit(kernels.fista_batch).lower(
            Xh, y, bv, bv, bv, sv, scB, scB, sc).compile()
        comp_s = jax.jit(kernels.screen).lower(
            X, y, gv, gv, sv, gv, sv, sc, sc).compile()

    out = {"arch": "sgl-paper", "shape": f"fista+screen n={n} G={G} ng={ng}",
           "multi_pod": multi_pod, "status": "ok", "chips": chips,
           "lambda_batch": B}
    for name, comp in (("fista", comp_f), ("fista_bf16", comp_fh),
                       (f"fista_batch{B}_bf16", comp_fb),
                       ("screen", comp_s)):
        mem = comp.memory_analysis()
        corrected = rl.analyze_hlo(comp.as_text())
        coll = {k[len("coll_"):]: v for k, v in corrected.items()
                if k.startswith("coll_")}
        # useful flops: 2 matvecs over the active design matrix = 4*n*p
        # (x B for the batched-lambda kernel — B path points per X pass)
        mf = 4.0 * n * G * ng * (B if "batch" in name else 1)
        roof = rl.Roofline(
            flops=corrected["flops"] * chips,
            bytes_accessed=corrected["bytes_accessed"] * chips,
            collective_bytes=corrected["collective_bytes"] * chips,
            chips=chips,
            model_flops=mf,
        )
        out[name] = {
            "collectives": coll,
            "roofline": roof.as_dict(),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            },
        }
    return out


def _emit(result, json_out, quiet):
    if json_out:
        with open(json_out, "w") as f:
            json.dump(result, f, indent=2)
    if not quiet:
        print(json.dumps(result, indent=2))


def sweep(out_dir: str, multi_pod_values=(False, True), timeout: int = 3600,
          archs=None, shapes=None):
    """Run every cell in a subprocess; write one JSON per cell."""
    from repro.configs import list_archs
    from repro.configs.base import LM_SHAPES

    os.makedirs(out_dir, exist_ok=True)
    archs = archs or [a for a in list_archs()]
    results = []
    for arch in archs:
        cell_shapes = (
            ["solve"] if arch == "sgl-paper"
            else (shapes or [s.name for s in LM_SHAPES])
        )
        for shape in cell_shapes:
            for mp in multi_pod_values:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                out_json = os.path.join(out_dir, tag + ".json")
                if os.path.exists(out_json):
                    print(f"[skip existing] {tag}")
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape,
                    "--json-out", out_json, "--quiet",
                ]
                if mp:
                    cmd.append("--multi-pod")
                print(f"[{time.strftime('%H:%M:%S')}] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    proc = subprocess.run(
                        cmd, timeout=timeout, capture_output=True, text=True
                    )
                    ok = proc.returncode == 0
                    if not ok:
                        with open(out_json, "w") as f:
                            json.dump({
                                "arch": arch, "shape": shape, "multi_pod": mp,
                                "status": "error",
                                "stderr": proc.stderr[-4000:],
                            }, f, indent=2)
                except subprocess.TimeoutExpired:
                    ok = False
                    with open(out_json, "w") as f:
                        json.dump({
                            "arch": arch, "shape": shape, "multi_pod": mp,
                            "status": "timeout", "timeout_s": timeout,
                        }, f, indent=2)
                print(f"    -> {'ok' if ok else 'FAIL'} "
                      f"({time.time()-t0:.0f}s)", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--json-out")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--archs", nargs="*")
    args = ap.parse_args()

    if args.all:
        sweep(args.out, timeout=args.timeout, archs=args.archs)
        return

    try:
        run_cell(args.arch, args.shape, args.multi_pod,
                 q_chunk=args.q_chunk, json_out=args.json_out,
                 quiet=args.quiet)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
