"""Mamba-2 (SSD — state-space duality) attention-free LM.

The SSD recurrence per head h with per-(token,head) scalar decay a_t:

    H_t = a_t H_{t-1} + (dt_t x_t) B_t^T        H in R^{hd x N}
    y_t = H_t C_t + D_skip x_t

Training uses the *chunked* dual form (arXiv:2405.21060): within a chunk the
quadratic masked-decay form runs on the MXU; across chunks a lax.scan carries
the (B, heads, hd, N) state.  Decoding is the O(1) recurrent update.  This is
the TPU-native adaptation: chunk size trades VMEM footprint against MXU
utilisation (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L


def _d_inner(cfg):
    return cfg.ssm_heads * cfg.ssm_head_dim


def _conv_dim(cfg):
    return _d_inner(cfg) + 2 * cfg.ssm_state


def _init_layer(cfg, key, dtype):
    D = cfg.d_model
    di = _d_inner(cfg)
    N = cfg.ssm_state
    Hh = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * N + Hh  # z, xBC, dt
    return {
        "ln": L.init_norm(cfg, dtype),
        "in_proj": jax.random.normal(ks[0], (D, proj_out), dtype) * D ** -0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, _conv_dim(cfg)),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((_conv_dim(cfg),), dtype),
        "A_log": jnp.zeros((Hh,), jnp.float32),
        "D_skip": jnp.ones((Hh,), jnp.float32),
        "dt_bias": jnp.zeros((Hh,), jnp.float32),
        "out_norm": jnp.zeros((di,), dtype),
        "out_proj": jax.random.normal(ks[2], (di, D), dtype) * di ** -0.5,
    }


def _layer_specs(cfg):
    return {
        "ln": P(None),
        "in_proj": P("data", "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P(None),
        "D_skip": P(None),
        "dt_bias": P(None),
        "out_norm": P("model"),
        "out_proj": P("model", "data"),
    }


def init_params(cfg, key, dtype=jnp.bfloat16):
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype)
        * cfg.d_model ** -0.5,
        "layers": stacked,
        "ln_f": L.init_norm(cfg, dtype),
        "unembed": jax.random.normal(ko, (cfg.d_model, cfg.vocab), dtype)
        * cfg.d_model ** -0.5,
    }


def param_specs(cfg, model_axis: int = 16):
    from .transformer import _stack_spec

    return {
        "embed": P("model", "data"),
        "layers": _stack_spec(_layer_specs(cfg)),
        "ln_f": P(None),
        "unembed": P("data", "model"),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv; x (B,S,C), w (W,C), b (C,)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def _split_proj(cfg, zxbcdt):
    di, N, Hh = _d_inner(cfg), cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xBC, dt


def _ssd_chunked(cfg, xh, Bm, Cm, la, state0=None):
    """Chunked SSD scan.

    xh: (B,S,H,hd) inputs already scaled by dt; Bm/Cm: (B,S,N);
    la: (B,S,H) log-decay (<= 0).  Returns y (B,S,H,hd), final state
    (B,H,hd,N).
    """
    Bsz, S, Hh, hd = xh.shape
    N = Bm.shape[-1]
    Lc = min(cfg.ssm_chunk, S)
    if S % Lc != 0:
        Lc = S  # irregular (smoke-test) lengths: single chunk
    nc = S // Lc

    xc = xh.reshape(Bsz, nc, Lc, Hh, hd)
    Bc = Bm.reshape(Bsz, nc, Lc, N)
    Cc = Cm.reshape(Bsz, nc, Lc, N)
    lac = la.reshape(Bsz, nc, Lc, Hh)
    cum = jnp.cumsum(lac, axis=2)                       # (B,nc,Lc,H)
    tot = cum[:, :, -1:]                                # chunk total decay

    # Intra-chunk (quadratic, MXU): scores[t,s] = (C_t.B_s) exp(cum_t-cum_s)
    CB = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)          # shared across heads
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(dec), 0.0)
    scores = CB[..., None] * M                          # (B,nc,t,s,H)
    y_intra = jnp.einsum("bctsh,bcshd->bcthd", scores.astype(xc.dtype), xc)

    # Per-chunk state contribution: sum_t exp(tot - cum_t) B_t (x_t)^T
    right = jnp.exp(tot - cum)                          # (B,nc,Lc,H)
    S_c = jnp.einsum("bcth,bctn,bcthd->bchdn",
                     right.astype(xc.dtype), Bc.astype(xc.dtype), xc)

    # Inter-chunk scan carrying state (B,H,hd,N)
    if state0 is None:
        state0 = jnp.zeros((Bsz, Hh, hd, N), xh.dtype)

    def step(h_prev, inputs):
        S_ci, tot_i, Cc_i, cum_i = inputs
        # y_inter[t] = exp(cum_t) * C_t . h_prev
        y_int = jnp.einsum("btn,bhdn->bthd", Cc_i.astype(h_prev.dtype), h_prev)
        y_int = y_int * jnp.exp(cum_i)[..., None].astype(y_int.dtype)
        h_new = h_prev * jnp.exp(tot_i)[:, 0, :, None, None].astype(h_prev.dtype) + S_ci
        return h_new, y_int

    # move chunk axis to front for scan
    xs = (
        jnp.moveaxis(S_c, 1, 0),
        jnp.moveaxis(tot, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    if nc <= 64:
        # unrolled chunk loop: costs visible to the HLO cost model
        state = state0
        ys = []
        for i in range(nc):
            state, yi = step(state, tuple(x[i] for x in xs))
            ys.append(yi)
        y_inter = jnp.stack(ys)
    else:
        state, y_inter = jax.lax.scan(step, state0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)               # (B,nc,Lc,H,hd)
    y = (y_intra + y_inter).reshape(Bsz, S, Hh, hd)
    return y, state


def _mixer(cfg, lp, x, conv_state=None, ssm_state=None, single_step=False):
    """The Mamba-2 mixer. x: (B,S,D).  Returns (y, new_conv, new_ssm)."""
    Bsz, S, D = x.shape
    di, N, Hh, hd = _d_inner(cfg), cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _split_proj(cfg, x @ lp["in_proj"])

    if single_step:
        # conv via carried state: (B, W-1, conv_dim)
        seq = jnp.concatenate([conv_state, xBC], axis=1)   # (B, W, C)
        new_conv = seq[:, 1:]
        xBC = (jnp.einsum("bwc,wc->bc", seq, lp["conv_w"]) + lp["conv_b"])[
            :, None
        ]
    else:
        xBC = _causal_conv(xBC, lp["conv_w"], lp["conv_b"])
        new_conv = xBC_last = None
    xBC = jax.nn.silu(xBC)

    xh = xBC[..., :di].reshape(Bsz, -1, Hh, hd)
    Bm = xBC[..., di : di + N]
    Cm = xBC[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])   # (B,S,H)
    la = -jnp.exp(lp["A_log"]) * dt                                 # log decay
    xdt = xh * dt[..., None].astype(xh.dtype)

    if single_step:
        a = jnp.exp(la)[:, 0]                                       # (B,H)
        upd = jnp.einsum("bn,bhd->bhdn", Bm[:, 0].astype(xdt.dtype), xdt[:, 0])
        new_ssm = ssm_state * a[..., None, None].astype(ssm_state.dtype) + upd
        y = jnp.einsum("bn,bhdn->bhd", Cm[:, 0].astype(new_ssm.dtype), new_ssm)
        y = y[:, None]                                              # (B,1,H,hd)
        y = y + lp["D_skip"][None, None, :, None].astype(y.dtype) * xh
        state_out = (new_conv, new_ssm)
    else:
        y, final_state = _ssd_chunked(cfg, xdt, Bm, Cm, la, state0=ssm_state)
        y = y + lp["D_skip"][None, None, :, None].astype(y.dtype) * xh
        state_out = (None, final_state)

    y = y.reshape(Bsz, -1, di)
    y = L.rms_norm(y * jax.nn.silu(z), lp["out_norm"], cfg.norm_eps)
    return y @ lp["out_proj"], state_out


def forward(cfg, params, tokens, embeds=None, *, remat: bool = True, **_):
    h = jnp.take(params["embed"], tokens, axis=0)

    def body(h, lp):
        a = L.rms_norm(h, lp["ln"], cfg.norm_eps)
        y, _ = _mixer(cfg, lp, a)
        return h + y, jnp.zeros((), jnp.float32)

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    return h @ params["unembed"], jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------------------
# Serving: recurrent state instead of a KV cache
# ----------------------------------------------------------------------------

class SSMCache(NamedTuple):
    conv: jax.Array   # (L, B, W-1, conv_dim)
    ssm: jax.Array    # (L, B, H, hd, N)
    pos: jax.Array


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    del max_seq  # state size is O(1) in sequence length
    return SSMCache(
        conv=jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1,
                        _conv_dim(cfg)), dtype),
        ssm=jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def cache_specs(cfg, model_axis: int = 16):
    return SSMCache(
        conv=P(None, "data", None, "model"),
        ssm=P(None, "data", "model", None, None),
        pos=P(),
    )


def prefill(cfg, params, tokens, embeds=None, *, dtype=jnp.bfloat16, **_):
    """Prompt pass producing the recurrent state."""
    Bsz, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)

    def body(h, lp):
        a = L.rms_norm(h, lp["ln"], cfg.norm_eps)
        y, (_, ssm_state) = _mixer(cfg, lp, a)
        # conv tail state: last W-1 pre-activation conv inputs
        z, xBC, dt = _split_proj(cfg, a @ lp["in_proj"])
        conv_tail = xBC[:, -(cfg.conv_width - 1):].astype(dtype)
        return h + y, (conv_tail, ssm_state)

    h, (convs, ssms) = jax.lax.scan(body, h, params["layers"])
    h = L.rms_norm(h[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = (h @ params["unembed"])[:, 0]
    return logits, SSMCache(conv=convs, ssm=ssms,
                            pos=jnp.asarray(S, jnp.int32))


def decode_step(cfg, params, cache: SSMCache, token, pos):
    Bsz = token.shape[0]
    h = jnp.take(params["embed"], token[:, None], axis=0)

    def body(h, lp_and_state):
        lp, conv, ssm = lp_and_state
        a = L.rms_norm(h, lp["ln"], cfg.norm_eps)
        y, (new_conv, new_ssm) = _mixer(
            cfg, lp, a, conv_state=conv.astype(a.dtype), ssm_state=ssm,
            single_step=True,
        )
        # the f32 ssm state must not promote the bf16 residual stream
        return h + y.astype(h.dtype), (new_conv.astype(conv.dtype), new_ssm)

    h, (convs, ssms) = jax.lax.scan(
        body, h, (params["layers"], cache.conv, cache.ssm)
    )
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = (h @ params["unembed"])[:, 0]
    return logits, SSMCache(conv=convs, ssm=ssms, pos=pos + 1)
