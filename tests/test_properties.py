"""Hypothesis property tests (epsilon-norm laws, screening safety).

Split out of test_epsilon_norm.py / test_solver.py so the rest of the suite
collects and runs in environments without hypothesis installed; this module
skips cleanly when it is absent.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import (
    epsilon_decomposition,
    epsilon_norm,
    epsilon_norm_dual,
    lambda_max,
    make_problem,
    solve,
)
from repro.data.synthetic import make_synthetic


def residual(x, alpha, R, nu):
    """Defining equation residual: sum S_{nu a}(x)^2 - (nu R)^2."""
    return np.sum(np.maximum(np.abs(x) - nu * alpha, 0.0) ** 2) - (nu * R) ** 2


@settings(max_examples=80, deadline=None)
@given(
    x=hnp.arrays(
        np.float64,
        st.integers(1, 32),
        elements=st.floats(-50, 50, allow_nan=False),
    ),
    eps=st.floats(0.01, 0.99),
)
def test_property_epsilon_norm_defining_eq(x, eps):
    nu = float(epsilon_norm(jnp.asarray(x), eps))
    if np.all(x == 0):
        assert nu == 0.0
        return
    rel = residual(x, 1.0 - eps, eps, nu)
    assert abs(rel) <= 1e-8 * max((nu * eps) ** 2, 1.0)


@settings(max_examples=60, deadline=None)
@given(
    x=hnp.arrays(np.float64, 16, elements=st.floats(-10, 10, allow_nan=False)),
    y=hnp.arrays(np.float64, 16, elements=st.floats(-10, 10, allow_nan=False)),
    eps=st.floats(0.05, 0.95),
)
def test_property_holder_inequality(x, y, eps):
    """|<x,y>| <= ||x||_eps * ||y||_eps^D  (duality, paper Lemma 4)."""
    ne = float(epsilon_norm(jnp.asarray(x), eps))
    nd = float(epsilon_norm_dual(jnp.asarray(y), eps))
    assert abs(float(x @ y)) <= ne * nd * (1 + 1e-9) + 1e-9


@settings(max_examples=40, deadline=None)
@given(
    x=hnp.arrays(np.float64, 24, elements=st.floats(-10, 10, allow_nan=False)),
    eps=st.floats(0.05, 0.95),
)
def test_property_epsilon_decomposition(x, eps):
    """Lemma 1: x = x_e + x_{1-e}, ||x_e|| = eps*nu, ||x_{1-e}||_inf = (1-eps)*nu."""
    if np.all(x == 0):
        return
    xe, xo, nu = epsilon_decomposition(jnp.asarray(x), eps)
    nu = float(nu)
    np.testing.assert_allclose(np.asarray(xe) + np.asarray(xo), x, atol=1e-12)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(xe)), eps * nu,
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(xo)).max(), (1 - eps) * nu,
                               rtol=1e-8, atol=1e-10)


@settings(max_examples=8, deadline=None)
@given(tau=st.floats(0.05, 0.95), lam_frac=st.floats(0.05, 0.5))
def test_property_gap_rule_never_changes_solution(tau, lam_frac):
    """Safety as a property: for random (tau, lambda) the GAP-screened
    solve must land on the same optimum as the unscreened solve."""
    X, y, _, sizes = make_synthetic(n=25, p=60, n_groups=10, gamma1=2,
                                    gamma2=3, seed=11)
    problem = make_problem(X, y, sizes, tau=tau)
    lam = float(lambda_max(problem)) * lam_frac
    bg = solve(problem, lam, tol=1e-10, rule="gap").beta
    bn = solve(problem, lam, tol=1e-10, rule="none").beta
    np.testing.assert_allclose(np.asarray(bg), np.asarray(bn), atol=1e-6)
