"""Figure 2c: time-to-convergence vs. prescribed duality-gap accuracy,
for the five screening strategies (none / static / dynamic / DST3 / GAP).

Paper setting: synthetic AR(1) design, n=100, p=10000 in 1000 groups of 10,
rho=0.5, gamma1=10, gamma2=4, tau=0.2, lambda-path of T values.  The default
here is a reduced instance so the whole harness runs in CPU-minutes; pass
``--full`` for the paper's dimensions.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import sgl
from repro.core.path import lambda_grid, solve_path
from repro.data.synthetic import make_synthetic

from .common import emit

RULES = ("gap", "dynamic", "dst3", "static", "none")


def run(n=100, p=2000, n_groups=200, T=20, delta=2.0,
        tols=(1e-2, 1e-4, 1e-6, 1e-8), tau=0.2, max_epochs=3000) -> None:
    X, y, _, sizes = make_synthetic(n=n, p=p, n_groups=n_groups)
    problem = make_problem_cached(X, y, sizes, tau)
    lam_max = float(sgl.lambda_max(problem))
    lambdas = lambda_grid(lam_max, T=T, delta=delta)

    for rule in RULES:
        for tol in tols:
            t0 = time.perf_counter()
            # Naive-loop mode: Fig 2c compares screening RULES, so every
            # rule must run under the identical per-lambda work schedule
            # (the path-engine features are benchmarked in bench_path.py).
            res = solve_path(
                problem, lambdas=lambdas, tol=tol,
                max_epochs=max_epochs, rule=rule,
                sequential=False, check_every=None,
            )
            dt = time.perf_counter() - t0
            case = f"{rule}_tol{tol:g}"
            emit("screening_fig2c", case, "path_seconds", dt)
            emit("screening_fig2c", case, "total_epochs", int(res.epochs.sum()))
            emit("screening_fig2c", case, "max_final_gap", float(res.gaps.max()))


_problem_cache = {}


def make_problem_cached(X, y, sizes, tau):
    key = (X.shape, float(tau))
    if key not in _problem_cache:
        _problem_cache[key] = sgl.make_problem(X, y, sizes, tau=tau)
    return _problem_cache[key]


def main(full: bool = False) -> None:
    if full:
        run(n=100, p=10_000, n_groups=1_000, T=100, delta=3.0)
    else:
        run()


if __name__ == "__main__":
    import argparse

    from .common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper dimensions (n=100, p=10000, T=100)")
    args = ap.parse_args()
    header()
    main(full=args.full)
