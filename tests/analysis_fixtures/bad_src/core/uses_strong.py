"""Seeded CS002 violation: a solver-layer module naming the unsafe rule.

Fixture for tests/test_analysis.py — parsed, never imported.
"""
from repro.rules import StrongSequentialRule


def pick_rule():
    # CS002: core/ special-casing the unsafe heuristic
    return StrongSequentialRule(shrink=0.5)
