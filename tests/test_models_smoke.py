"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness, and prefill/decode consistency vs the
training forward (the strongest cheap correctness check for the serve path).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import build

# Test-local reduced configs, one per model family/variant the zoo covers
# (the seed-era full-size LLM configs were pruned from repro.configs —
# these are exactly their .reduced() forms, now owned by the test).
_REDUCED = {
    "qwen2.5-14b": ArchConfig(
        name="qwen2.5-14b", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
        qkv_bias=True),
    "codeqwen1.5-7b": ArchConfig(
        name="codeqwen1.5-7b", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
        qkv_bias=True),
    "qwen3-8b": ArchConfig(
        name="qwen3-8b", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
        qk_norm=True),
    "llama3-405b": ArchConfig(
        name="llama3-405b", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16),
    "recurrentgemma-2b": ArchConfig(
        name="recurrentgemma-2b", family="hybrid", n_layers=3, d_model=64,
        n_heads=4, n_kv=1, d_ff=128, vocab=256, head_dim=16, window=32,
        hybrid_pattern=("rec", "rec", "attn"), ssm_chunk=8, conv_width=4,
        subquadratic=True),
    "olmoe-1b-7b": ArchConfig(
        name="olmoe-1b-7b", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
        moe=MoEConfig(n_experts=8, top_k=2), ssm_chunk=8),
    "mixtral-8x7b": ArchConfig(
        name="mixtral-8x7b", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16, window=32,
        moe=MoEConfig(n_experts=8, top_k=2), ssm_chunk=8,
        subquadratic=True),
    "mamba2-2.7b": ArchConfig(
        name="mamba2-2.7b", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv=0, d_ff=128, vocab=256, ssm_state=16,
        ssm_heads=4, ssm_head_dim=16, ssm_chunk=8, conv_width=4,
        subquadratic=True),
    "seamless-m4t-large-v2": ArchConfig(
        name="seamless-m4t-large-v2", family="encdec", n_layers=2,
        d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
        n_enc_layers=2, frontend_tokens=8, ssm_chunk=8),
    "llava-next-mistral-7b": ArchConfig(
        name="llava-next-mistral-7b", family="vlm", n_layers=2,
        d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
        frontend_tokens=8, ssm_chunk=8),
}

ARCHS = list(_REDUCED)
DTYPE = jnp.float32  # CPU smoke: f32 for tight decode-vs-forward comparison


def _make_inputs(cfg, key, batch=2, seq=16):
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    embeds = None
    if cfg.family in ("vlm", "encdec"):
        F = cfg.frontend_tokens
        embeds = (
            jax.random.normal(jax.random.fold_in(key, 1), (batch, F, cfg.d_model),
                              DTYPE) * 0.1
        )
    return tokens, embeds


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _REDUCED[arch]
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0), dtype=DTYPE)
    tokens, embeds = _make_inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = api.forward(params, tokens, embeds, q_chunk=8)
    F = cfg.frontend_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (2, 16 + F, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    from repro.train import make_train_step

    cfg = _REDUCED[arch]
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0), dtype=DTYPE)
    init_state, train_step = make_train_step(api, lr=1e-3, q_chunk=8)
    opt_state = init_state(params)
    tokens, embeds = _make_inputs(cfg, jax.random.PRNGKey(1))
    batch = {"tokens": tokens}
    if embeds is not None:
        batch["embeds"] = embeds
    p2, o2, metrics = jax.jit(train_step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode_step after prefill must reproduce the training forward's
    next-token logits (teacher forcing equivalence)."""
    cfg = _REDUCED[arch]
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0), dtype=DTYPE)
    B, S = 2, 12
    tokens, embeds = _make_inputs(cfg, jax.random.PRNGKey(1), batch=B, seq=S)

    # full forward over the first S-1 tokens + the last token appended
    logits_all, _ = api.forward(params, tokens, embeds, q_chunk=8)
    F = cfg.frontend_tokens if cfg.family == "vlm" else 0

    # prefill on the prompt (first S-1 tokens)
    prompt = tokens[:, : S - 1]
    F_pre = cfg.frontend_tokens if cfg.family == "vlm" else 0
    last_logits, cache = api.prefill(params, prompt, embeds, q_chunk=8,
                                     cache_len=S + F_pre + 4, dtype=DTYPE)
    ref_prompt, _ = api.forward(params, prompt, embeds, q_chunk=8)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(ref_prompt[:, -1]),
        rtol=2e-4, atol=2e-4,
    )

    # one decode step with the last token must match the full forward's last
    step_logits, cache = api.decode_step(
        params, cache, tokens[:, S - 1], jnp.asarray(S - 1 + F, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(logits_all[:, -1]),
        rtol=2e-3, atol=2e-3,
    )


def test_sgl_regularizer_prox_and_sparsity():
    """The paper-integration feature: SGL prox drives FFN groups to zero and
    the per-step screen matches the prox zeros (safe on the subproblem)."""
    from repro.train import make_train_step
    from repro.train.sgl_regularizer import (
        SGLRegConfig, apply_prox, group_sparsity, screen_groups,
    )

    cfg = _REDUCED["qwen3-8b"]
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0), dtype=DTYPE)
    reg = SGLRegConfig(lam=5e2, tau=0.3)  # heavy lam to force zeros fast
    init_state, train_step = make_train_step(api, lr=1e-2, sgl_cfg=reg,
                                             q_chunk=8)
    opt_state = init_state(params)
    tokens, _ = _make_inputs(cfg, jax.random.PRNGKey(1))
    p, o, m = jax.jit(train_step)(params, opt_state, {"tokens": tokens})
    sp = group_sparsity(p)
    assert any(v > 0 for v in sp.values()), sp

    # screen test agrees with prox zeros on a convex per-step subproblem
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (16, 8)))
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (16, 8)))
    lr = 0.1
    keep = np.asarray(screen_groups(jnp.asarray(w), jnp.asarray(g),
                                    SGLRegConfig(lam=5.0, tau=0.3,
                                                 screen_margin=1.0), lr))
    from repro.train.sgl_regularizer import _prox_columns
    u = jnp.asarray(w - lr * g)
    after_prox = _prox_columns(u, 5.0 * lr, 0.3)
    zero_cols = np.asarray(jnp.linalg.norm(after_prox, axis=0) == 0)
    # every screened-out (not kept) column must be zero after the prox
    assert np.all(zero_cols[~keep])
