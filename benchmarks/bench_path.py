"""Figure 3b: whole-path computation time on the climate-like dataset as a
function of the prescribed duality-gap accuracy, GAP rule vs no screening —
the sequential path engine vs the legacy naive per-lambda loop vs the
session front-end.

Paper: NCEP/NCAR Reanalysis 1, n=814, p=73577 (groups of 7 variables per
grid point), delta=2.5, tau*=0.4.  The offline generator reproduces the
group structure and preprocessing; the default grid is reduced so the
harness completes in CPU-minutes (``--full`` restores 144x73).

Modes:
* ``naive``   — the seed loop: warm-started beta only, fresh caches and a
  full active-set re-derivation at every lambda, f_ce-block epoch counts.
* ``engine``  — sequential GAP screening before the first epoch of each
  lambda, carried gather cache, sequential-gap-adaptive early exit
  (via the legacy ``solve_path`` wrapper).
* ``session`` — the same engine driven through ``SGLSession.solve_path``
  directly: one session per (rule, tol) owning the caches and, on the
  Pallas backend, ONE persistent transposed design for every certified
  round of the whole path.  ``transpose_copies_eliminated`` counts the
  per-round (p, n) copies of X the pre-session design materialised
  (``n_rounds``) minus the copies actually measured (trace audit,
  ``PathResult.n_transpose_copies``); reported as 0 on the XLA backend,
  where no transposed copy was ever at stake.

The session mode additionally reports the compacted-certified-round audit:
``compact_rounds`` / ``full_rounds`` split the path's certified rounds by
whether they ran on the compacted (n, p_active) buffer or the full
problem, and ``round_flop_reduction`` is the measured ratio between what
full-rounds-only would have cost (rounds x ~4 n p) and the round FLOPs
actually spent (``PathResult.round_flops``, fallback attempts included).

The ``path_pr4`` case records the fused-BCD-solver trajectory
(``solver_backend="pallas"``): wall-clock, epochs, certified-round split,
round FLOPs, fused-epoch-launch and batched-lambda counts, against the XLA
``lax.scan`` twin on the same grid.  ``--json PATH`` dumps every emitted row
(plus environment metadata) as machine-readable JSON — the recorded
``BENCH_pr4.json`` baseline future PRs diff against.

``--smoke`` runs a reduced synthetic config and *asserts* the audits the CI
watches — zero on-the-fly transposed copies, compact rounds actually
exercised, engine-vs-naive beta parity, AND the fused-solver invariants:
``solver_backend="pallas"`` (interpret mode on CPU) reproduces the XLA
path bit-for-bit with ``n_fused_epoch_launches > 0``, and the
batched-lambda run batches at least one coinciding-active-set stretch
(``batched_lambdas > 0``) while staying within tolerance — then exits.
"""
from __future__ import annotations

import time
import warnings

from repro.core import sgl
from repro.core.path import lambda_grid, solve_path
from repro.core.session import SGLSession, SolverConfig
from repro.core.solver import resolve_screen_backend
from repro.data.climate import make_climate_like

from .common import emit

MODES = ("naive", "engine", "session")
MODE_KWARGS = {
    "naive": dict(sequential=False, check_every=None),
    "engine": dict(sequential=True, check_every="auto"),
}


def smoke(n=64, p=512, n_groups=64, T=10, delta=2.0, tau=0.3,
          tol=1e-7, max_epochs=20_000) -> None:
    """CI-sized audit run: transpose + compact-round accounting asserted.

    Exercises both audits on every PR instead of only in manual benchmark
    runs: a session-wiring regression that reintroduced per-round (p, n)
    transposed copies, or one that silently stopped dispatching compact
    rounds, fails this step outright.
    """
    import numpy as np

    from repro.data.synthetic import make_synthetic

    X, y, _, sizes = make_synthetic(n=n, p=p, n_groups=n_groups, gamma1=3,
                                    gamma2=3, seed=11)
    problem = sgl.make_problem(X, y, sizes, tau=tau)

    # full_round_every is disabled so full rounds can ONLY come from the T
    # sequential screens, bound-crossing fallbacks, oversized buffers, and
    # the converged-round confirmation — which makes the full-round floor
    # below a real check of the confirmation invariant instead of being
    # satisfied by the sequential rounds alone.
    session = SGLSession(problem, SolverConfig(tol=tol,
                                               max_epochs=max_epochs,
                                               full_round_every=10 ** 9))
    res = session.solve_path(T=T, delta=delta)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        naive = solve_path(problem, T=T, delta=delta, tol=tol,
                           max_epochs=max_epochs, **MODE_KWARGS["naive"])

    assert (res.gaps <= tol).all(), "session path missed tolerance"
    assert res.n_transpose_copies == 0, (
        f"per-round transposed copies are back: {res.n_transpose_copies}"
    )
    assert res.n_compact_rounds > 0, "no compact certified rounds dispatched"
    # One sequential full round per lambda PLUS one converged full round
    # per lambda that ran epochs (lambdas converging on the sequential
    # round itself already reported a full-round gap).
    worked = int((res.epochs > 0).sum())
    assert res.n_full_rounds >= T + worked, (
        "every lambda's converged round must be a full round "
        f"(full={res.n_full_rounds}, T={T}, worked={worked})"
    )
    np.testing.assert_allclose(res.betas, naive.betas, atol=1e-8)
    full_equiv = res.n_rounds * 4.0 * problem.n * problem.G * problem.ng
    emit("path_smoke", "audit", "compact_rounds", res.n_compact_rounds)
    emit("path_smoke", "audit", "full_rounds", res.n_full_rounds)
    emit("path_smoke", "audit", "transpose_copies", res.n_transpose_copies)
    emit("path_smoke", "audit", "round_flop_reduction",
         full_equiv / max(res.round_flops, 1.0))

    # ---- fused-BCD solver backend (interpret mode on CPU) ----
    # Bit parity: the Pallas mega-kernel path must reproduce the XLA
    # lax.scan path exactly — betas, epoch counts, and screen counters —
    # while actually dispatching fused launches (so the kernel path cannot
    # silently rot on CPU-only CI).
    sess_p = SGLSession(problem, SolverConfig(tol=tol,
                                              max_epochs=max_epochs,
                                              full_round_every=10 ** 9,
                                              solver_backend="pallas"))
    res_p = sess_p.solve_path(T=T, delta=delta, batch_lambdas=1)
    assert res_p.n_fused_epoch_launches > 0, "no fused epoch launches"
    np.testing.assert_array_equal(res_p.betas, res.betas)
    assert (res_p.epochs == res.epochs).all(), "epoch counts diverged"
    assert np.array_equal(res_p.seq_screened, res.seq_screened)
    assert np.array_equal(res_p.dyn_screened, res.dyn_screened)
    emit("path_smoke", "pallas", "fused_epoch_launches",
         res_p.n_fused_epoch_launches)

    # Batched-lambda single-device path, on a DENSE grid whose warm tail
    # has coinciding certified active sets (batching is gated to warm
    # stretches — see SGLSession.solve_path): the stretch must batch
    # through the kernel's lambda-batch grid axis, stay safe, and land
    # within solver tolerance of the per-lambda XLA reference.
    dense = dict(T=T, delta=0.5)
    ref_d = SGLSession(problem, SolverConfig(
        tol=tol, max_epochs=max_epochs, full_round_every=10 ** 9,
    )).solve_path(batch_lambdas=1, **dense)
    sess_b = SGLSession(problem, SolverConfig(tol=tol,
                                              max_epochs=max_epochs,
                                              full_round_every=10 ** 9,
                                              solver_backend="pallas"))
    res_b = sess_b.solve_path(batch_lambdas=4, **dense)
    assert res_b.batched_lambdas > 0, "no batched lambdas on this grid"
    assert (res_b.gaps <= tol).all(), "batched path missed tolerance"
    np.testing.assert_allclose(res_b.betas, ref_d.betas, atol=1e-8)
    emit("path_smoke", "pallas_batched", "batched_lambdas",
         res_b.batched_lambdas)
    emit("path_smoke", "pallas_batched", "fused_epoch_launches",
         res_b.n_fused_epoch_launches)

    obs_payload = _obs_overhead_check(problem, T=T, delta=delta, tol=tol,
                                      max_epochs=max_epochs)
    print("SMOKE PASS")
    return obs_payload


def _obs_overhead_check(problem, *, T, delta, tol, max_epochs,
                        reps=3, budget=0.03) -> dict:
    """The obs zero-cost contract, measured on the smoke path: tracing
    enabled (sample_every=1) must leave the betas bit-identical and the
    wall-clock within ``budget`` of the untraced run.

    min-of-``reps`` on both sides damps scheduler noise — spans cost
    microseconds against a multi-second jitted solve, so any apparent
    overhead above noise is a real regression in the span fast path.
    """
    import numpy as np

    from repro.obs import trace as obs_trace

    def run_once():
        session = SGLSession(problem, SolverConfig(
            tol=tol, max_epochs=max_epochs, full_round_every=10 ** 9))
        t0 = time.perf_counter()
        res = session.solve_path(T=T, delta=delta)
        return time.perf_counter() - t0, np.asarray(res.betas)

    run_once()          # jit warm (XLA caches are process-global)
    t_off, betas_off = zip(*(run_once() for _ in range(reps)))
    obs_trace.configure(enabled=True, sample_every=1)
    obs_trace.TRACER.reset()
    t_on, betas_on = zip(*(run_once() for _ in range(reps)))
    counts = dict(obs_trace.TRACER.counts())
    stages = obs_trace.TRACER.stage_summary()
    obs_trace.configure(enabled=False)

    np.testing.assert_array_equal(
        betas_on[-1], betas_off[-1],
        err_msg="enabling tracing changed the path betas")
    assert counts.get("path", 0) == reps and counts.get("round", 0) > 0, (
        f"span sites silent under tracing: {counts}")
    overhead = min(t_on) / min(t_off) - 1.0
    emit("path_smoke", "obs", "overhead_frac", overhead)
    emit("path_smoke", "obs", "spans_counted", sum(counts.values()))
    assert overhead <= budget, (
        f"obs-enabled path overhead {overhead:.1%} exceeds {budget:.0%}")
    return {
        "shape": {"n": int(problem.n), "G": int(problem.G),
                  "ng": int(problem.ng), "T": T, "delta": delta,
                  "tol": tol},
        "base_s": float(min(t_off)),
        "obs_s": float(min(t_on)),
        "overhead_frac": float(overhead),
        "bit_identical": True,
        "span_counts": counts,
        "stages": stages,
    }


def main(n=256, n_lon=16, n_lat=8, T=20, delta=2.5, tau=0.4,
         tols=(1e-4, 1e-6, 1e-8), max_epochs=3000) -> None:
    X, y, _, sizes = make_climate_like(n=n, n_lon=n_lon, n_lat=n_lat)
    problem = sgl.make_problem(X, y, sizes, tau=tau)
    lam_max = float(sgl.lambda_max(problem))
    lambdas = lambda_grid(lam_max, T=T, delta=delta)

    for rule in ("gap", "none"):
        for tol in tols:
            for mode in MODES:
                t0 = time.perf_counter()
                if mode == "session":
                    session = SGLSession(problem, SolverConfig(
                        tol=tol, max_epochs=max_epochs, rule=rule,
                    ))
                    res = session.solve_path(lambdas=lambdas)
                else:
                    with warnings.catch_warnings():
                        warnings.simplefilter("ignore", DeprecationWarning)
                        res = solve_path(
                            problem, lambdas=lambdas, tol=tol,
                            max_epochs=max_epochs, rule=rule,
                            **MODE_KWARGS[mode],
                        )
                dt = time.perf_counter() - t0
                case = f"{rule}_{mode}_tol{tol:g}"
                emit("path_fig3b", case, "path_seconds", dt)
                emit("path_fig3b", case, "total_epochs", int(res.epochs.sum()))
                emit("path_fig3b", case, "zero_epoch_lambdas",
                     int((res.epochs == 0).sum()))
                emit("path_fig3b", case, "gathers", res.n_gathers)
                emit("path_fig3b", case, "certified_rounds", res.n_rounds)
                # (p, n) transposed copies of X eliminated by the persistent
                # transposed design: one per certified round on the Pallas
                # backend (pre-session behavior), minus any measured copies
                # (res.n_transpose_copies, from the trace audit).  Only the
                # Pallas backend ever had a copy at stake, so XLA-backed
                # runs report 0.
                pallas = resolve_screen_backend("auto") == "pallas"
                emit("path_fig3b", case, "transpose_copies_eliminated",
                     res.n_rounds - res.n_transpose_copies if pallas else 0)
                if mode == "session":
                    # Compacted-certified-round audit (session engine only;
                    # the legacy wrappers spin up their own sessions whose
                    # counters are not surfaced here).
                    emit("path_fig3b", case, "compact_rounds",
                         res.n_compact_rounds)
                    emit("path_fig3b", case, "full_rounds", res.n_full_rounds)
                    full_equiv = (res.n_rounds * 4.0 * problem.n
                                  * problem.G * problem.ng)
                    emit("path_fig3b", case, "round_flop_reduction",
                         full_equiv / max(res.round_flops, 1.0))
                    emit("path_fig3b", case, "round_flops", res.round_flops)
                    emit("path_fig3b", case, "fused_epoch_launches",
                         res.n_fused_epoch_launches)
                    emit("path_fig3b", case, "batched_lambdas",
                         res.batched_lambdas)
                if rule == "gap":
                    emit("path_fig3b", case, "seq_screened_groups",
                         int(res.seq_screened.sum()))
                    emit("path_fig3b", case, "dyn_screened_groups",
                         int(res.dyn_screened.sum()))


def pallas_case(n=64, p=512, n_groups=64, T=12, delta=2.0, tau=0.3,
                tol=1e-6, max_epochs=20_000) -> None:
    """Fused-BCD-solver trajectory vs its XLA twin on one synthetic grid.

    On this CPU container the fused kernel runs interpreted, so its
    wall-clock is an upper bound on dispatch overhead rather than a TPU
    number — the launch/batching audits and the epoch counts are the
    durable metrics (compiled-TPU wall-clock belongs in EXPERIMENTS.md).
    """
    import numpy as np

    from repro.data.synthetic import make_synthetic

    X, y, _, sizes = make_synthetic(n=n, p=p, n_groups=n_groups, gamma1=3,
                                    gamma2=3, seed=11)
    problem = sgl.make_problem(X, y, sizes, tau=tau)
    # Batching is gated to warm stretches, so the batched case runs on a
    # DENSE grid (delta=0.5: near-duplicate consecutive lambdas) where
    # coinciding-active-set warm stretches actually occur; its reference
    # is the XLA run of the SAME grid.
    runs = (
        ("xla", "xla", 1, delta),
        ("pallas", "pallas", 1, delta),
        ("xla_dense", "xla", 1, 0.5),
        ("pallas_batched", "pallas", 4, 0.5),
    )
    betas_ref = {}
    for case, backend, batch, delta_c in runs:
        session = SGLSession(problem, SolverConfig(
            tol=tol, max_epochs=max_epochs, solver_backend=backend,
        ))
        t0 = time.perf_counter()
        res = session.solve_path(T=T, delta=delta_c, batch_lambdas=batch)
        dt = time.perf_counter() - t0
        emit("path_pr4", case, "path_seconds", dt)
        emit("path_pr4", case, "total_epochs", int(res.epochs.sum()))
        emit("path_pr4", case, "certified_rounds", res.n_rounds)
        emit("path_pr4", case, "compact_rounds", res.n_compact_rounds)
        emit("path_pr4", case, "full_rounds", res.n_full_rounds)
        emit("path_pr4", case, "round_flops", res.round_flops)
        emit("path_pr4", case, "fused_epoch_launches",
             res.n_fused_epoch_launches)
        emit("path_pr4", case, "batched_lambdas", res.batched_lambdas)
        if delta_c not in betas_ref:
            betas_ref[delta_c] = np.asarray(res.betas)
        else:
            emit("path_pr4", case, "beta_max_diff_vs_xla",
                 float(np.abs(np.asarray(res.betas)
                              - betas_ref[delta_c]).max()))


if __name__ == "__main__":
    import argparse

    from .common import header, write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run asserting the transpose, "
                         "compact-round, and fused-solver audits")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump emitted rows as machine-readable JSON "
                         "(the BENCH_pr4.json perf-trajectory record)")
    ap.add_argument("--obs-json", metavar="PATH", default=None,
                    help="with --smoke: merge the obs overhead check and "
                         "the measured per-kernel timing harness into a "
                         "repro.obs.bench/v1 file (BENCH_pr10.json)")
    args = ap.parse_args()
    header()
    if args.smoke:
        obs_payload = smoke()
        if args.obs_json:
            from repro.obs.export import merge_bench
            from repro.obs.timing import measure_kernels

            merge_bench(args.obs_json, "path", obs_payload)
            merge_bench(args.obs_json, "kernels",
                        {"scale": "smoke",
                         "kernels": measure_kernels(scale="smoke")})
    elif args.full:
        main(n=814, n_lon=144, n_lat=73, T=100)
        pallas_case()
    else:
        main()
        pallas_case()
    if args.json:
        write_json(args.json)
