"""Unified solver-session API: one front-end for single-lambda, path, and
distributed solves.

The paper's speed story is one algorithm — certified GAP rounds (Thm 2) +
Theorem-1 screening wrapped around an inner solver — and the journal
follow-up (Ndiaye et al. 2017) frames the rule as penalty- and
solver-agnostic.  :class:`SGLSession` is that framing in code: it owns the
problem, the resolved screening backend, a **persistent transposed design**
for the Pallas correlation kernels, and the cross-call gather caches, and
exposes the whole algorithm family through three methods:

* :meth:`SGLSession.screen` — one certified gap + Theorem-1 round
  (:class:`repro.core.solver.RoundResult`), the resumable-round primitive;
* :meth:`SGLSession.solve` — one regularisation level, warm-startable and
  certificate-seedable;
* :meth:`SGLSession.solve_path` — the sequential-screening lambda-path
  engine (paper Section 7.1).

Strategies
----------
``SGLSession(problem)`` runs the single-device ISTA-BC solver
(Algorithm 2, :mod:`repro.core.solver`).  ``SGLSession(problem,
mesh=mesh)`` swaps in the distributed FISTA strategy
(:mod:`repro.distributed.solver_dist`) behind the *same* methods: the
sequential rule threads :class:`RoundResult` certificates and warm starts
through the shard_map kernels, and consecutive path points whose certified
active sets coincide are solved in ONE batched-lambda FISTA run
(``fista_batch`` — arithmetic intensity scales with the batch).

Screening-rule strategies
-------------------------
``SolverConfig.rule`` is a pluggable :mod:`repro.rules` strategy: a
:class:`repro.rules.ScreeningRule` object (or a registered name — the
legacy-string shim, resolved at session construction so unknown names
fail fast with the registered list).  The certified round is a shared
sphere-test skeleton (:func:`repro.core.solver._screen_round`) that asks
the rule only for its sphere; safety metadata gates everything else —
``supports_sequential`` decides whether the path engine runs pre-solve
rounds, ``supports_compact`` gates the compacted rounds, ``pre_screens``
routes the static rule's one up-front screen, and ``is_safe=False``
(unsafe heuristics like ``StrongSequentialRule``) flags every round
(``RoundResult.safe``) and path (``PathResult.certificates_safe``) so
heuristic discards are never reported as zero-certificates.

Persistent transposed design
----------------------------
On the Pallas backend the certified round's hot correlation ``X^T resid``
needs the feature-major (p, n) layout; before this session existed, every
round materialised a fresh transposed copy of X (ROADMAP perf item).  The
session builds it once (:func:`repro.kernels.ops.prepare_transposed`) and
feeds it to every round of every solve of the whole path; the elimination
is *measured* (``kernels.ops.transpose_trace_count`` moves iff a round
traced an on-the-fly transpose) and surfaced per path as
``PathResult.n_rounds`` / ``n_transpose_copies`` for the benchmarks.

Compacted certified rounds
--------------------------
The certified round itself used to stay O(n p) per round no matter how many
groups held a permanent certificate.  With ``SolverConfig.compact_rounds``
(default True, ``rule="gap"`` + compacted buffers only) the driver runs
most rounds through :func:`repro.core.solver._screen_round_compact` on the
gathered (n, p_active) buffer: screened groups re-enter the round only via
the dual scaling (Eq. 15), and their per-group eps-norm terms are bounded
from the last full round's cached reference by

    term_g(resid) <= term_g(resid_ref) + ||X_g||_2/scale_g * ||resid - resid_ref||

(proof in :mod:`repro.core.screening`).  Fallback policy — a FULL round
runs instead whenever (1) the bound crosses max(lambda, active-term max),
i.e. the residual drifted too far from the reference (the full round
refreshes it), (2) ``full_round_every`` compact rounds ran since the last
full one, or (3) a compact round's gap reaches ``tol``: convergence is
always re-confirmed on the full problem, so the *reported* gap and
certificate of every solve (and of every lambda on a path) are
full-problem exact even though compact rounds are themselves exact when
their bound holds.  ``PathResult.n_compact_rounds`` / ``n_full_rounds`` /
``round_flops`` audit the split next to the transpose audit.

Fused BCD epochs and batched lambdas
------------------------------------
``SolverConfig.solver_backend`` (``"auto"``/``"xla"``/``"pallas"``, same
resolution policy as the screening backend) picks the inner-epoch engine on
the single-device strategy: ``"pallas"`` dispatches whole epoch blocks as
ONE fused :mod:`repro.kernels.bcd_epoch` launch — residual carried in VMEM
across the group loop, design streamed tile-by-tile — instead of the
``lax.scan`` over groups (kept as the XLA fallback and bit-parity
reference).  The kernel's lambda-batch grid axis also brings the
batched-lambda path optimisation to the single-device solver: consecutive
path points whose sequential certificates agree on the active groups solve
in one run (:meth:`SGLSession._solve_batch_bcd`), mirroring the mesh
strategy's ``fista_batch``.  Audited as
``PathResult.n_fused_epoch_launches`` / ``batched_lambdas`` (session
counters ``fused_epoch_launches`` / ``batched_lambdas``).

Migration from the legacy front-ends
------------------------------------
``solve(...)`` / ``solve_path(...)`` loose kwargs became
:class:`SolverConfig` fields with the same names and defaults (``tol``,
``max_epochs``, ``f_ce``, ``rule``, ``compact``, ``inner_rounds``,
``check_every``, ``screen_backend``, ``solver_backend``,
``warm_gap_factor``); per-call state
(``lam_``, ``beta0``, ``first_round``, ``lambdas``) stays on the method.
``solve_distributed(mesh, X, y, w, ...)`` raw arrays became
``SGLSession(problem_from_grouped(X, y, tau, w), mesh=mesh)``.  The legacy
functions survive as thin deprecated wrappers delegating here.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp

from . import screening as scr
from . import sgl
from .sgl import SGLProblem
from .solver import (
    RoundResult,
    SolveCaches,
    SolveResult,
    _bucket,
    _inner_rounds,
    _inner_rounds_loss,
    _screen_round,
    _screen_round_compact,
    bcd_epochs,
    bcd_epochs_loss,
    check_rule_loss,
    resolve_screen_backend,
    resolve_solver_backend,
)
from ..faults.errors import KernelLaunchError, NumericsError
from ..faults.inject import fire as _fire_fault
from ..kernels import ops as kops
from ..losses import Loss, resolve_loss
from ..obs import trace as obs_trace
from ..rules import ScreeningRule, resolve_rule


def _launch_span(backend: str):
    """A ``kernel_launch`` span for Pallas dispatches; the XLA reference
    path gets the no-op singleton so span counts tally fused launches."""
    return (obs_trace.span("kernel_launch") if backend == "pallas"
            else obs_trace.NOOP)

__all__ = [
    "SolverConfig",
    "SGLSession",
    "PathResult",
    "lambda_grid",
]

_UNSET = object()


class _SolverConfigFields(NamedTuple):
    tol: float = 1e-8              # duality-gap stopping threshold
    max_epochs: int = 10_000       # BCD epochs (FISTA steps on a mesh)
    f_ce: int = 10                 # epochs between certified rounds
    rule: Union[str, ScreeningRule] = "gap"
                                   # screening strategy: a repro.rules
                                   #   ScreeningRule object, or a registered
                                   #   name (gap | static | dynamic | dst3 |
                                   #   none | strong) resolved through the
                                   #   registry (legacy-string shim; unknown
                                   #   names fail fast at session init with
                                   #   the registered list)
    compact: bool = True           # gather active groups into dense buffers
    inner_rounds: int = 5          # f_ce-blocks per jitted inner call
    check_every: Union[int, None, str] = "auto"  # reduced-gap exit cadence
    screen_backend: str = "auto"   # auto | xla | pallas
    warm_gap_factor: float = 1e3   # warm-lambda threshold for "auto"
    compact_rounds: bool = True    # run certified rounds on the compacted
                                   #   active buffer when provably exact
                                   #   (rule="gap" + compact buffers only);
                                   #   False restores full rounds everywhere
    full_round_every: int = 10     # certified rounds between forced full
                                   #   rounds (reference refresh); <= 0
                                   #   disables compact rounds outright
    solver_backend: str = "auto"   # auto | xla | pallas — backend for the
                                   #   inner BCD epochs: "pallas" fuses
                                   #   whole epoch blocks into ONE kernel
                                   #   launch (kernels/bcd_epoch.py, VMEM-
                                   #   resident residual, batched-lambda
                                   #   grid); "xla" keeps the lax.scan
                                   #   reference.  Single-device strategy
                                   #   only (the mesh strategy's FISTA
                                   #   kernels have their own dispatch).
    loss: Union[str, Loss] = "lsq"
                                   # data-fidelity strategy: a repro.losses
                                   #   Loss object or a registered name
                                   #   (lsq | logistic | ...), resolved
                                   #   through the registry at construction
                                   #   so unknown names fail fast with the
                                   #   registered list.  "lsq" is the
                                   #   paper's squared loss and keeps every
                                   #   historical code path bit-identical;
                                   #   other losses run full certified
                                   #   rounds (no compact rounds, no
                                   #   batched lambdas, no mesh strategy).


class SolverConfig(_SolverConfigFields):
    """Frozen bundle of every solver knob (formerly 13 loose kwargs).

    Field names match the legacy ``solve``/``solve_path`` keyword arguments
    one-to-one; anything not listed here (``lam_``, ``beta0``,
    ``first_round``, ``lambdas``, ``sequential``) is per-call state and
    lives on the session methods instead.

    Backend knobs are validated at *construction*: an unknown
    ``screen_backend``/``solver_backend`` raises here with the valid
    choices, instead of surfacing as a jit-time ``ValueError`` deep inside
    the first certified round (typos used to cost a full problem build +
    trace before failing).
    """

    __slots__ = ()

    _BACKENDS = ("auto", "xla", "pallas")

    def __new__(cls, *args, **kwargs):
        self = super().__new__(cls, *args, **kwargs)
        for knob in ("screen_backend", "solver_backend"):
            val = getattr(self, knob)
            if val not in cls._BACKENDS:
                raise ValueError(
                    f"unknown {knob.replace('_', ' ')}: {val!r} "
                    f"(choose one of {'|'.join(cls._BACKENDS)})"
                )
        # Loss names are validated here too (same fail-fast contract as the
        # backend knobs): resolve_loss raises with the registered list on
        # an unknown name, instead of deep inside the first round.
        resolve_loss(self.loss)
        return self

    def cache_token(self) -> tuple:
        """Hashable identity of every compile-relevant solver knob.

        The serving layer (:mod:`repro.serve`) keys its session/compile
        cache on this token together with the problem digest: two configs
        with equal tokens drive identical jitted programs, so a cached
        session can serve either without retracing.  ``rule`` is resolved
        through the :mod:`repro.rules` registry and keyed by its ``repr``
        (rules are frozen dataclasses, so the repr carries every
        parameter) — a registered name and the equivalent rule object
        produce the same token.
        """
        d = self._asdict()
        d["rule"] = repr(resolve_rule(d["rule"]))
        # Same treatment for the loss strategy: losses are frozen
        # dataclasses, so the repr is a stable parameter-carrying identity
        # — tenants solving different data fidelities can NEVER share a
        # cached session, path, or warm-start hint.
        d["loss"] = repr(resolve_loss(d["loss"]))
        return tuple(sorted(d.items()))


def lambda_grid(lam_max: float, T: int = 100, delta: float = 3.0) -> np.ndarray:
    """lambda_t = lambda_max * 10^(-delta t / (T-1)), t = 0..T-1 (paper §7.1)."""
    t = np.arange(T)
    return lam_max * 10.0 ** (-delta * t / max(T - 1, 1))


class PathResult(NamedTuple):
    """Dense path outputs; leading axis is the lambda grid (length T)."""

    lambdas: np.ndarray            # (T,)
    betas: np.ndarray              # (T, G, ng) coefficients
    gaps: np.ndarray               # (T,) final certified duality gaps
    epochs: np.ndarray             # (T,) int, BCD passes / FISTA steps
    group_active_frac: np.ndarray  # (T,)
    feat_active_frac: np.ndarray   # (T,)
    group_active: np.ndarray       # (T, G) bool, certified active masks
                                   #   (solver-final intersected with the
                                   #   sequential certificate).  False is a
                                   #   certificate of zero at the optimum,
                                   #   NOT a support indicator of betas[t]:
                                   #   a lambda converged on its sequential
                                   #   round keeps beta un-zeroed there.
    feat_active: np.ndarray        # (T, G, ng) bool, same semantics
    seq_screened: np.ndarray       # (T,) int, groups the sequential round
                                   #   certified inactive before any epoch
    dyn_screened: np.ndarray       # (T,) int, further groups screened out
                                   #   during the solve (dynamic rule)
    n_gathers: int                 # design re-gathers across the whole path
    results: list                  # per-lambda SolveResult (keep_results)
    n_rounds: int = 0              # certified rounds dispatched on the path
    n_transpose_copies: int = 0    # rounds that executed a jitted program
                                   #   which materialises an on-the-fly
                                   #   (p, n) transposed copy of X, measured
                                   #   via kernels.ops.transpose_trace_count
                                   #   — 0 when the session's persistent
                                   #   transposed design reached every
                                   #   Pallas round (and trivially 0 on the
                                   #   XLA backend, where no copy is ever at
                                   #   stake)
    n_compact_rounds: int = 0      # certified rounds run on the compacted
                                   #   active buffer (O(n p_active))
    n_full_rounds: int = 0         # certified rounds run on the full
                                   #   problem (every converged round, the
                                   #   sequential rounds, the forced
                                   #   full_round_every refreshes, and any
                                   #   bound-crossing fallbacks)
    round_flops: float = 0.0       # estimated FLOPs spent in certified
                                   #   rounds (~4*n*p_buffer per round,
                                   #   incl. discarded fallback attempts);
                                   #   full-round-only engines spend
                                   #   (n_compact+n_full) * 4*n*p
    n_fused_epoch_launches: int = 0  # epoch blocks dispatched as ONE fused
                                   #   Pallas launch (solver_backend=
                                   #   "pallas"); the lax.scan path would
                                   #   have paid O(G) scan steps per block.
                                   #   0 on the XLA solver backend and on
                                   #   the mesh strategy.
    batched_lambdas: int = 0       # path points solved through a
                                   #   batched-lambda run: the fused BCD
                                   #   kernel's lambda-batch grid axis on
                                   #   the single-device strategy, the
                                   #   fista_batch kernel on the mesh —
                                   #   consecutive lambdas whose sequential
                                   #   certificates agreed on the active
                                   #   groups.  0 when no batching engaged.
    rule_name: str = "gap"         # registered name of the screening rule
                                   #   that produced this path
    certificates_safe: bool = True # the group/feat_active masks are safe
                                   #   zero-certificates (ScreeningRule.
                                   #   is_safe).  False for unsafe rules
                                   #   (e.g. "strong"): the masks then only
                                   #   record what the heuristic discarded
                                   #   — they certify NOTHING, and Fig. 3
                                   #   style comparisons must treat them as
                                   #   potentially erroneous.
    degraded: str = ""             # "" = full path; "deadline" |
                                   #   "epoch_budget" = a SolveBudget
                                   #   tripped and the arrays hold only the
                                   #   prefix of lambdas actually solved —
                                   #   every entry still carries its honest
                                   #   certified full-problem gap.


@functools.partial(jax.jit, static_argnames=("backend",))
def _batch_reduced_gaps(Xt, fmask_b, bsub, resid, w, y, tau, lam_b,
                        backend="xla", xt_rows=None):
    """Per-lambda reduced-problem duality gaps on a shared batch buffer.

    The jitted batched twin of ``_inner_rounds``' early-exit heuristic —
    one correlation + vmapped norms per epoch block instead of per-lambda
    eager dispatches.  Work scheduling only; never reported (convergence
    is always confirmed by a full certified round).

    ``backend="pallas"`` routes the correlation through the batch-vmapped
    corr-only Pallas kernel (:func:`repro.kernels.ops.
    screening_corr_batched`) over ``xt_rows`` — the active-row slice of
    the persistent transposed design shared with the compact rounds —
    instead of the XLA einsum (previously the batched driver always paid
    the einsum even on TPU; PR 4 leftover).
    """
    if backend == "pallas" and xt_rows is not None:
        B = resid.shape[0]
        Gb, ng = Xt.shape[0], Xt.shape[2]
        corr = kops.screening_corr_batched(xt_rows, resid)[:, : Gb * ng]
        corr = corr.reshape(B, Gb, ng) * fmask_b
    else:
        corr = jnp.einsum("gnk,bn->bgk", Xt, resid) * fmask_b
    dn = jax.vmap(sgl.sgl_dual_norm, in_axes=(0, None, None))(corr, tau, w)
    theta = resid / jnp.maximum(lam_b, dn)[:, None]
    primal = (0.5 * jnp.sum(resid * resid, axis=1)
              + lam_b * jax.vmap(sgl.sgl_norm,
                                 in_axes=(0, None, None))(bsub, tau, w))
    diff = theta - y[None] / lam_b[:, None]
    dual = (0.5 * jnp.sum(y * y)
            - 0.5 * lam_b * lam_b * jnp.sum(diff * diff, axis=1))
    return primal - dual


def _global_lipschitz(problem: SGLProblem, n_iter: int = 150) -> float:
    """||X||_2^2 *estimate* via power iteration, +5% margin.

    NOT a certified upper bound — the Rayleigh quotient converges to the
    top eigenvalue from below, and a spectrum with a near-tied second
    singular value can leave the estimate a few percent short.  The FISTA
    drivers therefore back any auto-estimated constant with a divergence
    safeguard (gap growing while the active set is unchanged => double L
    and restart momentum), so an under-estimate costs speed, never
    correctness.  Callers with the exact constant should pass ``L=``.
    """
    X, mask = problem.X, problem.feat_mask
    dtype = X.dtype
    v0 = jnp.where(mask, 1.0, 0.0).astype(dtype)
    v0 = v0 * (1.0 + 1e-3 * jnp.arange(X.shape[2], dtype=dtype)[None, :])
    v0 = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-30)

    def body(_, v):
        u = jnp.einsum("ngk,gk->n", X, v)
        w = jnp.einsum("ngk,n->gk", X, u)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, n_iter, body, v0)
    u = jnp.einsum("ngk,gk->n", X, v)
    return float(jnp.sum(u * u)) * 1.05


def _fire_epoch_launch_fault() -> None:
    """Chaos hook for the fused epoch-kernel dispatch sites."""
    for s in _fire_fault("kernels.epochs"):
        if s.kind == "raise":
            raise KernelLaunchError("injected epoch-kernel launch failure")


class SGLSession:
    """Stateful front-end over one SGL problem (see module docstring).

    Parameters
    ----------
    problem : SGLProblem
    config : SolverConfig, optional
    mesh : jax.sharding.Mesh, optional
        When given, the distributed FISTA strategy replaces the local
        ISTA-BC solver behind the same ``screen``/``solve``/``solve_path``
        methods.
    multi_pod : bool
        Mesh has the leading "pod" axis (distributed strategy only).
    L : float, optional
        Global Lipschitz constant ||X||_2^2 for FISTA; estimated by power
        iteration when omitted (distributed strategy only).
    caches : SolveCaches, optional
        Pre-existing gather caches to adopt (the legacy ``solve`` wrapper
        passes its ``caches=`` argument through here).
    xt_pre : jax.Array, optional
        A pre-built persistent transposed design to adopt instead of
        building one lazily — the serving layer shares ONE
        :func:`repro.kernels.ops.prepare_transposed` copy across every
        session over the same design (perturbed-y tenants).  Must have
        exactly the padded (p_pad, n_pad) layout ``prepare_transposed``
        produces for this problem's shape; validated at construction.
    """

    def __init__(
        self,
        problem: SGLProblem,
        config: Optional[SolverConfig] = None,
        *,
        mesh=None,
        multi_pod: bool = False,
        L: Optional[float] = None,
        caches: Optional[SolveCaches] = None,
        xt_pre: Optional[jax.Array] = None,
    ) -> None:
        self.problem = problem
        self.config = config if config is not None else SolverConfig()
        self.caches = caches if caches is not None else SolveCaches()
        # Screening strategy: SolverConfig.rule may be a ScreeningRule
        # object or a legacy string name — resolved through the
        # repro.rules registry here so an unknown name fails at session
        # construction (with the registered list), never inside a round.
        self.rule = resolve_rule(self.config.rule)
        # Data-fidelity strategy, resolved and gated eagerly (same policy):
        # an unsupported rule x loss pairing fails at construction with the
        # rule's declared support list, never as a silently-unsafe screen.
        self.loss = resolve_loss(self.config.loss)
        if self.loss.multi_output:
            raise ValueError(
                f"loss={self.loss.name!r} is multi-output; SGLSession "
                "solves single-output problems — use the "
                "repro.core.sgl.multitask_* helpers for the multi-task "
                "screening math"
            )
        check_rule_loss(self.rule, self.loss)
        self.backend = resolve_screen_backend(self.config.screen_backend)
        # Inner-epoch backend (single-device BCD strategy): "pallas" runs
        # whole epoch blocks through the fused kernels/bcd_epoch.py launch,
        # "xla" keeps the lax.scan reference.  Resolved eagerly so an
        # invalid knob fails at session construction, like screen_backend.
        self.solver_backend = resolve_solver_backend(
            self.config.solver_backend
        )
        self.mesh = mesh
        # Auditable round accounting: every certified round dispatched
        # through this session.  Whether any of those rounds had to build a
        # per-call (p, n) transposed copy of X is *measured*, not assumed:
        # kernels.ops.transpose_trace_count() moves iff a jitted round
        # actually traced an on-the-fly transpose, and solve_path converts
        # its delta into PathResult.n_transpose_copies.
        self.rounds = 0
        # Compact-round audit: rounds run on the compacted active buffer vs
        # the full problem, attempts discarded because the screened-group
        # bound crossed the active max, and the estimated FLOPs actually
        # spent in rounds (~4 n p_buffer each, fallback attempts included).
        self.compact_rounds = 0
        self.full_rounds = 0
        self.compact_fallbacks = 0
        self.round_flops = 0.0
        self._rounds_since_full = 0
        # Lambdas solved through a batched-lambda run: the fused BCD
        # kernel's lambda-batch grid axis (single-device Pallas strategy)
        # or the fista_batch kernel (mesh strategy) — path points whose
        # sequential certificates agreed on the active groups.
        self.batched_lambdas = 0
        # Epoch blocks dispatched as ONE fused Pallas launch instead of an
        # O(G) lax.scan (solver_backend="pallas" only).
        self.fused_epoch_launches = 0
        # Fault-tolerance accounting + per-request budget (repro.faults):
        # certified rounds discarded for a non-finite gap (the solve loop
        # rewinds and re-runs them), pallas→reference kernel demotions
        # after failed launches, and the optional SolveBudget the serving
        # layer attaches for the duration of one request.
        self.nonfinite_rounds = 0
        self.kernel_demotions = 0
        self.budget = None
        if xt_pre is not None:
            p = problem.G * problem.ng
            bp, bn = kops._corr_blocks(p, problem.n)
            expect = (p + (-p) % bp, problem.n + (-problem.n) % bn)
            if tuple(xt_pre.shape) != expect:
                raise ValueError(
                    f"adopted xt_pre has shape {tuple(xt_pre.shape)}; "
                    f"prepare_transposed would produce {expect} for this "
                    f"problem ((n, p) = ({problem.n}, {p}))"
                )
        self._xt_pre: Optional[jax.Array] = xt_pre
        self._lam_max: Optional[float] = None
        if mesh is not None and self.rule.name != "gap":
            # The sharded screen kernel computes GAP-sphere certificates
            # only; accepting another rule here would silently hand back
            # gap-rule results under a different name.
            raise ValueError(
                "the distributed strategy implements rule='gap' only; "
                f"got rule={self.rule.name!r}"
            )
        if mesh is not None and self.loss.name != "lsq":
            # The shard_map FISTA/screen kernels hard-code the squared-loss
            # residual and dual; accepting another loss here would silently
            # solve the wrong problem on the mesh.
            raise ValueError(
                "the distributed strategy implements loss='lsq' only; "
                f"got loss={self.loss.name!r}"
            )
        self._dist = _DistStrategy(self, mesh, multi_pod=multi_pod, L=L) \
            if mesh is not None else None

    # -- lazily-built shared state -----------------------------------------

    @property
    def lam_max(self) -> float:
        """lambda_max = Omega^D(X^T rho_0), computed once per session
        (rho_0 = -grad F(0): y for the squared loss, y - 1/2 logistic)."""
        if self._lam_max is None:
            if self.loss.name == "lsq":
                self._lam_max = float(sgl.lambda_max(self.problem))
            else:
                self._lam_max = float(
                    sgl.lambda_max_loss(self.problem, self.loss)
                )
        return self._lam_max

    @property
    def xt_pre(self) -> Optional[jax.Array]:
        """Persistent transposed design for the Pallas correlation kernel
        (None when neither the screening rounds nor the inner reduced-gap
        checks run on Pallas — plain XLA einsums handle layout natively).
        The Pallas *solver* backend needs it too: ``_inner_rounds`` feeds
        its between-block gap correlation from the active-row slice."""
        if self.backend != "pallas" and self.solver_backend != "pallas":
            return None
        if self._xt_pre is None:
            self._xt_pre = kops.prepare_transposed(self.problem.X)
        return self._xt_pre

    def _certified_round(self, beta, lam_j, lam_max_j, rule,
                         caches: Optional[SolveCaches] = None) -> RoundResult:
        """One FULL certified round; refreshes the compact-round reference
        (residual + per-group dual-norm terms) on ``caches`` — but only
        when the round's gap is finite.  A corrupted round must never
        install its residual as the compact-round reference: the previous
        full round's reference stays cached, and it remains a valid bound
        anchor for later compact rounds.

        Fault sites: ``core.round`` (numeric corruption of this round's
        outputs, stalls), ``kernels.screen`` (Pallas launch failure — the
        session demotes itself to the XLA reference backend, retries the
        round once, and counts the demotion; pallas/XLA bit-parity keeps
        the retried round's outputs identical).
        """
        caches = self.caches if caches is None else caches
        problem = self.problem
        specs = _fire_fault("core.round")   # stall kinds sleep in fire()
        self.rounds += 1
        self.full_rounds += 1
        self._rounds_since_full = 0
        self.round_flops += 4.0 * problem.n * problem.G * problem.ng
        # loss=None for lsq keeps the legacy jit cache key (shared with
        # every pre-loss call site); non-lsq rounds screen from the
        # generalized residual rho = -grad F(X beta).
        loss_arg = None if self.loss.name == "lsq" else self.loss
        with obs_trace.span("round") as _sp:
            _sp.set("compact", False)
            try:
                for s in _fire_fault("kernels.screen"):
                    if s.kind == "raise":
                        raise KernelLaunchError(
                            "injected screening-kernel launch failure"
                        )
                with _launch_span(self.backend):
                    res, resid, terms = _screen_round(
                        problem, beta, lam_j, lam_max_j, rule, self.backend,
                        self.xt_pre, loss=loss_arg,
                    )
            except Exception:
                if self.backend != "pallas":
                    raise
                # Failed Pallas launch: demote the session to the XLA
                # reference path and retry ONCE.  Bit-parity between the
                # backends keeps the retried round's outputs identical; the
                # demotion is counted so a degraded node stays visible in the
                # fused-launch audit.
                self.backend = "xla"
                self.kernel_demotions += 1
                kops.note_kernel_demotion()
                res, resid, terms = _screen_round(
                    problem, beta, lam_j, lam_max_j, rule, "xla", None,
                    loss=loss_arg,
                )
        for s in specs:
            if s.kind in ("nan", "inf"):
                bad = float("nan") if s.kind == "nan" else float("inf")
                field = s.field or "theta"
                if field == "resid":
                    resid = resid * bad
                elif field == "corr":
                    terms = terms * bad
                else:
                    res = res._replace(theta=res.theta * bad)
                # Real corruption in resid/corr/theta propagates into the
                # gap through the same dataflow; mirror that so the gap
                # stays the universal corruption detector.
                res = res._replace(gap=res.gap * bad)
        if np.isfinite(float(res.gap)):
            caches.set_refs(problem, resid, terms)
        else:
            self.nonfinite_rounds += 1
        return res

    def _demote_solver_backend(self) -> None:
        """A fused epoch-kernel launch failed: fall back to the lax.scan
        reference path for the rest of the session.  Bit-parity between
        the paths keeps results identical; the demotion is counted so the
        degraded throughput stays visible in the fused-launch audit."""
        self.solver_backend = "xla"
        self.kernel_demotions += 1
        kops.note_kernel_demotion()

    def _compact_round(self, beta, lam_j, group_active, feat_active,
                       caches: SolveCaches) -> Optional[RoundResult]:
        """Certified round on the compacted active buffer, or None.

        Returns None — the caller must fall back to a full round — when no
        reference state is cached yet or when the screened-group dual-norm
        bound crossed max(lambda, active max) (the residual drifted too far
        from the last full round's reference; the fallback refreshes it).
        A non-None result is *exact* (see
        :func:`repro.core.solver._screen_round_compact`).
        """
        if caches.resid_ref is None or caches.ref_terms is None:
            return None
        problem = self.problem
        _, take, Xt, _, _, gmask = caches.gather(problem, group_active)
        xt_rows = None
        if self.backend == "pallas":
            xt_rows = caches.gather_xt_rows(problem, group_active,
                                            self.xt_pre)
        dtype = problem.X.dtype
        with obs_trace.span("round") as _sp:
            _sp.set("compact", True)
            with _launch_span(self.backend):
                gap, theta, g_keep, f_keep, valid = _screen_round_compact(
                    problem, Xt, take, gmask,
                    jnp.asarray(beta, dtype),
                    jnp.asarray(feat_active),
                    jnp.asarray(group_active),
                    caches.ref_terms, caches.resid_ref, lam_j,
                    self.backend, xt_rows,
                )
        # Attempt cost is spent either way (honest FLOP accounting).
        self.round_flops += 4.0 * problem.n * Xt.shape[0] * problem.ng
        if not bool(valid):
            self.compact_fallbacks += 1
            return None
        self.rounds += 1
        self.compact_rounds += 1
        self._rounds_since_full += 1
        # Compact rounds only run under the (safe) gap rule — see
        # supports_compact — but thread the metadata rather than claim it.
        return RoundResult(gap, theta, g_keep, f_keep, compact=True,
                           safe=self.rule.is_safe)

    # -- the three front-end methods ---------------------------------------

    def screen(self, lam_: float, beta=None,
               rule: Union[str, ScreeningRule, None] = None) -> RoundResult:
        """One certified gap + Theorem-1 screening round at ``lam_``.

        Called at a *new* lambda with the *previous* lambda's ``beta`` this
        is the paper's sequential rule; feed the result to :meth:`solve` as
        ``first_round``.  ``beta`` defaults to zeros (the cold start).
        ``rule``: per-call override — a :class:`repro.rules.ScreeningRule`
        or a registered name (unknown names fail fast with the registered
        list).  Rounds from an unsafe rule come back flagged
        ``safe=False``: heuristic discards, never zero-certificates.
        """
        rule = self.rule if rule is None else resolve_rule(rule)
        if rule is not self.rule:
            # Per-call overrides get the same rule x loss gate as the
            # session rule did at construction.
            check_rule_loss(rule, self.loss)
        problem = self.problem
        dtype = problem.X.dtype
        if beta is None:
            beta = jnp.zeros((problem.G, problem.ng), dtype)
        if self._dist is not None:
            if rule.name != "gap":
                raise ValueError(
                    "the distributed strategy implements rule='gap' only; "
                    f"got rule={rule.name!r}"
                )
            return self._dist.screen(lam_, beta)
        if rule.pre_screens:
            raise ValueError(
                f"rule={rule.name!r} has no per-round certificate; use "
                "screening.static_sphere + screening.screen, or solve()"
            )
        return self._certified_round(
            jnp.asarray(beta, dtype),
            jnp.asarray(lam_, dtype),
            jnp.asarray(self.lam_max, dtype),
            rule,
        )

    def solve(
        self,
        lam_: float,
        beta0=None,
        *,
        first_round: Optional[RoundResult] = None,
        lam_max: Optional[float] = None,
        check_every=_UNSET,
        caches: Optional[SolveCaches] = None,
    ) -> SolveResult:
        """Solve one SGL instance at regularisation ``lam_``.

        All solver knobs come from ``self.config``; per-call state:

        * ``beta0`` — warm start (required alongside ``first_round``);
        * ``first_round`` — a :class:`RoundResult` evaluated at
          (``beta0``, ``lam_``), consumed instead of recomputing round 1;
        * ``lam_max`` — the true lambda_max when already known (path);
        * ``check_every`` — per-call override of the config cadence
          ("auto" resolves from the ``first_round`` warm gap here);
        * ``caches`` — per-call gather-cache override (the naive path mode
          uses a throwaway instance; default is the session cache).
        """
        if self._dist is not None:
            return self._dist.solve(lam_, beta0=beta0,
                                    first_round=first_round)
        cfg = self.config
        problem = self.problem
        rule = self.rule
        tol, max_epochs, f_ce = cfg.tol, cfg.max_epochs, cfg.f_ce
        if first_round is not None and rule.pre_screens:
            # The pre-solve screen re-masks (and zeroes parts of) beta0
            # before the loop, so an injected certificate evaluated at the
            # original beta0 would no longer certify the beta actually
            # being solved.
            raise ValueError(
                "first_round certifies beta0 as passed; it cannot be "
                f"combined with rule={rule.name!r}"
            )
        if first_round is not None and beta0 is None:
            # Without beta0 the solve starts from zeros, which the injected
            # certificate was (almost certainly) not evaluated at — if its
            # gap were <= tol the zeros would be returned as "converged".
            raise ValueError(
                "first_round requires the beta0 it was evaluated at"
            )
        if first_round is not None and not isinstance(first_round,
                                                      RoundResult):
            first_round = RoundResult(*first_round)
        if (first_round is not None and rule.is_safe
                and not bool(first_round.safe)):
            # An unsafe rule's round carries heuristic discards; adopting
            # them here would apply them monotonically and report them
            # under this session's safe rule as zero-certificates —
            # exactly what the safe=False flag exists to prevent.  (An
            # unsafe-rule session injecting its own flagged rounds is
            # fine: its results are flagged certificates_safe=False.)
            raise ValueError(
                "first_round was produced by an unsafe rule (safe=False); "
                f"refusing to adopt its masks under safe rule "
                f"{rule.name!r}"
            )
        caches = self.caches if caches is None else caches

        ce = cfg.check_every if check_every is _UNSET else check_every
        if isinstance(ce, str):
            if ce != "auto":
                raise ValueError(f"unknown check_every: {ce!r}")
            # Warmness read off the injected certificate: a lambda whose
            # warm-start gap is already near tol stops within a handful of
            # passes, so per-epoch early-exit checks beat the f_ce floor.
            warm = (first_round is not None
                    and float(first_round.gap) <= cfg.warm_gap_factor * tol)
            ce = 1 if warm else None

        G, ng = problem.G, problem.ng
        dtype = problem.X.dtype
        beta = (jnp.zeros((G, ng), dtype) if beta0 is None
                else jnp.asarray(beta0, dtype))
        lam_j = jnp.asarray(lam_, dtype)
        check = f_ce if ce is None else max(1, int(ce))
        # Never exceed the certified-round cadence, and keep degenerate
        # inputs (f_ce or inner_rounds <= 0) from collapsing the block size.
        check = max(1, min(check, f_ce * cfg.inner_rounds))
        max_blocks = max(1, (f_ce * cfg.inner_rounds) // check)

        if lam_max is None:
            lam_max = self.lam_max           # session-cached; the legacy
                                             # stateless solve() recomputed
                                             # this O(n p) dual norm per call

        group_active = np.array(jnp.any(problem.feat_mask, axis=-1))
        feat_active = np.array(problem.feat_mask)

        # Pre-screening rules (static sphere) screen once, up front —
        # through the same backend-routed Theorem-1 tests as every round,
        # so the static rule's one correlation also runs on the Pallas
        # kernel (fed from the persistent transposed design) on TPU.
        if rule.pre_screens:
            pre = rule.pre_solve_sphere(
                problem, lam_j, jnp.asarray(lam_max, dtype)
            )
            res = scr.screen(problem, scr.Sphere(*pre),
                             backend=self.backend, xt_pre=self.xt_pre)
            group_active &= np.asarray(res.group_active)
            feat_active &= np.asarray(res.feat_active)
            beta = beta * jnp.asarray(feat_active, dtype)

        gap_history: list = []
        active_history: list = []
        epochs_done = 0
        lsq = self.loss.name == "lsq"
        # Placeholder dual point (overwritten by the first certified
        # round); lam_max is always known here (cached on the session).
        # Generic losses scale rho_0 = -grad F(0) the same way (feasible
        # at beta=0 by the lam_max definition).
        if lsq:
            theta = problem.y / max(float(lam_), float(lam_max))
        else:
            theta = (self.loss.lam_max_rho(problem.y)
                     / max(float(lam_), float(lam_max)))
        gap = jnp.inf
        round_res = first_round
        lam_max_j = jnp.asarray(lam_max, dtype)
        n_real_groups = int(np.asarray(
            jnp.any(problem.feat_mask, axis=-1)).sum())
        # Non-compact branch state, hoisted out of the round loop: ONE
        # transposed design for the whole solve and a carried residual —
        # the loop used to re-materialise a fresh (G, n, ng) copy of X and
        # recompute the full residual einsum every certified round.
        # Generic losses carry the linear predictor z = X beta instead
        # (the majorized-BCD state; rho = -grad F(z) is derived per group).
        Xt_full = None
        resid_nc = None
        z_nc = None
        # Fault-tolerance state: consecutive non-finite certified rounds
        # (cap 3 -> typed NumericsError), the best finite iterate to
        # rewind to when beta itself is corrupted, and the budget-trip
        # reason (threads into SolveResult.degraded).
        nonfinite_run = 0
        best_gap: Optional[float] = None
        best_beta = None
        degraded: Optional[str] = None

        while epochs_done < max_epochs:
            # ---- fused gap + screening round (paper does this every f_ce
            # passes on the full problem; here it runs on the compacted
            # active buffer whenever the screened-group bound proves that
            # exact — see _compact_round).  The first round may be injected
            # by the path engine (sequential screening). ----
            if round_res is None:
                # A compact round only pays when the gathered buffer is
                # smaller than the problem: with power-of-two buckets a
                # barely-screened active set rounds up PAST the real group
                # count (e.g. 130/200 active -> bucket 256), where the
                # "compacted" buffer would cost more than the full round it
                # replaces — those rounds go full directly.
                n_act = int(group_active.sum())
                # Compact rounds are lsq-only: the screened-group bound is
                # proved against the quadratic dual's reference residual
                # (repro.core.screening) — generic losses run every round
                # full-problem.
                if (lsq and rule.supports_compact and cfg.compact
                        and cfg.compact_rounds
                        and self._rounds_since_full < cfg.full_round_every
                        and 0 < n_act
                        and _bucket(n_act) < n_real_groups):
                    round_res = self._compact_round(
                        beta, lam_j, group_active, feat_active, caches
                    )
                if round_res is None:
                    round_res = self._certified_round(
                        beta, lam_j, lam_max_j, rule, caches=caches
                    )
                    if not cfg.compact and lsq:
                        # The full round just recomputed y - X beta exactly
                        # (stored as the compact-round reference): adopt it
                        # so the carried residual's incremental drift is
                        # reset every full round, matching the pre-hoist
                        # per-round recomputation.  Copied because
                        # bcd_epochs donates its residual buffer, which
                        # would otherwise invalidate the cached reference.
                        # Gated on round finiteness: a corrupted round left
                        # the PREVIOUS full round's reference cached, which
                        # no longer equals y - X beta for the current beta.
                        if np.isfinite(float(round_res.gap)):
                            resid_nc = caches.resid_ref.copy()
                    elif not cfg.compact:
                        # Generic losses: the full round's reference is
                        # rho, not z — drop the carried predictor so it is
                        # recomputed from beta (same drift-reset cadence).
                        z_nc = None
            if bool(round_res.compact) and float(round_res.gap) <= tol:
                # The REPORTED gap/certificate must always be full-problem
                # exact: re-confirm an (exact, but buffer-computed)
                # compact-round convergence with a full round before
                # stopping.  If the full gap disagrees (> tol), the loop
                # simply continues from the full round.
                round_res = self._certified_round(
                    beta, lam_j, lam_max_j, rule, caches=caches
                )
            gap_r, theta_r = round_res.gap, round_res.theta
            g_act, f_act = round_res.group_active, round_res.feat_active
            round_res = None
            gap_history.append((epochs_done, float(gap_r)))

            if not np.isfinite(float(gap_r)):
                # Corrupted round: NEVER adopt its masks/theta (an all-False
                # NaN-comparison mask would erase the active set and the
                # "certificate" would be garbage).  If beta itself is still
                # finite the corruption was round-local — keep beta and
                # simply re-run the round (jit determinism makes the re-run
                # bit-identical to the fault-free round).  If beta is
                # corrupted, rewind to the best finite certified iterate
                # and drop the incremental carries so they are recomputed.
                nonfinite_run += 1
                if nonfinite_run >= 3:
                    raise NumericsError(
                        f"{nonfinite_run} consecutive non-finite certified "
                        f"rounds at lambda={float(lam_):.3e}; rewind could "
                        "not recover a finite trajectory"
                    )
                if not bool(jnp.all(jnp.isfinite(beta))):
                    beta = (best_beta if best_beta is not None
                            else jnp.zeros((G, ng), dtype))
                    resid_nc = None
                    z_nc = None
                continue
            nonfinite_run = 0
            if best_gap is None or float(gap_r) < best_gap:
                best_gap = float(gap_r)
                best_beta = beta
            gap, theta = gap_r, theta_r

            if float(gap) <= tol:
                # Do NOT apply this round's masks: at convergence the
                # rounded gap can under-estimate the true gap (to exactly 0
                # in f32), so its sphere radius is not reliable, and zeroing
                # beta here would invalidate the gap just reported.  The
                # returned active sets reflect the last screen applied.
                break

            if self.budget is not None:
                reason = self.budget.exceeded()
                if reason is not None:
                    # Budget tripped at a certified boundary: return the
                    # prefix actually certified — gap/theta above are the
                    # honest full-problem values for the current beta.
                    degraded = reason
                    break

            if rule.is_dynamic:
                n_g0 = int(group_active.sum())
                n_f0 = int(feat_active.sum())
                group_active &= np.asarray(g_act)
                feat_active &= np.asarray(f_act)
                feat_active &= group_active[:, None]
                masks_changed = (int(group_active.sum()) != n_g0
                                 or int(feat_active.sum()) != n_f0)
                beta_masked = beta * jnp.asarray(feat_active, dtype)
                if resid_nc is not None and masks_changed:
                    # Keep the carried residual consistent with the newly
                    # zeroed coefficients (masks shrink monotonically, so
                    # an unchanged mask leaves beta — and resid — as-is).
                    if Xt_full is None:
                        Xt_full = jnp.transpose(problem.X, (1, 0, 2))
                    resid_nc = resid_nc + jnp.einsum(
                        "gnk,gk->n", Xt_full, beta - beta_masked
                    )
                if z_nc is not None and masks_changed:
                    # Same consistency rule for the generic-loss predictor
                    # carry: z = X beta shrinks by X (beta - beta_masked).
                    if Xt_full is None:
                        Xt_full = jnp.transpose(problem.X, (1, 0, 2))
                    z_nc = z_nc - jnp.einsum(
                        "gnk,gk->n", Xt_full, beta - beta_masked
                    )
                beta = beta_masked

            active_history.append(
                (epochs_done, int(group_active.sum()),
                 int(feat_active.sum()))
            )

            # ---- up to max_blocks x check BCD epochs in one jitted call --
            epochs_before = epochs_done
            if cfg.compact:
                idx, take, Xt, Lg, w, gmask = caches.gather(
                    problem, group_active
                )
                xt_rows = None
                if self.solver_backend == "pallas":
                    # Active-row slice of the persistent transposed design,
                    # feeding the Pallas reduced-gap correlation between
                    # epoch blocks (keyed on the same active-set bytes as
                    # the gather — a row gather, never a transpose).
                    xt_rows = caches.gather_xt_rows(
                        problem, group_active, self.xt_pre
                    )
                def _epochs_compact(backend, rows):
                    if backend == "pallas":
                        _fire_epoch_launch_fault()
                    with _launch_span(backend):
                        if lsq:
                            return _inner_rounds(
                                Xt, Lg, w, problem.y, beta,
                                jnp.asarray(feat_active),
                                take, gmask, problem.tau, lam_j,
                                jnp.asarray(tol, dtype), check, max_blocks,
                                backend, rows
                            )
                        return _inner_rounds_loss(
                            Xt, Lg, w, problem.y, beta,
                            jnp.asarray(feat_active),
                            take, gmask, problem.tau, lam_j,
                            jnp.asarray(tol, dtype), self.loss, check,
                            max_blocks, backend, rows
                        )

                with obs_trace.span("epoch_block"):
                    try:
                        beta, k_done, _ = _epochs_compact(
                            self.solver_backend, xt_rows
                        )
                    except Exception:
                        if self.solver_backend != "pallas":
                            raise
                        self._demote_solver_backend()
                        beta, k_done, _ = _epochs_compact("xla", None)
                epochs_done += check * int(k_done)
                if self.solver_backend == "pallas" and (
                        lsq or self.loss.name == "logistic"):
                    # Each inner block ran as ONE fused kernel launch
                    # (k_done of them) instead of O(G) scan steps.  Other
                    # generic losses fall back to the lax.scan epochs
                    # inside _inner_rounds_loss — no fused launch to count.
                    self.fused_epoch_launches += int(k_done)
            else:
                if Xt_full is None:
                    Xt_full = jnp.transpose(problem.X, (1, 0, 2))
                fmask = jnp.asarray(feat_active, dtype)
                Lg = problem.Lg * jnp.asarray(group_active, dtype)
                if lsq:
                    if resid_nc is None:
                        resid_nc = problem.y - jnp.einsum(
                            "gnk,gk->n", Xt_full, beta
                        )
                    if self.solver_backend == "pallas":
                        with obs_trace.span("epoch_block"):
                            try:
                                _fire_epoch_launch_fault()
                                with _launch_span("pallas"):
                                    beta_b, resid_b = kops.bcd_epochs_fused(
                                        Xt_full, Lg, problem.w, fmask[None],
                                        beta[None], resid_nc[None],
                                        problem.tau,
                                        jnp.reshape(lam_j, (1,)), f_ce
                                    )
                                beta, resid_nc = beta_b[0], resid_b[0]
                                self.fused_epoch_launches += 1
                            except Exception:
                                self._demote_solver_backend()
                                beta, resid_nc = bcd_epochs(
                                    Xt_full, Lg, problem.w, fmask, beta,
                                    resid_nc, problem.tau, lam_j, f_ce
                                )
                    else:
                        with obs_trace.span("epoch_block"):
                            beta, resid_nc = bcd_epochs(
                                Xt_full, Lg, problem.w, fmask, beta,
                                resid_nc, problem.tau, lam_j, f_ce
                            )
                else:
                    if z_nc is None:
                        z_nc = jnp.einsum("gnk,gk->n", Xt_full, beta)
                    if (self.solver_backend == "pallas"
                            and self.loss.name == "logistic"):
                        with obs_trace.span("epoch_block"):
                            try:
                                _fire_epoch_launch_fault()
                                with _launch_span("pallas"):
                                    beta_b, z_b = (
                                        kops.bcd_epochs_logistic_fused(
                                            Xt_full, Lg, problem.w,
                                            fmask[None], beta[None],
                                            z_nc[None], problem.y,
                                            problem.tau,
                                            jnp.reshape(lam_j, (1,)), f_ce
                                        )
                                    )
                                beta, z_nc = beta_b[0], z_b[0]
                                self.fused_epoch_launches += 1
                            except Exception:
                                self._demote_solver_backend()
                                beta, z_nc = bcd_epochs_loss(
                                    Xt_full, Lg, problem.w, fmask, beta,
                                    z_nc, problem.tau, lam_j, problem.y,
                                    self.loss, f_ce
                                )
                    else:
                        with obs_trace.span("epoch_block"):
                            beta, z_nc = bcd_epochs_loss(
                                Xt_full, Lg, problem.w, fmask, beta, z_nc,
                                problem.tau, lam_j, problem.y, self.loss,
                                f_ce
                            )
                epochs_done += f_ce

            if self.budget is not None:
                self.budget.note_epochs(epochs_done - epochs_before)
            # Chaos hook: corrupt the iterate AFTER an epoch block — the
            # next certified round sees the non-finite beta through the
            # real dataflow (its gap goes non-finite) and rewinds.
            for s in _fire_fault("core.epochs"):
                if s.kind in ("nan", "inf"):
                    beta = beta * (float("nan") if s.kind == "nan"
                                   else float("inf"))

        return SolveResult(
            beta=beta,
            theta=theta,
            gap=gap,
            n_epochs=epochs_done,
            group_active=group_active,
            feat_active=feat_active,
            gap_history=gap_history,
            active_history=active_history,
            degraded=degraded,
        )

    def _solve_batch_bcd(self, lams, beta0, certs, caches: SolveCaches):
        """Solve B consecutive path points in ONE fused-kernel run
        (single-device mirror of :meth:`_DistStrategy._solve_batch`).

        All B lambdas warm-start from the same previous-lambda ``beta0``
        and share one gathered design buffer over the UNION of their
        certified active-group sets (the batching precondition keeps that
        union inside one gather bucket); each carries its own
        coefficients, residual, feature mask, and threshold down the fused
        kernel's lambda-batch grid axis, so every epoch block is ONE launch
        and one streaming pass over the design for all B lambdas — groups
        a given lambda screened ride along with a zero mask, exactly like
        bucket padding.  Every
        ``f_ce`` epochs (every epoch when all certificates are warm) each
        unconverged lambda gets its own certified round — per-lambda
        dynamic screening inside the batch, expressed through the
        per-lambda feature masks (the shared buffer never re-gathers
        mid-run).  Converged lambdas are snapshotted; their rows keep
        iterating under a frozen mask until the batch drains (wasted but
        harmless work — same policy as the mesh ``_solve_batch``).

        Round cadence (mirrors the per-lambda driver's round economy):
        each epoch block is followed only by the cheap reduced-problem gap
        heuristic on the batch buffer (O(n p_active) per lambda, exactly
        ``_inner_rounds``' early-exit test; on the Pallas backend it runs
        through the batch-vmapped corr kernel over the persistent
        transposed design's active rows).  A certified round runs for a
        lambda only when its reduced gap crosses ``tol`` (the convergence
        confirmation, ALWAYS full-problem) or when ``f_ce * inner_rounds``
        epochs have passed since its last round (the dynamic-screening
        cadence — the same worst-case spacing as one per-lambda
        ``_inner_rounds`` call).  Cadence rounds run COMPACT on the shared
        union buffer whenever the screened-group bound proves them exact
        (:meth:`_compact_round` with the batch union as the active set, so
        the gather key coincides with the batch buffer), with the usual
        full-round fallback on bound crossings and ``full_round_every``
        refreshes — previously the batched driver always paid full rounds
        (PR 4 leftover).  A confirmation that FAILS (reduced gap under
        ``tol`` but full gap above — the reduced gap under-estimates once
        screened mass dominates) backs that lambda off for ``f_ce`` epochs
        so a saturating straggler cannot degrade to one full round per
        epoch.

        Trade-off vs the per-lambda sequential driver: every batched
        lambda warm-starts from the *batch-entry* beta instead of its
        predecessor's solution, so cold batches spend somewhat more epochs
        (and a lambda near the ``max_epochs`` budget can saturate where
        the warmer sequential start would just converge — the reported
        gap stays honest either way).  Batching pays off on the warm
        plateau stretches where certificates coincide because little is
        changing lambda-to-lambda.

        Returns per-lambda :class:`SolveResult`\\ s with the same reporting
        semantics as :meth:`solve` (masks reflect the last screen applied;
        a converging round's masks are never adopted).
        """
        cfg = self.config
        problem = self.problem
        dtype = problem.X.dtype
        tol, f_ce = cfg.tol, cfg.f_ce
        B = len(lams)
        self.batched_lambdas += B
        G, ng = problem.G, problem.ng
        y = problem.y
        lam_max_j = jnp.asarray(self.lam_max, dtype)
        real_grp = np.asarray(jnp.any(problem.feat_mask, axis=-1))
        base_g = real_grp & np.logical_or.reduce(
            [np.asarray(c.group_active) for c in certs]
        )
        fm_full = np.asarray(problem.feat_mask)

        g_act = [real_grp & np.asarray(certs[b].group_active)
                 for b in range(B)]
        f_act = [fm_full & np.asarray(c.feat_active)
                 & np.asarray(c.group_active)[:, None] for c in certs]
        gap_b = [float(c.gap) for c in certs]
        done = np.array([g <= tol for g in gap_b])
        gap_hist = [[(0, gap_b[b])] for b in range(B)]
        epochs_b = np.zeros(B, np.int64)
        beta0_j = jnp.asarray(beta0, dtype)
        # Lambdas converged on their sequential certificate report the
        # pre-screen state, exactly like solve(): beta untouched, masks =
        # the initial active sets (the path recorder intersects the
        # REPORTED masks with the certificate afterwards).
        final_beta = [beta0_j if done[b] else None for b in range(B)]
        final_g = [real_grp.copy() if done[b] else None for b in range(B)]
        final_f = [fm_full.copy() if done[b] else None for b in range(B)]
        final_theta = [certs[b].theta for b in range(B)]

        degraded_b = [None] * B

        def results():
            return [
                SolveResult(
                    beta=final_beta[b],
                    theta=final_theta[b],
                    gap=gap_hist[b][-1][1],
                    n_epochs=int(epochs_b[b]),
                    group_active=final_g[b],
                    feat_active=final_f[b],
                    gap_history=gap_hist[b],
                    active_history=[],
                    degraded=degraded_b[b],
                )
                for b in range(B)
            ]

        if done.all():
            return results()

        idx, take, Xt, Lg, w, gmask = caches.gather(problem, base_g)
        take_np = np.asarray(take)
        Lg_eff = Lg * gmask
        lam_b = jnp.asarray(np.asarray(lams), dtype)
        n_real_groups = int(real_grp.sum())
        n_base_act = int(base_g.sum())
        # Active-row slice of the persistent transposed design: feeds the
        # batch-vmapped Pallas corr kernel in _batch_reduced_gaps (keyed on
        # the SAME active-set bytes as the shared gather buffer, so it is
        # built at most once per batch).
        xt_rows = None
        if self.solver_backend == "pallas" and self.xt_pre is not None:
            xt_rows = caches.gather_xt_rows(problem, base_g, self.xt_pre)

        def gather_masks():
            return (jnp.asarray(np.stack(f_act)[:, take_np], dtype)
                    * gmask[None, :, None])

        fm_b = gather_masks()
        bsub = jnp.stack([
            jnp.take(beta0_j * jnp.asarray(f_act[b], dtype), take, axis=0)
            for b in range(B)
        ]) * fm_b
        resid = y[None] - jnp.einsum("gnk,bgk->bn", Xt, bsub)
        # All-warm batches (every certificate gap already near tol) check
        # after every epoch; otherwise the cheap f_ce-block cadence.
        warm = all(g <= cfg.warm_gap_factor * tol for g in gap_b)
        block = 1 if warm else f_ce
        cadence = f_ce * max(1, cfg.inner_rounds)
        last_round_b = np.zeros(B)     # sequential certificates count as
        hold_b = np.zeros(B)           # round 0; holds gate re-confirms

        step = 0
        while not done.all() and step < cfg.max_epochs:
            if self.budget is not None:
                reason = self.budget.exceeded()
                if reason is not None:
                    for b in range(B):
                        if not done[b]:
                            degraded_b[b] = reason
                    break
            try:
                _fire_epoch_launch_fault()
                with obs_trace.span("epoch_block"), _launch_span("pallas"):
                    bsub, resid = kops.bcd_epochs_fused(
                        Xt, Lg_eff, w, fm_b, bsub, resid, problem.tau,
                        lam_b, block
                    )
            except Exception as e:
                # The batched-lambda driver has no reference twin (the
                # lax.scan path is per-lambda); a failed fused launch
                # surfaces as a typed error instead of a silent retry.
                raise KernelLaunchError(
                    "batched fused epoch launch failed (no reference twin "
                    "for the batched driver)"
                ) from e
            self.fused_epoch_launches += 1
            step += block
            if self.budget is not None:
                self.budget.note_epochs(block * B)
            red = np.asarray(_batch_reduced_gaps(
                Xt, fm_b, bsub, resid, w, y, problem.tau, lam_b,
                backend=self.solver_backend, xt_rows=xt_rows,
            ))
            changed = False
            for b in range(B):
                if done[b]:
                    continue
                crossed = red[b] <= tol and step >= hold_b[b]
                due = (step - last_round_b[b] >= cadence
                       or step >= cfg.max_epochs)
                if not (crossed or due):
                    # Neither due for screening nor plausibly converged:
                    # keep iterating round-free (the cheap heuristic is
                    # the only per-block cost, as in _inner_rounds).
                    continue
                # Padded take slots alias group 0 but carry zero masks, so
                # their (zero) rows scatter harmlessly.
                beta_full = jnp.zeros((G, ng), dtype).at[take].add(
                    bsub[b] * fm_b[b]
                )
                last_round_b[b] = step
                rres = None
                if (not crossed and cfg.compact and cfg.compact_rounds
                        and self.rule.supports_compact
                        and self._rounds_since_full < cfg.full_round_every
                        and 0 < n_base_act
                        and _bucket(n_base_act) < n_real_groups):
                    # Cadence rounds (dynamic screening inside the batch)
                    # run compact on the SHARED base buffer: the round's
                    # group_active is the batch UNION active set, so the
                    # gather key coincides with the batch buffer (no
                    # re-gather) and the union-but-screened-for-b groups
                    # contribute their EXACT terms to the dual max while
                    # only the off-buffer groups are bounded from the
                    # reference — still exact when the bound holds.  The
                    # caller's per-lambda masks intersect monotonically,
                    # so union-level keep bits cannot resurrect anything
                    # lambda b already screened.  Convergence is NEVER
                    # adopted from a compact round: a crossed reduced gap
                    # (and a compact gap at tol, below) re-confirms with a
                    # FULL round, keeping every reported gap full-problem
                    # exact — the same policy as the per-lambda driver.
                    rres = self._compact_round(
                        beta_full, lam_b[b], base_g, f_act[b], caches
                    )
                    if rres is not None and float(rres.gap) <= tol:
                        rres = None        # full-round confirmation below
                if rres is None:
                    rres = self._certified_round(
                        beta_full, lam_b[b], lam_max_j, self.rule,
                        caches=caches
                    )
                gap_hist[b].append((step, float(rres.gap)))
                if not np.isfinite(float(rres.gap)):
                    # Corrupted round: adopt NOTHING (theta, masks,
                    # convergence).  The batch buffer state is untouched
                    # by rounds, so the next cadence round simply re-runs
                    # from healthy state.
                    continue
                final_theta[b] = rres.theta
                if float(rres.gap) <= tol:
                    # Converging round's masks are NOT adopted (same
                    # reporter contract as solve()).
                    done[b] = True
                    epochs_b[b] = step
                    final_beta[b] = beta_full
                    final_g[b] = g_act[b]
                    final_f[b] = f_act[b]
                    continue
                if crossed:
                    # Failed confirmation: the reduced gap sits under tol
                    # while the full gap does not — back off f_ce epochs
                    # before re-confirming this lambda.
                    hold_b[b] = step + f_ce
                n_g0, n_f0 = g_act[b].sum(), f_act[b].sum()
                g_act[b] &= np.asarray(rres.group_active)
                f_act[b] &= np.asarray(rres.feat_active)
                f_act[b] &= g_act[b][:, None]
                if g_act[b].sum() != n_g0 or f_act[b].sum() != n_f0:
                    changed = True
            if changed:
                # Some lambda screened further: re-mask its coefficients
                # and refresh the affected residuals (the buffer itself
                # stays at the shared base active set).
                fm_b = gather_masks()
                bsub = bsub * fm_b
                resid = y[None] - jnp.einsum("gnk,bgk->bn", Xt, bsub)

        for b in range(B):
            if not done[b]:        # max_epochs stragglers
                epochs_b[b] = step
                final_beta[b] = jnp.zeros((G, ng), dtype).at[take].add(
                    bsub[b] * fm_b[b]
                )
                final_g[b] = g_act[b]
                final_f[b] = f_act[b]
        return results()

    def solve_path(
        self,
        lambdas: Optional[Sequence[float]] = None,
        *,
        T: int = 100,
        delta: float = 3.0,
        sequential: bool = True,
        keep_results: bool = False,
        batch_lambdas: int = 4,
        beta0=None,
        prev_epochs: Optional[int] = None,
    ) -> PathResult:
        """Solve the whole lambda path with sequential + dynamic screening.

        ``beta0``/``prev_epochs`` resume a path mid-grid: ``beta0`` warm-
        starts the first lambda (default zeros — the cold start at
        lambda_max), and ``prev_epochs`` is the epoch count of the lambda
        solved immediately before this grid began, feeding the
        ``check_every="auto"`` warmness predictor and the batched-lambda
        gate exactly as ``epochs[t-1]`` would inside one grid.  With both
        threaded, a path chopped into consecutive sub-grids on one session
        is bit-identical to the one-shot run (``batch_lambdas=1``; batch
        probes never cross a sub-grid boundary, so batching may regroup).
        The serving layer's resumable paths are built on this.

        Engine behavior (see the module docstring of
        :mod:`repro.core.path` for the algorithmic background): a certified
        :meth:`screen` round at each new lambda from the previous primal
        point *before* any epoch, one gather cache carried down the grid,
        and ``check_every="auto"`` scheduling from the sequential gap.
        ``sequential=False`` reproduces the legacy naive loop (fresh caches
        and no pre-solve screening per lambda).

        Up to ``batch_lambdas`` *consecutive* path points whose sequential
        certificates agree on the active groups are solved in one
        batched-lambda run: the ``fista_batch`` kernel on the distributed
        strategy, and — with ``solver_backend="pallas"`` (f64, GAP rule) —
        the fused BCD epoch kernel's lambda-batch grid axis on the
        single-device strategy (:meth:`_solve_batch_bcd`).
        ``PathResult.batched_lambdas`` audits both.
        """
        if self._dist is not None:
            return self._dist.solve_path(
                lambdas=lambdas, T=T, delta=delta, sequential=sequential,
                keep_results=keep_results, batch_lambdas=batch_lambdas,
                beta0=beta0,
            )
        with obs_trace.span("path") as _sp:
            _sp.set("T", T)
            return self._solve_path_impl(
                lambdas, T=T, delta=delta, sequential=sequential,
                keep_results=keep_results, batch_lambdas=batch_lambdas,
                beta0=beta0, prev_epochs=prev_epochs,
            )

    def _solve_path_impl(self, lambdas, *, T, delta, sequential,
                         keep_results, batch_lambdas, beta0,
                         prev_epochs) -> PathResult:
        cfg = self.config
        problem = self.problem
        rule = self.rule
        lam_max = self.lam_max
        if lambdas is None:
            lambdas = lambda_grid(lam_max, T=T, delta=delta)
        lambdas = np.asarray(lambdas, float)
        T_ = len(lambdas)

        G, ng = problem.G, problem.ng
        dtype = problem.X.dtype
        n_feat = int(np.asarray(problem.feat_mask).sum())
        n_groups = int(np.asarray(jnp.any(problem.feat_mask, axis=-1)).sum())
        rounds0 = self.rounds
        compact0 = self.compact_rounds
        full0 = self.full_rounds
        flops0 = self.round_flops
        fused0 = self.fused_epoch_launches
        batched0 = self.batched_lambdas
        traces0 = kops.transpose_trace_count()

        # One cache for the whole path: the gather (and its jit cache)
        # survives across lambdas whose certified active set is unchanged.
        # The naive mode gets a fresh cache per lambda (seed behavior) but
        # still totals its gather count for the benchmark comparison.
        caches = self.caches if sequential else None
        n_gathers_total = 0

        beta = (jnp.zeros((G, ng), dtype) if beta0 is None
                else jnp.asarray(beta0, dtype))
        betas = np.zeros((T_, G, ng), np.dtype(dtype))   # no up-cast
        gaps = np.zeros(T_, float)
        epochs = np.zeros(T_, np.int64)
        gfrac = np.zeros(T_, float)
        ffrac = np.zeros(T_, float)
        g_act = np.zeros((T_, G), bool)
        f_act = np.zeros((T_, G, ng), bool)
        seq_scr = np.zeros(T_, np.int64)
        dyn_scr = np.zeros(T_, np.int64)
        results: list = []

        screening_rule = rule.is_dynamic

        def record(t, res, first_round, n_seq_active):
            """Per-lambda bookkeeping shared by the per-lambda and the
            batched-lambda drivers (mutates the dense path arrays)."""
            betas[t] = np.asarray(res.beta)
            gaps[t] = float(res.gap)
            epochs[t] = res.n_epochs
            g_act[t] = np.asarray(res.group_active)
            f_act[t] = np.asarray(res.feat_active)
            if first_round is not None and screening_rule:
                if np.dtype(dtype).itemsize >= 8:
                    # Report the sequential certificate even when solve
                    # converged on that very round without applying it (beta
                    # is untouched — only the REPORTED masks reflect the
                    # certificate; see the converged-round note in solve()).
                    # For lambdas where solve did apply screens this
                    # intersection is a no-op (final masks are already
                    # subsets).  Without it, Fig 2a/2b-style outputs read
                    # 1.0 active exactly at the lambdas screening handled
                    # outright.
                    g_act[t] &= np.asarray(first_round.group_active)
                    f_act[t] &= (np.asarray(first_round.feat_active)
                                 & g_act[t][:, None])
                elif res.n_epochs == 0:
                    # In low precision the converged gap's cancellation
                    # error can undershoot the GAP radius enough to
                    # mis-certify borderline groups, so the certificate is
                    # neither applied nor reported — zero the counter too,
                    # keeping counters and masks consistent (all-active,
                    # nothing discarded).
                    seq_scr[t] = 0
                    n_seq_active = n_groups
            gfrac[t] = g_act[t].sum() / max(n_groups, 1)
            ffrac[t] = f_act[t].sum() / max(n_feat, 1)
            if screening_rule:
                # g_act already includes the sequential certificate, so this
                # is non-negative; max() guards rounding of refactors only.
                dyn_scr[t] = max(0, n_seq_active - int(g_act[t].sum()))
            if keep_results:
                results.append(res)

        # Batched-lambda path points (the ROADMAP item the distributed
        # strategy delivered first): consecutive lambdas whose sequential
        # certificates agree on the active groups share ONE fused-kernel
        # run through the kernel's lambda-batch grid axis.  Pallas solver
        # backend only (the lax.scan reference has no batch axis), GAP rule
        # only (certificates must be safe spheres), and f64 only (the
        # batched driver adopts certificate masks the way the f64 reporter
        # does).  Additionally gated per-lambda on the path engine's WARM
        # predictor below: batching trades the sequential warm start for
        # launch count, which pays off (and cannot blow the epoch budget)
        # only where lambdas converge in a handful of passes — batching a
        # cold stretch costs extra epochs and discarded probe rounds for
        # nothing.
        batch_ok = (sequential and rule.name == "gap"
                    and self.solver_backend == "pallas"
                    and batch_lambdas > 1
                    and np.dtype(dtype).itemsize >= 8
                    # Batched-lambda runs are lsq-only: the batch driver's
                    # reduced-gap heuristic and fused kernel carry the
                    # squared-loss residual.
                    and self.loss.name == "lsq")

        path_degraded = ""
        t = 0
        while t < T_:
            if self.budget is not None:
                reason = self.budget.exceeded()
                if reason is not None:
                    # Budget tripped between lambdas: return the certified
                    # prefix (arrays truncated below) without starting the
                    # next sequential round.
                    path_degraded = reason
                    break
            lam_ = lambdas[t]
            # Previous-lambda epoch count for the warmness predictor; at
            # the head of a resumed sub-grid it comes from the caller
            # (prev_epochs), so chunked paths predict exactly like the
            # one-shot run.
            ep_prev = int(epochs[t - 1]) if t > 0 else int(prev_epochs or 0)
            first_round = None
            n_seq_active = n_groups
            if sequential and rule.supports_sequential:
                # Sequential rule: certified round at the NEW lambda from
                # the PREVIOUS lambda's primal point, before any epoch here.
                # Rules without sequential support are excluded: the static
                # rule's up-front screen re-masks beta before any round
                # (which would invalidate a certificate evaluated at the
                # un-masked warm start), and the dynamic/DST3 spheres
                # refine during a solve but transfer nothing across
                # lambdas.
                first_round = self.screen(float(lam_), beta, rule=rule)
                if not np.isfinite(float(first_round.gap)):
                    # Corrupted sequential round: refuse its masks (a
                    # NaN-poisoned comparison can claim everything
                    # screened) and re-run once at the same beta — a
                    # round-local corruption's re-run is bit-identical to
                    # the fault-free round (jit determinism).  Still bad:
                    # solve this lambda cold, with no sequential
                    # certificate at all.
                    first_round = self.screen(float(lam_), beta, rule=rule)
                    if not np.isfinite(float(first_round.gap)):
                        first_round = None
                if first_round is not None and screening_rule:
                    n_seq_active = int(
                        np.asarray(first_round.group_active).sum()
                    )
                    seq_scr[t] = n_groups - n_seq_active

            warm_here = (first_round is not None
                         and (float(first_round.gap)
                              <= cfg.warm_gap_factor * cfg.tol
                              or 0 < ep_prev <= 4 * cfg.f_ce))
            if batch_ok and warm_here and float(first_round.gap) > cfg.tol:
                # Probe ahead: every GAP sphere from a feasible point is
                # safe, so the current beta can certify several lambdas.
                # The batch shares ONE gathered buffer over the UNION of
                # the certified active sets while each lambda keeps its
                # own masks, so the sets need not coincide exactly — a
                # probe joins as long as the union's power-of-two gather
                # bucket stays within 2x the first lambda's (single-beta
                # certificates are sharp only one grid step ahead, so
                # probe sets balloon with lambda distance; a <= 2x buffer
                # is still a clear win against per-lambda launches on the
                # tiny warm-tail buckets this gate admits).  A probe that
                # would grow the bucket further re-certifies later from a
                # warmer beta (its round is discarded — honest accounting
                # keeps it in self.rounds; the warm gate above bounds that
                # waste to regions where probes usually succeed).
                certs = [first_round]
                union_g = np.asarray(first_round.group_active).copy()
                bucket0 = _bucket(max(int(union_g.sum()), 1))
                while (len(certs) < batch_lambdas
                       and t + len(certs) < T_):
                    k = t + len(certs)
                    ck = self.screen(float(lambdas[k]), beta, rule=rule)
                    if not np.isfinite(float(ck.gap)):
                        # A corrupted probe certificate must never enter
                        # the batched driver's adopted masks; stop probing
                        # — lambda k re-certifies later from a warmer beta.
                        break
                    cg = np.asarray(ck.group_active)
                    if (_bucket(max(int((union_g | cg).sum()), 1))
                            <= 2 * bucket0):
                        union_g |= cg
                        certs.append(ck)
                        seq_scr[k] = n_groups - int(cg.sum())
                    else:
                        break
                if len(certs) > 1:
                    with obs_trace.span("lambda") as _lsp:
                        _lsp.set("t", t).set("batched", len(certs))
                        run = self._solve_batch_bcd(
                            lambdas[t:t + len(certs)], beta, certs, caches
                        )
                    for j, res in enumerate(run):
                        record(t + j, res, certs[j],
                               n_groups - int(seq_scr[t + j]))
                    beta = run[-1].beta
                    t += len(certs)
                    deg = next((r.degraded for r in run if r.degraded),
                               None)
                    if deg is not None:
                        # Partially-solved lambdas stay in the prefix —
                        # their recorded gaps are the honest last-certified
                        # values; the unattempted tail is dropped.
                        path_degraded = deg
                        break
                    continue

            if cfg.check_every == "auto":
                # Warm lambdas finish in a handful of passes, so per-epoch
                # early-exit checks beat the f_ce-block floor; cold lambdas
                # keep the cheap block cadence.  Warmness is read off the
                # sequential certificate (gap already near tol), or
                # predicted from the path itself: the previous lambda's
                # epoch count, when positive and within four f_ce-blocks,
                # marks a warm region (warmness varies smoothly along a
                # geometric grid).  A zero count (lambda_max, or a user grid
                # jumping far from the last point) carries no signal and
                # must not force per-epoch checks on a cold lambda.
                warm = (first_round is not None
                        and float(first_round.gap)
                        <= cfg.warm_gap_factor * cfg.tol)
                warm |= 0 < ep_prev <= 4 * cfg.f_ce
                check_t = 1 if warm else None
            else:
                check_t = cfg.check_every

            lam_caches = caches if caches is not None else SolveCaches()
            with obs_trace.span("lambda") as _lsp:
                _lsp.set("t", t)
                res = self.solve(
                    float(lam_),
                    beta0=beta,
                    first_round=first_round,
                    lam_max=lam_max,
                    check_every=check_t,
                    caches=lam_caches,
                )
            beta = res.beta
            if caches is None:
                n_gathers_total += lam_caches.n_gathers
            record(t, res, first_round, n_seq_active)
            t += 1
            if res.degraded:
                path_degraded = res.degraded
                break

        if path_degraded and t < T_:
            # Truncate the dense arrays to the certified prefix: a
            # degraded path never pads with zeros that could be mistaken
            # for solved (and certified) lambdas.
            lambdas = lambdas[:t]
            betas, gaps, epochs = betas[:t], gaps[:t], epochs[:t]
            gfrac, ffrac = gfrac[:t], ffrac[:t]
            g_act, f_act = g_act[:t], f_act[:t]
            seq_scr, dyn_scr = seq_scr[:t], dyn_scr[:t]

        return PathResult(
            lambdas=lambdas,
            betas=betas,
            gaps=gaps,
            epochs=epochs,
            group_active_frac=gfrac,
            feat_active_frac=ffrac,
            group_active=g_act,
            feat_active=f_act,
            seq_screened=seq_scr,
            dyn_screened=dyn_scr,
            n_gathers=(caches.n_gathers if caches is not None
                       else n_gathers_total),
            results=results,
            n_rounds=self.rounds - rounds0,
            # Measured, not assumed: if any round during this path traced an
            # on-the-fly transpose (persistent-design wiring regressed),
            # every subsequent execution of that trace re-copies — attribute
            # the whole path's rounds to it.
            n_transpose_copies=(
                self.rounds - rounds0
                if kops.transpose_trace_count() > traces0 else 0
            ),
            n_compact_rounds=self.compact_rounds - compact0,
            n_full_rounds=self.full_rounds - full0,
            round_flops=self.round_flops - flops0,
            n_fused_epoch_launches=self.fused_epoch_launches - fused0,
            batched_lambdas=self.batched_lambdas - batched0,
            rule_name=rule.name,
            certificates_safe=rule.is_safe,
            degraded=path_degraded,
        )


# ---------------------------------------------------------------------------
# Distributed strategy: FISTA + GAP screening under shard_map, behind the
# same session methods
# ---------------------------------------------------------------------------


class _DistStrategy:
    """Distributed FISTA strategy for :class:`SGLSession` (mesh mode).

    Wraps the shard_map kernels of :mod:`repro.distributed.solver_dist`:
    the certified round is the sharded ``screen`` kernel (GAP sphere +
    Theorem-1 tests with psum/pmax collectives), single lambdas run the
    ``fista`` kernel, and consecutive path points with coinciding certified
    active sets run the ``fista_batch`` kernel — one X read serving all B
    lambdas per step.
    """

    def __init__(self, session: SGLSession, mesh, *, multi_pod: bool,
                 L: Optional[float]) -> None:
        from ..distributed.solver_dist import make_dist_step

        self.session = session
        problem = session.problem
        self.kernels = make_dist_step(
            mesh, tau=float(problem.tau), multi_pod=multi_pod
        )
        self.fista = jax.jit(self.kernels.fista)
        self.fista_batch = jax.jit(self.kernels.fista_batch)
        self.screen_k = jax.jit(self.kernels.screen)
        # Design-matrix norms: constants of the problem, computed once per
        # session on the mesh (Frobenius group bound — safe for Thm 1).
        self.colnorm, self.gfro = jax.jit(self.kernels.norms)(problem.X)
        self.ynorm2 = float(jnp.sum(problem.y * problem.y))
        self.L = float(L) if L is not None else _global_lipschitz(problem)

    # -- certified round ----------------------------------------------------

    def _round(self, lam_, beta, feat_mask):
        """Raw sharded round: (feat_mask', group_mask, gap, dual_scale)."""
        s = self.session
        problem = s.problem
        dtype = problem.X.dtype
        s.rounds += 1
        s.full_rounds += 1           # sharded rounds are always full-problem
        s.round_flops += 4.0 * problem.n * problem.G * problem.ng
        return self.screen_k(
            problem.X, problem.y, jnp.asarray(beta, dtype),
            jnp.asarray(feat_mask, dtype), problem.w,
            self.colnorm, self.gfro,
            jnp.asarray(lam_, dtype), jnp.asarray(self.ynorm2, dtype),
        )

    def screen(self, lam_, beta) -> RoundResult:
        problem = self.session.problem
        fm0 = jnp.asarray(problem.feat_mask, problem.X.dtype)
        fmask, gmask, gap, _sc = self._round(lam_, beta, fm0)
        # theta stays sharded on the mesh; certificates travel as masks.
        return RoundResult(gap, None, np.asarray(gmask) > 0,
                           np.asarray(fmask) > 0,
                           safe=self.session.rule.is_safe)

    # -- single-lambda solve ------------------------------------------------

    def _divergence_step(self, gap, state, mask_unchanged, gap0):
        """FISTA restart + divergence safeguard, one check at a time.

        ``state`` is the per-lambda ``[prev_gap, rose_before]`` pair
        (mutated in place).  Returns ``(restart, raise_L)``:

        * ``restart`` — the gap rose since the last check with no new
          screening: kill the momentum (adaptive restart, O'Donoghue &
          Candes 2015).  FISTA's gap is not monotone, and its ripples near
          convergence can span two orders of magnitude, so a rise alone
          says nothing about the step size — threshold-based detectors
          (2x-previous, 100x-best) were both observed to false-trigger and
          run L up by factors of 2^27.
        * ``raise_L`` — the gap rose at TWO consecutive checks despite the
          restart (or went non-finite) AND sits an order of magnitude above
          the solve's first gap ``gap0``: after a restart the first steps
          are momentum-free ISTA, which descends whenever the step is
          valid, so a persistent rise (with the active set unchanged) that
          also climbed past where the solve *started* is the signature of
          an under-estimated Lipschitz constant (see
          :func:`_global_lipschitz`).  L is doubled and persisted for the
          rest of the session: an under-estimate costs speed, never
          correctness.  The ``gap0`` gate exists because low-precision
          runs wobble indefinitely at the f32 gap floor — consecutive-rise
          noise there drove L up by 2^26 in testing, while true divergence
          blows past 10x the initial gap within a few rounds.
        """
        g = float(gap)
        if not np.isfinite(g):
            self.L *= 2.0
            state[0], state[1] = None, False
            return True, True
        rose = (state[0] is not None and mask_unchanged
                and g > state[0])
        raise_L = (rose and state[1]
                   and gap0 is not None and g > 10.0 * gap0)
        if raise_L:
            self.L *= 2.0
        state[0], state[1] = g, rose
        return rose, raise_L

    def solve(self, lam_, beta0=None, first_round=None,
              feat_mask0=None) -> SolveResult:
        cfg = self.session.config
        problem = self.session.problem
        dtype = problem.X.dtype
        tol, f_ce, max_steps = cfg.tol, cfg.f_ce, cfg.max_epochs
        # Low-precision guard (same reasoning as the single-device path
        # reporter): at convergence the rounded gap's cancellation error
        # can undershoot the GAP radius and mis-certify borderline groups,
        # so sub-f64 runs do not adopt the converged round's masks.
        low_prec = np.dtype(dtype).itemsize < 8
        beta = (jnp.zeros((problem.G, problem.ng), dtype) if beta0 is None
                else jnp.asarray(beta0, dtype))
        z = beta
        t_mom = jnp.ones(())
        feat_mask = (jnp.asarray(problem.feat_mask, dtype)
                     if feat_mask0 is None else jnp.asarray(feat_mask0,
                                                            dtype))
        gmask = jnp.asarray(jnp.any(problem.feat_mask, axis=-1), dtype)
        lam_j = jnp.asarray(lam_, dtype)
        gap = jnp.asarray(jnp.inf, dtype)
        gap_history: list = []
        injected = first_round
        div_state = [None, False]      # [prev_gap, rose_before]
        gap0 = None                    # first finite gap of this solve
        best_gap, best_beta = None, None
        prev_nact = None
        n_steps = 0

        for step in range(max_steps):
            if step % f_ce == 0:
                if injected is not None:
                    # Sequential certificate from the path engine — consumed
                    # as round 0 instead of recomputing it.
                    gap = injected.gap
                    gm_new = jnp.asarray(injected.group_active, dtype)
                    fm_new = feat_mask * jnp.asarray(
                        injected.feat_active, dtype
                    )
                    injected = None
                else:
                    fm_new, gm_new, gap, _sc = self._round(
                        lam_j, beta, feat_mask
                    )
                gap_history.append((step, float(gap)))
                if gap0 is None and np.isfinite(float(gap)):
                    gap0 = float(gap)
                if float(gap) <= tol:
                    if not low_prec:
                        feat_mask, gmask = fm_new, gm_new
                    break
                finite = np.isfinite(float(gap))
                nact = float(jnp.sum(fm_new))
                restart, raised = self._divergence_step(
                    gap, div_state, nact == prev_nact, gap0
                )
                if raised:
                    # A diverged trajectory can sit astronomically far from
                    # the optimum (FISTA would need O(dist^2) epochs to walk
                    # back): rewind to the best iterate seen.
                    beta = (best_beta if best_beta is not None
                            else jnp.zeros_like(beta))
                if restart:
                    z = beta
                    t_mom = jnp.ones(())
                if finite:
                    # A NaN round's Theorem-1 comparisons all read False —
                    # adopting those masks would permanently (masks are
                    # monotone) zero beta on a round that certified
                    # nothing.  Only finite rounds update the masks.
                    if best_gap is None or float(gap) < best_gap:
                        best_gap, best_beta = float(gap), beta
                    prev_nact = nact
                    feat_mask, gmask = fm_new, gm_new
                beta = beta * feat_mask
                z = z * feat_mask
            beta, z, t_mom = self.fista(
                problem.X, problem.y, beta, z, feat_mask, problem.w, t_mom,
                lam_j, jnp.asarray(self.L, dtype),
            )
            n_steps = step + 1

        return SolveResult(
            beta=beta,
            theta=None,
            gap=gap,
            n_epochs=n_steps,
            group_active=np.asarray(gmask) > 0,
            feat_active=np.asarray(feat_mask) > 0,
            gap_history=gap_history,
            active_history=[],
        )

    # -- batched-lambda solve (coinciding certified active sets) ------------

    def _solve_batch(self, lams, beta0, certs):
        """Solve B consecutive path points in ONE batched FISTA run.

        All B lambdas warm-start from the same previous-lambda beta and
        carry their own per-lambda certificate masks ((B, G, ng) state);
        every f_ce steps each unconverged lambda gets its own certified
        round (dynamic screening inside the batch).  Returns per-lambda
        SolveResults (beta/masks snapshotted at first convergence).
        """
        cfg = self.session.config
        problem = self.session.problem
        dtype = problem.X.dtype
        tol, f_ce, max_steps = cfg.tol, cfg.f_ce, cfg.max_epochs
        low_prec = np.dtype(dtype).itemsize < 8
        B = len(lams)
        self.session.batched_lambdas += B

        fm_full = jnp.asarray(problem.feat_mask, dtype)
        gm_full = jnp.asarray(jnp.any(problem.feat_mask, axis=-1), dtype)
        mask = jnp.stack([c[0] for c in certs])            # (B, G, ng)
        gmask_b = [c[1] for c in certs]
        gap_b = [c[2] for c in certs]
        gap_history = [[(0, float(g))] for g in gap_b]
        done = np.array([float(g) <= tol for g in gap_b])
        steps_b = np.zeros(B, np.int64)
        final_beta = [beta0 if done[b] else None for b in range(B)]
        # Low-precision guard: a certificate whose gap already reads <= tol
        # converged on a possibly-mis-rounded round, so sub-f64 runs report
        # the full masks instead of adopting it (mirrors the single-device
        # path reporter and _DistStrategy.solve).
        conv_mask = (lambda b: fm_full) if low_prec else (lambda b: mask[b])
        final_mask = [conv_mask(b) if done[b] else None for b in range(B)]
        if low_prec:
            gmask_b = [gm_full if done[b] else gmask_b[b] for b in range(B)]

        beta = jnp.repeat(beta0[None], B, axis=0) * mask
        z = beta
        t_mom = jnp.ones((B,))
        lam_j = jnp.asarray(np.asarray(lams), dtype)
        div_state = [[None, False] for _ in range(B)]
        gap0_b = [float(g) if np.isfinite(float(g)) else None
                  for g in gap_b]      # per-lambda first gap (certificate)
        best_gb = [None] * B
        best_bb = [None] * B
        prev_nact = [None] * B

        step = 0
        while not done.all() and step < max_steps:
            for _ in range(f_ce):
                beta, z, t_mom = self.fista_batch(
                    problem.X, problem.y, beta, z, mask, problem.w, t_mom,
                    lam_j, jnp.asarray(self.L, dtype),
                )
            step += f_ce
            new_mask = []
            restart_b = []
            for b in range(B):
                if done[b]:
                    # Converged lambdas keep iterating inert under their
                    # frozen mask (their reported state is the snapshot).
                    new_mask.append(mask[b])
                    continue
                fm, gm, gap, _sc = self._round(lams[b], beta[b], mask[b])
                gap_history[b].append((step, float(gap)))
                if float(gap) <= tol:
                    done[b] = True
                    steps_b[b] = step
                    final_beta[b] = beta[b]
                    # Same low-precision converged-round guard as above.
                    final_mask[b] = mask[b] if low_prec else fm
                    if not low_prec:
                        gmask_b[b] = gm
                    new_mask.append(fm if not low_prec else mask[b])
                    continue
                finite = np.isfinite(float(gap))
                if gap0_b[b] is None and finite:
                    gap0_b[b] = float(gap)
                nact = float(jnp.sum(fm))
                restart, raised = self._divergence_step(
                    gap, div_state[b], nact == prev_nact[b], gap0_b[b]
                )
                if raised:
                    # Rewind the diverged lambda to its best iterate (see
                    # the single-lambda driver).
                    beta = beta.at[b].set(
                        best_bb[b] if best_bb[b] is not None else 0.0
                    )
                if restart:
                    restart_b.append(b)
                if finite:
                    # NaN-round masks certify nothing — keep the previous
                    # ones (see the single-lambda driver).
                    gmask_b[b] = gm
                    if best_gb[b] is None or float(gap) < best_gb[b]:
                        best_gb[b], best_bb[b] = float(gap), beta[b]
                    prev_nact[b] = nact
                    new_mask.append(fm)
                else:
                    new_mask.append(mask[b])
            mask = jnp.stack(new_mask)
            beta = beta * mask
            z = z * mask
            for b in restart_b:                       # adaptive restarts
                z = z.at[b].set(beta[b])
                t_mom = t_mom.at[b].set(1.0)

        for b in range(B):
            if not done[b]:       # max_steps stragglers
                steps_b[b] = step
                final_beta[b] = beta[b]
                final_mask[b] = mask[b]

        return [
            SolveResult(
                beta=final_beta[b],
                theta=None,
                gap=gap_history[b][-1][1],
                n_epochs=int(steps_b[b]),
                group_active=np.asarray(gmask_b[b]) > 0,
                feat_active=np.asarray(final_mask[b]) > 0,
                gap_history=gap_history[b],
                active_history=[],
            )
            for b in range(B)
        ]

    # -- path engine --------------------------------------------------------

    def solve_path(self, lambdas, T, delta, sequential, keep_results,
                   batch_lambdas, beta0=None) -> PathResult:
        s = self.session
        cfg = s.config
        problem = s.problem
        dtype = problem.X.dtype
        lam_max = s.lam_max
        if lambdas is None:
            lambdas = lambda_grid(lam_max, T=T, delta=delta)
        lambdas = np.asarray(lambdas, float)
        T_ = len(lambdas)
        G, ng = problem.G, problem.ng
        fm_full = jnp.asarray(problem.feat_mask, dtype)
        n_feat = int(np.asarray(problem.feat_mask).sum())
        n_groups = int(np.asarray(jnp.any(problem.feat_mask, axis=-1)).sum())
        rounds0 = s.rounds
        flops0 = s.round_flops
        batched0 = s.batched_lambdas

        betas = np.zeros((T_, G, ng), np.dtype(dtype))
        gaps = np.zeros(T_, float)
        epochs = np.zeros(T_, np.int64)
        gfrac = np.zeros(T_, float)
        ffrac = np.zeros(T_, float)
        g_act = np.zeros((T_, G), bool)
        f_act = np.zeros((T_, G, ng), bool)
        seq_scr = np.zeros(T_, np.int64)
        dyn_scr = np.zeros(T_, np.int64)
        results: list = []

        def record(t, res, n_seq_active):
            betas[t] = np.asarray(res.beta)
            gaps[t] = float(res.gap)
            epochs[t] = res.n_epochs
            g_act[t] = np.asarray(res.group_active)
            f_act[t] = np.asarray(res.feat_active)
            gfrac[t] = g_act[t].sum() / max(n_groups, 1)
            ffrac[t] = f_act[t].sum() / max(n_feat, 1)
            dyn_scr[t] = max(0, n_seq_active - int(g_act[t].sum()))
            if keep_results:
                results.append(res)

        beta = (jnp.zeros((G, ng), dtype) if beta0 is None
                else jnp.asarray(beta0, dtype))
        t = 0
        while t < T_:
            if sequential:
                # Sequential certificates for the upcoming run, all from the
                # current (previous lambda's) primal point — every GAP
                # sphere from a feasible point is safe, so one beta can
                # certify several lambdas ahead.
                certs = [self._round(lambdas[t], beta, fm_full)]
                base = np.asarray(certs[0][1]) > 0
                while (len(certs) < batch_lambdas
                       and t + len(certs) < T_):
                    k = t + len(certs)
                    ck = self._round(lambdas[k], beta, fm_full)
                    if np.array_equal(np.asarray(ck[1]) > 0, base):
                        certs.append(ck)
                    else:
                        # Mismatch: k re-certifies later from a warmer beta.
                        break
                for j, c in enumerate(certs):
                    seq_scr[t + j] = n_groups - int(
                        (np.asarray(c[1]) > 0).sum()
                    )
            else:
                certs = [None]

            low_prec = np.dtype(dtype).itemsize < 8
            if len(certs) == 1:
                cert = certs[0]
                first = None
                n_seq_active = n_groups
                if cert is not None:
                    first = RoundResult(
                        cert[2], None, np.asarray(cert[1]) > 0,
                        np.asarray(cert[0]) > 0,
                        safe=s.rule.is_safe,
                    )
                    n_seq_active = int(np.asarray(first.group_active).sum())
                res = self.solve(float(lambdas[t]), beta0=beta,
                                 first_round=first)
                if low_prec and res.n_epochs == 0:
                    # Converged on the certificate round in sub-f64: the
                    # solve did not adopt (and does not report) its masks,
                    # so keep counters consistent (see the single-device
                    # path reporter).
                    seq_scr[t] = 0
                    n_seq_active = n_groups
                record(t, res, n_seq_active)
                beta = res.beta
                t += 1
            else:
                run = self._solve_batch(lambdas[t:t + len(certs)], beta,
                                        certs)
                for j, res in enumerate(run):
                    if low_prec and res.n_epochs == 0:
                        seq_scr[t + j] = 0
                    n_seq_active = n_groups - int(seq_scr[t + j])
                    record(t + j, res, n_seq_active)
                beta = run[-1].beta
                t += len(certs)

        return PathResult(
            lambdas=lambdas,
            betas=betas,
            gaps=gaps,
            epochs=epochs,
            group_active_frac=gfrac,
            feat_active_frac=ffrac,
            group_active=g_act,
            feat_active=f_act,
            seq_screened=seq_scr,
            dyn_screened=dyn_scr,
            n_gathers=0,
            results=results,
            n_rounds=s.rounds - rounds0,
            n_transpose_copies=0,   # sharded rounds are einsum-based: no
                                    # feature-major copy is ever at stake
            n_compact_rounds=0,     # the mesh strategy always screens on
                                    # the full (sharded) problem
            n_full_rounds=s.rounds - rounds0,
            round_flops=s.round_flops - flops0,
            n_fused_epoch_launches=0,   # BCD mega-kernel is single-device;
                                        # the mesh inner solver is FISTA
            batched_lambdas=s.batched_lambdas - batched0,
            rule_name=s.rule.name,
            certificates_safe=s.rule.is_safe,
        )


# ----------------------------------------------------------------------------
# Static-analysis hook (see repro.analysis.entrypoints for the template)
# ----------------------------------------------------------------------------

from ..analysis.registry import register_traceable  # noqa: E402

register_traceable("batch_reduced_gaps", _batch_reduced_gaps,
                   module=__name__, kind="jit")
