"""Figure 3b: whole-path computation time on the climate-like dataset as a
function of the prescribed duality-gap accuracy, GAP rule vs no screening —
the sequential path engine vs the legacy naive per-lambda loop vs the
session front-end.

Paper: NCEP/NCAR Reanalysis 1, n=814, p=73577 (groups of 7 variables per
grid point), delta=2.5, tau*=0.4.  The offline generator reproduces the
group structure and preprocessing; the default grid is reduced so the
harness completes in CPU-minutes (``--full`` restores 144x73).

Modes:
* ``naive``   — the seed loop: warm-started beta only, fresh caches and a
  full active-set re-derivation at every lambda, f_ce-block epoch counts.
* ``engine``  — sequential GAP screening before the first epoch of each
  lambda, carried gather cache, sequential-gap-adaptive early exit
  (via the legacy ``solve_path`` wrapper).
* ``session`` — the same engine driven through ``SGLSession.solve_path``
  directly: one session per (rule, tol) owning the caches and, on the
  Pallas backend, ONE persistent transposed design for every certified
  round of the whole path.  ``transpose_copies_eliminated`` counts the
  per-round (p, n) copies of X the pre-session design materialised
  (``n_rounds``) minus the copies actually measured (trace audit,
  ``PathResult.n_transpose_copies``); reported as 0 on the XLA backend,
  where no transposed copy was ever at stake.
"""
from __future__ import annotations

import time
import warnings

from repro.core import sgl
from repro.core.path import lambda_grid, solve_path
from repro.core.session import SGLSession, SolverConfig
from repro.core.solver import resolve_screen_backend
from repro.data.climate import make_climate_like

from .common import emit

MODES = ("naive", "engine", "session")
MODE_KWARGS = {
    "naive": dict(sequential=False, check_every=None),
    "engine": dict(sequential=True, check_every="auto"),
}


def main(n=256, n_lon=16, n_lat=8, T=20, delta=2.5, tau=0.4,
         tols=(1e-4, 1e-6, 1e-8), max_epochs=3000) -> None:
    X, y, _, sizes = make_climate_like(n=n, n_lon=n_lon, n_lat=n_lat)
    problem = sgl.make_problem(X, y, sizes, tau=tau)
    lam_max = float(sgl.lambda_max(problem))
    lambdas = lambda_grid(lam_max, T=T, delta=delta)

    for rule in ("gap", "none"):
        for tol in tols:
            for mode in MODES:
                t0 = time.perf_counter()
                if mode == "session":
                    session = SGLSession(problem, SolverConfig(
                        tol=tol, max_epochs=max_epochs, rule=rule,
                    ))
                    res = session.solve_path(lambdas=lambdas)
                else:
                    with warnings.catch_warnings():
                        warnings.simplefilter("ignore", DeprecationWarning)
                        res = solve_path(
                            problem, lambdas=lambdas, tol=tol,
                            max_epochs=max_epochs, rule=rule,
                            **MODE_KWARGS[mode],
                        )
                dt = time.perf_counter() - t0
                case = f"{rule}_{mode}_tol{tol:g}"
                emit("path_fig3b", case, "path_seconds", dt)
                emit("path_fig3b", case, "total_epochs", int(res.epochs.sum()))
                emit("path_fig3b", case, "zero_epoch_lambdas",
                     int((res.epochs == 0).sum()))
                emit("path_fig3b", case, "gathers", res.n_gathers)
                emit("path_fig3b", case, "certified_rounds", res.n_rounds)
                # (p, n) transposed copies of X eliminated by the persistent
                # transposed design: one per certified round on the Pallas
                # backend (pre-session behavior), minus any measured copies
                # (res.n_transpose_copies, from the trace audit).  Only the
                # Pallas backend ever had a copy at stake, so XLA-backed
                # runs report 0.
                pallas = resolve_screen_backend("auto") == "pallas"
                emit("path_fig3b", case, "transpose_copies_eliminated",
                     res.n_rounds - res.n_transpose_copies if pallas else 0)
                if rule == "gap":
                    emit("path_fig3b", case, "seq_screened_groups",
                         int(res.seq_screened.sum()))
                    emit("path_fig3b", case, "dyn_screened_groups",
                         int(res.dyn_screened.sum()))


if __name__ == "__main__":
    import argparse

    from .common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    header()
    if args.full:
        main(n=814, n_lon=144, n_lat=73, T=100)
    else:
        main()
