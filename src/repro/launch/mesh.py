"""Production mesh definitions and logical->physical spec translation.

Logical axes used throughout the model code: "data" (batch / FSDP) and
"model" (TP / EP).  The multi-pod mesh adds a leading "pod" axis which is
folded into data parallelism: every logical "data" entry becomes
("pod", "data").
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    # dry-run host platform exposes 512 devices; single-pod uses the first 256
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_test_mesh() -> Mesh:
    """Single-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def translate_spec(spec: P, *, multi_pod: bool) -> P:
    """Map logical 'data' entries to ('pod', 'data') on the multi-pod mesh."""
    if not multi_pod:
        return spec
    out = []
    for entry in spec:
        if entry == "data":
            out.append(("pod", "data"))
        elif isinstance(entry, (tuple, list)) and "data" in entry:
            expanded = []
            for e in entry:
                if e == "data":
                    expanded.extend(["pod", "data"])
                else:
                    expanded.append(e)
            out.append(tuple(expanded))
        else:
            out.append(entry)
    return P(*out)


def shardings_for(mesh: Mesh, spec_tree, *, multi_pod: bool):
    """Spec pytree -> NamedSharding pytree on the given mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, translate_spec(s, multi_pod=multi_pod)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh cannot divide evenly.

    For tuple entries (e.g. ("pod", "data")) the longest prefix whose
    product divides the dim is kept.  Configs with awkward sizes (a vocab
    of 256206, 8 experts on a 16-wide model axis, batch=1 decode) then
    lower cleanly with those dims replicated instead of erroring out.
    """
    sizes = _axis_sizes(mesh)
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if shape[i] % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
            else:
                break
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def shardings_for_structs(mesh: Mesh, spec_tree, struct_tree, *,
                          multi_pod: bool):
    """Like ``shardings_for`` but validated against concrete array shapes."""
    specs = jax.tree.map(
        lambda s: translate_spec(s, multi_pod=multi_pod),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, sanitize_spec(s, a.shape, mesh)),
        specs, struct_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def dp_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def model_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1)


def batch_spec(batch: int, mesh: Mesh) -> P:
    """Shard batch over data(+pod) when divisible, else replicate."""
    if batch % dp_size(mesh) == 0:
        if "pod" in mesh.axis_names:
            return P(("pod", "data"))
        return P("data")
    return P(None)
