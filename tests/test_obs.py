"""repro.obs: metrics registry, tracing spans, scoping, the --check gate.

The contracts defended here, in the order they matter:

* **zero overhead off** — with tracing disabled, ``span()`` returns the
  preallocated NOOP singleton and allocates nothing, and running a full
  solve with tracing ON is bit-identical to OFF;
* **scope parity** — ``MetricsRegistry.scope`` keeps the exact
  ``kernels.ops.audit_scope()`` semantics (zero on entry, live deltas,
  freeze on exit, outer values restored, nothing propagated);
* **back-compat shims** — ``SGLServer.counters`` still quacks like the
  dict it replaced, ``SessionCache.hits += 1`` still works;
* **exact counts, deterministic time** — span counters are exact under
  sampling and threads; an injected fake clock makes histograms and
  percentiles reproducible to the bit;
* **the gate finds things** — OB001/OB002 findings fire on seeded bad
  fixtures, and the live schema/snapshot pass clean.
"""
import json
import threading

import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.obs import check as ocheck
from repro.obs import export as oexport
from repro.obs import metrics as om
from repro.obs import trace as ot


# ---------------------------------------------------------------------------
# metrics: declarations, kinds, thread safety
# ---------------------------------------------------------------------------

def test_declare_enforces_names_and_kinds():
    with pytest.raises(ValueError):
        om.declare("NoDots", "counter", "x")
    with pytest.raises(ValueError):
        om.declare("Upper.case", "counter", "x")
    with pytest.raises(ValueError):
        om.declare("ok.name", "exotic", "x")
    om.declare("testobs.decl", "counter", "first help")
    om.declare("testobs.decl", "counter", "redeclare is idempotent")
    assert om.SCHEMA["testobs.decl"].help == "first help"
    with pytest.raises(ValueError):
        om.declare("testobs.decl", "gauge", "kind conflict")


def test_registry_requires_declaration():
    reg = om.MetricsRegistry()
    with pytest.raises(KeyError):
        reg.counter("testobs.never_declared")
    om.declare("testobs.kindmix", "counter", "h")
    with pytest.raises(TypeError):
        reg.gauge("testobs.kindmix")


def test_counter_threadsafe_exact():
    om.declare("testobs.threads", "counter", "h")
    c = om.MetricsRegistry().counter("testobs.threads")

    def worker():
        for _ in range(1000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000


def test_histogram_summary_and_percentile_match_numpy():
    om.declare("testobs.hist", "histogram", "h")
    h = om.MetricsRegistry().histogram("testobs.hist")
    vals = np.random.default_rng(3).standard_normal(257).tolist()
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.vmin == min(vals) and h.vmax == max(vals)
    for q in (0.0, 12.5, 50.0, 90.0, 99.0, 100.0):
        assert h.percentile(q) == pytest.approx(np.percentile(vals, q),
                                                abs=1e-12)
    s = h.summary()
    assert s["count"] == len(vals)
    assert s["mean"] == pytest.approx(np.mean(vals))
    assert s["p50"] == pytest.approx(np.percentile(vals, 50))


def test_percentile_edges():
    assert oexport.percentile([], 50) is None
    assert oexport.percentile([7.0], 0) == 7.0
    assert oexport.percentile([7.0], 100) == 7.0
    with pytest.raises(ValueError):
        oexport.percentile([1.0], 101)
    with pytest.raises(ValueError):
        oexport.percentile([1.0], -1)


# ---------------------------------------------------------------------------
# scoping: snapshot/diff/reset and audit_scope parity
# ---------------------------------------------------------------------------

def test_scope_zeroes_restores_freezes():
    om.declare("testobs.scope_a", "counter", "h")
    om.declare("testobs.scope_h", "histogram", "h")
    reg = om.MetricsRegistry()
    a = reg.counter("testobs.scope_a")
    h = reg.histogram("testobs.scope_h")
    a.inc(5)
    h.observe(1.0)
    with reg.scope() as view:
        assert view["testobs.scope_a"] == 0       # zero on entry
        assert view["testobs.scope_h"] == 0
        a.inc(3)
        h.observe(2.0)
        h.observe(4.0)
        assert view["testobs.scope_a"] == 3       # live in-scope deltas
        assert view["testobs.scope_h"] == 2
        assert not view.frozen
    assert view.frozen
    assert view["testobs.scope_a"] == 3           # frozen at exit values
    assert a.value == 5                           # outer value restored
    assert h.count == 1 and h.samples() == (1.0,)
    assert view.as_dict()["testobs.scope_h"] == 2


def test_scope_nested():
    om.declare("testobs.nested", "counter", "h")
    reg = om.MetricsRegistry()
    c = reg.counter("testobs.nested")
    c.inc(10)
    with reg.scope(["testobs.nested"]) as outer:
        c.inc(1)
        with reg.scope(["testobs.nested"]) as inner:
            c.inc(2)
            assert inner["testobs.nested"] == 2
        assert c.value == 1                       # inner restored
        assert outer["testobs.nested"] == 1
    assert c.value == 10


def test_snapshot_diff():
    om.declare("testobs.snap", "counter", "h")
    om.declare("testobs.snap_h", "histogram", "h")
    reg = om.MetricsRegistry()
    c = reg.counter("testobs.snap")
    h = reg.histogram("testobs.snap_h")
    c.inc(2)
    h.observe(0.5)
    snap = reg.snapshot()
    c.inc(3)
    h.observe(0.7)
    d = reg.diff(snap)
    assert d["testobs.snap"] == 3
    assert d["testobs.snap_h"] == 1               # histograms diff on count
    reg.reset(["testobs.snap"])
    assert c.value == 0 and h.count == 2


def test_audit_scope_parity():
    """The migrated kernels.ops.audit_scope keeps its exact contract."""
    base = kops.retrace_count()
    kops.note_retrace(2)
    with kops.audit_scope() as c:
        assert c.retraces == 0                    # zero on entry
        kops.note_retrace(3)
        kops.note_kernel_demotion()
        assert c.retraces == 3                    # live while open
        assert c.kernel_demotions == 1
    assert c.retraces == 3                        # frozen after exit
    assert c.kernel_demotions == 1
    assert kops.retrace_count() == base + 2       # outer value restored
    with kops.audit_scope() as c2:
        assert c2.retraces == 0 and c2.transpose_traces == 0
    assert kops.retrace_count() == base + 2


# ---------------------------------------------------------------------------
# back-compat shims: server counters dict, cache int attributes
# ---------------------------------------------------------------------------

def test_countermap_is_dict_shaped():
    om.declare("testobs.cm_a", "counter", "h")
    om.declare("testobs.cm_b", "counter", "h")
    reg = om.MetricsRegistry()
    m = om.CounterMap(reg, "testobs.", ("cm_a", "cm_b"))
    assert dict(m) == {"cm_a": 0, "cm_b": 0}
    m["cm_a"] += 2
    m["cm_b"] = 7
    assert m["cm_a"] == 2 and len(m) == 2
    assert {**m} == {"cm_a": 2, "cm_b": 7}
    assert reg.counter("testobs.cm_a").value == 2
    m.counter("cm_a").inc()                       # typed escape hatch
    assert m["cm_a"] == 3
    with pytest.raises(TypeError):
        del m["cm_a"]
    with pytest.raises(KeyError):
        m["unknown"]


def test_server_and_cache_shims():
    from repro.serve import ServeConfig, SessionCache, SGLServer

    server = SGLServer(ServeConfig())
    assert server.counters["requests"] == 0
    server.counters["requests"] += 2
    assert dict(server.counters)["requests"] == 2
    assert server.metrics.counter("serve.requests").value == 2
    # distinct servers keep distinct numbers under the shared schema
    other = SGLServer(ServeConfig())
    assert other.counters["requests"] == 0

    cache = SessionCache()
    cache.hits += 1
    cache.retraces += 4
    assert cache.stats()["hits"] == 1
    assert cache.metrics.counter("serve.cache_hits").value == 1
    assert cache.metrics.counter("serve.cache_retraces").value == 4


def test_faults_fired_counter():
    from repro.faults import FaultPlan, FaultSpec, inject
    from repro.faults.inject import fire

    fired = om.REGISTRY.counter("faults.fired")
    base = fired.value
    plan = FaultPlan((FaultSpec("core.round", "nan", hits=(0,)),))
    with inject(plan) as log:
        assert len(fire("core.round")) == 1
        assert fire("core.round") == ()           # hit 1 not scheduled
    assert log.count() == 1
    assert fired.value == base + 1


# ---------------------------------------------------------------------------
# tracing: disabled fast path, fake clock, sampling, threads
# ---------------------------------------------------------------------------

def test_disabled_span_is_noop_and_allocation_free():
    assert not ot.TRACER.enabled
    before = ot.Span.allocated()
    for _ in range(100):
        with ot.span("round") as sp:
            sp.set("k", 1)
    assert ot.span("path") is ot.NOOP
    assert ot.Span.allocated() == before


def _fake_clock(step=0.25):
    state = {"t": 0.0}

    def clock():
        state["t"] += step
        return state["t"]

    return clock


def test_fake_clock_deterministic_spans():
    tr = ot.Tracer(clock=_fake_clock())
    tr.configure(enabled=True)
    with tr.span("path") as root:
        with tr.span("round") as child:
            pass
    assert root.trace_id == child.trace_id
    assert child.parent_id == root.span_id
    # clock ticks: root enter=0.25, child enter=0.5, child exit=0.75,
    # root exit=1.0 — every duration is exact, no tolerance needed.
    assert child.duration_s == 0.25
    assert root.duration_s == 0.75
    recs = tr.records()
    assert [r["name"] for r in recs] == ["round", "path"]
    p = tr.percentiles("round")
    assert p["p50"] == 0.25 and p["n"] == 1
    assert tr.open_spans() == 0


def test_sampling_thins_records_not_counts():
    tr = ot.Tracer(clock=_fake_clock(), sample_every=2)
    tr.configure(enabled=True)
    for _ in range(4):
        with tr.span("lambda"):
            with tr.span("round"):
                pass
    assert tr.counts() == {"lambda": 4, "round": 4}   # exact
    # roots 1 and 3 sampled; each subtree contributes both spans
    assert len(tr.records("lambda")) == 2
    assert len(tr.records("round")) == 2


def test_span_threads_exact_counts():
    tr = ot.Tracer(clock=_fake_clock(1e-6), buffer=100_000)
    tr.configure(enabled=True)

    def worker():
        for _ in range(200):
            with tr.span("epoch_block"):
                with tr.span("kernel_launch"):
                    pass

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.counts() == {"epoch_block": 1600, "kernel_launch": 1600}
    assert tr.open_spans() == 0
    ids = [r["span"] for r in tr.records()]
    assert len(ids) == len(set(ids))                   # unique span ids


def test_export_jsonl(tmp_path):
    tr = ot.Tracer(clock=_fake_clock())
    tr.configure(enabled=True)
    with tr.span("path") as sp:
        sp.set("T", 4)
    out = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(str(out)) == 1
    rec = json.loads(out.read_text().strip())
    assert rec["name"] == "path" and rec["attrs"] == {"T": 4}


# ---------------------------------------------------------------------------
# end-to-end: tracing a real solve is bit-identical and leak-free
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_problem():
    from repro.core import sgl
    from repro.data.synthetic import make_synthetic

    X, y, _, sizes = make_synthetic(n=24, p=64, n_groups=8, gamma1=3,
                                    gamma2=2, seed=5)
    return sgl.make_problem(X, y, sizes, tau=0.3)


def test_traced_solve_bit_identical(small_problem):
    from repro.core.session import SGLSession, SolverConfig

    cfg = dict(tol=1e-6, max_epochs=2000)
    before = ot.Span.allocated()
    off = SGLSession(small_problem,
                     SolverConfig(**cfg)).solve_path(T=3, delta=1.5)
    assert ot.Span.allocated() == before          # hot path allocated nothing
    ot.configure(enabled=True, sample_every=1)
    ot.TRACER.reset()
    try:
        on = SGLSession(small_problem,
                        SolverConfig(**cfg)).solve_path(T=3, delta=1.5)
        counts = ot.TRACER.counts()
    finally:
        ot.configure(enabled=False)
    np.testing.assert_array_equal(np.asarray(on.betas),
                                  np.asarray(off.betas))
    assert counts["path"] == 1 and counts["lambda"] == 3
    assert counts["round"] > 0 and counts["epoch_block"] > 0
    assert ot.TRACER.open_spans() == 0


def test_serve_worker_traced_under_chaos(small_problem):
    """Spans + counters stay consistent when the serve worker (its own
    thread) dies mid-wave and restarts: no leaked open spans, exact
    request accounting, availability 1.0."""
    from repro.core.session import SolverConfig, lambda_grid
    from repro.core import sgl
    from repro.faults import FaultPlan, FaultSpec, inject
    from repro.serve import PathRequest, ServeConfig, SGLServer

    grid = lambda_grid(float(sgl.lambda_max(small_problem)), T=3, delta=1.5)
    solver = SolverConfig(tol=1e-6, max_epochs=2000)
    plan = FaultPlan((FaultSpec("serve.worker", "kill", hits=(0,)),))
    ot.configure(enabled=True, sample_every=1)
    ot.TRACER.reset()
    try:
        server = SGLServer(ServeConfig(default_solver=solver,
                                       coalesce_window_s=0.05,
                                       retry_backoff_s=0.01)).start()
        try:
            with inject(plan) as log:
                futs = [server.submit(
                    PathRequest(f"chaos-{i}", small_problem, grid))
                    for i in range(3)]
                resps = [f.result(timeout=600) for f in futs]
        finally:
            server.stop()
        counts = ot.TRACER.counts()
    finally:
        ot.configure(enabled=False)
    assert log.count("serve.worker") == 1
    assert server.counters["worker_restarts"] >= 1
    assert len(resps) == 3 and all(r.result is not None for r in resps)
    assert server.counters["responses"] == 3
    assert counts.get("serve.request", 0) >= 1
    assert counts.get("path", 0) >= 1
    assert ot.TRACER.open_spans() == 0
    # queue-wait histogram observed every response
    qw = server.metrics.histogram("serve.queue_wait_s").summary()
    assert qw["count"] == 3


# ---------------------------------------------------------------------------
# the --check gate: findings fire on seeded fixtures, live state is clean
# ---------------------------------------------------------------------------

def test_ob001_fires_on_bad_schema():
    bad = {
        "Bad Name": om.MetricSpec("counter", "ok"),
        "ok.kind": om.MetricSpec("exotic", "ok"),
        "ok.help": om.MetricSpec("counter", "   "),
    }
    fs = ocheck.check_schema(bad)
    assert [f.code for f in fs] == ["OB001"] * 3
    assert all(f.severity == "error" for f in fs)
    locs = {f.location for f in fs}
    assert locs == {"Bad Name", "ok.kind", "ok.help"}


def test_ob001_clean_on_live_schema():
    assert ocheck.check_schema() == []


def test_ob002_fires_on_missing_and_undeclared_sites():
    full = {site: 1 for site in ot.SPAN_SITES}
    assert ocheck.check_span_coverage(full) == []
    missing = dict(full)
    del missing["round"]
    fs = ocheck.check_span_coverage(missing)
    assert len(fs) == 1 and fs[0].code == "OB002"
    assert fs[0].location == "round" and fs[0].severity == "error"
    fs2 = ocheck.check_span_coverage({**full, "mystery": 2})
    assert len(fs2) == 1 and fs2[0].severity == "warning"
    assert fs2[0].location == "mystery"


# ---------------------------------------------------------------------------
# export: env meta, BENCH merging, markdown rendering
# ---------------------------------------------------------------------------

def test_env_meta_keys():
    meta = oexport.env_meta({"bench": "test"})
    assert {"jax", "backend", "platform", "device_count",
            "x64"} <= set(meta)
    assert meta["bench"] == "test"


def test_merge_bench_order_independent(tmp_path):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    for path, order in ((a, ("kernels", "serve")),
                        (b, ("serve", "kernels"))):
        for section in order:
            oexport.merge_bench(str(path), section, {"v": section},
                                meta_extra={"seed": 1})
    da = json.loads(a.read_text())
    db = json.loads(b.read_text())
    assert da["schema"] == oexport.BENCH_SCHEMA
    assert da["sections"] == db["sections"]
    assert da["sections"]["serve"] == {"v": "serve"}
    # merging replaces a section, keeps the others
    oexport.merge_bench(str(a), "serve", {"v": 2})
    da2 = json.loads(a.read_text())
    assert da2["sections"]["serve"] == {"v": 2}
    assert da2["sections"]["kernels"] == {"v": "kernels"}


def test_render_obs_markdown_smoke():
    from repro.launch.report import render_obs_markdown

    payload = {
        "schema": oexport.BENCH_SCHEMA,
        "meta": {"backend": "cpu"},
        "sections": {
            "kernels": {"scale": "smoke", "kernels": {
                "bcd_epoch/bucket": {
                    "measured_s": 1e-3, "min_s": 9e-4, "interpret": True,
                    "model_flops": 1e6, "model_bytes": 1e5,
                    "achieved": {"frac_peak_compute": 5e-9,
                                 "achieved_vs_model": 1e-5,
                                 "model_bottleneck": "memory"}}}},
            "path": {"shape": {"n": 64}, "base_s": 1.0, "obs_s": 1.01,
                     "overhead_frac": 0.01, "bit_identical": True,
                     "span_counts": {"path": 3},
                     "stages": {"round": {"n": 10, "p50": 1e-4,
                                          "p99": 2e-4, "mean": 1.2e-4}}},
            "serve": {"workload": {"tenants": 10},
                      "latency_s": {"p50": 0.5, "p99": 1.2, "n": 10},
                      "baseline_latency_s": {"p50": 1.5, "p99": 3.0},
                      "requests_per_sec": 4.0,
                      "baseline_requests_per_sec": 1.0,
                      "speedup_rps": 4.0,
                      "stages": {"serve.request": {"n": 5, "p50": 0.4,
                                                   "p99": 1.0,
                                                   "mean": 0.5}},
                      "queue_wait_s": {"p50": 1e-3, "p99": 1e-2,
                                       "count": 10},
                      "counters": {"requests": 10, "failed": 0}},
        },
    }
    md = render_obs_markdown(payload)
    assert "bcd_epoch/bucket" in md and "(interp)" in md
    assert "10 tenants" in md
    assert "`serve.request`" in md
    assert "+1.00%" in md
    assert "'failed'" not in md                    # zero counters dropped


def test_obs_check_payload_schema():
    payload = ocheck.run_check(smoke=False)
    assert payload["schema"] == "repro.analysis/v1"
    assert payload["ok"]
    assert payload["passes"]["obs"]["metrics_declared"] >= 20
    assert "serve.request" in payload["passes"]["obs"]["span_sites"]
