"""Burdakov's epsilon-norm and the paper's Algorithm 1 (Lambda(x, alpha, R)).

The epsilon-norm ||x||_eps is the unique nu >= 0 solving

    sum_i S_{(1-eps) nu}(x_i)^2 = (eps nu)^2            (paper Eq. 16/17)

and more generally ``Lambda(x, alpha, R)`` is the unique nu >= 0 solving

    sum_i S_{nu alpha}(x_i)^2 = (nu R)^2                (paper Prop. 9)

so ``||x||_eps = Lambda(x, 1 - eps, eps)``.

Two implementations are provided:

* :func:`lam` — the exact sorted prefix-sum algorithm (paper Algorithm 1),
  vectorised so a whole batch of groups is handled by one ``jnp.sort`` over
  the trailing axis.  O(d log d) per group, exact.
* :func:`lam_bisect` — a fixed-iteration bisection on the monotone function
  g(nu) = sum S_{nu alpha}(x)^2 - (nu R)^2.  All operations are elementwise
  (TPU-friendly, no sort); ``n_iter=80`` reaches f32/f64 machine precision.
  This is the formulation the Pallas kernel uses.

Both operate on the *absolute values* of x (the equation only depends on
|x_i|), accept arbitrary leading batch dimensions, and treat x == 0 rows by
returning 0 (the natural continuous extension: ||0||_eps = 0).

Special cases (paper Algorithm 1):
    alpha = 0, R = 0  ->  +inf (excluded upstream; Omega not a norm there)
    alpha = 0         ->  ||x|| / R
    R = 0             ->  ||x||_inf / alpha
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "lam",
    "lam_bisect",
    "epsilon_norm",
    "epsilon_norm_dual",
    "epsilon_decomposition",
]


def _lam_sorted_core(ax: jax.Array, alpha: jax.Array, R: jax.Array) -> jax.Array:
    """Generic-case Lambda via the sorted prefix-sum search.

    ``ax``: |x| with shape (..., d);  alpha, R broadcastable to (...,).
    Assumes alpha > 0 and R > 0 (callers handle the special cases).
    """
    d = ax.shape[-1]
    dtype = ax.dtype
    alpha = jnp.asarray(alpha, dtype)[..., None]  # (..., 1)
    R = jnp.asarray(R, dtype)[..., None]

    xs = jnp.sort(ax, axis=-1)[..., ::-1]  # descending: x_(1) >= ... >= x_(d)
    S = jnp.cumsum(xs, axis=-1)            # S_k  = sum_{j<=k} x_(j)
    S2 = jnp.cumsum(xs * xs, axis=-1)      # S2_k = sum_{j<=k} x_(j)^2
    k = jnp.arange(1, d + 1, dtype=dtype)

    # B(k) = g(x_(k)/alpha) / alpha^2 where g(nu) = sum S_{nu alpha}(x)^2:
    #   B(k) = S2_k / x_(k)^2 - 2 S_k / x_(k) + k
    # B is nondecreasing in k, B(1) = 0.  The bucket j0 is the largest k with
    # alpha^2 B(k) <= R^2 and x_(k) > 0 (zero entries can never be active).
    safe = jnp.where(xs > 0, xs, 1.0)
    B = jnp.where(xs > 0, S2 / (safe * safe) - 2.0 * S / safe + k, jnp.inf)
    target = (R / alpha) ** 2
    j0 = jnp.sum((B <= target) & (xs > 0), axis=-1)  # (...,) in [1, d]
    j0 = jnp.maximum(j0, 1)  # x != 0 guaranteed by caller
    idx = j0 - 1

    Sj = jnp.take_along_axis(S, idx[..., None], axis=-1)
    S2j = jnp.take_along_axis(S2, idx[..., None], axis=-1)
    j0f = j0[..., None].astype(dtype)

    # Solve (alpha^2 j0 - R^2) nu^2 - 2 alpha S_j0 nu + S2_j0 = 0 on the
    # bucket; the valid root is nu_1 (paper Eq. 36), except the degenerate
    # linear case alpha^2 j0 = R^2.
    a = alpha * alpha * j0f - R * R
    disc = alpha * alpha * Sj * Sj - S2j * a
    disc = jnp.maximum(disc, 0.0)
    linear = S2j / (2.0 * alpha * Sj)
    # For a != 0 use the stable ratio form: nu1 = S2j / (alpha Sj + sqrt(disc))
    # (equivalent to (alpha Sj - sqrt(disc)) / a, but avoids cancellation and
    # is well-behaved for a < 0 too).
    quad = S2j / (alpha * Sj + jnp.sqrt(disc))
    nu = jnp.where(jnp.abs(a) < jnp.finfo(dtype).tiny * 8, linear, quad)
    return nu[..., 0]


@functools.partial(jax.jit, static_argnames=())
def lam(x: jax.Array, alpha: jax.Array, R: jax.Array) -> jax.Array:
    """Exact Lambda(x, alpha, R) (paper Algorithm 1), batched over leading dims.

    x: (..., d); alpha, R: scalars or broadcastable to x.shape[:-1].
    Returns shape x.shape[:-1].
    """
    x = jnp.asarray(x)
    ax = jnp.abs(x)
    dtype = ax.dtype
    batch_shape = ax.shape[:-1]
    alpha = jnp.broadcast_to(jnp.asarray(alpha, dtype), batch_shape)
    R = jnp.broadcast_to(jnp.asarray(R, dtype), batch_shape)

    l2 = jnp.linalg.norm(ax, axis=-1)
    linf = jnp.max(ax, axis=-1)

    # Guard degenerate inputs for the generic branch.
    safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
    safe_R = jnp.where(R > 0, R, 1.0)
    generic = _lam_sorted_core(ax, safe_alpha, safe_R)

    out = generic
    out = jnp.where(R == 0, linf / safe_alpha, out)
    out = jnp.where(alpha == 0, l2 / safe_R, out)
    out = jnp.where((alpha == 0) & (R == 0), jnp.inf, out)
    out = jnp.where(linf == 0, 0.0, out)  # x == 0 row
    return out


@functools.partial(jax.jit, static_argnames=("n_iter",))
def lam_bisect(
    x: jax.Array, alpha: jax.Array, R: jax.Array, n_iter: int = 80
) -> jax.Array:
    """Lambda(x, alpha, R) by fixed-iteration bisection (TPU-friendly form).

    g(nu) = sum_i S_{nu alpha}(x_i)^2 - (nu R)^2 is continuous and strictly
    decreasing-through-zero on (0, ||x||_inf / alpha); the root lies in
    [||x||_inf / (alpha + R), ||x||_inf / alpha] (paper, App. proof of Prop 9).
    """
    x = jnp.asarray(x)
    ax = jnp.abs(x)
    dtype = ax.dtype
    batch_shape = ax.shape[:-1]
    alpha = jnp.broadcast_to(jnp.asarray(alpha, dtype), batch_shape)
    R = jnp.broadcast_to(jnp.asarray(R, dtype), batch_shape)

    l2 = jnp.linalg.norm(ax, axis=-1)
    linf = jnp.max(ax, axis=-1)

    safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
    safe_R = jnp.where(R > 0, R, 1.0)

    lo = linf / (safe_alpha + safe_R)
    hi = linf / safe_alpha

    def g(nu):
        st = jnp.maximum(ax - (nu * safe_alpha)[..., None], 0.0)
        return jnp.sum(st * st, axis=-1) - (nu * safe_R) ** 2

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        gm = g(mid)
        lo = jnp.where(gm > 0, mid, lo)
        hi = jnp.where(gm > 0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    out = 0.5 * (lo + hi)
    out = jnp.where(R == 0, linf / safe_alpha, out)
    out = jnp.where(alpha == 0, l2 / safe_R, out)
    out = jnp.where((alpha == 0) & (R == 0), jnp.inf, out)
    out = jnp.where(linf == 0, 0.0, out)
    return out


def epsilon_norm(x: jax.Array, eps: jax.Array) -> jax.Array:
    """||x||_eps = Lambda(x, 1 - eps, eps)  (paper Eq. 16)."""
    eps = jnp.asarray(eps, jnp.asarray(x).dtype)
    return lam(x, 1.0 - eps, eps)


def epsilon_norm_dual(x: jax.Array, eps: jax.Array) -> jax.Array:
    """Dual of the eps-norm: eps ||x|| + (1 - eps) ||x||_1  (paper Lemma 4)."""
    x = jnp.asarray(x)
    eps = jnp.asarray(eps, x.dtype)
    return eps * jnp.linalg.norm(x, axis=-1) + (1.0 - eps) * jnp.sum(
        jnp.abs(x), axis=-1
    )


def epsilon_decomposition(x: jax.Array, eps: jax.Array):
    """x = x_eps + x_{1-eps} with ||x_eps|| = eps||x||_e, ||x_{1-eps}||_inf =
    (1-eps)||x||_e  (paper Lemma 1). Returns (x_eps, x_one_minus_eps, nu)."""
    x = jnp.asarray(x)
    nu = epsilon_norm(x, eps)
    thr = ((1.0 - eps) * nu)[..., None]
    x_eps = jnp.sign(x) * jnp.maximum(jnp.abs(x) - thr, 0.0)
    return x_eps, x - x_eps, nu
