"""Fused two-level SGL prox Pallas kernel.

prox_{step * lam * Omega_{tau,w}}(beta) =
    S^gp_{(1-tau) w lam step}( S_{tau lam step}(beta) )

Layout: beta (G, ng) with groups on the sublane axis and in-group features on
the lane axis, so the group reduction is a lane-axis reduction — a single VPU
pass.  Each grid step owns a (block_g, ng) tile resident in VMEM; step and w
ride along as (block_g, 1) tiles.  ng should be padded to a multiple of 128
by the wrapper (padding features are zero and inert through both prox levels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import ArraySpec, LaunchSpec, block_specs, default_interpret, out_shapes


def sgl_prox_launch_spec(G: int, ng: int, *, block_g: int = 256,
                         dtype="float64") -> LaunchSpec:
    """Auditable launch geometry of :func:`sgl_prox_pallas`."""
    tile = ArraySpec((G, ng), (block_g, ng), lambda i: (i, 0), dtype)
    col = ArraySpec((G, 1), (block_g, 1), lambda i: (i, 0), dtype)
    return LaunchSpec(
        name="sgl_prox",
        grid=(G // block_g,),
        inputs=(tile, col, col),
        outputs=(tile,),
        carried=((),),
        note="fused two-level SGL prox",
    )


def _sgl_prox_kernel(beta_ref, step_ref, w_ref, out_ref, *, tau: float, lam: float):
    b = beta_ref[...]                     # (bg, ng)
    step = step_ref[...]                  # (bg, 1)
    w = w_ref[...]                        # (bg, 1)

    t1 = tau * lam * step
    z = jnp.sign(b) * jnp.maximum(jnp.abs(b) - t1, 0.0)

    nrm2 = jnp.sum(z * z, axis=1, keepdims=True)
    nrm = jnp.sqrt(nrm2)
    t2 = (1.0 - tau) * lam * w * step
    scale = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0)
    out_ref[...] = scale * z


def sgl_prox_pallas(
    beta: jax.Array,      # (G, ng)
    step: jax.Array,      # (G,)
    w: jax.Array,         # (G,)
    tau: float,
    lam: float,
    *,
    block_g: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    G, ng = beta.shape
    assert G % block_g == 0, (G, block_g)
    spec = sgl_prox_launch_spec(G, ng, block_g=block_g, dtype=beta.dtype)
    return pl.pallas_call(
        functools.partial(_sgl_prox_kernel, tau=float(tau), lam=float(lam)),
        grid=spec.grid,
        in_specs=block_specs(spec.inputs),
        out_specs=block_specs(spec.outputs)[0],
        out_shape=out_shapes(spec.outputs)[0],
        interpret=interpret,
    )(beta, step[:, None], w[:, None])
