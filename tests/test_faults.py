"""repro.faults: plans, injection, budgets, and the degradation protocol.

The deeper contracts (worker supervision, checkpoint quarantine + resume,
the full scenario matrix) live in the chaos suite (``python -m
repro.faults --check``); this file pins the unit-level value semantics
plus the three satellite regressions of PR 9:

* the NaN-round guard — corrupted rounds are refused (masks never
  adopted), beta rewinds, and the path still certifies against a
  tight-tolerance unscreened reference;
* ``RequestQueue.drain`` honours its window exactly (event-driven, no
  polling sleep) under a fake clock;
* ``install_sigterm_hook`` is idempotent, chains a pre-existing handler,
  and a second SIGTERM during an in-progress drain never re-enters the
  checkpoint write.
"""
import functools
import os
import signal
import threading
from concurrent.futures import Future

import numpy as np
import pytest

from repro import ckpt
from repro.core import sgl
from repro.core.session import SGLSession, SolverConfig, lambda_grid
from repro.data.synthetic import make_synthetic
from repro.faults import (
    Degraded,
    FaultLog,
    FaultPlan,
    FaultSpec,
    NumericsError,
    SolveBudget,
    active_plan,
    fire,
    inject,
)
from repro.faults.inject import corrupt_file
from repro.kernels import ops as kops
from repro.serve.queue import Pending, RequestQueue

CFG = SolverConfig(tol=1e-7, max_epochs=5_000)


def _problem(seed=0):
    X, y, _beta, sizes = make_synthetic(
        n=24, p=64, n_groups=8, gamma1=3, gamma2=3, seed=seed)
    return sgl.make_problem(X, y, sizes, tau=0.3)


def _grid(problem, T=4, delta=1.5):
    return lambda_grid(float(sgl.lambda_max(problem)), T=T, delta=delta)


@functools.lru_cache(maxsize=None)
def _baseline(seed=0):
    prob = _problem(seed)
    return prob, SGLSession(prob, CFG).solve_path(_grid(prob))


@functools.lru_cache(maxsize=None)
def _reference_betas(seed=0):
    prob = _problem(seed)
    ref = SGLSession(prob, SolverConfig(
        tol=1e-9, max_epochs=50_000, rule="none")).solve_path(_grid(prob))
    return np.asarray(ref.betas)


def _assert_certifies(result, seed=0):
    """Every screened group must be zero in the unscreened reference."""
    ref = _reference_betas(seed)
    for t in range(len(np.asarray(result.lambdas))):
        screened = ~np.asarray(result.group_active[t])
        nz = np.linalg.norm(ref[t], axis=-1) > 1e-8
        assert int((screened & nz).sum()) == 0
    assert result.certificates_safe


# ---------------------------------------------------------------------------
# plan / injection value semantics
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    FaultSpec("core.round", "nan").validate()
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("core.nowhere", "nan").validate()
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("core.round", "meteor").validate()
    with pytest.raises(ValueError, match="at least one hit"):
        FaultSpec("core.round", "nan", hits=()).validate()
    with pytest.raises(ValueError, match="negative hit"):
        FaultSpec("core.round", "nan", hits=(-1,)).validate()
    with pytest.raises(ValueError, match="stall_s"):
        FaultSpec("core.round", "stall").validate()


def test_fault_plan_is_a_value():
    plan = FaultPlan((FaultSpec("core.round", "nan", hits=(2,)),
                      FaultSpec("ckpt.payload", "truncate")), seed=7)
    assert plan.for_site("core.round") == (
        FaultSpec("core.round", "nan", hits=(2,)),)
    assert plan.for_site("serve.worker") == ()
    assert "seed=7" in repr(plan) and "core.round" in repr(plan)
    with pytest.raises(ValueError):
        FaultPlan((FaultSpec("bad.site", "nan"),))


def test_fire_counts_hits_and_logs():
    plan = FaultPlan((FaultSpec("core.round", "nan", hits=(1,)),))
    assert fire("core.round") == ()          # no plan active: free no-op
    assert active_plan() is None
    with inject(plan) as log:
        assert active_plan() is plan
        assert fire("core.round") == ()       # hit 0: not scheduled
        assert fire("core.epochs") == ()      # other site: own counter
        matched = fire("core.round")          # hit 1: fires
        assert matched[0].kind == "nan"
        assert log.count() == 1
        assert log.count("core.round") == 1
        assert log.events[0].hit == 1
    assert active_plan() is None


def test_inject_is_exclusive():
    plan = FaultPlan((FaultSpec("core.round", "nan"),))
    with inject(plan):
        with pytest.raises(RuntimeError, match="already active"):
            with inject(plan):
                pass
    with inject(plan) as log:                 # reusable after exit
        assert isinstance(log, FaultLog)


def test_corrupt_file_truncate_and_deterministic_bitflip(tmp_path):
    path = tmp_path / "payload.bin"
    blob = bytes(range(256)) * 4
    path.write_bytes(blob)
    assert corrupt_file(str(path), (FaultSpec("ckpt.payload",
                                              "truncate"),))
    assert path.read_bytes() == blob[:len(blob) // 2]

    def flip(seed):
        path.write_bytes(blob)
        with inject(FaultPlan((FaultSpec("ckpt.payload", "bitflip"),),
                              seed=seed)):
            corrupt_file(str(path),
                         (FaultSpec("ckpt.payload", "bitflip"),))
        return path.read_bytes()

    a, b = flip(3), flip(3)
    assert a == b and a != blob               # deterministic per seed
    assert sum(x != y for x, y in zip(a, blob)) == 1


def test_solve_budget_semantics():
    with pytest.raises(ValueError):
        SolveBudget()
    t = [0.0]
    b = SolveBudget(deadline_s=1.0, clock=lambda: t[0])
    assert b.exceeded() is None
    t[0] = 1.5
    assert b.exceeded() == "deadline"
    e = SolveBudget(max_epochs=10)
    e.note_epochs(4)
    assert e.exceeded() is None
    e.note_epochs(6)
    assert e.exceeded() == "epoch_budget"


# ---------------------------------------------------------------------------
# the NaN-round guard (satellite: rounds 1, k, final confirmation)
# ---------------------------------------------------------------------------

def _final_round_hit():
    prob = _problem()
    probe = SGLSession(prob, CFG)
    probe.solve_path(_grid(prob))
    # full rounds map 1:1 onto core.round hits, and the final
    # confirmation round (the convergence gate) is always full.
    return probe.full_rounds - 1


@pytest.mark.parametrize("which", ["round_1", "round_k", "final"])
def test_nan_round_guard_refuses_rewinds_and_certifies(which):
    prob, base = _baseline()
    hit = {"round_1": 1, "round_k": 3, "final": _final_round_hit()}[which]
    plan = FaultPlan((FaultSpec("core.round", "nan", hits=(hit,),
                                field="theta"),))
    sess = SGLSession(prob, CFG)
    with inject(plan) as log:
        res = sess.solve_path(_grid(prob))
    assert log.count() == 1                   # the fault really fired
    assert sess.nonfinite_rounds >= 1         # ...and was refused
    # mask adoption refused: reported masks match the fault-free run
    np.testing.assert_array_equal(np.asarray(res.group_active),
                                  np.asarray(base.group_active))
    # beta rewound/re-run: bit-identical recovery (round-local corruption
    # with a healthy beta re-runs deterministically)
    np.testing.assert_array_equal(np.asarray(res.betas),
                                  np.asarray(base.betas))
    np.testing.assert_array_equal(np.asarray(res.gaps),
                                  np.asarray(base.gaps))
    _assert_certifies(res)


def test_beta_corruption_rewinds_to_finite_iterate():
    prob, base = _baseline()
    plan = FaultPlan((FaultSpec("core.epochs", "nan", hits=(1,)),))
    sess = SGLSession(prob, CFG)
    with inject(plan) as log:
        res = sess.solve_path(_grid(prob))
    assert log.count() >= 1
    gaps = np.asarray(res.gaps)
    assert np.all(np.isfinite(gaps)) and np.all(gaps <= CFG.tol * (1 + 1e-12))
    # certified recovery (not bit-identical: the rewind restarts epochs)
    assert np.allclose(np.asarray(res.betas), np.asarray(base.betas),
                       atol=1e-4)
    _assert_certifies(res)


def test_nan_storm_raises_typed_numerics_error():
    prob, _ = _baseline()
    sess = SGLSession(prob, CFG)
    lam = float(_grid(prob)[1])
    plan = FaultPlan((FaultSpec("core.round", "nan", hits=(0, 1, 2),
                                field="theta"),))
    with inject(plan) as log:
        with pytest.raises(NumericsError, match="consecutive non-finite"):
            sess.solve(lam)
    assert log.count() == 3


def test_screen_kernel_failure_demotes_to_xla():
    prob = _problem()
    cfg = CFG._replace(screen_backend="pallas")
    base = SGLSession(prob, cfg).solve_path(_grid(prob))
    sess = SGLSession(prob, cfg)
    d0 = kops.kernel_demotion_count()
    plan = FaultPlan((FaultSpec("kernels.screen", "raise", hits=(0,)),))
    with inject(plan):
        res = sess.solve_path(_grid(prob))
    assert sess.kernel_demotions == 1
    assert kops.kernel_demotion_count() == d0 + 1
    assert sess.backend == "xla"              # demotion sticks
    # betas/masks bit-identical (kernel parity); reported gaps agree to
    # fp round-off (different reduction order)
    np.testing.assert_array_equal(np.asarray(res.betas),
                                  np.asarray(base.betas))
    np.testing.assert_allclose(np.asarray(res.gaps),
                               np.asarray(base.gaps),
                               rtol=1e-6, atol=1e-12)
    _assert_certifies(res)


def test_deadline_budget_degrades_with_honest_prefix():
    prob, _ = _baseline()
    sess = SGLSession(prob, CFG)
    sess.budget = SolveBudget(deadline_s=0.2)
    plan = FaultPlan((FaultSpec("core.round", "stall",
                                hits=tuple(range(2, 100)),
                                stall_s=0.05),))
    with inject(plan):
        res = sess.solve_path(_grid(prob))
    assert res.degraded == "deadline"
    T = len(np.asarray(res.lambdas))
    assert 0 < T < 4                          # truncated, never padded
    assert len(np.asarray(res.gaps)) == T
    assert np.all(np.isfinite(np.asarray(res.gaps)))
    _assert_certifies(res)


def test_serve_epoch_budget_resolves_future_with_degraded():
    from repro.serve import PathRequest, ServeConfig, SGLServer

    prob = _problem(seed=3)
    grid = _grid(prob)
    server = SGLServer(ServeConfig(default_solver=CFG,
                                   epoch_budget=10)).start()
    try:
        fut = server.submit(PathRequest("t0", prob, grid))
        with pytest.raises(Degraded) as ei:
            fut.result(600)
    finally:
        server.stop()
    e = ei.value
    assert e.reason == "epoch_budget"
    assert np.isfinite(e.gap)                 # the honest gap at truncation
    assert 0 < len(np.asarray(e.result.lambdas)) < len(grid)
    assert e.result.degraded == "epoch_budget"
    assert server.counters["degraded"] == 1
    # degraded results must never be stored as servable certificates
    assert server.store.stats()["exact_entries"] == 0


# ---------------------------------------------------------------------------
# RequestQueue.drain: exact window, no polling (fake clock)
# ---------------------------------------------------------------------------

def _pending(name="t0"):
    prob = _problem(seed=9)
    from repro.serve import PathRequest
    req = PathRequest(name, prob, _grid(prob))
    return Pending(req, Future(), req.digest(CFG), 0.0)


def test_drain_window_is_exact_under_fake_clock():
    clk = [0.0]
    waits = []

    def wait(timeout):
        waits.append(timeout)
        clk[0] += timeout                     # nothing arrives: full wait
        return False

    q = RequestQueue(clock=lambda: clk[0], wait=wait)
    p0 = _pending()
    with q._cond:
        q._items.append(p0)
    out = q.drain(max_batch=8, window_s=0.003)
    assert out == [p0]
    # exactly ONE condition wait for exactly the window — the old
    # implementation slept fixed 0.05s ticks regardless of window_s
    assert waits == [0.003]
    assert clk[0] == 0.003


def test_drain_collects_mid_window_arrival_and_closes_on_deadline():
    clk = [0.0]
    waits = []
    q = RequestQueue(clock=lambda: clk[0], wait=None)
    p0, p1 = _pending("t0"), _pending("t1")

    def wait(timeout):
        waits.append(timeout)
        if len(waits) == 1:                   # a submit lands mid-window
            clk[0] += 0.01
            q._items.append(p1)
            return True
        clk[0] += timeout                     # then the window drains out
        return False

    q._wait = wait
    with q._cond:
        q._items.append(p0)
    out = q.drain(max_batch=8, window_s=0.02)
    assert out == [p0, p1]
    # the second wait asks only for the REMAINING window, so the total
    # elapsed time is exactly window_s — never window + poll-tick
    assert waits == [0.02, pytest.approx(0.01)]
    assert clk[0] == pytest.approx(0.02)


def test_drain_max_batch_short_circuits_without_waiting():
    clk = [0.0]
    q = RequestQueue(clock=lambda: clk[0],
                     wait=lambda timeout: pytest.fail("waited"))
    ps = [_pending(f"t{i}") for i in range(3)]
    with q._cond:
        q._items.extend(ps)
    out = q.drain(max_batch=3, window_s=10.0)
    assert out == ps
    assert clk[0] == 0.0


# ---------------------------------------------------------------------------
# SIGTERM hook: idempotent, chaining, no re-entrant checkpoint write
# ---------------------------------------------------------------------------

@pytest.fixture
def sigterm_guard():
    old = signal.getsignal(signal.SIGTERM)
    try:
        yield
    finally:
        signal.signal(signal.SIGTERM, old)


def test_sigterm_hook_idempotent_and_chains(tmp_path, sigterm_guard):
    chained = []
    signal.signal(signal.SIGTERM, lambda s, f: chained.append(s))
    mgr = ckpt.CheckpointManager(str(tmp_path), every=1, keep=3)
    tree = {"beta": np.arange(4.0)}
    mgr.install_sigterm_hook(lambda: (1, tree))
    handler = signal.getsignal(signal.SIGTERM)
    # idempotent: re-installing swaps the provider, not the handler
    mgr.install_sigterm_hook(lambda: (2, tree))
    assert signal.getsignal(signal.SIGTERM) is handler
    with pytest.raises(SystemExit) as ei:
        handler(signal.SIGTERM, None)
    assert ei.value.code == 143
    # the save used the LATEST provider and the old handler was chained
    assert ckpt.latest_step(str(tmp_path)) == 2
    assert chained == [signal.SIGTERM]


def test_second_sigterm_during_drain_skips_checkpoint_write(
        tmp_path, sigterm_guard):
    mgr = ckpt.CheckpointManager(str(tmp_path), every=1, keep=3)
    saves = []
    in_save = threading.Event()
    release = threading.Event()

    def provider():
        saves.append(1)
        in_save.set()
        assert release.wait(10)
        return 1, {"beta": np.arange(4.0)}

    mgr.install_sigterm_hook(provider)
    handler = signal.getsignal(signal.SIGTERM)
    exits = []

    def first_sigterm():
        try:
            handler(signal.SIGTERM, None)
        except SystemExit as e:
            exits.append(e.code)

    t = threading.Thread(target=first_sigterm)
    t.start()
    assert in_save.wait(10)                   # drain save is in progress
    # second SIGTERM lands NOW: must skip the save, not re-enter it
    with pytest.raises(SystemExit):
        handler(signal.SIGTERM, None)
    assert saves == [1]                       # still only the first save
    release.set()
    t.join(10)
    assert exits == [143]
    assert saves == [1]
    assert ckpt.latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------------------------
# checkpoint integrity + store poison (unit level; chaos runs end-to-end)
# ---------------------------------------------------------------------------

def test_ckpt_quarantine_falls_back_to_intact_step(tmp_path):
    tree = {"beta": np.arange(12.0).reshape(3, 4)}
    ckpt.save(str(tmp_path), 1, tree)
    q0 = ckpt.quarantine_count()
    plan = FaultPlan((FaultSpec("ckpt.payload", "truncate", hits=(0,)),))
    with inject(plan) as log:
        ckpt.save(str(tmp_path), 2, tree)
    assert log.count() == 1
    step, manifest = ckpt.latest(str(tmp_path))
    assert step == 1 and manifest["step"] == 1
    assert ckpt.quarantine_count() == q0 + 1
    assert os.path.isdir(tmp_path / "quarantined.step_000000000002")
    restored = ckpt.restore(str(tmp_path), tree, step=1)
    np.testing.assert_array_equal(restored["beta"], tree["beta"])


def test_restore_of_corrupt_step_raises_typed(tmp_path):
    tree = {"beta": np.arange(6.0)}
    plan = FaultPlan((FaultSpec("ckpt.payload", "bitflip", hits=(0,)),))
    with inject(plan):
        ckpt.save(str(tmp_path), 5, tree)
    with pytest.raises(ckpt.CheckpointCorrupt, match="digest mismatch"):
        ckpt.restore(str(tmp_path), tree, step=5)


def test_store_poison_is_dropped_not_served():
    from repro.serve import CertificateStore
    prob, base = _baseline()
    store = CertificateStore(capacity=4)
    plan = FaultPlan((FaultSpec("store.record", "poison", hits=(0,)),))
    with inject(plan) as log:
        store.put("req0", prob, CFG, base)
    assert log.count() == 1
    assert store.exact("req0") is None        # digest mismatch: dropped
    assert store.poison_drops == 1
    assert store.exact_hits == 0
    # the poisoned entry is gone; a re-put serves normally again
    store.put("req0", prob, CFG, base)
    assert store.exact("req0") is not None
