"""Shared model building blocks: norms, RoPE, chunked (flash-style)
attention with GQA / sliding window, SwiGLU and MoE feed-forward.

All functions are pure; parameters are plain dict pytrees.  Every init_*
function has a matching specs_* function producing a same-structure pytree of
``jax.sharding.PartitionSpec`` with *logical* axis names "data" / "model"
(mapped to the physical mesh in launch/mesh.py; "data" becomes
("pod", "data") on the multi-pod mesh).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ----------------------------------------------------------------------------
# Activation sharding hints
# ----------------------------------------------------------------------------

_UNC = P.UNCONSTRAINED

# Mesh-shape hint for activation sharding constraints. The launch layer
# (specs.build_cell / launch.train) sets this before tracing; unit tests
# leave it None, making shard_act a no-op. (The legacy `with mesh:`
# context is not introspectable at trace time, hence the explicit hint.)
_ACT_MESH: Optional[dict] = None


def set_activation_mesh(sizes: Optional[dict]) -> None:
    """sizes: {axis_name: size} of the mesh activations will run under."""
    global _ACT_MESH
    _ACT_MESH = dict(sizes) if sizes else None


def shard_act(x, *spec):
    """Divisibility-aware partial ``with_sharding_constraint``.

    ``None`` entries are left UNCONSTRAINED (the partitioner keeps
    whatever it propagated — batch stays on data/pod); axis names are
    applied only when present in the hinted mesh and dividing the dim.
    No-op when no mesh hint is set (CPU unit tests).  This is how awkward
    head counts (e.g. 40 heads on a 16-wide model axis) get steered to
    shard head_dim instead of letting the partitioner all-gather whole
    activations — see EXPERIMENTS.md §Perf (qwen2.5-14b cell).
    """
    sizes = _ACT_MESH
    if not sizes:
        return x
    out = [_UNC] * x.ndim
    named = False
    for i, e in enumerate(spec):
        if e is None or i >= x.ndim:
            continue
        axes = e if isinstance(e, (tuple, list)) else (e,)
        if not all(a in sizes for a in axes):
            continue
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if prod and x.shape[i] % prod == 0:
            out[i] = e
            named = True
    if not named:
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))


def qkv_act_spec(n_heads, hd, model_axis: int):
    """Pick the shardable axis for (B, S, H, hd) activations: heads when
    divisible, else head_dim, else leave unconstrained."""
    if n_heads % model_axis == 0:
        return (None, None, "model", None)
    if hd % model_axis == 0:
        return (None, None, None, "model")
    return (None, None, None, None)


# ----------------------------------------------------------------------------
# Norms / rope
# ----------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta=1e6):
    """x: (..., S, n, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Attention (GQA, causal / windowed, chunked online-softmax)
# ----------------------------------------------------------------------------

def _attend_block(q, k, v, mask, scale):
    """GQA-native block attention.

    q: (B, K, G, Lq, hd) — K kv groups x G query heads per group;
    k/v: (B, K, Lk, hd);  mask broadcastable to (Lq, Lk).  f32 softmax.
    KV is never repeated across the G query heads (memory-faithful GQA).
    """
    s = jnp.einsum("bkgqd,bkld->bkgql", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgql,bkld->bkgqd", (p / jnp.maximum(denom, 1e-30)).astype(v.dtype), v)
    return o


def _split_gqa(q, n_kv):
    """(B, Sq, H, hd) -> (B, K, G, Sq, hd); query head h = k * G + g."""
    B, Sq, H, hd = q.shape
    G = H // n_kv
    return jnp.transpose(q.reshape(B, Sq, n_kv, G, hd), (0, 2, 3, 1, 4))


def _merge_gqa(o):
    """(B, K, G, Sq, hd) -> (B, Sq, H, hd) (inverse of _split_gqa)."""
    B, K, G, Sq, hd = o.shape
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, Sq, K * G, hd)


def causal_attention(q, k, v, *, window: Optional[int] = None,
                     q_chunk: int = 512, q_offset=0):
    """Chunked causal (optionally sliding-window) GQA attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd) with H % K == 0.
    q_offset: absolute position of q[0] relative to k[0] (prefill: 0 with
    Sq == Sk; decode: Sk - Sq).  Memory: O(q_chunk * band) scores per step,
    where band = min(Sk, window + q_chunk) for windowed attention.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    scale = 1.0 / float(hd) ** 0.5
    qg = _split_gqa(q, K)                      # (B,K,G,Sq,hd)
    kt = jnp.swapaxes(k, 1, 2)                 # (B,K,Sk,hd)
    vt = jnp.swapaxes(v, 1, 2)

    if Sq % q_chunk != 0:
        q_chunk = Sq  # irregular lengths: single block (smoke-test sizes)
    if Sq <= q_chunk:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        return _merge_gqa(_attend_block(qg, kt, vt, mask, scale))

    n_chunks = Sq // q_chunk
    qc = qg.reshape(B, K, H // K, n_chunks, q_chunk, hd)

    kv_span = None
    if window is not None:
        # Static-size kv band per query chunk instead of the full history.
        kv_span = min(Sk, window + q_chunk)

    def per_chunk(c):
        qb = qc[:, :, :, c]
        start = q_offset + c * q_chunk
        qpos = start + jnp.arange(q_chunk)[:, None]
        if kv_span is not None and kv_span < Sk:
            lo = jnp.clip(start + q_chunk - kv_span, 0, Sk - kv_span)
            kb = jax.lax.dynamic_slice_in_dim(kt, lo, kv_span, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(vt, lo, kv_span, axis=2)
            kpos = lo + jnp.arange(kv_span)[None, :]
        else:
            kb, vb = kt, vt
            kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        return _attend_block(qb, kb, vb, mask, scale)

    if n_chunks <= 64:
        # unrolled: every chunk's cost is visible to HLO cost analysis
        # (while-loop bodies are counted once by XLA's cost model)
        o = jnp.stack([per_chunk(c) for c in range(n_chunks)])
    else:
        o = jax.lax.map(per_chunk, jnp.arange(n_chunks))  # (nc,B,K,G,qc,hd)
    o = jnp.moveaxis(o, 0, 3)                          # (B,K,G,nc,qc,hd)
    o = o.reshape(B, K, H // K, Sq, hd)
    return _merge_gqa(o)


def full_attention(q, k, v, *, q_chunk: int = 512):
    """Bidirectional (encoder / cross) GQA attention, chunked over queries."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    scale = 1.0 / float(hd) ** 0.5
    qg = _split_gqa(q, K)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    mask = jnp.ones((1, Sk), bool)
    if Sq % q_chunk != 0:
        q_chunk = Sq
    if Sq <= q_chunk:
        return _merge_gqa(_attend_block(qg, kt, vt, mask, scale))
    n_chunks = Sq // q_chunk
    qc = qg.reshape(B, K, H // K, n_chunks, q_chunk, hd)

    def per_chunk(c):
        return _attend_block(qc[:, :, :, c], kt, vt, mask, scale)

    if n_chunks <= 64:
        o = jnp.stack([per_chunk(c) for c in range(n_chunks)])
    else:
        o = jax.lax.map(per_chunk, jnp.arange(n_chunks))
    o = jnp.moveaxis(o, 0, 3).reshape(B, K, H // K, Sq, hd)
    return _merge_gqa(o)


# ----------------------------------------------------------------------------
# Attention block params
# ----------------------------------------------------------------------------

def init_attn(key, cfg, dtype):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (D, H * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (D, K * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (D, K * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (H * hd, D), dtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def specs_attn(cfg):
    p = {
        "wq": P("data", "model"),
        "wk": P("data", "model") if (cfg.n_kv * cfg.hd) % 2 == 0 else P("data", None),
        "wv": P("data", "model") if (cfg.n_kv * cfg.hd) % 2 == 0 else P("data", None),
        "wo": P("model", "data"),
    }
    if cfg.qkv_bias:
        p["bq"] = P("model")
        p["bk"] = P("model")
        p["bv"] = P("model")
    if cfg.qk_norm:
        p["q_norm"] = P(None)
        p["k_norm"] = P(None)
    return p


def attn_qkv(p, x, cfg, positions):
    """Project + rope. Returns q (B,S,H,hd), k/v (B,S,K,hd)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    # NOTE: forcing head_dim sharding here when H % model_axis != 0 was
    # tried and REFUTED (qwen2.5-14b: collective term 312s -> 2297s, SPMD
    # "involuntary full rematerialization") — XLA's own partial solution
    # (8-way heads + 2-way replica) beats a forced 16-way hd constraint
    # because the surrounding reshapes can't re-factor it. See
    # EXPERIMENTS.md §Perf. shard_act is kept for opt-in use.
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


# ----------------------------------------------------------------------------
# Feed-forward: SwiGLU dense and MoE
# ----------------------------------------------------------------------------

def init_mlp(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(ks[0], (D, F), dtype) * D ** -0.5,
        "w3": jax.random.normal(ks[1], (D, F), dtype) * D ** -0.5,
        "w2": jax.random.normal(ks[2], (F, D), dtype) * F ** -0.5,
    }


def specs_mlp(cfg):
    return {"w1": P("data", "model"), "w3": P("data", "model"),
            "w2": P("model", "data")}


def mlp(p, x):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]


def init_moe(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    E = cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (D, E), jnp.float32) * D ** -0.5,
        "w1": jax.random.normal(ks[1], (E, D, F), dtype) * D ** -0.5,
        "w3": jax.random.normal(ks[2], (E, D, F), dtype) * D ** -0.5,
        "w2": jax.random.normal(ks[3], (E, F, D), dtype) * F ** -0.5,
    }


def specs_moe(cfg, model_axis: int):
    E = cfg.moe.n_experts
    if E % model_axis == 0:
        # expert parallelism over the model axis
        ew = P("model", "data", None)
        ew2 = P("model", None, "data")
    else:
        # TP inside each expert (mixtral: 8 experts on 16-way model axis)
        ew = P(None, "data", "model")
        ew2 = P(None, "model", "data")
    return {"router": P("data", "model"), "w1": ew, "w3": ew, "w2": ew2}


def moe_ffn(p, x, cfg):
    """Top-k capacity-based MoE (gather per expert, scatter-add combine).

    x: (B, S, D).  FLOPs scale with top_k (not n_experts): each expert
    processes a static capacity C = T/E * top_k * capacity_factor tokens.
    Tokens over capacity are dropped (standard Switch-style behaviour).
    """
    B, S, D = x.shape
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    T = B * S
    if T <= 512:
        # decode / smoke-test sizes: exact routing, no dropping (every expert
        # may hold every token; FLOPs are negligible at these T and decode
        # must not drop tokens)
        C = T
    else:
        C = min(max(1, int(T * k * cfg.moe.capacity_factor / E)), T)

    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                     # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # per-(token, expert) combine weight; 0 if expert not in token's top-k
    combine = jnp.zeros((T, E), jnp.float32)
    combine = combine.at[jnp.arange(T)[:, None], topi].add(topv)

    # each expert picks its top-C tokens by routing weight
    escore = combine.T                                       # (E, T)
    cscore, cidx = jax.lax.top_k(escore, C)                  # (E, C)
    ex = jnp.take(xt, cidx.reshape(-1), axis=0).reshape(E, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", ex, p["w3"])
    eo = jnp.einsum("ecf,efd->ecd", h, p["w2"])              # (E, C, D)

    eo = eo * cscore[..., None].astype(eo.dtype)
    out = jnp.zeros((T, D), eo.dtype)
    out = out.at[cidx.reshape(-1)].add(eo.reshape(E * C, D))
    # router z-loss / load-balance aux (returned for the train loss)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean((combine > 0).astype(jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, D), aux


def init_norm(cfg, dtype):
    return jnp.zeros((cfg.d_model,), dtype)


def fill_rolling_cache(k, buf_len, dtype):
    """Scatter the last min(S, buf_len) kv entries of k (B,S,K,hd) into a
    rolling buffer of length buf_len at slots abs_pos % buf_len — the layout
    decode_step's age-based validity mask assumes."""
    B, S, K, hd = k.shape
    keep = min(buf_len, S)
    ks = k[:, S - keep:]
    idx = jnp.arange(S - keep, S) % buf_len
    out = jnp.zeros((B, buf_len, K, hd), dtype)
    return out.at[:, idx].set(ks.astype(dtype))
