"""Seeded CS001 violations: every way a safety claim can be forged.

This file is a FIXTURE for tests/test_analysis.py — it is never imported,
only parsed.  Each construction below must be flagged by
repro.analysis.cert_lint.lint_result_constructions; the clean ones must
not.
"""


def forged_keyword(gap, theta, g, f):
    # CS001: hard-coded literal claim
    return RoundResult(gap, theta, g, f, safe=True)          # noqa: F821


def forged_positional(gap, theta, g, f):
    # CS001: literal True smuggled through the positional safe slot
    return RoundResult(gap, theta, g, f, False, True)        # noqa: F821


def omitted_key(gap, theta, g, f):
    # CS001: omission silently claims safety via the field default
    return RoundResult(gap, theta, g, f)                     # noqa: F821


def omitted_path_key(lambdas, betas):
    # CS001: PathResult without certificates_safe=
    return PathResult(lambdas=lambdas, betas=betas)          # noqa: F821


def clean_threaded(gap, theta, g, f, rule):
    # fine: threaded from rule metadata
    return RoundResult(gap, theta, g, f, safe=rule.is_safe)  # noqa: F821


def clean_rewrap(r):
    # fine: the bit travels through the star
    return RoundResult(*r)                                   # noqa: F821


def clean_kwargs_forward(lambdas, **kw):
    # fine: the bit travels through **kw
    return PathResult(lambdas=lambdas, **kw)                 # noqa: F821
