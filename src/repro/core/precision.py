"""Explicit f64 posture for every certificate-producing computation.

The GAP safe guarantee (paper Thm 1/2) is only as good as the arithmetic
the certificate is evaluated in: the duality gap, the Eq. 15 dual
scaling, and the sphere radii must be computed in full f64 precision on
the full problem.  JAX defaults to f32 unless ``jax_enable_x64`` is set,
and a silently-f32 "certificate" is the worst kind of bug — numerically
plausible, formally worthless.

:func:`ensure_x64` is called when :mod:`repro.core` is first imported
(before any array can be built by solver code), so every front end — the
test suite, the benchmark drivers, ``python -m repro.analysis`` — gets
the same posture without each having to remember an environment
variable.  The jaxpr lints (JX001, :mod:`repro.analysis.jaxpr_lints`)
then verify statically that no traced program demotes a float below f64.

The ONE sanctioned sub-f64 path is the mesh strategy's low-precision
FISTA solves (``SGLSession`` over a mesh with a non-f64 dtype): those
rounds are never adopted as certificates — the session re-certifies in
f64 before reporting — and the analysis gate documents the exemption via
the ``dist_fista/f32-mesh`` entry spec (``min_float_bits=32``).  Enabling
x64 does not forbid f32 arrays; it only stops f64 requests from being
silently truncated.

Set ``REPRO_ALLOW_F32=1`` to skip enforcement entirely (e.g. profiling
runs on accelerators without f64 support); certificates produced under
that escape hatch are NOT trustworthy and the variable exists so the
choice is loud and greppable.
"""
from __future__ import annotations

import os

__all__ = ["ensure_x64"]


def ensure_x64() -> bool:
    """Enable (and verify) ``jax_enable_x64``; returns True when enforced.

    Raises ``RuntimeError`` if x64 cannot be enabled — e.g. another
    library froze the config after arrays were created — instead of
    letting certificate arithmetic silently truncate to f32.
    """
    if os.environ.get("REPRO_ALLOW_F32") == "1":
        return False
    import jax

    if not jax.config.read("jax_enable_x64"):
        jax.config.update("jax_enable_x64", True)
    if not jax.config.read("jax_enable_x64"):   # pragma: no cover
        raise RuntimeError(
            "repro.core requires jax_enable_x64 for certificate "
            "arithmetic, but it could not be enabled. Set "
            "JAX_ENABLE_X64=1 before importing jax, or export "
            "REPRO_ALLOW_F32=1 to explicitly accept untrustworthy "
            "f32 certificates."
        )
    return True
