"""Session/compile cache: repeat tenants never re-trace.

A :class:`repro.core.session.SGLSession` owns every expensive per-problem
artifact — the jit-warm solver programs, the persistent transposed design,
``lam_max``, the gather caches.  :class:`SessionCache` keeps an LRU of
sessions keyed on the problem *value* digest + the config's
:meth:`SolverConfig.cache_token`, so a repeat tenant (or a new tenant with
the same problem) reuses the compiled machinery outright.

Two sub-caches sharpen the miss path:

* **shared transposed design** — ``prepare_transposed(X)`` depends only on
  X, so perturbed-``y`` tenants (new problem digest, same design) adopt
  the cached copy through ``SGLSession(xt_pre=...)`` instead of
  re-materialising the (p, n) layout (``design_hits`` counts these);
* **retrace watch** — the `kernels.ops` retrace audit as the cache's
  correctness check: :meth:`watch_retraces` snapshots the jit-cache sizes
  of every registered traceable around a served request; growth during a
  request that hit the cache with an exact-repeat digest is a retrace
  regression, counted on the cache AND fed to
  :func:`repro.kernels.ops.note_retrace` so ``kernels.ops.audit_scope``
  (and the tests built on it) see it.
"""
from __future__ import annotations

import contextlib
from collections import OrderedDict
from typing import Optional

from ..core.session import SGLSession, SolverConfig
from ..core.sgl import SGLProblem
from ..core.solver import resolve_screen_backend, resolve_solver_backend
from ..kernels import ops as kops
from ..losses import resolve_loss
from ..obs import metrics as obs_metrics
from .types import array_digest, problem_digest

__all__ = ["SessionCache"]

_CACHE_COUNTERS = {
    "hits": "Session-cache hits (jit-warm session reused)",
    "misses": "Session-cache misses (fresh session built)",
    "evictions": "Sessions evicted by the LRU capacity bound",
    "design_hits": "Transposed-design sub-cache hits across tenants",
    "retraces": "Jit-cache growth observed by watch_retraces on a hit",
    "loss_rejects": "Cache hits refused for a mismatched loss (collision)",
}
for _k, _h in _CACHE_COUNTERS.items():
    obs_metrics.declare("serve.cache_" + _k, "counter", _h)


def _counter_attr(key: str):
    """Int-attribute shim over a registry counter (``self.hits += 1`` and
    plain reads keep working while the number lives on the registry)."""

    def _get(self) -> int:
        return self._m[key].value

    def _set(self, v: int) -> None:
        self._m[key]._set(int(v))

    return property(_get, _set, doc=_CACHE_COUNTERS[key])


def _traceable_cache_sizes() -> int:
    """Total jit-cache entries across every registered traceable (the
    same objects the analysis retrace harness watches)."""
    import repro.core.session  # noqa: F401  (registers core traceables)
    import repro.serve.store   # noqa: F401  (registers serve_warm_eval)

    from ..analysis.registry import traceables

    total = 0
    for entry in traceables().values():
        fn = entry["fn"]
        if hasattr(fn, "_cache_size"):
            total += fn._cache_size()
    return total


class SessionCache:
    """LRU of jit-warm :class:`SGLSession` objects, value-keyed.

    ``capacity=0`` disables caching (every lookup is a miss and nothing
    is retained — the shared transposed-design sub-cache is bypassed
    too) — the serving benchmark's fully-cold no-cache baseline.
    """

    def __init__(self, capacity: int = 8, design_capacity: int = 8):
        self.capacity = int(capacity)
        self.design_capacity = int(design_capacity)
        self._sessions: OrderedDict[tuple, SGLSession] = OrderedDict()
        self._designs: OrderedDict[str, object] = OrderedDict()
        # Per-cache registry under the shared declared names; the historic
        # int attributes (hits/misses/...) are properties over it.
        self.metrics = obs_metrics.MetricsRegistry()
        self._m = {k: self.metrics.counter("serve.cache_" + k)
                   for k in _CACHE_COUNTERS}

    hits = _counter_attr("hits")
    misses = _counter_attr("misses")
    evictions = _counter_attr("evictions")
    design_hits = _counter_attr("design_hits")
    retraces = _counter_attr("retraces")
    loss_rejects = _counter_attr("loss_rejects")

    # -- lookups -----------------------------------------------------------

    def key(self, problem: SGLProblem, config: SolverConfig) -> tuple:
        return (problem_digest(problem, config), config.cache_token())

    def get(self, problem: SGLProblem,
            config: SolverConfig) -> tuple[SGLSession, bool]:
        """``(session, hit)`` — builds (and caches) a session on miss."""
        key = self.key(problem, config)
        sess = self._sessions.get(key)
        if sess is not None:
            if repr(sess.loss) != repr(resolve_loss(config.loss)):
                # Defense-in-depth: the key already hashes the loss (via
                # cache_token), so a hit with a mismatched loss means the
                # keying itself regressed — refuse to hand a tenant a
                # session compiled for another data fidelity.
                self.loss_rejects += 1
                raise RuntimeError(
                    f"session-cache key collision across losses: cached "
                    f"session solves {sess.loss.name!r}, request asks "
                    f"for {resolve_loss(config.loss).name!r}"
                )
            self._sessions.move_to_end(key)
            self.hits += 1
            return sess, True
        self.misses += 1
        sess = self._build(problem, config)
        if self.capacity > 0:
            self._sessions[key] = sess
            while len(self._sessions) > self.capacity:
                self._sessions.popitem(last=False)
                self.evictions += 1
        return sess, False

    def _build(self, problem: SGLProblem, config: SolverConfig) -> SGLSession:
        xt_pre = None
        needs_xt = (resolve_screen_backend(config.screen_backend) == "pallas"
                    or resolve_solver_backend(config.solver_backend)
                    == "pallas")
        # capacity=0 means fully cold: no design reuse either, so the
        # no-cache baseline really rebuilds everything per request.
        if needs_xt and self.capacity > 0 and self.design_capacity > 0:
            dkey = array_digest(problem.X)
            xt_pre = self._designs.get(dkey)
            if xt_pre is not None:
                self._designs.move_to_end(dkey)
                self.design_hits += 1
            else:
                xt_pre = kops.prepare_transposed(problem.X)
                self._designs[dkey] = xt_pre
                while len(self._designs) > self.design_capacity:
                    self._designs.popitem(last=False)
        return SGLSession(problem, config, xt_pre=xt_pre)

    # -- retrace watch (cache correctness check) ---------------------------

    @contextlib.contextmanager
    def watch_retraces(self):
        """Assert-by-measurement that a cached session really is jit-warm.

        Opened by the server around exact-repeat requests served from a
        cache hit: any jit-cache growth across the watched block means the
        "cached" session retraced — counted on ``self.retraces`` and
        reported through :func:`repro.kernels.ops.note_retrace` so
        ``audit_scope`` assertions catch it.
        """
        before = _traceable_cache_sizes()
        try:
            yield
        finally:
            delta = _traceable_cache_sizes() - before
            if delta > 0:
                self.retraces += delta
                kops.note_retrace(delta)

    # -- reporting ---------------------------------------------------------

    def stats(self) -> dict:
        return {
            "sessions": len(self._sessions),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "design_hits": self.design_hits,
            "retraces": self.retraces,
            "loss_rejects": self.loss_rejects,
        }
