"""Orchestrates the three analysis passes into one findings payload.

Pass order is cheap-to-expensive: the pure-AST cert lints, then the
static Pallas launch auditor, then the jaxpr lints (which import jax,
trace every registered entry point, and execute each retrace template
twice).  ``run_checks`` never raises on a finding — a broken invariant is
data in the payload; only the CLI turns errors into a non-zero exit.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from .findings import Finding, to_payload

__all__ = ["run_checks"]

ALL_PASSES = ("cert", "pallas", "jaxpr")


def run_checks(passes: Optional[Sequence[str]] = None,
               *, check_retrace: bool = True) -> Dict[str, Any]:
    """Run the selected passes (default: all) and assemble the payload.

    ``check_retrace=False`` skips the execute-twice retrace harness (the
    only part that actually runs the solver) — used by fast test paths;
    the CI gate always runs everything.
    """
    selected = tuple(passes) if passes is not None else ALL_PASSES
    unknown = [p for p in selected if p not in ALL_PASSES]
    if unknown:
        raise ValueError(f"unknown passes {unknown}; choose from "
                         f"{list(ALL_PASSES)}")

    findings: List[Finding] = []
    ctx: Dict[str, Dict[str, Any]] = {}

    if "cert" in selected:
        from . import cert_lint

        before = len(findings)
        findings += cert_lint.run()
        ctx["cert"] = {"findings": len(findings) - before}

    if "pallas" in selected:
        from . import pallas_audit
        from .registry import kernel_audits

        import repro.kernels.ops  # noqa: F401  (registers the builders)

        before = len(findings)
        findings += pallas_audit.run()
        ctx["pallas"] = {
            "findings": len(findings) - before,
            "kernels": sorted(kernel_audits()),
            "vmem_budget_bytes": pallas_audit.DEFAULT_VMEM_BUDGET,
        }

    if "jaxpr" in selected:
        from . import jaxpr_lints
        from .entrypoints import default_entry_specs, pairing_findings

        specs = default_entry_specs()
        if not check_retrace:
            import dataclasses

            specs = [dataclasses.replace(s, check_retrace=False)
                     for s in specs]
        before = len(findings)
        findings += pairing_findings(specs)
        findings += jaxpr_lints.run(specs)
        ctx["jaxpr"] = {
            "findings": len(findings) - before,
            "entry_points": [s.name for s in specs],
            "retrace_checked": [s.name for s in specs if s.check_retrace],
        }

    return to_payload(findings, passes=ctx)
