"""Recompute roofline terms for dry-run cells from their saved HLO.

    PYTHONPATH=src python -m repro.launch.reanalyze artifacts/dryrun2

The dry-run saves each cell's compiled HLO next to its JSON
(<cell>.json.hlo.gz), so analyzer improvements can be re-applied without
recompiling 40 cells.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from . import roofline as rl


def reanalyze_cell(json_path: str) -> bool:
    hlo_path = json_path + ".hlo.gz"
    if not os.path.exists(hlo_path):
        return False
    with open(json_path) as f:
        d = json.load(f)
    if d.get("status") != "ok" or "roofline" not in d:
        return False
    with gzip.open(hlo_path, "rt") as f:
        hlo = f.read()
    a = rl.analyze_hlo(hlo)
    chips = d["chips"]
    roof = rl.Roofline(
        flops=a["flops"] * chips,
        bytes_accessed=a["bytes_accessed"] * chips,
        collective_bytes=a["collective_bytes"] * chips,
        chips=chips,
        model_flops=d["roofline"]["model_flops"],
    )
    d["roofline"] = roof.as_dict()
    d["collectives"] = {k[len("coll_"):]: v for k, v in a.items()
                        if k.startswith("coll_")}
    with open(json_path, "w") as f:
        json.dump(d, f, indent=2)
    return True


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun2"
    n = 0
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if reanalyze_cell(p):
            n += 1
            print(f"reanalyzed {os.path.basename(p)}")
    print(f"{n} cells reanalyzed")


if __name__ == "__main__":
    main()
